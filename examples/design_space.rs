//! Design-space exploration example: a reduced Fig. 13 sweep.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```
//!
//! Explores two networks across three architecture classes (single-core,
//! homogeneous quad-core, heterogeneous quad-core), optimizing EDP with
//! the GA under both layer-by-layer and layer-fused scheduling, and
//! prints the EDP matrix with the fused-vs-LBL reduction factors —
//! the qualitative shape of the paper's Fig. 13 at example scale.

use stream::allocator::GaParams;
use stream::experiments::{exploration_sweep, SweepConfig};
use stream::experiments::fig13::{format_fig13, format_fig14, format_fig15};

fn main() {
    let cfg = SweepConfig {
        workloads: vec!["resnet18".into(), "squeezenet".into()],
        archs: vec!["sc-tpu".into(), "hom-tpu".into(), "hetero".into()],
        ga: GaParams { population: 16, generations: 10, ..Default::default() },
        lines: vec![1, 4],
    };
    println!(
        "sweeping {} workloads x {} architectures (GA pop {}, {} gens)...\n",
        cfg.workloads.len(),
        cfg.archs.len(),
        cfg.ga.population,
        cfg.ga.generations
    );
    let t = std::time::Instant::now();
    let cells = exploration_sweep(&cfg);
    println!("sweep finished in {:.1} s\n", t.elapsed().as_secs_f64());

    println!("-- Fig. 13 (EDP) --\n{}", format_fig13(&cells));
    println!("-- Fig. 14 (latency at best-EDP) --\n{}", format_fig14(&cells));
    println!("-- Fig. 15 (energy breakdown) --\n{}", format_fig15(&cells));
}
