//! End-to-end driver: Stream schedules the ResNet-18 first segment on
//! the DIANA-like heterogeneous model, and the PJRT runtime *executes*
//! the resulting layer-fused schedule numerically from the AOT-compiled
//! XLA artifacts — verifying that the fused execution order Stream
//! produced computes exactly the same tensor as the layer-by-layer
//! baseline and as the Python oracle.
//!
//! ```bash
//! make artifacts && cargo run --release --example fused_resnet_segment
//! ```
//!
//! This is the composition proof for the full three-layer stack:
//! L1 Pallas kernels -> L2 JAX segment -> AOT HLO artifacts ->
//! L3 Rust scheduler + PJRT execution (Python never on this path).

use stream::arch::presets;
use stream::cn::CnGranularity;
use stream::cost::{fmt_bytes, fmt_cycles, fmt_energy};
use stream::pipeline::{SchedulePriority, Stream, StreamOpts};
use stream::runtime::{Runtime, SegmentExecutor};
use stream::workload::models;

fn main() -> stream::util::error::Result<()> {
    // --- 1) model + schedule with Stream (cost-model world) ---
    let workload = models::tiny_segment(); // 112x112 artifact geometry
    let arch = presets::diana();
    let s = Stream::new(
        workload.clone(),
        arch.clone(),
        StreamOpts {
            granularity: CnGranularity::Lines(4),
            priority: SchedulePriority::Latency,
            ga: stream::allocator::GaParams {
                population: 16,
                generations: 10,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let r = s.run().map_err(|e| stream::anyhow!("{e}"))?;
    let best = r.best_edp().expect("nonempty front");
    let m = &best.result.metrics;
    println!(
        "Stream schedule on {}: latency {} | energy {} | peak mem {}",
        arch.name,
        fmt_cycles(m.latency_cc),
        fmt_energy(m.energy_pj),
        fmt_bytes(m.peak_mem_bytes)
    );
    println!("{}", stream::viz::gantt(&best.result, &workload, &arch, 90));

    // --- 2) translate the schedule into a CN execution order ---
    // CN ids are deterministic for a (workload, granularity) pair, so
    // rebuilding the CN set gives the id -> (layer, idx) mapping.
    let gran = CnGranularity::Lines(4).for_arch(&arch);
    let cns = stream::cn::CnSet::build(&workload, gran);
    let mut placed = best.result.cns.clone();
    placed.sort_by_key(|p| (p.start, p.end));
    let order: Vec<(usize, usize)> = placed
        .iter()
        .map(|p| {
            let node = cns.node(p.cn);
            (node.layer.0, node.idx)
        })
        .collect();

    // --- 3) execute the order numerically on the PJRT runtime ---
    let art_dir = std::env::var("STREAM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let mut rt = Runtime::new(&art_dir)?;
    println!("PJRT platform: {}", rt.platform());
    let exec = SegmentExecutor::new(&rt)?;

    let t = std::time::Instant::now();
    let lbl = exec.run_layer_by_layer(&mut rt)?;
    let d_lbl = exec.verify(&lbl, 1e-3)?;
    println!(
        "layer-by-layer baseline: max|diff| = {d_lbl:.2e} vs python oracle ({:.0} ms)",
        t.elapsed().as_secs_f64() * 1e3
    );

    let t = std::time::Instant::now();
    let fused = exec.run_fused(&mut rt, &order)?;
    let d_fused = exec.verify(&fused, 1e-3)?;
    println!(
        "Stream fused schedule ({} CNs): max|diff| = {d_fused:.2e} vs python oracle ({:.0} ms)",
        order.len(),
        t.elapsed().as_secs_f64() * 1e3
    );

    let cross = fused.max_abs_diff(&lbl);
    println!("fused vs layer-by-layer: max|diff| = {cross:.2e}");
    assert!(cross < 1e-3);
    println!("\nall three agree: Stream's fused schedule is executable and exact ✓");
    Ok(())
}
