//! Hardware validation example: the paper's Table I as a runnable
//! program.
//!
//! ```bash
//! cargo run --release --example validate_hw
//! ```
//!
//! Models the three measured SotA accelerators (DepFiN, the 4x4 AiMC
//! array of Jia et al., and DIANA), schedules the workloads each chip
//! was measured with (fixed allocation, latency priority) and prints
//! Stream's modeled latency / peak memory against the paper's published
//! measurements.

use stream::experiments::{table1, table1::format_table};

fn main() {
    let t = std::time::Instant::now();
    let rows = table1();
    println!("{}", format_table(&rows));
    for r in &rows {
        println!(
            "{:<10} modeled in {:>7.1} ms (paper framework runtime: 2-5 s)",
            r.arch, r.runtime_ms
        );
    }
    println!("\ntotal validation runtime: {:.1} s", t.elapsed().as_secs_f64());
}
