//! Quickstart: schedule a tiny CNN on a dual-core accelerator.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the whole Stream pipeline on a 5-layer branchy network: CN
//! splitting, R-tree dependency generation, intra-core cost extraction,
//! GA allocation, multi-core scheduling — then prints the schedule as an
//! ASCII Gantt chart next to the layer-by-layer baseline.

use stream::allocator::GaParams;
use stream::arch::presets;
use stream::cn::CnGranularity;
use stream::cost::{fmt_bytes, fmt_cycles, fmt_energy};
use stream::pipeline::{Stream, StreamOpts};
use stream::workload::models;

fn main() {
    let workload = models::tiny_branchy();
    let arch = presets::test_dual();
    println!(
        "workload `{}`: {} layers, {:.2} MMAC",
        workload.name,
        workload.len(),
        workload.total_macs() as f64 / 1e6
    );
    println!("architecture `{}`: {} cores\n", arch.name, arch.cores.len());

    let ga = GaParams { population: 16, generations: 10, ..Default::default() };

    for (label, gran) in [
        ("layer-by-layer", CnGranularity::LayerByLayer),
        ("layer-fused (2 lines/CN)", CnGranularity::Lines(2)),
    ] {
        let s = Stream::new(
            workload.clone(),
            arch.clone(),
            StreamOpts { granularity: gran, ga, ..Default::default() },
        );
        let r = s.run().expect("pipeline");
        let best = r.best_edp().expect("nonempty");
        let m = &best.result.metrics;
        println!("== {label}: {} CNs, {} edges ==", r.n_cns, r.n_edges);
        println!(
            "   latency {} | energy {} | peak mem {} | EDP {:.3e}",
            fmt_cycles(m.latency_cc),
            fmt_energy(m.energy_pj),
            fmt_bytes(m.peak_mem_bytes),
            m.edp()
        );
        println!("{}", stream::viz::gantt(&best.result, &workload, &arch, 80));
    }
}
