"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact functional counterpart
here, written with plain ``jax.numpy`` / ``jax.lax`` primitives.  The
pytest suite asserts ``assert_allclose(kernel(...), ref(...))`` over a
hypothesis-driven sweep of shapes; these functions are the single source
of numerical truth for the whole stack (the Rust runtime's end-to-end
check ultimately compares against an AOT-compiled lowering of these).

All tensors are NCHW (batch dimension elided: CHW) and f32 unless noted.
"""

import jax
import jax.numpy as jnp
import jax.lax as lax


def matmul_ref(x: jax.Array, w: jax.Array, b=None, relu: bool = False) -> jax.Array:
    """[M,K] @ [K,N] (+ bias[N]) (+ ReLU) in f32."""
    out = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        out = out + b[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def conv2d_ref(x: jax.Array, w: jax.Array, b=None, stride: int = 1,
               padding: int = 0, relu: bool = False) -> jax.Array:
    """Direct convolution oracle.

    x: [C, H, W], w: [K, C, FY, FX], b: [K] -> [K, OY, OX].
    """
    out = lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    if b is not None:
        out = out + b[:, None, None]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def maxpool_ref(x: jax.Array, ksize: int = 3, stride: int = 2,
                padding: int = 0) -> jax.Array:
    """Max pooling oracle. x: [C, H, W] -> [C, OY, OX].

    Padding uses -inf so it never wins the max (matches framework
    semantics for post-ReLU activations and any-signed inputs alike).
    """
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, ksize, ksize),
        window_strides=(1, stride, stride),
        padding=[(0, 0), (padding, padding), (padding, padding)],
    )


def add_relu_ref(a: jax.Array, b: jax.Array, relu: bool = True) -> jax.Array:
    """Elementwise residual add (+ ReLU)."""
    out = a + b
    if relu:
        out = jnp.maximum(out, 0.0)
    return out
