"""L1 Pallas kernels: the CN compute primitives of the Stream stack.

- :mod:`.matmul` — tiled PE-array matmul (C-unroll x K-unroll dataflow)
- :mod:`.conv` — convolution as implicit GEMM on the matmul kernel
- :mod:`.pool` — SIMD-core max pooling
- :mod:`.eltwise` — SIMD-core residual add (+ ReLU)
- :mod:`.ref` — pure-jnp oracles for all of the above
"""

from . import conv, eltwise, matmul, pool, ref  # noqa: F401
