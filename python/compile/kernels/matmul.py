"""L1 Pallas kernel: tiled matrix multiply — the PE-array model.

This kernel is the compute hot-spot of the whole stack.  The paper's
accelerator cores are spatially-unrolled PE arrays: the TPU-like core of
Fig. 11 unrolls the input channels ``C 32`` across PE rows (a reduction)
and the output channels ``K 32`` across PE columns (parallel outputs) —
which is *exactly* a blocked matmul with the reduction dimension mapped
to the systolic rows.  We therefore realize every dense CN (convolution
via implicit GEMM, fully-connected) as this one tiled matmul.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the block sizes play the
role of the spatial unrolling — ``BK`` ↔ the C-unroll, ``BN`` ↔ the
K-unroll, ``BM`` ↔ the output-pixel tile streamed through the array; the
BlockSpec index maps express the HBM↔VMEM schedule the paper's cores
implement with their local SRAMs.  The accumulation across the k-grid
axis models the temporal reduction through the array.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the interpreted kernel lowers to plain HLO that the
Rust runtime loads and runs.  Real-TPU efficiency is estimated
analytically from the block shapes (see DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes. 8 x 128 multiples line up with the MXU/VPU native
# tile of real TPUs; on the interpret path they just bound VMEM usage.
BM = 32
BN = 64
BK = 64


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, relu: bool):
    """Grid point (i, j, k): accumulate x[i,k] @ w[k,j] into o[i,j].

    The output block is revisited across the k grid axis (its index map
    ignores ``k``), so it doubles as the VMEM accumulator; the epilogue
    (bias + optional ReLU) runs on the last k step, mirroring a systolic
    array draining into the output SRAM through an activation unit.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...][None, :]
        if relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("relu", "bm", "bn", "bk")
)
def matmul(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
           relu: bool = False, bm: int = BM, bn: int = BN,
           bk: int = BK) -> jax.Array:
    """Tiled Pallas matmul: ``x[M,K] @ w[K,N] (+ b[N]) (+ ReLU)``.

    Shapes need not be multiples of the block sizes: inputs are
    zero-padded up to the grid and the result is sliced back, which is
    numerically exact for matmul (padded rows/cols contribute zeros).
    """
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, f"contraction mismatch {kdim} vs {k2}"
    if b is None:
        b = jnp.zeros((n,), jnp.float32)

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(b, 0, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, relu=relu),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_bytes(bm: int = BM, bn: int = BN, bk: int = BK) -> int:
    """Estimated VMEM residency of one grid step (f32): x, w, bias, acc, out.

    Used by the analytic TPU performance estimate in DESIGN.md §Perf and
    by the L3 mapping model's sanity checks.
    """
    return 4 * (bm * bk + bk * bn + bn + 2 * bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int = BM, bn: int = BN,
                    bk: int = BK, mxu: int = 128) -> float:
    """Estimated MXU utilization for an [M,K]x[K,N] problem.

    The systolic array is ``mxu x mxu``; a block only fills
    ``min(bk, k) x min(bn, n)`` of it, and edge blocks are partially
    empty.  This mirrors the paper's *spatial under-utilization* term.
    """
    fill_rows = min(bk, k) / mxu if k < mxu or bk < mxu else 1.0
    fill_cols = min(bn, n) / mxu if n < mxu or bn < mxu else 1.0
    def edge(total, block):
        import math
        nblk = math.ceil(total / block)
        return total / (nblk * block)
    return min(1.0, fill_rows) * min(1.0, fill_cols) * edge(m, bm) * edge(n, bn) * edge(k, bk)
