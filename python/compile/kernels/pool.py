"""L1 Pallas kernel: max pooling.

Pooling CNs run on the paper's SIMD core (the auxiliary vector core every
explored architecture carries for pool / residual-add layers).  The
kernel tiles the channel dimension — the SIMD lanes — and computes the
window max with statically unrolled shifted slices, which is how a
line-buffered vector datapath implements pooling.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BC = 16  # channel block = SIMD lane tile


def _maxpool_kernel(x_ref, o_ref, *, ksize: int, stride: int):
    """One channel block: [bc, H, W] -> [bc, OY, OX].

    The (fy, fx) loops are static Python loops — they unroll into the
    vector max tree a SIMD core would execute.
    """
    x = x_ref[...]
    _, h, w = x.shape
    oy = (h - ksize) // stride + 1
    ox = (w - ksize) // stride + 1
    out = None
    for dy in range(ksize):
        for dx in range(ksize):
            win = x[:, dy:dy + (oy - 1) * stride + 1:stride,
                    dx:dx + (ox - 1) * stride + 1:stride]
            out = win if out is None else jnp.maximum(out, win)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("ksize", "stride", "padding"))
def maxpool(x: jax.Array, ksize: int = 3, stride: int = 2,
            padding: int = 0) -> jax.Array:
    """Max pooling over [C, H, W] with -inf padding, channel-tiled."""
    c, h, w = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding)),
                    constant_values=-jnp.inf)
        h, w = h + 2 * padding, w + 2 * padding
    oy = (h - ksize) // stride + 1
    ox = (w - ksize) // stride + 1

    bc = min(BC, c)
    rem = (-c) % bc
    if rem:
        x = jnp.pad(x, ((0, rem), (0, 0), (0, 0)),
                    constant_values=-jnp.inf)
    cp = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_maxpool_kernel, ksize=ksize, stride=stride),
        grid=(cp // bc,),
        in_specs=[pl.BlockSpec((bc, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bc, oy, ox), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, oy, ox), jnp.float32),
        interpret=True,
    )(x)
    return out[:c]
