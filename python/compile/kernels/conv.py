"""L1 Pallas kernel wrapper: convolution as implicit GEMM.

The paper's dataflow cores execute convolutional CNs on a PE array with
``C`` unrolled across rows (reduction) and ``K`` across columns.  We
realize this as im2col patch extraction (layout transform, done by XLA)
feeding the tiled Pallas matmul of :mod:`.matmul` — the patches matrix
has the contraction dimension ``C*FY*FX`` exactly where the PE array's
C-unroll sits.

The patch extraction is *not* the hot-spot (it is a gather the paper's
cores implement with line buffers / address generators); the MACs all
happen inside the Pallas kernel.
"""

import functools

import jax
import jax.numpy as jnp
import jax.lax as lax

from . import matmul as mm


def _im2col(x: jax.Array, fy: int, fx: int, stride: int,
            padding: int) -> jax.Array:
    """x: [C, H, W] -> patches [OY*OX, C*FY*FX] (f32)."""
    patches = lax.conv_general_dilated_patches(
        x[None],
        filter_shape=(fy, fx),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]  # [C*FY*FX, OY, OX]
    cff, oy, ox = patches.shape
    return patches.reshape(cff, oy * ox).T, (oy, ox)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "relu"))
def conv2d(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
           stride: int = 1, padding: int = 0, relu: bool = False) -> jax.Array:
    """Implicit-GEMM convolution on the Pallas matmul kernel.

    x: [C, H, W], w: [K, C, FY, FX], b: [K] -> [K, OY, OX].
    """
    k, c, fy, fx = w.shape
    patches, (oy, ox) = _im2col(x, fy, fx, stride, padding)
    wmat = w.reshape(k, c * fy * fx).T  # [C*FY*FX, K]
    out = mm.matmul(patches, wmat, b, relu=relu)  # [OY*OX, K]
    return out.T.reshape(k, oy, ox)


def macs(x_shape, w_shape, stride: int, padding: int) -> int:
    """Exact MAC count of the convolution (for the L3 cost model tests)."""
    c, h, wdt = x_shape
    k, c2, fy, fx = w_shape
    oy = (h + 2 * padding - fy) // stride + 1
    ox = (wdt + 2 * padding - fx) // stride + 1
    return k * oy * ox * c * fy * fx
