"""L1 Pallas kernel: elementwise residual add (+ ReLU).

Residual-sum CNs run on the SIMD core; this kernel tiles the flattened
tensor across the vector lanes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _add_relu_kernel(a_ref, b_ref, o_ref, *, relu: bool):
    out = a_ref[...] + b_ref[...]
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("relu",))
def add_relu(a: jax.Array, b: jax.Array, relu: bool = True) -> jax.Array:
    """Elementwise ``a + b`` (+ ReLU) over same-shape tensors."""
    assert a.shape == b.shape, (a.shape, b.shape)
    shape = a.shape
    af, bf = a.reshape(-1), b.reshape(-1)
    n = af.shape[0]
    blk = min(BLOCK, n)
    rem = (-n) % blk
    if rem:
        af = jnp.pad(af, (0, rem))
        bf = jnp.pad(bf, (0, rem))
    npad = af.shape[0]
    out = pl.pallas_call(
        functools.partial(_add_relu_kernel, relu=relu),
        grid=(npad // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,)),
                  pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(af, bf)
    return out[:n].reshape(shape)
