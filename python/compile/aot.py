"""AOT pipeline: lower every L2 entry point to HLO **text** artifacts.

Python runs exactly once (``make artifacts``); the Rust coordinator then
loads ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and
executes them on the PJRT CPU client.  Python is never on the request
path.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  (See
/opt/xla-example/README.md.)

Outputs, all under ``--out`` (default ``../artifacts``):

- ``<name>.hlo.txt``  — one per artifact (CN tiles, full layers, oracle)
- ``weights/<name>.f32`` — raw little-endian f32 dumps of the segment
  weights, the sample input, and the oracle output, so the Rust runtime
  is bit-identical to the Python build
- ``manifest.json`` — artifact registry (input/output shapes) + the
  segment geometry (:func:`model.segment_spec`) the Rust tile slicer
  mirrors
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def build_artifact_registry():
    """name -> (callable, [input shapes]). Single f32 output each."""
    spec = model.segment_spec()
    reg: dict[str, tuple] = {}

    # --- CN tile artifacts (layer-fused path) ---
    for ls in spec:
        if ls.kind == "conv":
            fn = functools.partial(
                model.cn_conv, stride=ls.stride, relu=ls.relu)
            reg[ls.artifact] = (
                fn, [ls.tile_in_shape, ls.weight, (ls.weight[0],)])
        elif ls.kind == "pool":
            reg[ls.artifact] = (model.cn_maxpool, [ls.tile_in_shape])
        elif ls.kind == "add":
            reg[ls.artifact] = (
                model.cn_add, [ls.tile_in_shape, ls.tile_in_shape])

    # --- full-layer artifacts (layer-by-layer baseline path) ---
    for ls in spec:
        if ls.kind == "conv":
            fn = functools.partial(
                model.layer_conv, stride=ls.stride, pad=ls.pad, relu=ls.relu)
            reg[ls.layer_artifact] = (
                fn, [ls.in_shape, ls.weight, (ls.weight[0],)])
        elif ls.kind == "pool":
            reg[ls.layer_artifact] = (model.layer_maxpool, [ls.in_shape])
        elif ls.kind == "add":
            reg[ls.layer_artifact] = (
                model.layer_add, [ls.in_shape, ls.in_shape])

    # --- whole-segment oracle + quickstart FC ---
    wshapes = [model.IN_SHAPE,
               spec[0].weight, (64,), spec[2].weight, (64,),
               spec[3].weight, (64,)]
    reg["segment_oracle"] = (model.segment_oracle, wshapes)
    reg["fc_demo"] = (model.fc_demo, [(1, 256), (256, 128), (128,)])
    return reg


def out_shape_of(fn, in_shapes):
    out = jax.eval_shape(fn, *[_spec(s) for s in in_shapes])
    (o,) = out  # every artifact returns a 1-tuple
    return list(o.shape)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    wdir = os.path.join(args.out, "weights")
    os.makedirs(wdir, exist_ok=True)

    reg = build_artifact_registry()
    manifest: dict = {"artifacts": {}, "segment": {}, "weights": {}}

    for name, (fn, in_shapes) in sorted(reg.items()):
        lowered = jax.jit(fn).lower(*[_spec(s) for s in in_shapes])
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out, path), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": path,
            "inputs": [list(s) for s in in_shapes],
            "output": out_shape_of(fn, in_shapes),
        }
        print(f"  lowered {name:24s} ({len(text)} chars)")

    # Segment geometry for the Rust tile slicer.
    spec = model.segment_spec()
    manifest["segment"] = {
        "in_shape": list(model.IN_SHAPE),
        "rows_per_cn": model.ROWS_PER_CN,
        "layers": [
            {
                **{k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in dataclasses.asdict(ls).items()},
                "n_cns": ls.n_cns,
                "tile_in_shape": list(ls.tile_in_shape),
                "tile_out_shape": list(ls.tile_out_shape),
                "tile_in_rows": ls.tile_in_rows,
            }
            for ls in spec
        ],
    }

    # Deterministic weights + sample input + oracle output as raw f32.
    params = model.make_params()
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=model.IN_SHAPE), jnp.float32)
    (y,) = model.segment_oracle(x, *params)
    blobs = {
        "input": np.asarray(x),
        "oracle_output": np.asarray(y),
        "w0": np.asarray(params[0]), "b0": np.asarray(params[1]),
        "w2": np.asarray(params[2]), "b2": np.asarray(params[3]),
        "w3": np.asarray(params[4]), "b3": np.asarray(params[5]),
    }
    for name, arr in blobs.items():
        path = os.path.join("weights", f"{name}.f32")
        arr.astype("<f4").tofile(os.path.join(args.out, path))
        manifest["weights"][name] = {"file": path, "shape": list(arr.shape)}

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(reg)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
