"""L2: the JAX workload model, built on the L1 Pallas kernels.

This module defines the *functional* counterpart of the workloads the L3
Rust coordinator schedules: the first segment of ResNet-18 (conv7x7/s2 →
maxpool3x3/s2 → conv3x3 → conv3x3 → residual add), the workload DIANA's
published measurements use and the paper's validation Section IV-C models.

Two families of entry points are exported:

- **full-layer functions** (``layer*``) — one call computes an entire
  layer; AOT artifacts of these implement the *layer-by-layer* execution
  baseline in the Rust runtime;
- **CN tile functions** (``cn_*``) — one call computes a single
  computation node (a block of output rows) from a pre-sliced input tile
  (halo included); AOT artifacts of these are what the Rust scheduler's
  *layer-fused* execution actually runs, CN by CN, in schedule order.

The segment geometry (tile shapes, halos, strides) is described by
:func:`segment_spec`, which ``aot.py`` serializes into
``artifacts/manifest.json`` so the Rust side slices tiles identically.

Everything here is build-time only: ``aot.py`` lowers each entry point
once to HLO text and the Python interpreter is never on the Rust request
path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import conv, eltwise, matmul, pool, ref

# ---------------------------------------------------------------------------
# Segment geometry
# ---------------------------------------------------------------------------

#: Input feature map of the segment: CHW. 112x112 is the paper's ResNet-18
#: first-segment geometry scaled 2x down so the CPU-interpret end-to-end
#: run stays fast; every structural property (strides, halos, fusion
#: pattern) is preserved. See DESIGN.md §Substitutions.
IN_SHAPE = (3, 112, 112)
#: Output rows computed per computation node (the scheduling granularity —
#: 4 lines, the line-buffered granularity DepFiN/DIANA implement).
ROWS_PER_CN = 4


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Geometry of one fused layer, shared with the Rust runtime."""

    name: str
    kind: str            # conv | pool | add
    in_shape: tuple      # C,H,W (unpadded)
    out_shape: tuple     # K,OY,OX
    fy: int = 0
    fx: int = 0
    stride: int = 1
    pad: int = 0
    relu: bool = True
    weight: tuple = ()   # K,C,FY,FX for conv
    #: which earlier layer's output is the second addend (for `add`)
    residual_of: int = -1
    artifact: str = ""      # CN tile artifact name
    layer_artifact: str = ""  # full-layer artifact name

    @property
    def n_cns(self) -> int:
        return self.out_shape[1] // ROWS_PER_CN

    @property
    def tile_in_rows(self) -> int:
        """Input rows a CN needs: (rows_out-1)*stride + fy (conv/pool)."""
        if self.kind == "add":
            return ROWS_PER_CN
        return (ROWS_PER_CN - 1) * self.stride + self.fy

    @property
    def tile_in_shape(self) -> tuple:
        c = self.in_shape[0]
        if self.kind == "add":
            return (c, ROWS_PER_CN, self.in_shape[2])
        return (c, self.tile_in_rows, self.in_shape[2] + 2 * self.pad)

    @property
    def tile_out_shape(self) -> tuple:
        return (self.out_shape[0], ROWS_PER_CN, self.out_shape[2])

    def cn_input_row_start(self, cn_idx: int) -> int:
        """First (possibly negative → padded) input row of CN ``cn_idx``."""
        if self.kind == "add":
            return cn_idx * ROWS_PER_CN
        return cn_idx * ROWS_PER_CN * self.stride - self.pad


def segment_spec() -> list[LayerSpec]:
    """The ResNet-18 first-segment layer stack (Fig. 10c workload)."""
    c, h, w = IN_SHAPE
    return [
        LayerSpec("conv7x7", "conv", (c, h, w), (64, h // 2, w // 2),
                  fy=7, fx=7, stride=2, pad=3, relu=True,
                  weight=(64, c, 7, 7),
                  artifact="cn_conv7x7", layer_artifact="layer_conv7x7"),
        LayerSpec("maxpool", "pool", (64, h // 2, w // 2),
                  (64, h // 4, w // 4), fy=3, fx=3, stride=2, pad=1,
                  relu=False,
                  artifact="cn_maxpool", layer_artifact="layer_maxpool"),
        LayerSpec("conv3x3a", "conv", (64, h // 4, w // 4),
                  (64, h // 4, w // 4), fy=3, fx=3, stride=1, pad=1,
                  relu=True, weight=(64, 64, 3, 3),
                  artifact="cn_conv3x3_relu", layer_artifact="layer_conv3x3_relu"),
        LayerSpec("conv3x3b", "conv", (64, h // 4, w // 4),
                  (64, h // 4, w // 4), fy=3, fx=3, stride=1, pad=1,
                  relu=False, weight=(64, 64, 3, 3),
                  artifact="cn_conv3x3", layer_artifact="layer_conv3x3"),
        LayerSpec("add", "add", (64, h // 4, w // 4), (64, h // 4, w // 4),
                  relu=True, residual_of=1,
                  artifact="cn_add", layer_artifact="layer_add"),
    ]


# ---------------------------------------------------------------------------
# Full-layer functions (layer-by-layer baseline artifacts)
# ---------------------------------------------------------------------------

def layer_conv(x, w, b, stride: int, pad: int, relu: bool):
    return (conv.conv2d(x, w, b, stride=stride, padding=pad, relu=relu),)


def layer_maxpool(x):
    return (pool.maxpool(x, ksize=3, stride=2, padding=1),)


def layer_add(a, b):
    return (eltwise.add_relu(a, b, relu=True),)


def fc_demo(x, w, b):
    """Small fully-connected head used by the quickstart example."""
    return (matmul.matmul(x, w, b, relu=True),)


# ---------------------------------------------------------------------------
# CN tile functions (layer-fused artifacts)
# ---------------------------------------------------------------------------
# Each takes a pre-sliced, pre-padded input tile (the Rust runtime slices
# rows with halo and pads the width), computes VALID conv/pool, and emits
# exactly ROWS_PER_CN output rows.

def cn_conv(x_tile, w, b, stride: int, relu: bool):
    return (conv.conv2d(x_tile, w, b, stride=stride, padding=0, relu=relu),)


def cn_maxpool(x_tile):
    return (pool.maxpool(x_tile, ksize=3, stride=2, padding=0),)


def cn_add(a_tile, b_tile):
    return (eltwise.add_relu(a_tile, b_tile, relu=True),)


# ---------------------------------------------------------------------------
# Whole-segment oracle (pure jnp, no Pallas) — the numerical ground truth
# ---------------------------------------------------------------------------

def segment_oracle(x, w0, b0, w2, b2, w3, b3):
    """Reference forward pass of the full fused segment."""
    y0 = ref.conv2d_ref(x, w0, b0, stride=2, padding=3, relu=True)
    y1 = ref.maxpool_ref(y0, ksize=3, stride=2, padding=1)
    y2 = ref.conv2d_ref(y1, w2, b2, stride=1, padding=1, relu=True)
    y3 = ref.conv2d_ref(y2, w3, b3, stride=1, padding=1, relu=False)
    y4 = ref.add_relu_ref(y3, y1, relu=True)
    return (y4,)


def segment_pallas(x, w0, b0, w2, b2, w3, b3):
    """Same forward pass, every op on the Pallas kernels (for pytest)."""
    y0 = conv.conv2d(x, w0, b0, stride=2, padding=3, relu=True)
    y1 = pool.maxpool(y0, ksize=3, stride=2, padding=1)
    y2 = conv.conv2d(y1, w2, b2, stride=1, padding=1, relu=True)
    y3 = conv.conv2d(y2, w3, b3, stride=1, padding=1, relu=False)
    y4 = eltwise.add_relu(y3, y1, relu=True)
    return (y4,)


def make_params(seed: int = 42):
    """Deterministic segment weights, identical on the Rust side via the
    raw-f32 dumps ``aot.py`` writes next to the artifacts."""
    import numpy as np

    rng = np.random.default_rng(seed)
    spec = segment_spec()

    def w(shape, fan_in):
        return jnp.asarray(
            rng.normal(0.0, (2.0 / fan_in) ** 0.5, size=shape), jnp.float32)

    w0 = w(spec[0].weight, 3 * 7 * 7)
    b0 = w((64,), 64)
    w2 = w(spec[2].weight, 64 * 9)
    b2 = w((64,), 64)
    w3 = w(spec[3].weight, 64 * 9)
    b3 = w((64,), 64)
    return w0, b0, w2, b2, w3, b3
