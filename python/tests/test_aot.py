"""AOT artifact tests: manifest consistency and HLO-text sanity.

These run against the ``artifacts/`` directory produced by
``make artifacts`` (skipped if it has not been built yet), plus
registry-level checks that need no built artifacts.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
built = os.path.exists(os.path.join(ART, "manifest.json"))
needs_artifacts = pytest.mark.skipif(
    not built, reason="run `make artifacts` first")


def test_registry_shapes_consistent():
    reg = aot.build_artifact_registry()
    assert len(reg) >= 12
    for name, (fn, in_shapes) in reg.items():
        out = aot.out_shape_of(fn, in_shapes)
        assert all(d > 0 for d in out), name


def test_registry_covers_segment():
    reg = aot.build_artifact_registry()
    for ls in model.segment_spec():
        assert ls.artifact in reg
        assert ls.layer_artifact in reg


@needs_artifacts
def test_manifest_files_exist():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, meta in man["artifacts"].items():
        path = os.path.join(ART, meta["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text, f"{name}: not HLO text"
        assert "HloModule" in text
    for name, meta in man["weights"].items():
        path = os.path.join(ART, meta["file"])
        n = int(np.prod(meta["shape"]))
        assert os.path.getsize(path) == 4 * n, name


@needs_artifacts
def test_manifest_segment_geometry_matches_model():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    seg = man["segment"]
    assert seg["rows_per_cn"] == model.ROWS_PER_CN
    assert tuple(seg["in_shape"]) == model.IN_SHAPE
    spec = model.segment_spec()
    assert len(seg["layers"]) == len(spec)
    for got, ls in zip(seg["layers"], spec):
        assert got["name"] == ls.name
        assert tuple(got["tile_in_shape"]) == ls.tile_in_shape
        assert tuple(got["tile_out_shape"]) == ls.tile_out_shape
        assert got["n_cns"] == ls.n_cns


@needs_artifacts
def test_oracle_dump_matches_recompute():
    """weights/*.f32 dumps reproduce segment_oracle exactly."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)

    def load(name):
        meta = man["weights"][name]
        arr = np.fromfile(os.path.join(ART, meta["file"]), "<f4")
        return jnp.asarray(arr.reshape(meta["shape"]))

    x = load("input")
    (y,) = model.segment_oracle(x, load("w0"), load("b0"), load("w2"),
                                load("b2"), load("w3"), load("b3"))
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(load("oracle_output")),
                               rtol=1e-6, atol=1e-6)


@needs_artifacts
def test_hlo_text_round_trips_through_xla_parser():
    """The text must parse back into an XlaComputation (what Rust does)."""
    from jax._src.lib import xla_client as xc
    path = os.path.join(ART, "fc_demo.hlo.txt")
    text = open(path).read()
    # jax's bundled XLA can re-parse HLO text
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
