"""L2 model tests: fused-segment numerics and CN tile geometry.

The critical test here is :func:`test_cn_tiling_equals_full_layer`: it
slices input tiles with exactly the geometry ``segment_spec`` exports to
the Rust runtime (halo rows, width padding, pad values), runs the CN tile
functions, stitches the row blocks, and checks the result is identical to
the full-layer computation.  If this passes, the Rust tile slicer — which
mirrors the same spec from ``manifest.json`` — computes the same numbers.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(99)


def randf(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


@pytest.fixture(scope="module")
def params():
    return model.make_params()


@pytest.fixture(scope="module")
def x_in():
    return randf(*model.IN_SHAPE)


def test_segment_pallas_vs_oracle(params, x_in):
    (want,) = model.segment_oracle(x_in, *params)
    (got,) = model.segment_pallas(x_in, *params)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_segment_spec_geometry():
    spec = model.segment_spec()
    # chained shapes
    for prev, cur in zip(spec, spec[1:]):
        if cur.kind != "add":
            assert prev.out_shape == cur.in_shape
    # every layer's output rows divide evenly into CNs
    for ls in spec:
        assert ls.out_shape[1] % model.ROWS_PER_CN == 0
        assert ls.n_cns == ls.out_shape[1] // model.ROWS_PER_CN
    # conv7x7 halo: (4-1)*2 + 7 = 13 input rows per CN
    assert spec[0].tile_in_rows == 13
    assert spec[1].tile_in_rows == 9
    assert spec[2].tile_in_rows == 6


def _slice_tile(x, ls, cn_idx, pad_value):
    """Reference implementation of the Rust tile slicer."""
    c, h, w = ls.in_shape
    rows = ls.tile_in_rows
    start = ls.cn_input_row_start(cn_idx)
    tile = np.full((c, rows, w + 2 * ls.pad), pad_value, np.float32)
    for r in range(rows):
        src = start + r
        if 0 <= src < h:
            tile[:, r, ls.pad: ls.pad + w] = np.asarray(x[:, src, :])
    return jnp.asarray(tile)


@pytest.mark.parametrize("layer_idx", [0, 1, 2, 3])
def test_cn_tiling_equals_full_layer(layer_idx, params, x_in):
    spec = model.segment_spec()
    w0, b0, w2, b2, w3, b3 = params
    # compute the layer inputs with the oracle up to layer_idx
    acts = [x_in]
    acts.append(ref.conv2d_ref(acts[0], w0, b0, 2, 3, True))
    acts.append(ref.maxpool_ref(acts[1], 3, 2, 1))
    acts.append(ref.conv2d_ref(acts[2], w2, b2, 1, 1, True))
    acts.append(ref.conv2d_ref(acts[3], w3, b3, 1, 1, False))

    ls = spec[layer_idx]
    x = acts[layer_idx]
    full = acts[layer_idx + 1]
    wgt = {0: (w0, b0), 2: (w2, b2), 3: (w3, b3)}.get(layer_idx)

    tiles = []
    for i in range(ls.n_cns):
        # conv pads with 0; pool input is post-ReLU so 0-padding is exact
        tile = _slice_tile(x, ls, i, 0.0)
        if ls.kind == "conv":
            (out,) = model.cn_conv(tile, wgt[0], wgt[1],
                                   stride=ls.stride, relu=ls.relu)
        else:
            (out,) = model.cn_maxpool(tile)
        assert out.shape == ls.tile_out_shape
        tiles.append(out)
    stitched = jnp.concatenate(tiles, axis=1)
    np.testing.assert_allclose(stitched, full, rtol=1e-3, atol=1e-4)


def test_cn_add_tiling(params, x_in):
    spec = model.segment_spec()
    w0, b0, w2, b2, w3, b3 = params
    y1 = ref.maxpool_ref(
        ref.conv2d_ref(x_in, w0, b0, 2, 3, True), 3, 2, 1)
    y3 = ref.conv2d_ref(
        ref.conv2d_ref(y1, w2, b2, 1, 1, True), w3, b3, 1, 1, False)
    want = ref.add_relu_ref(y3, y1)
    ls = spec[4]
    r = model.ROWS_PER_CN
    tiles = []
    for i in range(ls.n_cns):
        (out,) = model.cn_add(y3[:, i * r:(i + 1) * r, :],
                              y1[:, i * r:(i + 1) * r, :])
        tiles.append(out)
    np.testing.assert_allclose(jnp.concatenate(tiles, axis=1), want,
                               rtol=1e-5, atol=1e-5)


def test_make_params_deterministic():
    a = model.make_params()
    b = model.make_params()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
