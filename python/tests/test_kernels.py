"""Kernel-vs-oracle correctness: the core numerical signal of the stack.

Each Pallas kernel is compared against its pure-jnp oracle in ``ref.py``
over both hand-picked shapes and hypothesis-driven sweeps (shapes,
strides, paddings, block sizes).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, eltwise, matmul, pool, ref

RNG = np.random.default_rng(1234)


def randf(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (32, 64, 64), (37, 53, 41),
                                   (128, 256, 128), (5, 300, 7)])
@pytest.mark.parametrize("relu", [False, True])
def test_matmul_fixed(m, k, n, relu):
    x, w, b = randf(m, k), randf(k, n), randf(n)
    got = matmul.matmul(x, w, b, relu=relu)
    want = ref.matmul_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_no_bias():
    x, w = randf(16, 16), randf(16, 16)
    np.testing.assert_allclose(matmul.matmul(x, w), ref.matmul_ref(x, w),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
       bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([16, 32, 64]),
       bk=st.sampled_from([16, 32, 64]))
def test_matmul_hypothesis(m, k, n, bm, bn, bk):
    x, w, b = randf(m, k), randf(k, n), randf(n)
    got = matmul.matmul(x, w, b, relu=True, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(x, w, b, relu=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_block_size_invariance():
    """Result must not depend on the BlockSpec tiling."""
    x, w, b = randf(50, 90), randf(90, 33), randf(33)
    a = matmul.matmul(x, w, b, bm=8, bn=16, bk=16)
    c = matmul.matmul(x, w, b, bm=32, bn=64, bk=64)
    np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)


def test_vmem_estimate_positive():
    assert matmul.vmem_bytes() > 0
    assert matmul.vmem_bytes(8, 8, 8) < matmul.vmem_bytes(128, 128, 128)


def test_mxu_utilization_bounds():
    u = matmul.mxu_utilization(3136, 64, 576)
    assert 0.0 < u <= 1.0
    # bigger aligned problem → higher estimated utilization
    assert matmul.mxu_utilization(4096, 128, 1024, bn=128, bk=128) >= u


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride,pad,fy", [(1, 0, 1), (1, 1, 3), (2, 3, 7),
                                           (2, 1, 3), (1, 2, 5)])
def test_conv_fixed(stride, pad, fy):
    x = randf(3, 24, 20)
    w = randf(8, 3, fy, fy)
    b = randf(8)
    got = conv.conv2d(x, w, b, stride=stride, padding=pad, relu=True)
    want = ref.conv2d_ref(x, w, b, stride=stride, padding=pad, relu=True)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(c=st.integers(1, 8), k=st.integers(1, 16),
       h=st.integers(7, 24), fy=st.sampled_from([1, 3, 5]),
       stride=st.sampled_from([1, 2]), pad=st.integers(0, 2),
       relu=st.booleans())
def test_conv_hypothesis(c, k, h, fy, stride, pad, relu):
    x = randf(c, h, h)
    w = randf(k, c, fy, fy)
    b = randf(k)
    got = conv.conv2d(x, w, b, stride=stride, padding=pad, relu=relu)
    want = ref.conv2d_ref(x, w, b, stride=stride, padding=pad, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_conv_macs():
    assert conv.macs((3, 8, 8), (4, 3, 3, 3), 1, 1) == 4 * 8 * 8 * 3 * 9
    assert conv.macs((3, 8, 8), (4, 3, 3, 3), 2, 1) == 4 * 4 * 4 * 3 * 9


# ---------------------------------------------------------------------------
# maxpool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,h,w,k,s,p", [(64, 56, 56, 3, 2, 1),
                                         (3, 9, 9, 3, 3, 0),
                                         (19, 15, 17, 3, 2, 1),
                                         (16, 8, 8, 2, 2, 0)])
def test_pool_fixed(c, h, w, k, s, p):
    x = randf(c, h, w)
    np.testing.assert_allclose(pool.maxpool(x, k, s, p),
                               ref.maxpool_ref(x, k, s, p), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(c=st.integers(1, 40), h=st.integers(5, 20),
       k=st.sampled_from([2, 3]), s=st.sampled_from([1, 2]),
       p=st.integers(0, 1))
def test_pool_hypothesis(c, h, k, s, p):
    x = randf(c, h, h)
    np.testing.assert_allclose(pool.maxpool(x, k, s, p),
                               ref.maxpool_ref(x, k, s, p), rtol=1e-6)


def test_pool_negative_padding_semantics():
    """-inf padding: border maxima of all-negative inputs stay negative."""
    x = -jnp.ones((4, 6, 6), jnp.float32)
    out = pool.maxpool(x, 3, 2, 1)
    assert float(out.max()) == -1.0


# ---------------------------------------------------------------------------
# eltwise
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 5000), relu=st.booleans())
def test_add_relu_hypothesis(n, relu):
    a, b = randf(n), randf(n)
    np.testing.assert_allclose(eltwise.add_relu(a, b, relu=relu),
                               ref.add_relu_ref(a, b, relu=relu), rtol=1e-6)


def test_add_relu_3d():
    a, b = randf(64, 4, 28), randf(64, 4, 28)
    np.testing.assert_allclose(eltwise.add_relu(a, b),
                               ref.add_relu_ref(a, b), rtol=1e-6)
