//! Bench: Fig. 14 — latency at the best-EDP points of the exploration
//! (reuses the Fig. 13 sweep cache when present).
//!
//! ```bash
//! cargo bench --bench fig13_edp && cargo bench --bench fig14_latency
//! ```

use stream::allocator::GaParams;
use stream::experiments::fig13::{default_cache_path, format_fig14, sweep_cached};
use stream::experiments::SweepConfig;
use stream::util::bench::paper_scale;

fn main() {
    let ga = if paper_scale() {
        GaParams { population: 32, generations: 24, ..Default::default() }
    } else {
        GaParams { population: 12, generations: 6, ..Default::default() }
    };
    let cfg = SweepConfig { ga, ..Default::default() };
    println!("=== Fig. 14: latency at the best-EDP points ===\n");
    let t = std::time::Instant::now();
    let cells = sweep_cached(&cfg, &default_cache_path());
    println!("{}", format_fig14(&cells));
    println!("total: {:.1} s", t.elapsed().as_secs_f64());
}
