//! Bench: Fig. 12 — GA-based automatic layer-core allocation vs manual
//! allocation, ResNet-18 on HomTPU and Hetero, both scheduler
//! priorities.
//!
//! ```bash
//! cargo bench --bench fig12_allocation                 # reduced GA
//! STREAM_BENCH_SCALE=paper cargo bench --bench fig12_allocation
//! ```

use stream::allocator::GaParams;
use stream::experiments::{fig12, fig12::format_rows};
use stream::util::bench::paper_scale;

fn main() {
    let ga = if paper_scale() {
        GaParams { population: 32, generations: 24, ..Default::default() }
    } else {
        GaParams { population: 16, generations: 10, ..Default::default() }
    };
    println!(
        "=== Fig. 12: automatic (GA) vs manual allocation (pop {}, {} gens) ===\n",
        ga.population, ga.generations
    );

    // serial baseline (1 fitness worker, same seed): must produce the
    // exact same rows, only slower
    let t = std::time::Instant::now();
    let serial_rows = fig12(GaParams { threads: 1, ..ga });
    let serial_s = t.elapsed().as_secs_f64();

    let t = std::time::Instant::now();
    let rows = fig12(ga);
    let parallel_s = t.elapsed().as_secs_f64();
    println!("{}", format_rows(&rows));

    for (a, b) in serial_rows.iter().zip(&rows) {
        assert_eq!(
            (a.latency_cc, a.peak_mem_kb.to_bits()),
            (b.latency_cc, b.peak_mem_kb.to_bits()),
            "serial and parallel rows must be bit-identical ({} {} {})",
            a.arch,
            a.method,
            a.priority,
        );
    }
    println!(
        "serial {:.1} s -> parallel+memoized {:.1} s on {} threads ({:.2}x), rows bit-identical",
        serial_s,
        parallel_s,
        stream::util::thread_count(0),
        serial_s / parallel_s
    );

    // the paper's headline: the GA memory leader trades latency for
    // memory on the heterogeneous architecture
    let ga_lat = rows
        .iter()
        .find(|r| r.arch == "MC:Hetero" && r.method == "GA" && r.priority == "latency")
        .unwrap();
    let ga_mem = rows
        .iter()
        .find(|r| r.arch == "MC:Hetero" && r.method == "GA" && r.priority == "memory")
        .unwrap();
    println!(
        "hetero GA memory-leader vs latency-leader: {:.0}% memory at {:.0}% latency",
        100.0 * ga_mem.peak_mem_kb / ga_lat.peak_mem_kb,
        100.0 * ga_mem.latency_cc as f64 / ga_lat.latency_cc as f64,
    );
    println!("(paper: 44% of the memory at 154% of the latency)");
    println!("\ntotal: {:.1} s", t.elapsed().as_secs_f64());
}
