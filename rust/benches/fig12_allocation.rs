//! Bench: Fig. 12 — GA-based automatic layer-core allocation vs manual
//! allocation, ResNet-18 on HomTPU and Hetero, both scheduler
//! priorities.
//!
//! ```bash
//! cargo bench --bench fig12_allocation                 # reduced GA
//! STREAM_BENCH_SCALE=paper cargo bench --bench fig12_allocation
//! ```

use stream::allocator::GaParams;
use stream::experiments::{fig12, fig12::format_rows};
use stream::util::bench::paper_scale;

fn main() {
    let ga = if paper_scale() {
        GaParams { population: 32, generations: 24, ..Default::default() }
    } else {
        GaParams { population: 16, generations: 10, ..Default::default() }
    };
    println!(
        "=== Fig. 12: automatic (GA) vs manual allocation (pop {}, {} gens) ===\n",
        ga.population, ga.generations
    );

    let total = std::time::Instant::now();

    // serial baseline (1 fitness worker, same seed): must produce the
    // exact same rows, only slower
    let t = std::time::Instant::now();
    let serial_rows = fig12(GaParams { threads: 1, ..ga });
    let serial_s = t.elapsed().as_secs_f64();

    let t = std::time::Instant::now();
    let rows = fig12(ga);
    let parallel_s = t.elapsed().as_secs_f64();
    println!("{}", format_rows(&rows));

    let assert_same = |other: &[stream::experiments::Fig12Row], label: &str| {
        for (a, b) in other.iter().zip(&rows) {
            assert_eq!(
                (a.latency_cc, a.peak_mem_kb.to_bits()),
                (b.latency_cc, b.peak_mem_kb.to_bits()),
                "{label} rows must be bit-identical ({} {} {})",
                a.arch,
                a.method,
                a.priority,
            );
        }
    };
    assert_same(&serial_rows, "serial and parallel");
    println!(
        "serial {:.1} s -> parallel+memoized {:.1} s on {} threads ({:.2}x), rows bit-identical",
        serial_s,
        parallel_s,
        stream::util::thread_count(0),
        serial_s / parallel_s
    );

    // incremental delta evaluation (the GaParams default, active in
    // both runs above) vs full per-genome re-simulation: same rows,
    // the speedup is pure genome-evals/sec
    let t = std::time::Instant::now();
    let full_rows = fig12(GaParams { incremental: false, ..ga });
    let full_s = t.elapsed().as_secs_f64();
    assert_same(&full_rows, "full and delta-evaluated");
    println!(
        "full re-simulation {:.1} s -> delta evaluation {:.1} s ({:.2}x evals/sec), \
         rows bit-identical",
        full_s,
        parallel_s,
        full_s / parallel_s
    );

    // the paper's headline: the GA memory leader trades latency for
    // memory on the heterogeneous architecture
    let ga_lat = rows
        .iter()
        .find(|r| r.arch == "MC:Hetero" && r.method == "GA" && r.priority == "latency")
        .unwrap();
    let ga_mem = rows
        .iter()
        .find(|r| r.arch == "MC:Hetero" && r.method == "GA" && r.priority == "memory")
        .unwrap();
    println!(
        "hetero GA memory-leader vs latency-leader: {:.0}% memory at {:.0}% latency",
        100.0 * ga_mem.peak_mem_kb / ga_lat.peak_mem_kb,
        100.0 * ga_mem.latency_cc as f64 / ga_lat.latency_cc as f64,
    );
    println!("(paper: 44% of the memory at 154% of the latency)");

    // machine-readable summary for the committed BENCH_fig12.json
    let mut j = std::collections::BTreeMap::new();
    let num = stream::util::Json::Num;
    j.insert("status".to_string(), stream::util::Json::Str("measured".to_string()));
    j.insert("population".to_string(), num(ga.population as f64));
    j.insert("generations".to_string(), num(ga.generations as f64));
    j.insert("threads".to_string(), num(stream::util::thread_count(0) as f64));
    j.insert("serial_seconds".to_string(), num(serial_s));
    j.insert("parallel_seconds".to_string(), num(parallel_s));
    j.insert("full_resim_seconds".to_string(), num(full_s));
    j.insert("parallel_speedup".to_string(), num(serial_s / parallel_s));
    j.insert("incremental_speedup".to_string(), num(full_s / parallel_s));
    j.insert(
        "hetero_mem_leader_memory_pct".to_string(),
        num(100.0 * ga_mem.peak_mem_kb / ga_lat.peak_mem_kb),
    );
    j.insert(
        "hetero_mem_leader_latency_pct".to_string(),
        num(100.0 * ga_mem.latency_cc as f64 / ga_lat.latency_cc as f64),
    );
    let out = stream::util::Json::Obj(j).to_string_compact() + "\n";
    match std::fs::write("BENCH_fig12.json", &out) {
        Ok(()) => println!("wrote BENCH_fig12.json"),
        Err(e) => println!("could not write BENCH_fig12.json: {e}"),
    }

    println!("\ntotal: {:.1} s", total.elapsed().as_secs_f64());
}
