//! Bench: Fig. 12 — GA-based automatic layer-core allocation vs manual
//! allocation, ResNet-18 on HomTPU and Hetero, both scheduler
//! priorities.
//!
//! ```bash
//! cargo bench --bench fig12_allocation                 # reduced GA
//! STREAM_BENCH_SCALE=paper cargo bench --bench fig12_allocation
//! ```

use stream::allocator::GaParams;
use stream::experiments::{fig12, fig12::format_rows};
use stream::util::bench::paper_scale;

fn main() {
    let ga = if paper_scale() {
        GaParams { population: 32, generations: 24, ..Default::default() }
    } else {
        GaParams { population: 16, generations: 10, ..Default::default() }
    };
    println!(
        "=== Fig. 12: automatic (GA) vs manual allocation (pop {}, {} gens) ===\n",
        ga.population, ga.generations
    );
    let t = std::time::Instant::now();
    let rows = fig12(ga);
    println!("{}", format_rows(&rows));

    // the paper's headline: the GA memory leader trades latency for
    // memory on the heterogeneous architecture
    let ga_lat = rows
        .iter()
        .find(|r| r.arch == "MC:Hetero" && r.method == "GA" && r.priority == "latency")
        .unwrap();
    let ga_mem = rows
        .iter()
        .find(|r| r.arch == "MC:Hetero" && r.method == "GA" && r.priority == "memory")
        .unwrap();
    println!(
        "hetero GA memory-leader vs latency-leader: {:.0}% memory at {:.0}% latency",
        100.0 * ga_mem.peak_mem_kb / ga_lat.peak_mem_kb,
        100.0 * ga_mem.latency_cc as f64 / ga_lat.latency_cc as f64,
    );
    println!("(paper: 44% of the memory at 154% of the latency)");
    println!("\ntotal: {:.1} s", t.elapsed().as_secs_f64());
}
