//! Serving throughput: the bounded-memory streaming engine on long
//! request traces (`ScenarioRunner::run_streamed`).
//!
//! Two measurements on `llm_serving` / `chiplet_8x8`:
//!
//! 1. **Saturation sweep** — arbitration x rate-scale over a
//!    fixed-duration trace: wall-clock requests/sec simulated, steady
//!    p99 and miss rate as the offered load pushes the fabric toward
//!    saturation (the rate-scale axis of the CLI `--rate-scale` flag).
//! 2. **Headline long trace** — `llm_serving` extended to >= 100k
//!    requests (1M under `STREAM_BENCH_SCALE=paper`), streamed in
//!    untraced bounded mode.  The interesting numbers are simulated
//!    requests/sec and the peak-live-vs-total ratio: live state is
//!    O(admission window + in-flight), never O(trace length), which is
//!    what makes million-request traces tractable at all.
//!
//! Results land in `BENCH_serving.json`.
//!
//! ```bash
//! cargo bench --bench serving_throughput
//! STREAM_BENCH_SCALE=paper cargo bench --bench serving_throughput   # 1M-request headline
//! ```

use std::time::Instant;

use stream::arch::presets;
use stream::scenario::{llm_serving, Arbitration, ScenarioResult, ScenarioSim, StreamingOpts};
use stream::util::bench::paper_scale;
use stream::util::Json;

/// One streamed bounded-mode run; returns the result and wall seconds.
fn run_streamed(
    sim: &ScenarioSim<'_>,
    arb: Arbitration,
    duration_cc: u64,
) -> (ScenarioResult, f64) {
    let allocs = sim.greedy_allocations();
    let opts = StreamingOpts {
        window: 64,
        retain_events: false,
        window_cc: (duration_cc / 64).max(1),
        max_windows: 64,
        warmup_cc: duration_cc / 10,
    };
    let t0 = Instant::now();
    let r = sim.runner().run_streamed(&allocs, arb, &opts);
    (r, t0.elapsed().as_secs_f64())
}

fn main() {
    println!("=== serving throughput: streamed llm_serving on chiplet_8x8 ===\n");
    let arch = presets::chiplet_8x8();
    let mut j = std::collections::BTreeMap::new();
    j.insert("status".to_string(), Json::Str("measured".to_string()));

    // --- saturation sweep: arbitration x offered load ---------------
    const SWEEP_DUR: u64 = 6_000_000_000;
    println!("--- sweep: {SWEEP_DUR} cc trace, rate scales x1 / x2 / x4 ---");
    for arb in [Arbitration::Fifo, Arbitration::Priority, Arbitration::Edf] {
        for scale in [1.0f64, 2.0, 4.0] {
            let scenario = llm_serving().scale_rate(scale).extend_to(SWEEP_DUR);
            let n = scenario.n_requests();
            let sim = ScenarioSim::new(&scenario, &arch).expect("scenario builds");
            let (r, wall_s) = run_streamed(&sim, arb, SWEEP_DUR);
            let s = r.streaming.as_ref().expect("streamed run attaches stats");
            assert_eq!(s.retired, n as u64, "{arb} x{scale}: every request retires");
            let wall_rps = n as f64 / wall_s.max(1e-9);
            let p99 = s.steady_p99_cc();
            let misses: u64 = s.steady_misses.iter().sum();
            let miss_rate = misses as f64 / s.steady.count().max(1) as f64;
            println!(
                "{arb:<8} x{scale:<3} {n:>7} req | {wall_rps:>9.0} req/s wall | p99 {p99:>9} cc \
                 | miss {:>5.1}% | live peak {}",
                miss_rate * 100.0,
                s.live_peak
            );
            let key = format!("{arb}_x{scale}");
            j.insert(format!("{key}_requests"), Json::Num(n as f64));
            j.insert(format!("{key}_wall_rps"), Json::Num(wall_rps));
            j.insert(format!("{key}_p99_cc"), Json::Num(p99 as f64));
            j.insert(format!("{key}_miss_rate"), Json::Num(miss_rate));
            j.insert(format!("{key}_live_peak"), Json::Num(s.live_peak as f64));
        }
    }

    // --- headline: the long trace ------------------------------------
    let headline_dur: u64 = if paper_scale() { 1_500_000_000_000 } else { 150_000_000_000 };
    let scenario = llm_serving().extend_to(headline_dur);
    let n = scenario.n_requests();
    println!("\n--- headline: {headline_dur} cc trace, {n} requests, EDF ---");
    assert!(n >= 100_000, "headline trace must hold >= 100k requests, got {n}");
    let sim = ScenarioSim::new(&scenario, &arch).expect("scenario builds");
    let (r, wall_s) = run_streamed(&sim, Arbitration::Edf, headline_dur);
    let s = r.streaming.as_ref().unwrap();
    assert_eq!(s.admitted, n as u64);
    assert_eq!(s.retired, n as u64);
    let live_ratio = s.live_peak as f64 / n as f64;
    // the bounded-memory claim, asserted: live state never approaches
    // trace length (window 64 + in-flight vs >= 100k requests)
    assert!(
        s.live_peak <= 64 + s.inflight_peak,
        "live peak {} must stay within window + in-flight {}",
        s.live_peak,
        s.inflight_peak
    );
    let wall_rps = n as f64 / wall_s.max(1e-9);
    println!(
        "{n} requests in {:.2}s wall = {wall_rps:.0} req/s simulated | live peak {} \
         ({:.4}% of trace) | steady p99 {} cc",
        wall_s,
        s.live_peak,
        live_ratio * 100.0,
        s.steady_p99_cc()
    );
    j.insert("headline_requests".to_string(), Json::Num(n as f64));
    j.insert("headline_wall_s".to_string(), Json::Num(wall_s));
    j.insert("headline_wall_rps".to_string(), Json::Num(wall_rps));
    j.insert("headline_live_peak".to_string(), Json::Num(s.live_peak as f64));
    j.insert("headline_inflight_peak".to_string(), Json::Num(s.inflight_peak as f64));
    j.insert("headline_live_ratio".to_string(), Json::Num(live_ratio));
    j.insert("headline_p99_cc".to_string(), Json::Num(s.steady_p99_cc() as f64));

    let out = Json::Obj(j).to_string_compact() + "\n";
    match std::fs::write("BENCH_serving.json", &out) {
        Ok(()) => println!("\nwrote BENCH_serving.json"),
        Err(e) => println!("\ncould not write BENCH_serving.json: {e}"),
    }
}
