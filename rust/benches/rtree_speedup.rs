//! Bench: the Section III-B claim — R-tree-based inter-layer dependency
//! generation vs the quadratic pairwise baseline.
//!
//! The paper's case: 448x448 producer CNs x 448x448 consumer CNs
//! (~2x10^5 each side); the pairwise baseline would take >9 hours, the
//! R-tree 6 seconds (10^3x).  We measure the R-tree at full size and the
//! baseline on subsampled grids, extrapolating its quadratic cost to
//! full size for the speedup estimate (plus an equivalence check).
//!
//! ```bash
//! cargo bench --bench rtree_speedup
//! ```

use stream::rtree::{RTree, Rect};
use stream::util::ScopeTimer;

/// Producer CNs: a g x g grid of unit output tiles.
fn producer_rects(g: i64) -> Vec<Rect> {
    let mut v = Vec::with_capacity((g * g) as usize);
    for y in 0..g {
        for x in 0..g {
            v.push(Rect::chw(0..1, y..y + 1, x..x + 1));
        }
    }
    v
}

/// Consumer CNs: one 3x3-halo input window per output pixel (stride 1).
fn consumer_windows(g: i64) -> Vec<(Rect, u32)> {
    let mut v = Vec::with_capacity((g * g) as usize);
    let mut id = 0u32;
    for y in 0..g {
        for x in 0..g {
            v.push((
                Rect::chw(0..1, (y - 1).max(0)..(y + 2).min(g), (x - 1).max(0)..(x + 2).min(g)),
                id,
            ));
            id += 1;
        }
    }
    v
}

fn rtree_pass(g: i64) -> (u64, f64) {
    let t = ScopeTimer::start();
    let tree = RTree::bulk_load(consumer_windows(g));
    let mut edges = 0u64;
    for p in producer_rects(g) {
        tree.query(&p, |_, _| edges += 1);
    }
    (edges, t.elapsed_ms())
}

fn pairwise_pass(g: i64) -> (u64, f64) {
    let t = ScopeTimer::start();
    let consumers = consumer_windows(g);
    let mut edges = 0u64;
    for p in producer_rects(g) {
        for (c, _) in &consumers {
            if p.intersects(c) {
                edges += 1;
            }
        }
    }
    (edges, t.elapsed_ms())
}

fn main() {
    println!("=== R-tree dependency generation vs pairwise baseline ===\n");

    // equivalence on a small grid
    let (e_rt, _) = rtree_pass(32);
    let (e_pw, _) = pairwise_pass(32);
    assert_eq!(e_rt, e_pw, "R-tree and pairwise must find identical edges");
    println!("equivalence check (32x32): {e_rt} edges from both paths\n");

    // R-tree at the paper's full 448x448 scale
    let (edges, rt_ms) = rtree_pass(448);
    println!("R-tree   448x448 -> 448x448: {edges} edges in {rt_ms:.0} ms (paper: 6 s)");

    // pairwise cost measured at increasing sizes, extrapolated to 448
    let mut last = (0u64, 0.0f64);
    for g in [32i64, 64, 96] {
        let (e, ms) = pairwise_pass(g);
        println!("pairwise {g:>3}x{g:<3}: {e} edges in {ms:.0} ms");
        last = (e, ms);
    }
    let scale = (448.0f64 / 96.0).powi(4); // n^2 pairs, n = g^2
    let extrapolated_ms = last.1 * scale;
    println!(
        "\npairwise extrapolated to 448x448: {:.0} s  (paper: >9 h on their setup)",
        extrapolated_ms / 1e3
    );
    println!(
        "estimated speedup: {:.0}x  (paper: ~10^3x)",
        extrapolated_ms / rt_ms.max(1e-6)
    );
}
