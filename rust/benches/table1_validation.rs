//! Bench: Table I — validation of modeled latency & peak memory against
//! the three measured SotA architectures.  Prints the same rows the
//! paper reports plus the framework runtime per target.
//!
//! ```bash
//! cargo bench --bench table1_validation
//! ```

use stream::experiments::{table1, table1::format_table};

fn main() {
    println!("=== Table I: validation against measured silicon ===\n");
    let t = std::time::Instant::now();
    let rows = table1();
    println!("{}", format_table(&rows));
    println!("paper reference accuracies: DepFiN 91%/97%, 4x4 AiMC 99%/N-A, DIANA 96%/98%");
    for r in &rows {
        println!(
            "{:<10} Stream runtime {:>8.1} ms (paper: 5 s / 3 s / 2 s)",
            r.arch, r.runtime_ms
        );
    }
    println!("\ntotal: {:.2} s", t.elapsed().as_secs_f64());
}
