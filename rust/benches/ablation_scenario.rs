//! Ablation: multi-DNN serving — arbitration policy × interconnect
//! topology on the heterogeneous quad-core.
//!
//! Co-schedules the `edge_mix` scenario (periodic classifier +
//! enhancement net + bursty detector) under fifo / priority / EDF
//! arbitration on the bus and mesh fabrics, and compares the greedy
//! per-tenant partitioning against the scenario-level NSGA-II
//! co-optimized one.  Reports per-tenant p50/p99 latency, deadline-miss
//! rate, throughput and the busiest links.  EDF and FIFO must disagree
//! on the tight-deadline tenant — identical tails would mean the
//! arbitration axis does nothing.
//!
//! ```bash
//! cargo bench --bench ablation_scenario
//! ```

use stream::allocator::GaParams;
use stream::arch::presets;
use stream::cost::{fmt_cycles, fmt_energy};
use stream::scenario::{self, Arbitration, ScenarioGa, ScenarioResult, ScenarioSim};

fn print_result(tag: &str, r: &ScenarioResult) {
    println!(
        "  {:<10} makespan {:>12} | energy {:>12} | misses {} | dense util {:>4.0}%",
        tag,
        fmt_cycles(r.makespan_cc()),
        fmt_energy(r.metrics.energy_pj),
        r.total_misses(),
        100.0 * r.metrics.avg_core_util,
    );
    for t in &r.tenants {
        println!(
            "    {:<12} p50 {:>10}  p99 {:>10}  miss {}/{}  {:>7.1} req/s",
            t.name,
            fmt_cycles(t.p50_cc),
            fmt_cycles(t.p99_cc),
            t.misses,
            t.requests,
            t.throughput_rps,
        );
    }
}

fn main() {
    println!("=== ablation: multi-DNN serving (edge_mix, MC:Hetero) ===\n");
    let scenario = scenario::edge_mix();
    let ga = GaParams { population: 8, generations: 4, ..Default::default() };

    let mut mesh_runs: Vec<(Arbitration, ScenarioResult)> = Vec::new();
    for arch_name in ["hetero_quad@bus", "hetero_quad@mesh"] {
        let arch = presets::by_name(arch_name).expect("preset");
        let sim = ScenarioSim::new(&scenario, &arch).expect("scenario builds");
        let allocs = sim.greedy_allocations();
        println!("--- {} ---", arch.name);
        for arb in [Arbitration::Fifo, Arbitration::Priority, Arbitration::Edf] {
            let t = stream::util::ScopeTimer::start();
            let r = sim.run(&allocs, arb);
            print_result(&format!("{arb}"), &r);
            println!("    ({:.1} ms sim)", t.elapsed_ms());
            if arch_name == "hetero_quad@mesh" {
                mesh_runs.push((arb, r));
            }
        }
        println!();
    }

    // the arbitration axis must actually reorder the contended requests
    let completions = |arb: Arbitration| -> Vec<u64> {
        mesh_runs
            .iter()
            .find(|(a, _)| *a == arb)
            .unwrap()
            .1
            .outcomes
            .iter()
            .map(|o| o.completion_cc)
            .collect()
    };
    let (fifo, edf) = (completions(Arbitration::Fifo), completions(Arbitration::Edf));
    assert_ne!(fifo, edf, "EDF and FIFO produced identical completions — arbitration inert?");
    println!("fifo vs edf request completions differ — arbitration modeled OK\n");

    // co-optimized (tenant, layer) -> core partitioning vs greedy
    let arch = presets::by_name("hetero_quad@mesh").expect("preset");
    let sim = ScenarioSim::new(&scenario, &arch).expect("scenario builds");
    let greedy = sim.run(&sim.greedy_allocations(), Arbitration::Edf);
    let t = stream::util::ScopeTimer::start();
    let mut sga = ScenarioGa::new(&sim, Arbitration::Edf, ga);
    let front = sga.run();
    let best = front.first().expect("nonempty scenario front");
    let coopt = sim.run(&best.allocations, Arbitration::Edf);
    println!("--- co-optimized partitioning (NSGA-II, {:.1} ms) ---", t.elapsed_ms());
    print_result("greedy", &greedy);
    print_result("co-opt", &coopt);
    assert!(
        (coopt.total_misses(), coopt.worst_p99_cc())
            <= (greedy.total_misses(), greedy.worst_p99_cc()),
        "the searched partitioning must not serve worse than greedy: {:?} vs {:?}",
        (coopt.total_misses(), coopt.worst_p99_cc()),
        (greedy.total_misses(), greedy.worst_p99_cc()),
    );
}
