//! Ablation: the fusion axis — co-searching per-edge fuse/cut
//! decisions with the core allocation vs the two uniform regimes
//! (all-fuse `Lines(4)` and all-cut layer-by-layer).
//!
//! For each (network, architecture) point the bench runs:
//!
//! - **fused**: the classic pipeline at uniform `Lines(4)`;
//! - **cut**: the classic pipeline layer-by-layer;
//! - **co-search**: `Stream::run_fuse_search` — one fuse gene per
//!   workload edge, searched jointly with the allocation, seeded with
//!   both regime winners.
//!
//! Because the regime winners are re-seeded into the co-search and
//! re-evaluated as exact cache hits, the co-search's best EDP can
//! never be worse than either regime's — the bench asserts that
//! invariant and reports where the mixed patterns actually win (and
//! how mixed the winning pattern is).
//!
//! The second section repeats the comparison on a ViT-Base@384-class
//! encoder stack in the weights-resident regime (32 MB weight SRAMs),
//! where fusion's activation-spill savings dominate — the frontier the
//! co-search is meant to navigate per edge instead of globally.
//!
//! ```bash
//! cargo bench --bench ablation_fusion_axis                 # reduced
//! STREAM_BENCH_SCALE=paper cargo bench --bench ablation_fusion_axis
//! ```

use stream::allocator::GaParams;
use stream::arch::presets;
use stream::pipeline::{Stream, StreamOpts, StreamResult};
use stream::util::bench::paper_scale;
use stream::workload::models;

fn best(r: &StreamResult) -> (f64, Option<(usize, usize)>) {
    let p = r.best_edp().expect("nonempty front");
    (p.edp(), p.fuse.as_ref().map(|f| (f.n_fused, f.n_cut)))
}

fn main() {
    let (pop, gens) = if paper_scale() { (24, 12) } else { (12, 6) };
    let ga = GaParams { population: pop, generations: gens, ..Default::default() };
    println!("=== ablation: fusion axis (GA pop {pop} x {gens}) ===\n");
    println!(
        "{:<14} {:<14} {:>12} {:>12} {:>12} {:>8} {:>11}",
        "workload", "arch", "EDP fused", "EDP cut", "EDP co", "gain", "co pattern"
    );

    let points: &[(&str, &str)] = if paper_scale() {
        &[
            ("resnet18", "hetero_quad"),
            ("squeezenet", "hetero_quad"),
            ("fsrcnn", "hetero_quad"),
            ("tiny-branchy", "hetero_quad@mesh"),
        ]
    } else {
        &[("tiny-branchy", "hetero_quad"), ("tiny-segment", "hetero")]
    };

    for &(net, arch_name) in points {
        let w = models::by_name(net).unwrap();
        let arch = presets::by_name(arch_name).unwrap();
        let run = |opts: StreamOpts| {
            Stream::new(w.clone(), arch.clone(), StreamOpts { ga, ..opts })
                .run()
                .unwrap()
        };
        let (fused, _) = best(&run(StreamOpts::default()));
        let (cut, _) = best(&run(StreamOpts::layer_by_layer()));
        let (co, pattern) = best(&run(StreamOpts::fuse_search()));
        let baseline = fused.min(cut);
        let (n_fused, n_cut) = pattern.expect("co-search points carry a pattern");
        println!(
            "{:<14} {:<14} {:>12.3e} {:>12.3e} {:>12.3e} {:>7.2}x {:>6}f/{:<4}c",
            net,
            arch_name,
            fused,
            cut,
            co,
            baseline / co.max(f64::MIN_POSITIVE),
            n_fused,
            n_cut,
        );
        assert!(
            co <= baseline,
            "{net} on {arch_name}: co-search EDP {co} must weakly dominate \
             both regimes (fused {fused}, cut {cut})"
        );
    }

    // --- transformer frontier: weights-resident ViT stack --------------
    println!("\n=== ablation: fusion axis on ViT-Base@384 (weights-resident) ===\n");
    let (dim, mlp, blocks) = if paper_scale() { (768, 3072, 2) } else { (384, 1536, 1) };
    let vit = models::vit_stack("vit-base-384-seg", 384, dim, mlp, blocks);
    let mut arch = presets::hetero_quad();
    for c in arch.cores.iter_mut().filter(|c| !c.is_simd()) {
        c.wgt_mem_bytes = 32 << 20;
    }
    let run = |opts: StreamOpts| {
        Stream::new(vit.clone(), arch.clone(), StreamOpts { ga, ..opts })
            .run()
            .unwrap()
    };
    let (fused, _) = best(&run(StreamOpts::default()));
    let (cut, _) = best(&run(StreamOpts::layer_by_layer()));
    let (co, pattern) = best(&run(StreamOpts::fuse_search()));
    let (n_fused, n_cut) = pattern.expect("co-search points carry a pattern");
    println!(
        "EDP fused {fused:.3e} | cut {cut:.3e} | co-search {co:.3e} \
         (pattern: {n_fused} fused / {n_cut} cut edges)"
    );
    assert!(
        co <= fused.min(cut),
        "ViT stack: co-search EDP {co} must weakly dominate both regimes"
    );
    println!("\nco-search weakly dominates both uniform regimes at every point: OK");
}
