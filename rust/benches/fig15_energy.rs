//! Bench: Fig. 15 — energy breakdown (MAC / on-chip SRAM / bus / DRAM)
//! at the best-EDP points of the exploration (reuses the Fig. 13 sweep
//! cache when present).  The paper's qualitative claim to check: fusion
//! slashes the off-chip (DRAM) energy share.
//!
//! ```bash
//! cargo bench --bench fig13_edp && cargo bench --bench fig15_energy
//! ```

use stream::allocator::GaParams;
use stream::experiments::fig13::{default_cache_path, format_fig15, sweep_cached};
use stream::experiments::SweepConfig;
use stream::util::bench::paper_scale;

fn main() {
    let ga = if paper_scale() {
        GaParams { population: 32, generations: 24, ..Default::default() }
    } else {
        GaParams { population: 12, generations: 6, ..Default::default() }
    };
    let cfg = SweepConfig { ga, ..Default::default() };
    println!("=== Fig. 15: energy breakdown at the best-EDP points ===\n");
    let t = std::time::Instant::now();
    let cells = sweep_cached(&cfg, &default_cache_path());
    println!("{}", format_fig15(&cells));

    // fusion's DRAM-energy reduction, aggregated
    let (mut lbl_dram, mut fused_dram) = (0.0, 0.0);
    for c in &cells {
        lbl_dram += c.lbl.breakdown.dram_pj;
        fused_dram += c.fused.breakdown.dram_pj;
    }
    println!(
        "aggregate DRAM energy: lbl {:.3e} pJ -> fused {:.3e} pJ ({:.1}x lower)",
        lbl_dram,
        fused_dram,
        lbl_dram / fused_dram.max(f64::MIN_POSITIVE)
    );
    println!("total: {:.1} s", t.elapsed().as_secs_f64());
}
