//! Ablation: interconnect topology sensitivity on the heterogeneous
//! quad-core — the new axis the `arch::topology` subsystem opens.
//!
//! Runs ResNet-18 on `hetero_quad` under its four interconnect presets
//! (shared bus, ring, 2-D mesh with two DRAM ports, crossbar), for both
//! layer-by-layer and fine-grained layer-fused scheduling, and reports
//! makespan / energy / EDP plus per-link utilization of the best-EDP
//! schedule.  The bus and the mesh must disagree — identical results
//! would mean routing and link contention are not actually modeled.
//!
//! ```bash
//! cargo bench --bench ablation_topology
//! ```

use stream::allocator::GaParams;
use stream::arch::{presets, Accelerator};
use stream::cn::CnGranularity;
use stream::cost::{fmt_cycles, fmt_energy};
use stream::pipeline::{Stream, StreamOpts};
use stream::scheduler::ScheduleResult;
use stream::workload::models;

fn best_edp(arch: &Accelerator, gran: CnGranularity, ga: GaParams) -> ScheduleResult {
    let s = Stream::new(
        models::resnet18(),
        arch.clone(),
        StreamOpts { granularity: gran, ga, ..Default::default() },
    );
    let mut r = s.run().unwrap();
    let best = (0..r.points.len())
        .min_by(|&a, &b| r.points[a].result.edp().total_cmp(&r.points[b].result.edp()))
        .expect("nonempty front");
    r.points.swap_remove(best).result
}

fn print_links(arch: &Accelerator, r: &ScheduleResult) {
    let span = r.metrics.latency_cc.max(1) as f64;
    println!("    {:>10} {:>8} {:>12} {:>12}", "link", "util", "busy(cc)", "bytes");
    for (link, stat) in arch.topology.links().iter().zip(&r.link_stats) {
        if stat.bytes_moved == 0 {
            continue;
        }
        println!(
            "    {:>10} {:>7.1}% {:>12} {:>12}",
            link.name,
            100.0 * stat.busy_cycles as f64 / span,
            stat.busy_cycles,
            stat.bytes_moved
        );
    }
}

fn main() {
    println!("=== ablation: interconnect topology (ResNet-18, MC:Hetero) ===\n");
    let ga = GaParams { population: 12, generations: 6, ..Default::default() };

    let mut fused_results: Vec<(String, ScheduleResult, Accelerator)> = Vec::new();
    for noc in presets::TOPOLOGY_NAMES {
        let arch = presets::with_noc(presets::hetero_quad(), noc).expect("preset noc");
        println!("--- {} · {} ---", arch.name, arch.topology);
        for (tag, gran) in [
            ("layer-by-layer", CnGranularity::LayerByLayer),
            ("fused", CnGranularity::Lines(4)),
        ] {
            let r = best_edp(&arch, gran, ga);
            println!(
                "  {:<15} makespan {:>12} | energy {:>12} | EDP {:>10.3e}",
                tag,
                fmt_cycles(r.metrics.latency_cc),
                fmt_energy(r.metrics.energy_pj),
                r.metrics.edp()
            );
            if tag == "fused" {
                print_links(&arch, &r);
                fused_results.push((noc.to_string(), r, arch.clone()));
            }
        }
        println!();
    }

    // contention must actually be modeled: bus and mesh cannot coincide
    let bus = &fused_results.iter().find(|(n, _, _)| n == "bus").unwrap().1;
    let mesh = &fused_results.iter().find(|(n, _, _)| n == "mesh").unwrap().1;
    assert!(
        bus.metrics.latency_cc != mesh.metrics.latency_cc
            || bus.metrics.energy_pj.to_bits() != mesh.metrics.energy_pj.to_bits(),
        "bus and mesh schedules are identical — topology has no effect?"
    );
    let multi_hop = mesh.comms.iter().filter(|c| c.links.len() > 1).count();
    println!(
        "mesh vs bus: {} vs {} cc, {} of {} mesh comms multi-hop — contention modeled OK",
        mesh.metrics.latency_cc,
        bus.metrics.latency_cc,
        multi_hop,
        mesh.comms.len()
    );
}
