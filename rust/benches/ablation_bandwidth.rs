//! Ablation: inter-core bus and DRAM-port bandwidth sensitivity.
//!
//! The paper fixes the bus at 128 bit/cc and the DRAM port at 64 bit/cc;
//! this ablation sweeps both to show where communication becomes the
//! bottleneck for fine-grained fusion on the heterogeneous quad-core —
//! the architectural-decision axis Stream is built to explore.
//!
//! ```bash
//! cargo bench --bench ablation_bandwidth
//! ```

use stream::allocator::GaParams;
use stream::arch::{presets, Topology};
use stream::cn::CnGranularity;
use stream::pipeline::{Stream, StreamOpts};
use stream::workload::models;

fn main() {
    println!("=== ablation: bus / DRAM bandwidth (ResNet-18, MC:Hetero, fused) ===\n");
    let ga = GaParams { population: 12, generations: 6, ..Default::default() };

    println!("{:>14} {:>12} {:>12} {:>12}", "bus(bit/cc)", "latency(cc)", "noc(uJ)", "EDP");
    for bus_bw in [16u64, 32, 64, 128, 256, 512] {
        let arch = presets::hetero_quad();
        let n = arch.cores.len();
        // inherit everything but the swept scalar from the preset
        let (_, bus_pj, dram_bw, dram_pj) = arch.topology.as_shared_bus().unwrap();
        let arch =
            arch.with_topology(Topology::shared_bus(n, bus_bw, bus_pj, dram_bw, dram_pj));
        let s = Stream::new(
            models::resnet18(),
            arch,
            StreamOpts { granularity: CnGranularity::Lines(4), ga, ..Default::default() },
        );
        let m = s.run().unwrap().best_edp().unwrap().result.metrics;
        println!(
            "{:>14} {:>12} {:>12.3} {:>12.3e}",
            bus_bw,
            m.latency_cc,
            m.breakdown.noc_pj / 1e6,
            m.edp()
        );
    }

    println!();
    println!("{:>14} {:>12} {:>12} {:>12}", "dram(bit/cc)", "latency(cc)", "dram(uJ)", "EDP");
    for dram_bw in [16u64, 32, 64, 128, 256] {
        let arch = presets::hetero_quad();
        let n = arch.cores.len();
        let (bus_bw, bus_pj, _, dram_pj) = arch.topology.as_shared_bus().unwrap();
        let arch =
            arch.with_topology(Topology::shared_bus(n, bus_bw, bus_pj, dram_bw, dram_pj));
        let s = Stream::new(
            models::resnet18(),
            arch,
            StreamOpts { granularity: CnGranularity::Lines(4), ga, ..Default::default() },
        );
        let m = s.run().unwrap().best_edp().unwrap().result.metrics;
        println!(
            "{:>14} {:>12} {:>12.3} {:>12.3e}",
            dram_bw,
            m.latency_cc,
            m.breakdown.dram_pj / 1e6,
            m.edp()
        );
    }
}
