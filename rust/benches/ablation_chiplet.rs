//! Ablation: chiplet-scale fan-out — the chip-partitioned parallel
//! simulation core (`STREAM_SIM_THREADS`) on the hierarchical packages.
//!
//! Co-schedules one chip-pure ResNet-18 tenant per chip (a burst of
//! two simultaneous requests each — the multi-tenant serving shape the
//! partitioner targets) and sweeps the simulation worker count on each
//! chiplet package.  Results are bit-identical at every thread count
//! (asserted here, pinned exhaustively by
//! `rust/tests/parallel_sim_equivalence.rs`); the interesting number is
//! the scaling curve cores x threads -> co-schedules/sec, written to
//! `BENCH_chiplet.json`.
//!
//! Target: >= 3x single-schedule speedup at 4 threads on `chiplet_8x8`
//! (4 chips -> 4 partitions, so 4x is the ceiling).
//!
//! ```bash
//! cargo bench --bench ablation_chiplet
//! STREAM_BENCH_SCALE=paper cargo bench --bench ablation_chiplet   # + chiplet_16x16
//! ```

use stream::allocator::allocation_from_genome;
use stream::arch::{presets, Accelerator, CoreId};
use stream::scenario::{Arbitration, Arrival, Scenario, ScenarioSim, Tenant};
use stream::util::bench::{bench, paper_scale};
use stream::util::Json;

/// One chip-pure tenant per chip: tenant `c`'s dense layers spread over
/// chip `c`'s dense cores round-robin (chip-major core ids, so gene
/// `c*P + i` is dense core `i` of chip `c`).
fn per_chip_scenario(arch: &Accelerator, dense_per_chip: usize) -> (Scenario, Vec<Vec<u16>>) {
    let n_chips = arch.topology.n_chips();
    let tenants: Vec<Tenant> = (0..n_chips)
        .map(|c| {
            Tenant::new(&format!("chip{c}"), "resnet18", Arrival::Burst { times_cc: vec![0, 0] })
        })
        .collect();
    let scenario = Scenario::new(&format!("per-chip {}", arch.name), tenants);
    let n_genes = stream::workload::models::by_name("resnet18").unwrap().dense_layers().len();
    let genomes = (0..n_chips)
        .map(|c| (0..n_genes).map(|i| (c * dense_per_chip + i % dense_per_chip) as u16).collect())
        .collect();
    (scenario, genomes)
}

fn main() {
    println!("=== ablation: chiplet fan-out (per-chip ResNet-18 burst) ===\n");
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host parallelism: {host_threads}\n");

    let mut packages = vec![(presets::chiplet_4x4(), 4usize), (presets::chiplet_8x8(), 16)];
    if paper_scale() {
        packages.push((presets::chiplet_16x16(), 16));
    }

    let mut j = std::collections::BTreeMap::new();
    j.insert("status".to_string(), Json::Str("measured".to_string()));
    j.insert("host_threads".to_string(), Json::Num(host_threads as f64));
    let mut speedup_8x8_t4 = 0.0f64;

    for (arch, dense_per_chip) in &packages {
        let (scenario, genomes) = per_chip_scenario(arch, *dense_per_chip);
        let sim = ScenarioSim::new(&scenario, arch).expect("scenario builds");
        let allocs: Vec<Vec<CoreId>> = sim
            .builds()
            .iter()
            .zip(&genomes)
            .map(|(b, g)| allocation_from_genome(&b.workload, arch, g))
            .collect();
        let runner = sim.runner();
        let n_chips = arch.topology.n_chips();
        println!(
            "--- {} ({} cores, {n_chips} chips, {} requests) ---",
            arch.name,
            arch.cores.len(),
            scenario.n_requests()
        );

        let seq = runner.run_with_threads(&allocs, Arbitration::Fifo, 1);
        let mut seq_ms = 0.0f64;
        for threads in [1usize, 2, 4, 8] {
            let r = runner.run_with_threads(&allocs, Arbitration::Fifo, threads);
            assert_eq!(
                r.metrics.latency_cc, seq.metrics.latency_cc,
                "x{threads}: latency must be bit-identical"
            );
            assert_eq!(
                r.metrics.energy_pj.to_bits(),
                seq.metrics.energy_pj.to_bits(),
                "x{threads}: energy must be bit-identical"
            );
            if threads > 1 {
                assert_eq!(r.partitions, n_chips, "x{threads}: the parallel core must engage");
            }

            let s = bench(&format!("{} x{threads}", arch.name), 1, 7, || {
                std::hint::black_box(runner.run_with_threads(&allocs, Arbitration::Fifo, threads));
            });
            if threads == 1 {
                seq_ms = s.median_ms;
            }
            let speedup = seq_ms / s.median_ms;
            println!("{s}  | {:>6.1} sched/s | speedup {:.2}x", 1e3 / s.median_ms, speedup);
            let key = format!("{}_t{threads}_ms", arch.name);
            j.insert(key, Json::Num(s.median_ms));
            if arch.name == "chiplet_8x8" && threads == 4 {
                speedup_8x8_t4 = speedup;
            }
        }
        println!();
    }

    println!("chiplet_8x8 @ 4 threads: {speedup_8x8_t4:.2}x (target >= 3x, ceiling 4x)");
    j.insert("speedup_8x8_t4".to_string(), Json::Num(speedup_8x8_t4));
    if host_threads >= 4 {
        assert!(
            speedup_8x8_t4 >= 3.0,
            "chiplet_8x8 must reach >= 3x at 4 simulation threads, got {speedup_8x8_t4:.2}x"
        );
    } else {
        println!("(host has < 4 threads — skipping the 3x assertion)");
    }

    let out = Json::Obj(j).to_string_compact() + "\n";
    match std::fs::write("BENCH_chiplet.json", &out) {
        Ok(()) => println!("\nwrote BENCH_chiplet.json"),
        Err(e) => println!("\ncould not write BENCH_chiplet.json: {e}"),
    }
}
