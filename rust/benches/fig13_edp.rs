//! Bench: Fig. 13 — best EDP over the 5 DNNs x 7 iso-area architectures
//! under layer-by-layer vs fine-grained layer-fused scheduling, with the
//! per-architecture geometric-mean EDP reduction the paper headlines
//! (single-core 2.4-4.7x, homogeneous 10-19x, heterogeneous 30.4x).
//!
//! ```bash
//! cargo bench --bench fig13_edp                    # reduced GA budget
//! STREAM_BENCH_SCALE=paper cargo bench --bench fig13_edp
//! ```
//!
//! The sweep result is cached under target/stream-bench/ and reused by
//! the Fig. 14 / Fig. 15 benches.

use stream::allocator::GaParams;
use stream::experiments::fig13::{default_cache_path, format_fig13, sweep_cached};
use stream::experiments::SweepConfig;
use stream::util::bench::paper_scale;

fn main() {
    let ga = if paper_scale() {
        GaParams { population: 32, generations: 24, ..Default::default() }
    } else {
        GaParams { population: 12, generations: 6, ..Default::default() }
    };
    let cfg = SweepConfig { ga, ..Default::default() };
    println!(
        "=== Fig. 13: EDP, {} workloads x {} archs (GA pop {}, {} gens) ===\n",
        cfg.workloads.len(),
        cfg.archs.len(),
        ga.population,
        ga.generations
    );
    let t = std::time::Instant::now();
    let cells = sweep_cached(&cfg, &default_cache_path());
    println!("{}", format_fig13(&cells));
    println!("paper reference geomeans: SC 2.4-4.7x, Hom 10-19x, Hetero 30.4x");
    println!("\nsweep: {:.1} s (cached for fig14/fig15)", t.elapsed().as_secs_f64());
}
