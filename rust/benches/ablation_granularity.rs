//! Ablation: CN granularity impact (paper Fig. 4's design axis).
//!
//! Sweeps lines-per-CN over {1, 2, 4, 8, 16, layer-by-layer} for three
//! representative networks on the heterogeneous quad-core, showing the
//! latency / energy / peak-memory trade-off that motivates Stream's
//! granularity-aware Step 1: fine granularity minimizes memory but pays
//! scheduling and weight-locality overheads; coarse granularity loses
//! parallelism and floods the activation memory.
//!
//! ```bash
//! cargo bench --bench ablation_granularity
//! ```

use stream::allocator::GaParams;
use stream::arch::presets;
use stream::cn::CnGranularity;
use stream::pipeline::{Stream, StreamOpts};
use stream::workload::models;

fn main() {
    println!("=== ablation: CN granularity (MC:Hetero, GA pop 12 x 6) ===\n");
    let ga = GaParams { population: 12, generations: 6, ..Default::default() };
    println!(
        "{:<12} {:>14} {:>12} {:>12} {:>12} {:>10}",
        "workload", "granularity", "latency(cc)", "energy(uJ)", "EDP", "peak(KB)"
    );
    for net in ["resnet18", "squeezenet", "fsrcnn"] {
        let grans: Vec<(String, CnGranularity)> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&l| (format!("Lines({l})"), CnGranularity::Lines(l)))
            .chain(std::iter::once(("layer-by-layer".to_string(), CnGranularity::LayerByLayer)))
            .collect();
        for (name, gran) in grans {
            let s = Stream::new(
                models::by_name(net).unwrap(),
                presets::hetero_quad(),
                StreamOpts { granularity: gran, ga, ..Default::default() },
            );
            let r = s.run().unwrap();
            let m = r.best_edp().unwrap().result.metrics;
            println!(
                "{:<12} {:>14} {:>12} {:>12.2} {:>12.3e} {:>10.1}",
                net,
                name,
                m.latency_cc,
                m.energy_pj / 1e6,
                m.edp(),
                m.peak_mem_bytes / 1024.0
            );
        }
        println!();
    }
}
