//! Ablation: CN granularity impact (paper Fig. 4's design axis).
//!
//! Sweeps lines-per-CN over {1, 2, 4, 8, 16, layer-by-layer} for three
//! representative networks on the heterogeneous quad-core, showing the
//! latency / energy / peak-memory trade-off that motivates Stream's
//! granularity-aware Step 1: fine granularity minimizes memory but pays
//! scheduling and weight-locality overheads; coarse granularity loses
//! parallelism and floods the activation memory.
//!
//! The second section sweeps a ViT-Base@384-class encoder stack fused
//! vs layer-by-layer — the attention frontier, where a single MLP
//! activation (1.18 MB) overflows the pooled SRAM — in two regimes:
//! the stock 120 KB weight SRAMs (fine granularity pays weight-refetch
//! thrash when projections time-share a core) and a weights-resident
//! variant (32 MB weight SRAMs — the whole 14.2 MB weight set stays
//! on-chip) that isolates fusion's activation-spill savings, where the
//! fused stack moves strictly less DRAM traffic.
//!
//! ```bash
//! cargo bench --bench ablation_granularity
//! ```

use stream::allocator::GaParams;
use stream::arch::presets;
use stream::cn::CnGranularity;
use stream::pipeline::{Stream, StreamOpts};
use stream::workload::models;

fn main() {
    println!("=== ablation: CN granularity (MC:Hetero, GA pop 12 x 6) ===\n");
    let ga = GaParams { population: 12, generations: 6, ..Default::default() };
    println!(
        "{:<12} {:>14} {:>12} {:>12} {:>12} {:>10}",
        "workload", "granularity", "latency(cc)", "energy(uJ)", "EDP", "peak(KB)"
    );
    for net in ["resnet18", "squeezenet", "fsrcnn"] {
        let grans: Vec<(String, CnGranularity)> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&l| (format!("Lines({l})"), CnGranularity::Lines(l)))
            .chain(std::iter::once(("layer-by-layer".to_string(), CnGranularity::LayerByLayer)))
            .collect();
        for (name, gran) in grans {
            let s = Stream::new(
                models::by_name(net).unwrap(),
                presets::hetero_quad(),
                StreamOpts { granularity: gran, ga, ..Default::default() },
            );
            let r = s.run().unwrap();
            let m = r.best_edp().unwrap().result.metrics;
            println!(
                "{:<12} {:>14} {:>12} {:>12.2} {:>12.3e} {:>10.1}",
                net,
                name,
                m.latency_cc,
                m.energy_pj / 1e6,
                m.edp(),
                m.peak_mem_bytes / 1024.0
            );
        }
        println!();
    }

    // --- transformer frontier: fused vs layer-by-layer ViT stack -------
    println!("=== ablation: ViT-Base@384 stack, fused vs layer-by-layer ===\n");
    let vit = models::vit_stack("vit-base-384-seg", 384, 768, 3072, 2);
    let grans: Vec<(String, CnGranularity)> = [4usize, 16, 64]
        .iter()
        .map(|&l| (format!("Lines({l})"), CnGranularity::Lines(l)))
        .chain(std::iter::once(("layer-by-layer".to_string(), CnGranularity::LayerByLayer)))
        .collect();
    println!(
        "{:<18} {:>14} {:>12} {:>10} {:>10} {:>10}",
        "regime", "granularity", "latency(cc)", "DRAM(uJ)", "NoC(uJ)", "peak(KB)"
    );
    let mut fused_dram = f64::NAN;
    let mut lbl_dram = f64::NAN;
    for (regime, wgt_mem) in [("stock-120KB-wgt", None), ("weights-resident", Some(32 << 20))] {
        for (name, gran) in &grans {
            let mut arch = presets::hetero_quad();
            if let Some(wm) = wgt_mem {
                for c in arch.cores.iter_mut().filter(|c| !c.is_simd()) {
                    c.wgt_mem_bytes = wm;
                }
            }
            let s = Stream::new(
                vit.clone(),
                arch,
                StreamOpts { granularity: *gran, ga, ..Default::default() },
            );
            let r = s.run().unwrap();
            let m = r.best_edp().unwrap().result.metrics;
            println!(
                "{:<18} {:>14} {:>12} {:>10.2} {:>10.2} {:>10.1}",
                regime,
                name,
                m.latency_cc,
                m.breakdown.dram_pj / 1e6,
                m.breakdown.noc_pj / 1e6,
                m.peak_mem_bytes / 1024.0
            );
            if regime == "weights-resident" {
                match gran {
                    CnGranularity::Lines(4) => fused_dram = m.breakdown.dram_pj,
                    CnGranularity::LayerByLayer => lbl_dram = m.breakdown.dram_pj,
                    _ => {}
                }
            }
        }
        println!();
    }
    println!(
        "weights-resident fused (4 lines) DRAM {:.2} uJ vs layer-by-layer {:.2} uJ ({:+.0}%)",
        fused_dram / 1e6,
        lbl_dram / 1e6,
        100.0 * (fused_dram - lbl_dram) / lbl_dram
    );
    assert!(
        fused_dram < lbl_dram,
        "fused ViT stack must move less DRAM traffic than layer-by-layer \
         in the weights-resident regime"
    );
}
