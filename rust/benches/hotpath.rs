//! Bench: hot-path microbenchmarks for the §Perf pass — the inner loops
//! the GA hammers (dependency generation, cost-model build, one
//! scheduler run, one GA generation) on ResNet-18 / Hetero.
//!
//! ```bash
//! cargo bench --bench hotpath
//! ```

use stream::allocator::{allocation_from_genome, Ga, GaParams, Objective};
use stream::arch::presets;
use stream::cn::{CnGranularity, CnSet};
use stream::depgraph::generate;
use stream::mapping::CostModel;
use stream::scheduler::{SchedulePriority, Scheduler};
use stream::util::bench::bench;
use stream::workload::models::resnet18;

fn main() {
    println!("=== hot-path microbenchmarks (ResNet-18 on MC:Hetero) ===\n");
    let w = resnet18();
    let arch = presets::hetero_quad();
    let gran = CnGranularity::Lines(4);

    let s = bench("cn_split", 2, 10, || {
        std::hint::black_box(CnSet::build(&w, gran));
    });
    println!("{s}");

    let s = bench("depgraph_generate (rtree)", 2, 10, || {
        std::hint::black_box(generate(&w, CnSet::build(&w, gran)));
    });
    println!("{s}");

    let cns = CnSet::build(&w, gran);
    let s = bench("cost_model_build", 2, 10, || {
        std::hint::black_box(CostModel::build(&w, &cns, &arch));
    });
    println!("{s}");

    let costs = CostModel::build(&w, &cns, &arch);
    let graph = generate(&w, CnSet::build(&w, gran));
    println!(
        "graph: {} CNs, {} edges, cost table {} entries",
        graph.len(),
        graph.edges.len(),
        costs.len()
    );
    let sched = Scheduler::new(&w, &graph, &costs, &arch);
    let genome: Vec<u16> = (0..w.dense_layers().len()).map(|i| (i % 4) as u16).collect();
    let alloc = allocation_from_genome(&w, &arch, &genome);

    let s = bench("scheduler_run (latency prio)", 3, 20, || {
        std::hint::black_box(sched.run(&alloc, SchedulePriority::Latency));
    });
    println!("{s}");
    let heap_lat = s.median_ms;

    let s = bench("scheduler_run (memory prio)", 3, 20, || {
        std::hint::black_box(sched.run(&alloc, SchedulePriority::Memory));
    });
    println!("{s}");

    // the seed's O(n)-scan candidate selection, same results bit-for-bit
    let s = bench("scheduler_run linear-scan baseline", 3, 20, || {
        std::hint::black_box(sched.run_reference(&alloc, SchedulePriority::Latency));
    });
    println!("{s}");
    let linear_ms = s.median_ms;
    println!("  -> heap pool speedup vs linear scan: {:.2}x\n", linear_ms / heap_lat);

    // --- flight-recorder overhead gate ---
    // The recorder (src/obs) is always compiled in; disabled, its only
    // hot-path cost is one relaxed atomic load per count()/span site
    // (the pool push/pop counters are the only per-CN sites).  Measure
    // that per-site cost directly, bound the per-run site volume, and
    // require the product to stay under 2% of a scheduler run — an
    // analytical gate that is robust to machine noise.  CI exports
    // OBS_GATE=1 to make the bound fatal; locally it just prints.
    let (obs_ns_per_site, obs_enabled_x);
    {
        assert!(!stream::obs::enabled(), "recorder must start disabled");
        let loops: u64 = 10_000_000;
        let t = std::time::Instant::now();
        for _ in 0..loops {
            stream::obs::count(std::hint::black_box(stream::obs::Counter::PoolPushes), 1);
        }
        obs_ns_per_site = t.elapsed().as_secs_f64() * 1e9 / loops as f64;
        // per run: one push + one pop per CN, plus the run-constant
        // sites (simulate span, finish() aggregation) — bounded by 32
        let sites_per_run = 2 * graph.len() + 32;
        let overhead_ms = obs_ns_per_site * sites_per_run as f64 / 1e6;
        let pct = 100.0 * overhead_ms / heap_lat;
        println!(
            "obs disabled: {obs_ns_per_site:.2} ns/site x {sites_per_run} sites/run \
             -> {pct:.3}% of scheduler_run"
        );
        if std::env::var("OBS_GATE").as_deref() == Ok("1") {
            assert!(pct < 2.0, "disabled recorder exceeds the 2% hot-path budget ({pct:.3}%)");
        }

        // enabled cost, for the record (spans + counters + report)
        stream::obs::set_enabled(true);
        let s = bench("scheduler_run (recorder enabled)", 3, 20, || {
            std::hint::black_box(sched.run(&alloc, SchedulePriority::Latency));
        });
        stream::obs::set_enabled(false);
        stream::obs::reset();
        println!("{s}");
        obs_enabled_x = s.median_ms / heap_lat;
        println!("  -> recorder-enabled overhead: {obs_enabled_x:.2}x\n");
    }

    // heavyweight case: FSRCNN at line granularity (4480 CNs)
    {
        use stream::workload::models::fsrcnn;
        let w = fsrcnn(560, 960);
        let gran = CnGranularity::Lines(1);
        let cns = CnSet::build(&w, gran);
        let costs = CostModel::build(&w, &cns, &arch);
        let graph = generate(&w, CnSet::build(&w, gran));
        let sched = Scheduler::new(&w, &graph, &costs, &arch);
        let genome: Vec<u16> = (0..w.dense_layers().len()).map(|i| (i % 4) as u16).collect();
        let alloc = allocation_from_genome(&w, &arch, &genome);
        let s = bench("scheduler_run fsrcnn lines1 (4480 CNs)", 2, 10, || {
            std::hint::black_box(sched.run(&alloc, SchedulePriority::Latency));
        });
        println!("{s}");
    }

    let s = bench("ga_8pop_2gen", 1, 5, || {
        let mut ga = Ga::new(
            &w,
            &arch,
            &sched,
            SchedulePriority::Latency,
            Objective::Edp,
            GaParams { population: 8, generations: 2, ..Default::default() },
        );
        std::hint::black_box(ga.run());
    });
    println!("{s}");

    // --- the tentpole: parallel + memoized GA fitness evaluation ---
    // serial (1 thread, cold cache) vs parallel (all cores) vs a warm
    // shared cache; results are bit-identical in all three cases.
    let ga_params = GaParams { population: 24, generations: 6, ..Default::default() };
    let run_edp = |threads: usize, cache: Option<&stream::cost::ScheduleCache>| {
        let mut ga = Ga::new(
            &w,
            &arch,
            &sched,
            SchedulePriority::Latency,
            Objective::Edp,
            GaParams { threads, ..ga_params },
        );
        if let Some(c) = cache {
            ga = ga.with_cache(c);
        }
        ga.run()[0].metrics.edp()
    };

    let s = bench("ga_24pop_6gen serial (1 thread)", 1, 3, || {
        std::hint::black_box(run_edp(1, None));
    });
    println!("{s}");
    let serial_ms = s.median_ms;

    let threads = stream::util::thread_count(0);
    let s = bench("ga_24pop_6gen parallel (auto)", 1, 3, || {
        std::hint::black_box(run_edp(0, None));
    });
    println!("{s}");
    let parallel_ms = s.median_ms;
    println!(
        "  -> parallel fitness speedup on {threads} threads: {:.2}x",
        serial_ms / parallel_ms
    );

    let cache = stream::cost::ScheduleCache::new();
    let cold = run_edp(0, Some(&cache));
    let s = bench("ga_24pop_6gen warm shared cache", 1, 3, || {
        std::hint::black_box(run_edp(0, Some(&cache)));
    });
    println!("{s}");
    println!("  -> memoized rerun speedup vs serial: {:.2}x", serial_ms / s.median_ms);
    let (hits, misses, entries) = cache.stats();
    println!("  -> cache: {hits} hits / {misses} misses / {entries} entries");

    let serial = run_edp(1, None);
    assert_eq!(serial.to_bits(), cold.to_bits(), "serial vs parallel EDP must be bit-equal");
    println!("  -> serial / parallel / memoized EDP bit-identical OK");

    // --- incremental delta evaluation vs full re-simulation ---
    // same seed, same trajectory: the delta path replays each child
    // genome from its parent's cached segments instead of simulating
    // from scratch, so the distinct-genome count is identical and the
    // speedup is pure evals/sec.
    let run_timed = |incremental: bool, lb_prune: bool| {
        let mut ga = Ga::new(
            &w,
            &arch,
            &sched,
            SchedulePriority::Latency,
            Objective::Edp,
            GaParams { incremental, lb_prune, ..ga_params },
        );
        let t = std::time::Instant::now();
        let front = ga.run();
        let secs = t.elapsed().as_secs_f64();
        let (_, evals, _) = ga.cache().stats();
        (front[0].metrics.edp(), secs, evals, ga.pruned_count())
    };
    let (edp_full, full_s, evals_full, _) = run_timed(false, false);
    let (edp_inc, inc_s, evals_inc, _) = run_timed(true, false);
    assert_eq!(edp_full.to_bits(), edp_inc.to_bits(), "delta evaluation must not change EDP");
    assert_eq!(evals_full, evals_inc, "delta evaluation must not change the eval count");
    let (eps_full, eps_inc) = (evals_full as f64 / full_s, evals_inc as f64 / inc_s);
    println!("\nga_24pop_6gen full re-simulation: {full_s:.2} s ({eps_full:.1} evals/s)");
    println!("ga_24pop_6gen delta evaluation:   {inc_s:.2} s ({eps_inc:.1} evals/s)");
    println!("  -> incremental speedup: {:.2}x (bit-identical front)", full_s / inc_s);
    let (_, prune_s, evals_prune, pruned) = run_timed(true, true);
    println!(
        "ga_24pop_6gen delta + lb-prune:   {prune_s:.2} s \
         ({evals_prune} simulated, {pruned} pruned by floors)"
    );

    // machine-readable summary for the committed BENCH_hotpath.json
    let mut j = std::collections::BTreeMap::new();
    let num = stream::util::Json::Num;
    j.insert("status".to_string(), stream::util::Json::Str("measured".to_string()));
    j.insert("threads".to_string(), num(threads as f64));
    j.insert("heap_vs_linear_speedup".to_string(), num(linear_ms / heap_lat));
    j.insert("parallel_speedup".to_string(), num(serial_ms / parallel_ms));
    j.insert("full_evals_per_sec".to_string(), num(eps_full));
    j.insert("incremental_evals_per_sec".to_string(), num(eps_inc));
    j.insert("incremental_speedup".to_string(), num(full_s / inc_s));
    j.insert("lb_prune_seconds".to_string(), num(prune_s));
    j.insert("lb_pruned_genomes".to_string(), num(pruned as f64));
    j.insert("obs_disabled_ns_per_site".to_string(), num(obs_ns_per_site));
    j.insert("obs_enabled_overhead_x".to_string(), num(obs_enabled_x));
    let out = stream::util::Json::Obj(j).to_string_compact() + "\n";
    match std::fs::write("BENCH_hotpath.json", &out) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json"),
        Err(e) => println!("\ncould not write BENCH_hotpath.json: {e}"),
    }
}
