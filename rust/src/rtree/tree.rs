//! STR-bulk-loaded R-tree with arena storage.

use super::rect::{Rect, DIMS};

/// Maximum children per internal node / entries per leaf.
const NODE_CAP: usize = 16;

#[derive(Debug)]
struct Node {
    bbox: Rect,
    /// Child node indices (internal) — empty for leaves.
    children: Vec<u32>,
    /// (rect, payload) entries — empty for internal nodes.
    entries: Vec<(Rect, u32)>,
}

/// An immutable R-tree over `(Rect, payload: u32)` entries.
///
/// Built once per producer–consumer layer pair by STR bulk loading,
/// then queried once per producer CN — the access pattern of paper
/// Step 2.
#[derive(Debug)]
pub struct RTree {
    nodes: Vec<Node>,
    root: Option<u32>,
    len: usize,
}

impl RTree {
    /// Bulk-load with Sort-Tile-Recursive packing.
    pub fn bulk_load(mut items: Vec<(Rect, u32)>) -> Self {
        let len = items.len();
        if items.is_empty() {
            return RTree { nodes: vec![], root: None, len: 0 };
        }
        let mut nodes = Vec::with_capacity(2 * len / NODE_CAP + 2);

        // STR: recursively sort by successive axes' centers and tile.
        str_sort(&mut items, 0);

        // leaf level
        let mut level: Vec<u32> = items
            .chunks(NODE_CAP)
            .map(|chunk| {
                let bbox = chunk
                    .iter()
                    .map(|(r, _)| *r)
                    .reduce(|a, b| a.union(&b))
                    .unwrap();
                nodes.push(Node { bbox, children: vec![], entries: chunk.to_vec() });
                (nodes.len() - 1) as u32
            })
            .collect();

        // internal levels
        while level.len() > 1 {
            // order parent groups by bbox center for locality
            let mut keyed: Vec<(u32, Rect)> =
                level.iter().map(|&i| (i, nodes[i as usize].bbox)).collect();
            keyed.sort_by_key(|(_, r)| (r.center2(1), r.center2(2), r.center2(0)));
            level = keyed
                .chunks(NODE_CAP)
                .map(|chunk| {
                    let bbox = chunk
                        .iter()
                        .map(|(_, r)| *r)
                        .reduce(|a, b| a.union(&b))
                        .unwrap();
                    let children = chunk.iter().map(|(i, _)| *i).collect();
                    nodes.push(Node { bbox, children, entries: vec![] });
                    (nodes.len() - 1) as u32
                })
                .collect();
        }

        let root = Some(level[0]);
        RTree { nodes, root, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Visit every payload whose rect intersects `query`.
    pub fn query<F: FnMut(&Rect, u32)>(&self, query: &Rect, mut f: F) {
        if let Some(root) = self.root {
            self.query_rec(root, query, &mut f);
        }
    }

    fn query_rec<F: FnMut(&Rect, u32)>(&self, node: u32, query: &Rect, f: &mut F) {
        let n = &self.nodes[node as usize];
        if !n.bbox.intersects(query) {
            return;
        }
        for (r, p) in &n.entries {
            if r.intersects(query) {
                f(r, *p);
            }
        }
        for &c in &n.children {
            self.query_rec(c, query, f);
        }
    }

    /// Collect intersecting payloads into a vec (convenience).
    pub fn query_vec(&self, query: &Rect) -> Vec<u32> {
        let mut out = Vec::new();
        self.query(query, |_, p| out.push(p));
        out
    }

    /// Tree height (for tests / diagnostics).
    pub fn height(&self) -> usize {
        let mut h = 0;
        let mut cur = self.root;
        while let Some(i) = cur {
            h += 1;
            cur = self.nodes[i as usize].children.first().copied();
        }
        h
    }
}

/// Recursive STR: sort by axis `d`'s center, split into vertical slabs,
/// recurse into the next axis within each slab.
fn str_sort(items: &mut [(Rect, u32)], d: usize) {
    if d >= DIMS - 1 || items.len() <= NODE_CAP {
        items.sort_by_key(|(r, _)| r.center2(d.min(DIMS - 1)));
        return;
    }
    items.sort_by_key(|(r, _)| r.center2(d));
    // number of slabs so that each slab holds ~sqrt of the leaves
    let n_leaves = items.len().div_ceil(NODE_CAP);
    let n_slabs = (n_leaves as f64).powf(1.0 / (DIMS - d) as f64).ceil() as usize;
    let slab = items.len().div_ceil(n_slabs.max(1));
    for chunk in items.chunks_mut(slab.max(1)) {
        str_sort(chunk, d + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force oracle.
    fn brute(items: &[(Rect, u32)], q: &Rect) -> Vec<u32> {
        let mut v: Vec<u32> =
            items.iter().filter(|(r, _)| r.intersects(q)).map(|(_, p)| *p).collect();
        v.sort_unstable();
        v
    }

    fn grid_items(n: i64, size: i64) -> Vec<(Rect, u32)> {
        let mut items = Vec::new();
        let mut id = 0;
        for y in 0..n {
            for x in 0..n {
                items.push((
                    Rect::chw(0..1, y * size..(y + 1) * size, x * size..(x + 1) * size),
                    id,
                ));
                id += 1;
            }
        }
        items
    }

    #[test]
    fn empty_tree() {
        let t = RTree::bulk_load(vec![]);
        assert!(t.is_empty());
        assert_eq!(t.query_vec(&Rect::chw(0..10, 0..10, 0..10)), vec![]);
    }

    #[test]
    fn single_item() {
        let t = RTree::bulk_load(vec![(Rect::chw(0..2, 0..2, 0..2), 7)]);
        assert_eq!(t.query_vec(&Rect::chw(1..3, 1..3, 1..3)), vec![7]);
        assert_eq!(t.query_vec(&Rect::chw(2..3, 0..2, 0..2)), vec![]);
    }

    #[test]
    fn grid_queries_match_brute_force() {
        let items = grid_items(16, 4); // 256 tiles
        let t = RTree::bulk_load(items.clone());
        assert_eq!(t.len(), 256);
        for q in [
            Rect::chw(0..1, 0..4, 0..4),
            Rect::chw(0..1, 3..9, 3..9),
            Rect::chw(0..1, 0..64, 30..34),
            Rect::chw(0..1, 63..64, 63..64),
            Rect::chw(0..1, 100..200, 100..200), // off-grid
        ] {
            let mut got = t.query_vec(&q);
            got.sort_unstable();
            assert_eq!(got, brute(&items, &q), "query {q:?}");
        }
    }

    #[test]
    fn random_rects_match_brute_force() {
        // deterministic xorshift so the test is reproducible
        let mut s: u64 = 0x9E3779B97F4A7C15;
        let mut rnd = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        };
        let mut items = Vec::new();
        for i in 0..500u32 {
            let c0 = (rnd() % 8) as i64;
            let y0 = (rnd() % 100) as i64;
            let x0 = (rnd() % 100) as i64;
            items.push((
                Rect::chw(
                    c0..c0 + 1 + (rnd() % 4) as i64,
                    y0..y0 + 1 + (rnd() % 20) as i64,
                    x0..x0 + 1 + (rnd() % 20) as i64,
                ),
                i,
            ));
        }
        let t = RTree::bulk_load(items.clone());
        for _ in 0..50 {
            let y0 = (rnd() % 110) as i64;
            let x0 = (rnd() % 110) as i64;
            let q = Rect::chw(0..10, y0..y0 + 15, x0..x0 + 15);
            let mut got = t.query_vec(&q);
            got.sort_unstable();
            assert_eq!(got, brute(&items, &q));
        }
    }

    #[test]
    fn height_is_logarithmic() {
        let t = RTree::bulk_load(grid_items(32, 2)); // 1024 entries
        assert!(t.height() <= 4, "height {}", t.height());
    }

    #[test]
    fn large_tree_point_queries() {
        let items = grid_items(64, 1); // 4096 unit tiles
        let t = RTree::bulk_load(items.clone());
        // each unit query hits exactly one tile
        for (r, p) in items.iter().step_by(97) {
            assert_eq!(t.query_vec(r), vec![*p]);
        }
    }
}
