//! Axis-aligned integer hyper-rectangles (half-open ranges per axis).

/// Maximum dimensionality of CN loop-range rectangles: (channel, y, x).
/// Unused axes are stored as the degenerate full range `[0, 1)`.
pub const DIMS: usize = 3;

/// An axis-aligned box of half-open integer ranges `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rect {
    pub lo: [i64; DIMS],
    pub hi: [i64; DIMS],
}

impl Rect {
    /// Build from per-axis `[lo, hi)` ranges.
    pub fn new(lo: [i64; DIMS], hi: [i64; DIMS]) -> Self {
        debug_assert!(lo.iter().zip(&hi).all(|(a, b)| a <= b), "{lo:?}..{hi:?}");
        Rect { lo, hi }
    }

    /// Rectangle over (channels, rows, cols).
    pub fn chw(c: std::ops::Range<i64>, y: std::ops::Range<i64>, x: std::ops::Range<i64>) -> Self {
        Rect::new([c.start, y.start, x.start], [c.end, y.end, x.end])
    }

    /// The empty rectangle.
    pub fn empty() -> Self {
        Rect { lo: [0; DIMS], hi: [0; DIMS] }
    }

    pub fn is_empty(&self) -> bool {
        self.lo.iter().zip(&self.hi).any(|(a, b)| a >= b)
    }

    /// Do two boxes share any volume? (half-open semantics)
    #[inline]
    pub fn intersects(&self, other: &Rect) -> bool {
        for d in 0..DIMS {
            if self.lo[d] >= other.hi[d] || other.lo[d] >= self.hi[d] {
                return false;
            }
        }
        true
    }

    /// Volume of the intersection (0 if disjoint).
    #[inline]
    pub fn intersection_volume(&self, other: &Rect) -> u64 {
        let mut v: u64 = 1;
        for d in 0..DIMS {
            let lo = self.lo[d].max(other.lo[d]);
            let hi = self.hi[d].min(other.hi[d]);
            if hi <= lo {
                return 0;
            }
            v *= (hi - lo) as u64;
        }
        v
    }

    /// Total volume.
    pub fn volume(&self) -> u64 {
        let mut v: u64 = 1;
        for d in 0..DIMS {
            if self.hi[d] <= self.lo[d] {
                return 0;
            }
            v *= (self.hi[d] - self.lo[d]) as u64;
        }
        v
    }

    /// Smallest box containing both.
    pub fn union(&self, other: &Rect) -> Rect {
        let mut lo = [0i64; DIMS];
        let mut hi = [0i64; DIMS];
        for d in 0..DIMS {
            lo[d] = self.lo[d].min(other.lo[d]);
            hi[d] = self.hi[d].max(other.hi[d]);
        }
        Rect { lo, hi }
    }

    /// Center coordinate along one axis (x2 to stay integral).
    #[inline]
    pub fn center2(&self, d: usize) -> i64 {
        self.lo[d] + self.hi[d]
    }

    /// Clip to a bounding box; may produce an empty rect.
    pub fn clip(&self, bounds: &Rect) -> Rect {
        let mut lo = [0i64; DIMS];
        let mut hi = [0i64; DIMS];
        for d in 0..DIMS {
            lo[d] = self.lo[d].max(bounds.lo[d]);
            hi[d] = self.hi[d].min(bounds.hi[d]).max(lo[d]);
        }
        Rect { lo, hi }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intersection_basics() {
        let a = Rect::chw(0..4, 0..4, 0..4);
        let b = Rect::chw(2..6, 2..6, 2..6);
        assert!(a.intersects(&b));
        assert_eq!(a.intersection_volume(&b), 8);
        // touching edges (half-open) do not intersect
        let c = Rect::chw(4..8, 0..4, 0..4);
        assert!(!a.intersects(&c));
        assert_eq!(a.intersection_volume(&c), 0);
    }

    #[test]
    fn volume_and_union() {
        let a = Rect::chw(0..2, 0..3, 0..5);
        assert_eq!(a.volume(), 30);
        let b = Rect::chw(1..4, 1..2, 0..1);
        let u = a.union(&b);
        assert_eq!(u, Rect::chw(0..4, 0..3, 0..5));
    }

    #[test]
    fn empty_rect() {
        assert!(Rect::empty().is_empty());
        assert_eq!(Rect::empty().volume(), 0);
        let a = Rect::chw(0..1, 5..5, 0..1);
        assert!(a.is_empty());
    }

    #[test]
    fn clip() {
        let a = Rect::chw(-2..10, -1..5, 0..3);
        let b = a.clip(&Rect::chw(0..4, 0..4, 0..4));
        assert_eq!(b, Rect::chw(0..4, 0..4, 0..3));
    }

    #[test]
    fn self_intersection_is_volume() {
        let a = Rect::chw(3..7, 1..9, 2..4);
        assert_eq!(a.intersection_volume(&a), a.volume());
    }
}
