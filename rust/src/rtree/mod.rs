//! A from-scratch N-dimensional R-tree (Guttman 1984) for the fast
//! inter-layer CN dependency generation of paper Step 2 / Fig. 6.
//!
//! CN loop ranges are axis-aligned integer hyper-rectangles in the
//! producer's output-tensor coordinate space (channel, y, x).  The
//! consumer CNs' required input ranges are bulk-loaded with the
//! Sort-Tile-Recursive (STR) packing algorithm, and each producer CN's
//! generated output range is queried for intersection.  Compared with
//! the quadratic pairwise check this is the paper's 10^3x speedup
//! (`benches/rtree_speedup.rs` reproduces the claim).
//!
//! # Examples
//!
//! ```
//! use stream::rtree::{Rect, RTree};
//!
//! // three consumer input windows (channel, y, x), bulk-loaded by id
//! let tree = RTree::bulk_load(vec![
//!     (Rect::chw(0..16, 0..4, 0..8), 0),
//!     (Rect::chw(0..16, 2..6, 0..8), 1),
//!     (Rect::chw(0..16, 6..10, 0..8), 2),
//! ]);
//!
//! // which windows overlap a producer's output rows 3..5?
//! let mut hits = tree.query_vec(&Rect::chw(0..16, 3..5, 0..8));
//! hits.sort();
//! assert_eq!(hits, vec![0, 1]);
//! ```

mod rect;
mod tree;

pub use rect::Rect;
pub use tree::RTree;
