//! A from-scratch N-dimensional R-tree (Guttman 1984) for the fast
//! inter-layer CN dependency generation of paper Step 2 / Fig. 6.
//!
//! CN loop ranges are axis-aligned integer hyper-rectangles in the
//! producer's output-tensor coordinate space (channel, y, x).  The
//! consumer CNs' required input ranges are bulk-loaded with the
//! Sort-Tile-Recursive (STR) packing algorithm, and each producer CN's
//! generated output range is queried for intersection.  Compared with
//! the quadratic pairwise check this is the paper's 10^3x speedup
//! (`benches/rtree_speedup.rs` reproduces the claim).

mod rect;
mod tree;

pub use rect::Rect;
pub use tree::RTree;
