//! Table I — validation against the three measured SotA architectures.
//!
//! The paper validates Stream's modeled latency and peak memory against
//! silicon measurements of DepFiN (FSRCNN @ 560x960), Jia et al.'s 4x4
//! AiMC array (ResNet-50 segment) and DIANA (ResNet-18 first segment).
//! We rebuild the three architecture models and workloads, run the
//! pipeline with the *fixed* allocation each chip used and the
//! latency-prioritized scheduler, and report modeled vs the paper's
//! published measured numbers.

use crate::arch::{presets, Accelerator, CoreId};
use crate::cn::CnGranularity;
use crate::pipeline::{SchedulePriority, Stream, StreamOpts};
use crate::workload::models;
use crate::workload::{OpType, WorkloadGraph};

/// One validation row (paper Table I).
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub arch: String,
    pub workload: String,
    pub measured_cc: f64,
    pub stream_cc: f64,
    /// None when the paper reports no measurement (AiMC memory).
    pub measured_kb: Option<f64>,
    pub stream_kb: f64,
    pub runtime_ms: f64,
}

impl Table1Row {
    /// Accuracy as the paper computes it: 100 x (1 - |err|/measured).
    pub fn latency_accuracy(&self) -> f64 {
        100.0 * (1.0 - (self.stream_cc - self.measured_cc).abs() / self.measured_cc)
    }

    pub fn memory_accuracy(&self) -> Option<f64> {
        self.measured_kb
            .map(|m| 100.0 * (1.0 - (self.stream_kb - m).abs() / m))
    }
}

fn run_fixed(
    workload: WorkloadGraph,
    arch: Accelerator,
    gran: CnGranularity,
    alloc: Vec<CoreId>,
) -> (f64, f64, f64) {
    let t = crate::util::ScopeTimer::start();
    let s = Stream::new(
        workload,
        arch,
        StreamOpts {
            granularity: gran,
            priority: SchedulePriority::Latency,
            allocation: Some(alloc),
            ..Default::default()
        },
    );
    let r = s.run().expect("pipeline");
    let p = &r.points[0].result;
    (p.latency() as f64, p.peak_mem() / 1024.0, t.elapsed_ms())
}

/// DepFiN runs everything on its single dense core, line-buffered.
fn depfin_row() -> Table1Row {
    let w = models::fsrcnn(560, 960);
    let arch = presets::depfin();
    let simd = arch.simd_core().unwrap();
    let alloc: Vec<CoreId> = w
        .layers()
        .iter()
        .map(|l| if l.op.is_dense() { CoreId(0) } else { simd })
        .collect();
    // DepFiN schedules at true line granularity (line-buffered CNs)
    let (cc, kb, ms) = run_fixed(w, arch, CnGranularity::Lines(1), alloc);
    Table1Row {
        arch: "DepFiN".into(),
        workload: "FSRCNN 560x960".into(),
        measured_cc: 6.18e6,
        stream_cc: cc,
        measured_kb: Some(238.0),
        stream_kb: kb,
        runtime_ms: ms,
    }
}

/// Jia et al. pipeline ResNet-50 segment layers across the 16 AiMC
/// cores, one layer per core in order.
fn aimc_row() -> Table1Row {
    let w = models::resnet50_segment();
    let arch = presets::aimc_4x4();
    let simd = arch.simd_core().unwrap();
    let mut next = 0usize;
    let alloc: Vec<CoreId> = w
        .layers()
        .iter()
        .map(|l| {
            if l.op.is_dense() {
                let c = CoreId(next % 16);
                next += 1;
                c
            } else {
                simd
            }
        })
        .collect();
    let (cc, kb, ms) = run_fixed(w, arch, CnGranularity::Lines(4), alloc);
    Table1Row {
        arch: "4x4 AiMC".into(),
        workload: "ResNet-50 segment".into(),
        measured_cc: 3.66e5,
        stream_cc: cc,
        measured_kb: None,
        stream_kb: kb,
        runtime_ms: ms,
    }
}

/// DIANA maps the heavy convolutions on the AiMC core, the remaining
/// conv on the digital core, pool/add on the SIMD core (Fig. 10c).
fn diana_row() -> Table1Row {
    let w = models::resnet18_first_segment();
    let arch = presets::diana();
    let simd = arch.simd_core().unwrap();
    let alloc: Vec<CoreId> = w
        .layers()
        .iter()
        .map(|l| match (l.op, l.name.as_str()) {
            (OpType::Conv, "conv2a") => CoreId(0), // digital
            (OpType::Conv, _) => CoreId(1),        // aimc
            _ => simd,
        })
        .collect();
    let (cc, kb, ms) = run_fixed(w, arch, CnGranularity::Lines(4), alloc);
    Table1Row {
        arch: "DIANA".into(),
        workload: "ResNet-18 first segment".into(),
        measured_cc: 8.12e5,
        stream_cc: cc,
        measured_kb: Some(134.0),
        stream_kb: kb,
        runtime_ms: ms,
    }
}

/// Run all three validations.
pub fn table1() -> Vec<Table1Row> {
    vec![depfin_row(), aimc_row(), diana_row()]
}

/// Format the table the way the paper prints it.
pub fn format_table(rows: &[Table1Row]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(s, "{:<10} {:>14} {:>14} {:>9}  (latency)", "arch", "measured(cc)", "stream(cc)", "acc(%)");
    for r in rows {
        let _ = writeln!(
            s,
            "{:<10} {:>14.3e} {:>14.3e} {:>9.1}",
            r.arch, r.measured_cc, r.stream_cc, r.latency_accuracy()
        );
    }
    let _ = writeln!(s, "{:<10} {:>14} {:>14} {:>9}  (peak memory)", "arch", "measured(KB)", "stream(KB)", "acc(%)");
    for r in rows {
        let m = r.measured_kb.map(|v| format!("{v:.1}")).unwrap_or_else(|| "N/A".into());
        let acc = r
            .memory_accuracy()
            .map(|v| format!("{v:.1}"))
            .unwrap_or_else(|| "N/A".into());
        let _ = writeln!(s, "{:<10} {:>14} {:>14.1} {:>9}", r.arch, m, r.stream_kb, acc);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diana_validation_runs_fast_and_sane() {
        let r = diana_row();
        assert!(r.stream_cc > 1e4, "{}", r.stream_cc);
        assert!(r.stream_kb > 1.0);
        // the paper's own runtime was 2 s; ours should be far under
        assert!(r.runtime_ms < 10_000.0);
    }

    #[test]
    fn aimc_validation_order_of_magnitude() {
        let r = aimc_row();
        // within 10x of the measured cycles (the substitution bound)
        let ratio = r.stream_cc / r.measured_cc;
        assert!(ratio > 0.1 && ratio < 10.0, "ratio {ratio}");
    }

    #[test]
    fn format_contains_all_archs() {
        let rows = vec![diana_row()];
        let s = format_table(&rows);
        assert!(s.contains("DIANA"));
    }
}
