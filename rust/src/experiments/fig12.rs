//! Fig. 12 — impact of the automatic (GA) layer-core allocation vs the
//! manual baselines, for ResNet-18 on HomTPU and Hetero, under both
//! scheduler priorities.

use crate::allocator::{manual_allocation, Ga, GaParams, Objective};
use crate::arch::{presets, Accelerator};
use crate::cn::{CnGranularity, CnSet};
use crate::cost::ScheduleCache;
use crate::depgraph::generate;
use crate::mapping::CostModel;
use crate::scheduler::{SchedulePriority, Scheduler};
use crate::workload::models::resnet18;

/// One point of Fig. 12.
#[derive(Debug, Clone)]
pub struct Fig12Row {
    pub arch: String,
    pub method: String,   // "manual" | "GA"
    pub priority: String, // "latency" | "memory"
    pub latency_cc: u64,
    pub peak_mem_kb: f64,
}

fn run_arch(arch: Accelerator, heterogeneous: bool, ga_params: GaParams) -> Vec<Fig12Row> {
    let w = resnet18();
    let gran = CnGranularity::Lines(4).for_arch(&arch);
    let cns = CnSet::build(&w, gran);
    let costs = CostModel::build(&w, &cns, &arch);
    let graph = generate(&w, CnSet::build(&w, gran));
    let sched = Scheduler::new(&w, &graph, &costs, &arch);
    // one memo shared by both priorities' GA runs and the final
    // reporting re-schedules (keys include the priority and topology)
    let cache = ScheduleCache::new();
    let topo_fp = arch.topology.fingerprint();

    let manual = manual_allocation(&w, &arch, &costs, &cns, heterogeneous);
    let mut rows = Vec::new();

    for (pname, priority) in
        [("latency", SchedulePriority::Latency), ("memory", SchedulePriority::Memory)]
    {
        // manual baseline
        let m = cache
            .get_or_compute(&manual, priority, topo_fp, || sched.run(&manual, priority).metrics);
        rows.push(Fig12Row {
            arch: arch.name.clone(),
            method: "manual".into(),
            priority: pname.into(),
            latency_cc: m.latency_cc,
            peak_mem_kb: m.peak_mem_bytes / 1024.0,
        });

        // GA (bi-objective latency+memory, matching the figure's axes)
        let mut ga = Ga::new(&w, &arch, &sched, priority, Objective::LatencyMemory, ga_params)
            .with_cache(&cache);
        let front = ga.run();
        // report the front's latency leader under latency priority and
        // memory leader under memory priority
        let best = match priority {
            SchedulePriority::Latency => front
                .iter()
                .min_by_key(|r| r.metrics.latency_cc)
                .expect("front nonempty"),
            SchedulePriority::Memory => front
                .iter()
                .min_by(|a, b| a.metrics.peak_mem_bytes.total_cmp(&b.metrics.peak_mem_bytes))
                .expect("front nonempty"),
        };
        let m = cache
            .get_or_compute(&best.allocation, priority, topo_fp, || {
                sched.run(&best.allocation, priority).metrics
            });
        rows.push(Fig12Row {
            arch: arch.name.clone(),
            method: "GA".into(),
            priority: pname.into(),
            latency_cc: m.latency_cc,
            peak_mem_kb: m.peak_mem_bytes / 1024.0,
        });
    }
    rows
}

/// Run the full Fig. 12 experiment.
pub fn fig12(ga_params: GaParams) -> Vec<Fig12Row> {
    let mut rows = run_arch(presets::hom_tpu(), false, ga_params);
    rows.extend(run_arch(presets::hetero_quad(), true, ga_params));
    rows
}

/// Text rendering of the rows.
pub fn format_rows(rows: &[Fig12Row]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<10} {:<7} {:<8} {:>12} {:>12}",
        "arch", "method", "priority", "latency(cc)", "peakmem(KB)"
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{:<10} {:<7} {:<8} {:>12} {:>12.1}",
            r.arch, r.method, r.priority, r.latency_cc, r.peak_mem_kb
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ga_beats_or_matches_manual_on_hetero() {
        let params = GaParams { population: 10, generations: 5, ..Default::default() };
        let rows = run_arch(presets::hetero_quad(), true, params);
        let manual_lat = rows
            .iter()
            .find(|r| r.method == "manual" && r.priority == "latency")
            .unwrap()
            .latency_cc;
        let ga_lat = rows
            .iter()
            .find(|r| r.method == "GA" && r.priority == "latency")
            .unwrap()
            .latency_cc;
        assert!(ga_lat <= manual_lat, "GA {ga_lat} vs manual {manual_lat}");
    }
}
