//! Experiment harnesses shared by the CLI and the `benches/` targets:
//! one function per paper table / figure.

pub mod fig12;
pub mod fig13;
pub mod table1;

pub use fig12::{fig12, Fig12Row};
pub use fig13::{exploration_sweep, ExplorationCell, SweepConfig};
pub use table1::{table1, Table1Row};
