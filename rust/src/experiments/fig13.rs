//! Figs. 13/14/15 — the architecture exploration: best EDP (and its
//! latency / energy breakdown) over the 5 DNNs x 7 iso-area
//! architectures, under layer-by-layer vs layer-fused scheduling.

use crate::allocator::{GaParams, Objective};
use crate::arch::{presets, Accelerator};
use crate::cn::CnGranularity;
use crate::cost::{geomean, ScheduleMetrics};
use crate::pipeline::{SchedulePriority, Stream, StreamOpts};
use crate::workload::{models, WorkloadGraph};

/// One (workload, architecture) cell of the exploration.
#[derive(Debug, Clone)]
pub struct ExplorationCell {
    pub workload: String,
    pub arch: String,
    /// Best-EDP metrics under layer-by-layer scheduling.
    pub lbl: ScheduleMetrics,
    /// Best-EDP metrics under fine-grained layer fusion.
    pub fused: ScheduleMetrics,
}

impl ExplorationCell {
    pub fn edp_reduction(&self) -> f64 {
        self.lbl.edp() / self.fused.edp().max(f64::MIN_POSITIVE)
    }
}

/// Sweep configuration (sized down for tests, paper-scale for benches).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub workloads: Vec<String>,
    pub archs: Vec<String>,
    pub ga: GaParams,
    /// Candidate CN granularities for the layer-fused runs; the best
    /// EDP across them is reported (Stream's Step-1 granularity
    /// optimization: big-activation networks want line granularity,
    /// weight-heavy networks want coarser blocks).
    pub lines: Vec<usize>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            workloads: vec![
                "resnet18".into(),
                "mobilenetv2".into(),
                "squeezenet".into(),
                "tinyyolo".into(),
                "fsrcnn".into(),
            ],
            archs: vec![
                "sc-tpu".into(),
                "sc-eye".into(),
                "sc-env".into(),
                "hom-tpu".into(),
                "hom-eye".into(),
                "hom-env".into(),
                "hetero".into(),
            ],
            ga: GaParams::default(),
            lines: vec![1, 4],
        }
    }
}

fn best_edp(
    workload: &WorkloadGraph,
    arch: &Accelerator,
    gran: CnGranularity,
    ga: GaParams,
) -> ScheduleMetrics {
    // the sweep is already data-parallel across (workload, arch) cells,
    // so the inner GA runs serially to avoid thread oversubscription
    let ga = GaParams { threads: 1, ..ga };
    let s = Stream::new(
        workload.clone(),
        arch.clone(),
        StreamOpts {
            granularity: gran,
            priority: SchedulePriority::Latency,
            objective: Objective::Edp,
            ga,
            allocation: None,
            fuse: None,
        },
    );
    let r = s.run().expect("pipeline");
    r.best_edp().expect("nonempty front").result.metrics
}

/// Run the exploration sweep; cells are evaluated in parallel.
pub fn exploration_sweep(cfg: &SweepConfig) -> Vec<ExplorationCell> {
    let pairs: Vec<(String, String)> = cfg
        .workloads
        .iter()
        .flat_map(|w| cfg.archs.iter().map(move |a| (w.clone(), a.clone())))
        .collect();

    crate::util::parallel_map(pairs, |(wname, aname)| {
        let w = models::by_name(&wname).expect("workload");
        let a = presets::by_name(&aname).expect("arch");
        let lbl = best_edp(&w, &a, CnGranularity::LayerByLayer, cfg.ga);
        let fused = cfg
            .lines
            .iter()
            .map(|&l| best_edp(&w, &a, CnGranularity::Lines(l), cfg.ga))
            .min_by(|x, y| x.edp().total_cmp(&y.edp()))
            .expect("at least one granularity");
        ExplorationCell { workload: wname, arch: aname, lbl, fused }
    })
}

/// Serialize sweep cells to JSON (so the Fig. 14/15 benches reuse the
/// Fig. 13 sweep instead of recomputing it).
pub fn cells_to_json(cells: &[ExplorationCell]) -> String {
    use crate::util::Json;
    use std::collections::BTreeMap;

    fn metrics_json(m: &ScheduleMetrics) -> Json {
        let mut o = BTreeMap::new();
        o.insert("latency_cc".into(), Json::Num(m.latency_cc as f64));
        o.insert("energy_pj".into(), Json::Num(m.energy_pj));
        o.insert("peak_mem_bytes".into(), Json::Num(m.peak_mem_bytes));
        o.insert("mac_pj".into(), Json::Num(m.breakdown.mac_pj));
        o.insert("onchip_pj".into(), Json::Num(m.breakdown.onchip_pj));
        o.insert("noc_pj".into(), Json::Num(m.breakdown.noc_pj));
        o.insert("dram_pj".into(), Json::Num(m.breakdown.dram_pj));
        o.insert("avg_core_util".into(), Json::Num(m.avg_core_util));
        Json::Obj(o)
    }

    let arr: Vec<Json> = cells
        .iter()
        .map(|c| {
            let mut o = BTreeMap::new();
            o.insert("workload".into(), Json::Str(c.workload.clone()));
            o.insert("arch".into(), Json::Str(c.arch.clone()));
            o.insert("lbl".into(), metrics_json(&c.lbl));
            o.insert("fused".into(), metrics_json(&c.fused));
            Json::Obj(o)
        })
        .collect();
    crate::util::Json::Arr(arr).to_string_compact()
}

/// Parse cells back from [`cells_to_json`] output.
pub fn cells_from_json(text: &str) -> Option<Vec<ExplorationCell>> {
    use crate::util::Json;

    fn metrics(j: &Json) -> Option<ScheduleMetrics> {
        Some(ScheduleMetrics {
            latency_cc: j.get("latency_cc")?.as_f64()? as u64,
            energy_pj: j.get("energy_pj")?.as_f64()?,
            peak_mem_bytes: j.get("peak_mem_bytes")?.as_f64()?,
            breakdown: crate::cost::EnergyBreakdown {
                mac_pj: j.get("mac_pj")?.as_f64()?,
                onchip_pj: j.get("onchip_pj")?.as_f64()?,
                noc_pj: j.get("noc_pj")?.as_f64()?,
                dram_pj: j.get("dram_pj")?.as_f64()?,
            },
            avg_core_util: j.get("avg_core_util")?.as_f64()?,
        })
    }

    let j = Json::parse(text).ok()?;
    j.as_arr()?
        .iter()
        .map(|c| {
            Some(ExplorationCell {
                workload: c.get("workload")?.as_str()?.to_string(),
                arch: c.get("arch")?.as_str()?.to_string(),
                lbl: metrics(c.get("lbl")?)?,
                fused: metrics(c.get("fused")?)?,
            })
        })
        .collect()
}

/// Run the sweep, caching the result at `path` (reused by later benches
/// with the same config; delete the file to force a re-run).
pub fn sweep_cached(cfg: &SweepConfig, path: &std::path::Path) -> Vec<ExplorationCell> {
    let key = format!(
        "{:?}|{:?}|{}|{}|{:?}",
        cfg.workloads, cfg.archs, cfg.ga.population, cfg.ga.generations, cfg.lines
    );
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Some((stored_key, body)) = text.split_once('\n') {
            if stored_key == key {
                if let Some(cells) = cells_from_json(body) {
                    return cells;
                }
            }
        }
    }
    let cells = exploration_sweep(cfg);
    let _ = std::fs::create_dir_all(path.parent().unwrap_or(std::path::Path::new(".")));
    let _ = std::fs::write(path, format!("{key}\n{}", cells_to_json(&cells)));
    cells
}

/// Default cache location under target/.
pub fn default_cache_path() -> std::path::PathBuf {
    std::path::PathBuf::from("target/stream-bench/fig13_cells.json")
}

/// Geometric-mean EDP reduction per architecture (the Fig. 13 labels).
pub fn geomean_reduction_per_arch(cells: &[ExplorationCell]) -> Vec<(String, f64)> {
    let mut archs: Vec<String> = cells.iter().map(|c| c.arch.clone()).collect();
    archs.dedup();
    archs.sort();
    archs.dedup();
    archs
        .into_iter()
        .map(|a| {
            let rs: Vec<f64> = cells
                .iter()
                .filter(|c| c.arch == a)
                .map(|c| c.edp_reduction())
                .collect();
            (a, geomean(&rs))
        })
        .collect()
}

/// Fig. 13 text rendering: EDP matrix + geomean reductions.
pub fn format_fig13(cells: &[ExplorationCell]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<12} {:<9} {:>13} {:>13} {:>8}",
        "workload", "arch", "EDP lbl", "EDP fused", "gain"
    );
    for c in cells {
        let _ = writeln!(
            s,
            "{:<12} {:<9} {:>13.3e} {:>13.3e} {:>7.1}x",
            c.workload,
            c.arch,
            c.lbl.edp(),
            c.fused.edp(),
            c.edp_reduction()
        );
    }
    let _ = writeln!(s, "-- geomean EDP reduction (layer-by-layer -> fused) --");
    for (a, g) in geomean_reduction_per_arch(cells) {
        let _ = writeln!(s, "{a:<9} {g:>6.1}x");
    }
    s
}

/// Fig. 14 rendering: latency at the best-EDP points.
pub fn format_fig14(cells: &[ExplorationCell]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<12} {:<9} {:>13} {:>13} {:>8}",
        "workload", "arch", "lat lbl(cc)", "lat fused(cc)", "gain"
    );
    for c in cells {
        let _ = writeln!(
            s,
            "{:<12} {:<9} {:>13} {:>13} {:>7.1}x",
            c.workload,
            c.arch,
            c.lbl.latency_cc,
            c.fused.latency_cc,
            c.lbl.latency_cc as f64 / c.fused.latency_cc.max(1) as f64
        );
    }
    s
}

/// Fig. 15 rendering: energy breakdown at the best-EDP points.
pub fn format_fig15(cells: &[ExplorationCell]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<12} {:<9} {:<6} {:>11} {:>11} {:>11} {:>11}",
        "workload", "arch", "sched", "mac(pJ)", "onchip(pJ)", "noc(pJ)", "dram(pJ)"
    );
    for c in cells {
        for (tag, m) in [("lbl", &c.lbl), ("fused", &c.fused)] {
            let b = m.breakdown;
            let _ = writeln!(
                s,
                "{:<12} {:<9} {:<6} {:>11.3e} {:>11.3e} {:>11.3e} {:>11.3e}",
                c.workload, c.arch, tag, b.mac_pj, b.onchip_pj, b.noc_pj, b.dram_pj
            );
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> SweepConfig {
        SweepConfig {
            workloads: vec!["tiny-segment".into()],
            archs: vec!["sc-tpu".into(), "hetero".into()],
            ga: GaParams { population: 8, generations: 4, ..Default::default() },
            lines: vec![4],
        }
    }

    #[test]
    fn sweep_produces_all_cells() {
        let cells = exploration_sweep(&tiny_cfg());
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert!(c.fused.edp() > 0.0);
            assert!(c.lbl.edp() > 0.0);
        }
    }

    #[test]
    fn fusion_reduces_edp() {
        let cells = exploration_sweep(&tiny_cfg());
        for c in &cells {
            assert!(
                c.edp_reduction() > 1.0,
                "{} on {}: reduction {}",
                c.workload,
                c.arch,
                c.edp_reduction()
            );
        }
    }

    #[test]
    fn renderings_nonempty() {
        let cells = exploration_sweep(&tiny_cfg());
        assert!(format_fig13(&cells).contains("geomean"));
        assert!(format_fig14(&cells).contains("lat"));
        assert!(format_fig15(&cells).contains("dram"));
    }
}
