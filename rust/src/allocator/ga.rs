//! The genetic algorithm driving the layer–core allocation search.
//!
//! Fitness evaluation is the hot path: each unseen genome costs one
//! full event-driven schedule simulation.  Two mechanisms keep it fast:
//!
//! - **data parallelism** — unseen genomes of a generation are
//!   evaluated concurrently on [`GaParams::threads`] workers (0 = the
//!   `STREAM_THREADS` environment variable, else all cores).  Workers
//!   share only immutable state (the prebuilt [`Scheduler`]) plus the
//!   thread-safe memo cache, so serial (`threads: 1`) and parallel runs
//!   produce **bit-identical** results for a fixed seed;
//! - **memoization** — schedule metrics are cached in a
//!   [`ScheduleCache`] keyed by the expanded core allocation, so
//!   genomes resurfacing across generations (or across GA runs sharing
//!   a cache via [`Ga::with_cache`]) cost a hash lookup.

use std::collections::{HashMap, HashSet};

use crate::util::{parallel_map_with, thread_count};

use super::allocation_from_genome;
use super::evolve::{evolve, EvoProblem};
use crate::arch::{Accelerator, CoreId};
use crate::cost::{ScheduleCache, ScheduleMetrics};
use crate::scheduler::{SchedulePriority, Scheduler};
use crate::workload::WorkloadGraph;

/// What the GA minimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Objective {
    /// Energy-delay product (scalar) — the Section V criterion.
    #[default]
    Edp,
    Latency,
    Energy,
    /// Bi-objective latency + peak memory (Fig. 12's Pareto axes).
    LatencyMemory,
    /// Bi-objective latency + energy.
    LatencyEnergy,
}

impl Objective {
    /// Objective vector (all minimized) from schedule metrics.
    pub fn values(&self, m: &ScheduleMetrics) -> Vec<f64> {
        match self {
            Objective::Edp => vec![m.edp()],
            Objective::Latency => vec![m.latency_cc as f64],
            Objective::Energy => vec![m.energy_pj],
            Objective::LatencyMemory => vec![m.latency_cc as f64, m.peak_mem_bytes],
            Objective::LatencyEnergy => vec![m.latency_cc as f64, m.energy_pj],
        }
    }
}

/// GA hyper-parameters (paper Section III-D defaults).
#[derive(Debug, Clone, Copy)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    /// Ordered-crossover probability (paper: 0.3).
    pub crossover_p: f64,
    /// Mutation probability (paper: 0.7).
    pub mutation_p: f64,
    pub seed: u64,
    /// Stop early after this many generations without best-front change.
    pub patience: usize,
    /// Fitness-evaluation worker threads.  0 = auto (`STREAM_THREADS`
    /// env var, else all available cores); 1 = fully serial.  Results
    /// are bit-identical for any value.
    pub threads: usize,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams {
            population: 32,
            generations: 24,
            crossover_p: 0.3,
            mutation_p: 0.7,
            seed: 42,
            patience: 8,
            threads: 0,
        }
    }
}

/// One Pareto-front member returned by the GA.
#[derive(Debug, Clone)]
pub struct GaResult {
    pub genome: Vec<u16>,
    pub allocation: Vec<CoreId>,
    pub metrics: ScheduleMetrics,
}

/// How a [`Ga`] reaches its schedule-metrics memo: its own private
/// cache, or one shared with other GA runs / the surrounding
/// experiment (see [`Ga::with_cache`]).
enum CacheRef<'a> {
    Owned(Box<ScheduleCache>),
    Shared(&'a ScheduleCache),
}

/// The GA engine. Owns nothing heavy: fitness evaluation borrows the
/// prebuilt [`Scheduler`].
///
/// # Examples
///
/// ```
/// use stream::allocator::{Ga, GaParams, Objective};
/// use stream::arch::presets;
/// use stream::cn::{CnGranularity, CnSet};
/// use stream::depgraph::generate;
/// use stream::mapping::CostModel;
/// use stream::scheduler::{SchedulePriority, Scheduler};
/// use stream::workload::models::tiny_segment;
///
/// let workload = tiny_segment();
/// let arch = presets::hetero_quad();
/// let cns = CnSet::build(&workload, CnGranularity::Lines(4));
/// let costs = CostModel::build(&workload, &cns, &arch);
/// let graph = generate(&workload, CnSet::build(&workload, CnGranularity::Lines(4)));
/// let scheduler = Scheduler::new(&workload, &graph, &costs, &arch);
///
/// let params = GaParams { population: 8, generations: 3, ..Default::default() };
/// let mut ga = Ga::new(&workload, &arch, &scheduler, SchedulePriority::Latency,
///                      Objective::Edp, params);
/// let front = ga.run();
/// assert!(!front.is_empty());
/// assert_eq!(front[0].allocation.len(), workload.len());
/// ```
pub struct Ga<'a> {
    pub workload: &'a WorkloadGraph,
    pub arch: &'a Accelerator,
    pub scheduler: &'a Scheduler<'a>,
    pub priority: SchedulePriority,
    pub objective: Objective,
    pub params: GaParams,
    /// Schedule-metrics memo, possibly shared across GA runs.
    cache: CacheRef<'a>,
    /// Metrics per genome this run evaluated (the shared driver keeps
    /// the deterministic first-seen record; this map only resolves the
    /// front's genomes back to their [`ScheduleMetrics`]).
    evaluated_metrics: HashMap<Vec<u16>, ScheduleMetrics>,
}

impl<'a> Ga<'a> {
    pub fn new(
        workload: &'a WorkloadGraph,
        arch: &'a Accelerator,
        scheduler: &'a Scheduler<'a>,
        priority: SchedulePriority,
        objective: Objective,
        params: GaParams,
    ) -> Ga<'a> {
        Ga {
            workload,
            arch,
            scheduler,
            priority,
            objective,
            params,
            cache: CacheRef::Owned(Box::new(ScheduleCache::new())),
            evaluated_metrics: HashMap::new(),
        }
    }

    /// Share a schedule-metrics cache with other GA runs over the same
    /// (workload, CN graph, cost model).  The cache key is the
    /// (allocation, priority, interconnect-topology fingerprint)
    /// triple, so runs over different topologies of the same cores may
    /// share a cache; the caller must still guarantee the workload, CN
    /// graph and cost model are identical.
    pub fn with_cache(mut self, cache: &'a ScheduleCache) -> Ga<'a> {
        self.cache = CacheRef::Shared(cache);
        self
    }

    /// The memo this run consults (owned or shared).
    pub fn cache(&self) -> &ScheduleCache {
        match &self.cache {
            CacheRef::Owned(c) => c,
            CacheRef::Shared(c) => c,
        }
    }

    /// Fitness of every genome in `genomes` (order-preserving).
    ///
    /// Distinct genomes not yet in this run's record are dispatched to
    /// [`GaParams::threads`] workers in first-seen order; each worker
    /// consults the [`ScheduleCache`] and only simulates on a miss.
    /// The workers share only `&Scheduler` and the cache,
    /// `parallel_map_with` preserves order, and — crucially — the
    /// record order is the same whether a genome hits or misses the
    /// cache, so neither the thread count nor a pre-warmed shared
    /// cache can perturb the GA trajectory or the final front's
    /// tie-breaking.
    fn eval_metrics(&mut self, genomes: &[Vec<u16>]) -> Vec<ScheduleMetrics> {
        let mut jobs: Vec<Vec<u16>> = Vec::new();
        let mut seen: HashSet<&[u16]> = HashSet::new();
        for g in genomes {
            if !self.evaluated_metrics.contains_key(g) && seen.insert(g.as_slice()) {
                jobs.push(g.clone());
            }
        }

        let (workload, arch, scheduler, priority) =
            (self.workload, self.arch, self.scheduler, self.priority);
        let cache = match &self.cache {
            CacheRef::Owned(c) => c.as_ref(),
            CacheRef::Shared(c) => c,
        };
        let threads = thread_count(self.params.threads);
        let topo_fp = arch.topology.fingerprint();
        let results: Vec<(Vec<u16>, ScheduleMetrics)> = parallel_map_with(
            jobs,
            |g| {
                let alloc = allocation_from_genome(workload, arch, &g);
                let m = cache.get_or_compute(&alloc, priority, topo_fp, || {
                    scheduler.run(&alloc, priority).metrics
                });
                (g, m)
            },
            threads,
        );
        for (g, m) in results {
            self.evaluated_metrics.entry(g).or_insert(m);
        }
        genomes.iter().map(|g| self.evaluated_metrics[g]).collect()
    }

    /// Run the GA on the shared evolutionary driver
    /// ([`evolve`](fn@super::evolve)); returns the final Pareto front
    /// (deduplicated), best EDP first.
    pub fn run(&mut self) -> Vec<GaResult> {
        let params = self.params;
        let outcome = evolve(self, &params);
        let mut results: Vec<GaResult> = outcome
            .front
            .iter()
            .map(|&i| {
                let genome = outcome.evaluated[i].0.clone();
                let metrics = self.evaluated_metrics[&genome];
                GaResult {
                    allocation: allocation_from_genome(self.workload, self.arch, &genome),
                    genome,
                    metrics,
                }
            })
            .collect();
        results.sort_by(|a, b| {
            a.metrics
                .edp()
                .partial_cmp(&b.metrics.edp())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        results
    }
}

/// The [`Ga`]'s instantiation of the shared evolutionary driver: the
/// genome assigns every dense layer one dense core, fitness is the
/// (cached, possibly parallel) schedule simulation projected through
/// [`Objective::values`], and the patience scalarization is the plain
/// objective product.
impl EvoProblem for Ga<'_> {
    fn genome_len(&self) -> usize {
        self.workload.dense_layers().len()
    }

    fn n_cores(&self) -> usize {
        self.arch.dense_cores().len()
    }

    /// Heuristic seed genomes: round-robin ping-pong, each
    /// single-core-only assignment, and per-layer greedy minimum-EDP —
    /// cheap starting points the GA refines (it converges far faster on
    /// 50-gene genomes than from pure noise).
    fn seed_genomes(&self) -> Vec<Vec<u16>> {
        let n = self.genome_len();
        let k = self.n_cores();
        let mut seeds = Vec::new();
        // ping-pong
        seeds.push((0..n).map(|i| (i % k) as u16).collect());
        // each core alone
        for c in 0..k {
            seeds.push(vec![c as u16; n]);
        }
        // greedy: per dense layer, the core with the lowest CN edp
        let dense_cores = self.arch.dense_cores();
        let mut greedy = Vec::with_capacity(n);
        for lid in self.workload.dense_layers() {
            let cn = &self.scheduler.graph.cns.layer_cns(lid)[0];
            let best = (0..dense_cores.len())
                .min_by(|&a, &b| {
                    let ca = self.scheduler.costs.cn_cost(cn, dense_cores[a]).edp();
                    let cb = self.scheduler.costs.cn_cost(cn, dense_cores[b]).edp();
                    ca.partial_cmp(&cb).unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap_or(0);
            greedy.push(best as u16);
        }
        seeds.push(greedy);
        seeds
    }

    fn evaluate(&mut self, genomes: &[Vec<u16>]) -> Vec<Vec<f64>> {
        let metrics = self.eval_metrics(genomes);
        metrics.iter().map(|m| self.objective.values(m)).collect()
    }

    // scalarize: the trait's default (objective product) is exactly the
    // historical Ga saturation criterion.
}

/// The manual baselines of Section V-A: ping-pong across cores for
/// homogeneous architectures, best-spatial-utilization core for
/// heterogeneous ones.
pub fn manual_allocation(
    workload: &WorkloadGraph,
    arch: &Accelerator,
    costs: &crate::mapping::CostModel,
    cns: &crate::cn::CnSet,
    heterogeneous: bool,
) -> Vec<CoreId> {
    let dense = arch.dense_cores();
    let simd = arch.simd_core().unwrap_or(dense[0]);
    let mut i = 0usize;
    workload
        .layers()
        .iter()
        .map(|l| {
            if !l.op.is_dense() {
                return simd;
            }
            let core = if heterogeneous {
                // pick the dense core with the best spatial utilization
                let cn = &cns.layer_cns(l.id)[0];
                *dense
                    .iter()
                    .max_by(|&&a, &&b| {
                        let ua = costs.cn_cost(cn, a).spatial_util;
                        let ub = costs.cn_cost(cn, b).spatial_util;
                        ua.partial_cmp(&ub).unwrap_or(std::cmp::Ordering::Equal)
                    })
                    .unwrap()
            } else {
                // ping-pong: subsequent layers on subsequent cores
                dense[i % dense.len()]
            };
            i += 1;
            core
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cn::{CnGranularity, CnSet};
    use crate::depgraph::generate;
    use crate::mapping::CostModel;
    use crate::workload::models::tiny_segment;

    struct Fixture {
        w: WorkloadGraph,
        arch: Accelerator,
        g: crate::depgraph::CnGraph,
        costs: CostModel,
    }

    fn fixture() -> Fixture {
        let w = tiny_segment();
        let arch = presets::hetero_quad();
        let cns = CnSet::build(&w, CnGranularity::Lines(4));
        let costs = CostModel::build(&w, &cns, &arch);
        let g = generate(&w, CnSet::build(&w, CnGranularity::Lines(4)));
        Fixture { w, arch, g, costs }
    }

    #[test]
    fn ga_improves_over_random() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let params = GaParams { population: 12, generations: 8, ..Default::default() };
        let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                             Objective::Edp, params);
        let front = ga.run();
        assert!(!front.is_empty());
        // the best found EDP must beat a deliberately bad allocation
        // (everything on one small core)
        let bad = allocation_from_genome(&f.w, &f.arch, &[0, 0, 0]);
        let bad_m = sched.run(&bad, SchedulePriority::Latency).metrics;
        assert!(front[0].metrics.edp() <= bad_m.edp());
    }

    #[test]
    fn ga_deterministic_for_seed() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let params = GaParams { population: 8, generations: 4, ..Default::default() };
        let run = |seed| {
            let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                                 Objective::Edp, GaParams { seed, ..params });
            ga.run()[0].metrics.edp()
        };
        assert_eq!(run(7).to_bits(), run(7).to_bits());
    }

    #[test]
    fn serial_and_parallel_fitness_identical() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let run = |threads: usize| {
            let params = GaParams {
                population: 10,
                generations: 5,
                threads,
                ..Default::default()
            };
            let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                                 Objective::LatencyMemory, params);
            ga.run()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.metrics.latency_cc, b.metrics.latency_cc);
            assert_eq!(a.metrics.energy_pj.to_bits(), b.metrics.energy_pj.to_bits());
            assert_eq!(
                a.metrics.peak_mem_bytes.to_bits(),
                b.metrics.peak_mem_bytes.to_bits()
            );
        }
    }

    #[test]
    fn shared_cache_is_reused_across_runs() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let cache = crate::cost::ScheduleCache::new();
        let params = GaParams { population: 8, generations: 3, ..Default::default() };
        let run = || {
            let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                                 Objective::Edp, params)
                .with_cache(&cache);
            ga.run()[0].metrics.edp()
        };
        let first = run();
        let misses_after_first = cache.misses();
        let second = run();
        assert_eq!(first.to_bits(), second.to_bits(), "cache must not change results");
        // the second run re-visits the same genome sequence: every
        // schedule comes from the cache, no new misses
        assert_eq!(cache.misses(), misses_after_first);
        assert!(cache.hits() > 0);
    }

    #[test]
    fn pareto_front_is_nondominated() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let params = GaParams { population: 12, generations: 6, ..Default::default() };
        let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                             Objective::LatencyMemory, params);
        let front = ga.run();
        for a in &front {
            for b in &front {
                let pa = Objective::LatencyMemory.values(&a.metrics);
                let pb = Objective::LatencyMemory.values(&b.metrics);
                assert!(!super::super::nsga2::dominates(&pa, &pb) || pa == pb);
            }
        }
    }

    #[test]
    fn manual_heterogeneous_picks_best_fit() {
        let f = fixture();
        let cns = CnSet::build(&f.w, CnGranularity::Lines(4));
        let alloc = manual_allocation(&f.w, &f.arch, &f.costs, &cns, true);
        // all layers allocated, simd layers pinned
        assert_eq!(alloc.len(), f.w.len());
        assert_eq!(alloc[1], f.arch.simd_core().unwrap());
    }

    #[test]
    fn manual_pingpong_cycles_cores() {
        let f = fixture();
        let cns = CnSet::build(&f.w, CnGranularity::Lines(4));
        let alloc = manual_allocation(&f.w, &f.arch, &f.costs, &cns, false);
        // dense layers 0,2,3 -> cores 0,1,2
        assert_eq!(alloc[0], CoreId(0));
        assert_eq!(alloc[2], CoreId(1));
        assert_eq!(alloc[3], CoreId(2));
    }

    /// The driver's variation operators produce genomes the expansion
    /// accepts (the operator-level unit tests live in `evolve.rs`).
    #[test]
    fn driver_variation_expands_to_valid_allocations() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                         Objective::Edp, GaParams::default());
        let mut rng = crate::util::XorShift64::new(1);
        let a = super::super::evolve::random_genome(ga.genome_len(), ga.n_cores(), &mut rng);
        let b = super::super::evolve::random_genome(ga.genome_len(), ga.n_cores(), &mut rng);
        for _ in 0..50 {
            let mut c = super::super::evolve::crossover(&a, &b, &mut rng);
            super::super::evolve::mutate(&mut c, ga.n_cores(), &mut rng);
            assert_eq!(c.len(), a.len());
            let alloc = allocation_from_genome(&f.w, &f.arch, &c);
            assert_eq!(alloc.len(), f.w.len());
        }
    }
}
