//! The genetic algorithm driving the layer–core allocation search.
//!
//! Fitness evaluation is the hot path: each unseen genome costs one
//! full event-driven schedule simulation.  Four mechanisms keep it
//! fast:
//!
//! - **data parallelism** — unseen genomes of a generation are
//!   evaluated concurrently on [`GaParams::threads`] workers (0 = the
//!   `STREAM_THREADS` environment variable, else all cores).  Workers
//!   share only immutable state (the prebuilt [`Scheduler`]) plus the
//!   thread-safe caches, so serial (`threads: 1`) and parallel runs
//!   produce **bit-identical** results for a fixed seed;
//! - **memoization** — schedule metrics are cached in a
//!   [`ScheduleCache`] keyed by the expanded core allocation, so
//!   genomes resurfacing across generations (or across GA runs sharing
//!   a cache via [`Ga::with_cache`]) cost a hash lookup;
//! - **delta evaluation** ([`GaParams::incremental`], default on) —
//!   every simulated genome is traced ([`Scheduler::run_traced`]) and
//!   its resumable segments kept in a bounded [`DeltaCache`]; a child
//!   genome then replays its parent's schedule prefix and re-simulates
//!   only from the first decision that could observe a changed layer
//!   ([`Scheduler::run_resumed_traced`]).  The replay is bit-identical
//!   to a cold run, so the GA trajectory and the final front do not
//!   depend on the knob, the cache's hit pattern, or the thread count
//!   (pinned by `rust/tests/delta_equivalence.rs`);
//! - **lower-bound early-abort** ([`GaParams::lb_prune`], default
//!   *off*) — before dispatch, each unseen genome's admissible
//!   objective floors ([`Scheduler::lower_bounds`]) are checked
//!   against the points already evaluated; a genome whose floors are
//!   strictly dominated cannot reach the Pareto front and is recorded
//!   with its floor vector instead of being simulated.  Pruning is
//!   decided serially pre-dispatch, so it is deterministic for a
//!   fixed seed — but unlike delta evaluation it *does* change which
//!   genomes get exact metrics, hence the separate opt-in knob.
//!
//! The `STREAM_INCREMENTAL` environment variable overrides both knobs
//! at [`Ga::new`] time: `0`/`off` disables delta evaluation,
//! `1`/`delta` enables it alone (the default), `2`/`prune` adds the
//! lower-bound early-abort.

use std::collections::{HashMap, HashSet};

use crate::util::{parallel_map_with, thread_count};

use super::allocation_from_genome;
use super::evolve::{evolve, EvoProblem};
use super::nsga2::dominates;
use crate::arch::{Accelerator, CoreId};
use crate::cost::{DeltaCache, ScheduleCache, ScheduleMetrics};
use crate::scheduler::{SchedulePriority, Scheduler};
use crate::workload::WorkloadGraph;

/// What the GA minimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Objective {
    /// Energy-delay product (scalar) — the Section V criterion.
    #[default]
    Edp,
    Latency,
    Energy,
    /// Bi-objective latency + peak memory (Fig. 12's Pareto axes).
    LatencyMemory,
    /// Bi-objective latency + energy.
    LatencyEnergy,
}

impl Objective {
    /// Objective vector (all minimized) from schedule metrics.
    pub fn values(&self, m: &ScheduleMetrics) -> Vec<f64> {
        match self {
            Objective::Edp => vec![m.edp()],
            Objective::Latency => vec![m.latency_cc as f64],
            Objective::Energy => vec![m.energy_pj],
            Objective::LatencyMemory => vec![m.latency_cc as f64, m.peak_mem_bytes],
            Objective::LatencyEnergy => vec![m.latency_cc as f64, m.energy_pj],
        }
    }
}

/// GA hyper-parameters (paper Section III-D defaults).
#[derive(Debug, Clone, Copy)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    /// Ordered-crossover probability (paper: 0.3).
    pub crossover_p: f64,
    /// Mutation probability (paper: 0.7).
    pub mutation_p: f64,
    pub seed: u64,
    /// Stop early after this many generations without best-front change.
    pub patience: usize,
    /// Fitness-evaluation worker threads.  0 = auto (`STREAM_THREADS`
    /// env var, else all available cores); 1 = fully serial.  Results
    /// are bit-identical for any value.
    pub threads: usize,
    /// Delta evaluation: re-simulate child genomes from their parent's
    /// cached schedule segments instead of from scratch.  Results are
    /// bit-identical either way (the knob only trades memory for
    /// speed).  Overridable via `STREAM_INCREMENTAL`.
    pub incremental: bool,
    /// Lower-bound early-abort: skip simulating genomes whose
    /// admissible objective floors are already strictly dominated by
    /// an evaluated point.  Never removes a would-be front member, but
    /// dominated genomes are recorded with floor values instead of
    /// exact metrics — off by default.  Overridable via
    /// `STREAM_INCREMENTAL=2`.
    pub lb_prune: bool,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams {
            population: 32,
            generations: 24,
            crossover_p: 0.3,
            mutation_p: 0.7,
            seed: 42,
            patience: 8,
            threads: 0,
            incremental: true,
            lb_prune: false,
        }
    }
}

/// One Pareto-front member returned by the GA.
#[derive(Debug, Clone)]
pub struct GaResult {
    pub genome: Vec<u16>,
    pub allocation: Vec<CoreId>,
    pub metrics: ScheduleMetrics,
}

/// How a [`Ga`] reaches its schedule-metrics memo: its own private
/// cache, or one shared with other GA runs / the surrounding
/// experiment (see [`Ga::with_cache`]).
enum CacheRef<'a> {
    Owned(Box<ScheduleCache>),
    Shared(&'a ScheduleCache),
}

/// The GA engine. Owns nothing heavy: fitness evaluation borrows the
/// prebuilt [`Scheduler`].
///
/// # Examples
///
/// ```
/// use stream::allocator::{Ga, GaParams, Objective};
/// use stream::arch::presets;
/// use stream::cn::{CnGranularity, CnSet};
/// use stream::depgraph::generate;
/// use stream::mapping::CostModel;
/// use stream::scheduler::{SchedulePriority, Scheduler};
/// use stream::workload::models::tiny_segment;
///
/// let workload = tiny_segment();
/// let arch = presets::hetero_quad();
/// let cns = CnSet::build(&workload, CnGranularity::Lines(4));
/// let costs = CostModel::build(&workload, &cns, &arch);
/// let graph = generate(&workload, CnSet::build(&workload, CnGranularity::Lines(4)));
/// let scheduler = Scheduler::new(&workload, &graph, &costs, &arch);
///
/// let params = GaParams { population: 8, generations: 3, ..Default::default() };
/// let mut ga = Ga::new(&workload, &arch, &scheduler, SchedulePriority::Latency,
///                      Objective::Edp, params);
/// let front = ga.run();
/// assert!(!front.is_empty());
/// assert_eq!(front[0].allocation.len(), workload.len());
/// ```
pub struct Ga<'a> {
    pub workload: &'a WorkloadGraph,
    pub arch: &'a Accelerator,
    pub scheduler: &'a Scheduler<'a>,
    pub priority: SchedulePriority,
    pub objective: Objective,
    pub params: GaParams,
    /// Schedule-metrics memo, possibly shared across GA runs.
    cache: CacheRef<'a>,
    /// Segmented parent schedules for delta evaluation
    /// (`Some` iff [`GaParams::incremental`]).
    delta: Option<DeltaCache>,
    /// Genomes skipped by the lower-bound early-abort; their
    /// `evaluated_metrics` entries hold admissible *floors*, not exact
    /// metrics, and they are excluded from the prune archive (floors
    /// must only ever be compared against exactly evaluated points).
    pruned: HashSet<Vec<u16>>,
    /// Metrics per genome this run evaluated (the shared driver keeps
    /// the deterministic first-seen record; this map only resolves the
    /// front's genomes back to their [`ScheduleMetrics`]).
    evaluated_metrics: HashMap<Vec<u16>, ScheduleMetrics>,
}

impl<'a> Ga<'a> {
    pub fn new(
        workload: &'a WorkloadGraph,
        arch: &'a Accelerator,
        scheduler: &'a Scheduler<'a>,
        priority: SchedulePriority,
        objective: Objective,
        params: GaParams,
    ) -> Ga<'a> {
        let mut params = params;
        if let Ok(v) = std::env::var("STREAM_INCREMENTAL") {
            match v.as_str() {
                "0" | "off" => (params.incremental, params.lb_prune) = (false, false),
                "1" | "delta" => (params.incremental, params.lb_prune) = (true, false),
                "2" | "prune" => (params.incremental, params.lb_prune) = (true, true),
                _ => {}
            }
        }
        // hold at least one full generation of parents+offspring so a
        // survivor's segments are never evicted before its children
        // look them up next generation
        let delta = params
            .incremental
            .then(|| DeltaCache::new((2 * params.population).max(64)));
        Ga {
            workload,
            arch,
            scheduler,
            priority,
            objective,
            params,
            cache: CacheRef::Owned(Box::new(ScheduleCache::new())),
            delta,
            pruned: HashSet::new(),
            evaluated_metrics: HashMap::new(),
        }
    }

    /// Share a schedule-metrics cache with other GA runs over the same
    /// (workload, CN graph, cost model).  The cache key is the
    /// (allocation, priority, interconnect-topology fingerprint)
    /// triple, so runs over different topologies of the same cores may
    /// share a cache; the caller must still guarantee the workload, CN
    /// graph and cost model are identical.
    pub fn with_cache(mut self, cache: &'a ScheduleCache) -> Ga<'a> {
        self.cache = CacheRef::Shared(cache);
        self
    }

    /// The memo this run consults (owned or shared).
    pub fn cache(&self) -> &ScheduleCache {
        match &self.cache {
            CacheRef::Owned(c) => c,
            CacheRef::Shared(c) => c,
        }
    }

    /// The delta-evaluation segment cache, when
    /// [`GaParams::incremental`] is on (diagnostics: its
    /// [`stats`](DeltaCache::stats) count warm resumes vs cold runs).
    pub fn delta_cache(&self) -> Option<&DeltaCache> {
        self.delta.as_ref()
    }

    /// Genomes skipped by the lower-bound early-abort so far.
    pub fn pruned_count(&self) -> usize {
        self.pruned.len()
    }

    /// Fitness of every genome in `genomes` (order-preserving), with
    /// the driver's lineage hints (`parents[i]` = in-batch index of
    /// genome `i`'s primary parent, if any).
    ///
    /// Serial pre-pass, in first-seen order: duplicates and
    /// already-recorded genomes are dropped; with
    /// [`GaParams::lb_prune`], a genome whose admissible floors
    /// ([`Scheduler::lower_bounds`]) are strictly dominated by an
    /// already-evaluated point is recorded with its floor vector and
    /// never dispatched (it provably cannot reach the front — floors
    /// are compared only against *exactly* evaluated points, so prune
    /// decisions can never chain through other floors).
    ///
    /// Survivors are dispatched to [`GaParams::threads`] workers in
    /// first-seen order.  Each worker consults the [`ScheduleCache`]
    /// first; on a miss, with [`GaParams::incremental`], it resumes
    /// from the parent's cached segments at the divergence decision
    /// ([`Scheduler::run_resumed_traced`]) — bit-identical to a cold
    /// run — falling back to a traced cold run
    /// ([`Scheduler::run_traced`]) when the parent is unknown or
    /// diverges too early, and caches the new segments either way.
    /// The workers share only `&Scheduler` and the thread-safe caches,
    /// `parallel_map_with` preserves order, and the record order is
    /// the same whether a genome hits or misses either cache, so
    /// neither the thread count, a pre-warmed shared cache, nor the
    /// delta cache's eviction timing can perturb the GA trajectory or
    /// the final front's tie-breaking.
    fn eval_metrics(
        &mut self,
        genomes: &[Vec<u16>],
        parents: &[Option<usize>],
    ) -> Vec<ScheduleMetrics> {
        // exact objective points already established (floors excluded):
        // the only archive prune decisions may compare against
        let archive: Vec<Vec<f64>> = if self.params.lb_prune {
            self.evaluated_metrics
                .iter()
                .filter(|(g, _)| !self.pruned.contains(g.as_slice()))
                .map(|(_, m)| self.objective.values(m))
                .collect()
        } else {
            Vec::new()
        };

        let mut jobs: Vec<(Vec<u16>, Option<Vec<u16>>)> = Vec::new();
        let mut seen: HashSet<&[u16]> = HashSet::new();
        for (i, g) in genomes.iter().enumerate() {
            if self.evaluated_metrics.contains_key(g) || !seen.insert(g.as_slice()) {
                continue;
            }
            if self.params.lb_prune {
                let alloc = allocation_from_genome(self.workload, self.arch, g);
                let lb = self.scheduler.lower_bounds(&alloc);
                let lbv = self.objective.values(&lb);
                if archive.iter().any(|a| dominates(a, &lbv)) {
                    // dominated even in the best case: record the
                    // floors (themselves dominated, so they can never
                    // displace a legitimate front member) and skip
                    self.pruned.insert(g.clone());
                    self.evaluated_metrics.insert(g.clone(), lb);
                    crate::obs::count(crate::obs::Counter::GaPruned, 1);
                    continue;
                }
            }
            let parent = parents.get(i).copied().flatten().map(|a| genomes[a].clone());
            jobs.push((g.clone(), parent));
        }

        let (workload, arch, scheduler, priority) =
            (self.workload, self.arch, self.scheduler, self.priority);
        let cache = match &self.cache {
            CacheRef::Owned(c) => c.as_ref(),
            CacheRef::Shared(c) => c,
        };
        let delta = self.delta.as_ref();
        let every = scheduler.snap_interval();
        let threads = thread_count(self.params.threads);
        let topo_fp = arch.topology.fingerprint();
        crate::obs::count(crate::obs::Counter::GaEvals, jobs.len() as u64);
        let results: Vec<(Vec<u16>, ScheduleMetrics)> = parallel_map_with(
            jobs,
            |(g, parent)| {
                let alloc = allocation_from_genome(workload, arch, &g);
                let m = match (cache.get(&alloc, priority, topo_fp), delta) {
                    (Some(m), _) => m,
                    (None, None) => {
                        let m = scheduler.run(&alloc, priority).metrics;
                        cache.insert(&alloc, priority, topo_fp, m);
                        m
                    }
                    (None, Some(dc)) => {
                        let warm = parent.as_ref().and_then(|pg| {
                            let pa = allocation_from_genome(workload, arch, pg);
                            let e = dc.get(&pa, priority, topo_fp)?;
                            let d = e.segments.divergence(&e.allocation, &alloc);
                            scheduler.run_resumed_traced(&alloc, priority, &e.segments, d, every)
                        });
                        let (res, segs) = warm.unwrap_or_else(|| {
                            scheduler.run_traced(&alloc, priority, every)
                        });
                        dc.insert(&alloc, priority, topo_fp, res.metrics, segs);
                        cache.insert(&alloc, priority, topo_fp, res.metrics);
                        res.metrics
                    }
                };
                (g, m)
            },
            threads,
        );
        for (g, m) in results {
            self.evaluated_metrics.entry(g).or_insert(m);
        }
        genomes.iter().map(|g| self.evaluated_metrics[g]).collect()
    }

    /// Run the GA on the shared evolutionary driver
    /// ([`evolve`](fn@super::evolve)); returns the final Pareto front
    /// (deduplicated), best EDP first.
    pub fn run(&mut self) -> Vec<GaResult> {
        let params = self.params;
        let outcome = evolve(self, &params);
        let mut results: Vec<GaResult> = outcome
            .front
            .iter()
            .map(|&i| {
                let genome = outcome.evaluated[i].0.clone();
                let metrics = self.evaluated_metrics[&genome];
                GaResult {
                    allocation: allocation_from_genome(self.workload, self.arch, &genome),
                    genome,
                    metrics,
                }
            })
            .collect();
        // total_cmp: a NaN EDP would make the partial_cmp-or-Equal
        // comparator inconsistent and the sort order arbitrary
        results.sort_by(|a, b| a.metrics.edp().total_cmp(&b.metrics.edp()));
        results
    }
}

/// The [`Ga`]'s instantiation of the shared evolutionary driver: the
/// genome assigns every dense layer one dense core, fitness is the
/// (cached, possibly parallel) schedule simulation projected through
/// [`Objective::values`], and the patience scalarization is the plain
/// objective product.
impl EvoProblem for Ga<'_> {
    fn genome_len(&self) -> usize {
        self.workload.dense_layers().len()
    }

    fn n_cores(&self) -> usize {
        self.arch.dense_cores().len()
    }

    /// Heuristic seed genomes: round-robin ping-pong, each
    /// single-core-only assignment, and per-layer greedy minimum-EDP —
    /// cheap starting points the GA refines (it converges far faster on
    /// 50-gene genomes than from pure noise).
    fn seed_genomes(&self) -> Vec<Vec<u16>> {
        let n = self.genome_len();
        let k = self.n_cores();
        let mut seeds = Vec::new();
        // ping-pong
        seeds.push((0..n).map(|i| (i % k) as u16).collect());
        // each core alone
        for c in 0..k {
            seeds.push(vec![c as u16; n]);
        }
        // greedy: per dense layer, the core with the lowest CN edp
        let dense_cores = self.arch.dense_cores();
        let mut greedy = Vec::with_capacity(n);
        for lid in self.workload.dense_layers() {
            let cn = &self.scheduler.graph.cns.layer_cns(lid)[0];
            let best = (0..dense_cores.len())
                .min_by(|&a, &b| {
                    let ca = self.scheduler.costs.cn_cost(cn, dense_cores[a]).edp();
                    let cb = self.scheduler.costs.cn_cost(cn, dense_cores[b]).edp();
                    ca.total_cmp(&cb)
                })
                .unwrap_or(0);
            greedy.push(best as u16);
        }
        seeds.push(greedy);
        seeds
    }

    fn evaluate(&mut self, genomes: &[Vec<u16>]) -> Vec<Vec<f64>> {
        self.evaluate_with_parents(genomes, &vec![None; genomes.len()])
    }

    /// The driver's lineage hints feed the delta-evaluation path
    /// (`Ga::eval_metrics`); results are identical with or without
    /// them.
    fn evaluate_with_parents(
        &mut self,
        genomes: &[Vec<u16>],
        parents: &[Option<usize>],
    ) -> Vec<Vec<f64>> {
        let metrics = self.eval_metrics(genomes, parents);
        metrics.iter().map(|m| self.objective.values(m)).collect()
    }

    // scalarize: the trait's default (objective product) is exactly the
    // historical Ga saturation criterion.
}

/// The manual baselines of Section V-A: ping-pong across cores for
/// homogeneous architectures, best-spatial-utilization core for
/// heterogeneous ones.
pub fn manual_allocation(
    workload: &WorkloadGraph,
    arch: &Accelerator,
    costs: &crate::mapping::CostModel,
    cns: &crate::cn::CnSet,
    heterogeneous: bool,
) -> Vec<CoreId> {
    let dense = arch.dense_cores();
    let simd = arch.simd_core().unwrap_or(dense[0]);
    let mut i = 0usize;
    workload
        .layers()
        .iter()
        .map(|l| {
            if !l.op.is_dense() {
                return simd;
            }
            let core = if heterogeneous {
                // pick the dense core with the best spatial utilization
                let cn = &cns.layer_cns(l.id)[0];
                *dense
                    .iter()
                    .max_by(|&&a, &&b| {
                        let ua = costs.cn_cost(cn, a).spatial_util;
                        let ub = costs.cn_cost(cn, b).spatial_util;
                        ua.total_cmp(&ub)
                    })
                    .unwrap()
            } else {
                // ping-pong: subsequent layers on subsequent cores
                dense[i % dense.len()]
            };
            i += 1;
            core
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cn::{CnGranularity, CnSet};
    use crate::depgraph::generate;
    use crate::mapping::CostModel;
    use crate::workload::models::tiny_segment;

    struct Fixture {
        w: WorkloadGraph,
        arch: Accelerator,
        g: crate::depgraph::CnGraph,
        costs: CostModel,
    }

    fn fixture() -> Fixture {
        let w = tiny_segment();
        let arch = presets::hetero_quad();
        let cns = CnSet::build(&w, CnGranularity::Lines(4));
        let costs = CostModel::build(&w, &cns, &arch);
        let g = generate(&w, CnSet::build(&w, CnGranularity::Lines(4)));
        Fixture { w, arch, g, costs }
    }

    #[test]
    fn ga_improves_over_random() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let params = GaParams { population: 12, generations: 8, ..Default::default() };
        let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                             Objective::Edp, params);
        let front = ga.run();
        assert!(!front.is_empty());
        // the best found EDP must beat a deliberately bad allocation
        // (everything on one small core)
        let bad = allocation_from_genome(&f.w, &f.arch, &[0, 0, 0]);
        let bad_m = sched.run(&bad, SchedulePriority::Latency).metrics;
        assert!(front[0].metrics.edp() <= bad_m.edp());
    }

    #[test]
    fn ga_deterministic_for_seed() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let params = GaParams { population: 8, generations: 4, ..Default::default() };
        let run = |seed| {
            let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                                 Objective::Edp, GaParams { seed, ..params });
            ga.run()[0].metrics.edp()
        };
        assert_eq!(run(7).to_bits(), run(7).to_bits());
    }

    #[test]
    fn serial_and_parallel_fitness_identical() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let run = |threads: usize| {
            let params = GaParams {
                population: 10,
                generations: 5,
                threads,
                ..Default::default()
            };
            let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                                 Objective::LatencyMemory, params);
            ga.run()
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.metrics.latency_cc, b.metrics.latency_cc);
            assert_eq!(a.metrics.energy_pj.to_bits(), b.metrics.energy_pj.to_bits());
            assert_eq!(
                a.metrics.peak_mem_bytes.to_bits(),
                b.metrics.peak_mem_bytes.to_bits()
            );
        }
    }

    #[test]
    fn shared_cache_is_reused_across_runs() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let cache = crate::cost::ScheduleCache::new();
        let params = GaParams { population: 8, generations: 3, ..Default::default() };
        let run = || {
            let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                                 Objective::Edp, params)
                .with_cache(&cache);
            ga.run()[0].metrics.edp()
        };
        let first = run();
        let misses_after_first = cache.misses();
        let second = run();
        assert_eq!(first.to_bits(), second.to_bits(), "cache must not change results");
        // the second run re-visits the same genome sequence: every
        // schedule comes from the cache, no new misses
        assert_eq!(cache.misses(), misses_after_first);
        assert!(cache.hits() > 0);
    }

    #[test]
    fn pareto_front_is_nondominated() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let params = GaParams { population: 12, generations: 6, ..Default::default() };
        let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                             Objective::LatencyMemory, params);
        let front = ga.run();
        for a in &front {
            for b in &front {
                let pa = Objective::LatencyMemory.values(&a.metrics);
                let pb = Objective::LatencyMemory.values(&b.metrics);
                assert!(!super::super::nsga2::dominates(&pa, &pb) || pa == pb);
            }
        }
    }

    #[test]
    fn manual_heterogeneous_picks_best_fit() {
        let f = fixture();
        let cns = CnSet::build(&f.w, CnGranularity::Lines(4));
        let alloc = manual_allocation(&f.w, &f.arch, &f.costs, &cns, true);
        // all layers allocated, simd layers pinned
        assert_eq!(alloc.len(), f.w.len());
        assert_eq!(alloc[1], f.arch.simd_core().unwrap());
    }

    #[test]
    fn manual_pingpong_cycles_cores() {
        let f = fixture();
        let cns = CnSet::build(&f.w, CnGranularity::Lines(4));
        let alloc = manual_allocation(&f.w, &f.arch, &f.costs, &cns, false);
        // dense layers 0,2,3 -> cores 0,1,2
        assert_eq!(alloc[0], CoreId(0));
        assert_eq!(alloc[2], CoreId(1));
        assert_eq!(alloc[3], CoreId(2));
    }

    /// Tentpole pin (GA level): the delta-evaluation path must change
    /// nothing observable — same genomes, same bit-exact metrics, same
    /// front order — while actually resuming children from parent
    /// segments (the crate-level fig12 pin lives in
    /// `rust/tests/delta_equivalence.rs`).
    #[test]
    fn incremental_and_full_runs_are_bit_identical() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let run = |incremental: bool| {
            let params = GaParams {
                population: 10,
                generations: 6,
                incremental,
                ..Default::default()
            };
            let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                                 Objective::LatencyMemory, params);
            let front = ga.run();
            if incremental {
                let dc = ga.delta_cache().expect("incremental GA owns a delta cache");
                assert!(dc.stats().0 > 0, "delta path must actually resume children");
            } else {
                assert!(ga.delta_cache().is_none());
            }
            front
        };
        let full = run(false);
        let inc = run(true);
        assert_eq!(full.len(), inc.len());
        for (a, b) in full.iter().zip(&inc) {
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.metrics.latency_cc, b.metrics.latency_cc);
            assert_eq!(a.metrics.energy_pj.to_bits(), b.metrics.energy_pj.to_bits());
            assert_eq!(a.metrics.peak_mem_bytes.to_bits(), b.metrics.peak_mem_bytes.to_bits());
        }
    }

    /// The early-abort still yields a valid, non-dominated front of
    /// exactly-evaluated points (the admissibility sweep lives in
    /// `rust/tests/delta_equivalence.rs`).
    #[test]
    fn lb_prune_front_is_exact_and_nondominated() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let params = GaParams {
            population: 10,
            generations: 6,
            lb_prune: true,
            ..Default::default()
        };
        let mut ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                             Objective::LatencyMemory, params);
        let front = ga.run();
        assert!(!front.is_empty());
        for r in &front {
            // front members carry exact simulated metrics, never floors
            let exact = sched.run(&r.allocation, SchedulePriority::Latency).metrics;
            assert_eq!(r.metrics.latency_cc, exact.latency_cc);
            assert_eq!(r.metrics.energy_pj.to_bits(), exact.energy_pj.to_bits());
        }
        for a in &front {
            for b in &front {
                let pa = Objective::LatencyMemory.values(&a.metrics);
                let pb = Objective::LatencyMemory.values(&b.metrics);
                assert!(!super::super::nsga2::dominates(&pa, &pb) || pa == pb);
            }
        }
    }

    /// The driver's variation operators produce genomes the expansion
    /// accepts (the operator-level unit tests live in `evolve.rs`).
    #[test]
    fn driver_variation_expands_to_valid_allocations() {
        let f = fixture();
        let sched = Scheduler::new(&f.w, &f.g, &f.costs, &f.arch);
        let ga = Ga::new(&f.w, &f.arch, &sched, SchedulePriority::Latency,
                         Objective::Edp, GaParams::default());
        let mut rng = crate::util::XorShift64::new(1);
        let a = super::super::evolve::random_genome(ga.genome_len(), ga.n_cores(), &mut rng);
        let b = super::super::evolve::random_genome(ga.genome_len(), ga.n_cores(), &mut rng);
        for _ in 0..50 {
            let mut c = super::super::evolve::crossover(&a, &b, &mut rng);
            super::super::evolve::mutate(&mut c, ga.n_cores(), &mut rng);
            assert_eq!(c.len(), a.len());
            let alloc = allocation_from_genome(&f.w, &f.arch, &c);
            assert_eq!(alloc.len(), f.w.len());
        }
    }
}
