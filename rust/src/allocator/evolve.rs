//! The shared (μ+λ) evolutionary driver.
//!
//! The single-model [`Ga`](super::Ga) and the scenario-level
//! [`ScenarioGa`](crate::scenario::ScenarioGa) used to carry two
//! hand-mirrored copies of the same loop — population init + seeding,
//! ordered two-point crossover / gene-or-swap mutation, NSGA-II
//! environmental selection ([`select_survivors`]), patience-based
//! early stopping and the final Pareto-front extraction.  [`evolve`]
//! is that loop, written once; a search instantiates it by
//! implementing [`EvoProblem`] (genome shape, seed genomes, batched
//! fitness, the patience scalarization).
//!
//! Determinism guarantees carried over from both originals:
//!
//! - the RNG ([`XorShift64`]) is consumed in exactly the same order as
//!   the historical loops, so a fixed [`GaParams::seed`] reproduces
//!   the historical trajectories bit-for-bit;
//! - every evaluated genome is recorded in **first-seen order** and
//!   the final front is computed over that record, so neither hash-map
//!   iteration order, a pre-warmed fitness memo, nor the evaluation
//!   thread count can perturb the result
//!   (`rust/tests/evolve_pinning.rs`, `rust/tests/parallel_equivalence.rs`).

use std::collections::HashSet;

use super::ga::GaParams;
use super::nsga2::{fast_non_dominated_sort, select_survivors};
use crate::util::XorShift64;

/// What a search must provide to run on the shared driver.
///
/// All objectives are minimized.  `evaluate` is **batched** so an
/// implementation can dispatch unseen genomes to parallel workers (the
/// single-model GA does) or loop serially (the scenario GA does); it
/// must return one objective vector per input genome,
/// order-preserving.
///
/// # Examples
///
/// ```
/// use stream::allocator::{evolve, EvoProblem, GaParams};
///
/// /// Toy search: minimize the number of nonzero genes.
/// struct ZeroMin;
/// impl EvoProblem for ZeroMin {
///     fn genome_len(&self) -> usize { 4 }
///     fn n_cores(&self) -> usize { 2 }
///     fn evaluate(&mut self, genomes: &[Vec<u16>]) -> Vec<Vec<f64>> {
///         genomes
///             .iter()
///             .map(|g| vec![g.iter().filter(|&&v| v != 0).count() as f64])
///             .collect()
///     }
/// }
///
/// let params = GaParams { population: 16, generations: 10, ..Default::default() };
/// let out = evolve(&mut ZeroMin, &params);
/// assert!(!out.front.is_empty());
/// let best = &out.evaluated[out.front[0]];
/// assert!(best.1[0] <= 1.0, "driver must nearly zero the genome");
/// ```
pub trait EvoProblem {
    /// Gene count of one genome.
    fn genome_len(&self) -> usize;
    /// Exclusive upper bound of every gene value (the core count).
    fn n_cores(&self) -> usize;
    /// Heuristic starting genomes; truncated / padded with random
    /// genomes to the population size.
    fn seed_genomes(&self) -> Vec<Vec<u16>> {
        Vec::new()
    }
    /// Objective vectors (all minimized) of `genomes`, order-preserving.
    fn evaluate(&mut self, genomes: &[Vec<u16>]) -> Vec<Vec<f64>>;
    /// Like [`evaluate`](Self::evaluate), but with the driver's
    /// lineage hints: `parents[i]` is the index *within this batch* of
    /// the primary (gene-order) parent genome `i` was derived from, or
    /// `None` for genomes with no in-batch parent (the surviving
    /// population, seeds).  An implementation with an incremental
    /// fitness path (the single-model GA's delta evaluation) uses the
    /// hint to re-simulate only from where child and parent diverge;
    /// the result must be identical to [`evaluate`](Self::evaluate) —
    /// the hints are an optimization channel, never a semantic one.
    /// Defaults to ignoring the hints.
    fn evaluate_with_parents(
        &mut self,
        genomes: &[Vec<u16>],
        parents: &[Option<usize>],
    ) -> Vec<Vec<f64>> {
        debug_assert_eq!(genomes.len(), parents.len());
        let _ = parents;
        self.evaluate(genomes)
    }
    /// Scalarization used only by the patience-based early-stopping
    /// check (default: product of the objectives).
    fn scalarize(&self, point: &[f64]) -> f64 {
        point.iter().product()
    }
}

/// The driver's result: every distinct genome evaluated (first-seen
/// order) and the deduplicated first Pareto front over that record.
pub struct EvolveOutcome {
    /// `(genome, objective vector)` per distinct genome, in
    /// deterministic first-seen order.
    pub evaluated: Vec<(Vec<u16>, Vec<f64>)>,
    /// Indices into [`evaluated`](Self::evaluated) of the first
    /// non-dominated front, deduplicated by objective vector.
    pub front: Vec<usize>,
}

/// One random genome (every gene uniform below the core count).
pub(crate) fn random_genome(len: usize, n_cores: usize, rng: &mut XorShift64) -> Vec<u16> {
    (0..len).map(|_| rng.below(n_cores as u64) as u16).collect()
}

/// Ordered two-point crossover: child takes parent A's gene order
/// outside the cut and parent B's inside (assignment-genome variant of
/// the paper's ordered crossover).
pub(crate) fn crossover(a: &[u16], b: &[u16], rng: &mut XorShift64) -> Vec<u16> {
    let n = a.len();
    if n < 2 {
        return a.to_vec();
    }
    let mut lo = rng.below(n as u64) as usize;
    let mut hi = rng.below(n as u64) as usize;
    if lo > hi {
        std::mem::swap(&mut lo, &mut hi);
    }
    let mut child = a.to_vec();
    child[lo..=hi].copy_from_slice(&b[lo..=hi]);
    child
}

/// Mutation: bit flip (random gene to a random core) or position flip
/// (swap two genes), 50/50.
pub(crate) fn mutate(g: &mut [u16], n_cores: usize, rng: &mut XorShift64) {
    let n = g.len();
    if n == 0 {
        return;
    }
    if rng.unit() < 0.5 || n == 1 {
        let i = rng.below(n as u64) as usize;
        g[i] = rng.below(n_cores as u64) as u16;
    } else {
        let i = rng.below(n as u64) as usize;
        let j = rng.below(n as u64) as usize;
        g.swap(i, j);
    }
}

/// Run the (μ+λ) evolutionary loop on `problem` under `params`; see
/// the [module docs](self) and [`EvoProblem`].
pub fn evolve<P: EvoProblem + ?Sized>(problem: &mut P, params: &GaParams) -> EvolveOutcome {
    let mut rng = XorShift64::new(params.seed);
    let pop_size = params.population.max(4);
    let mut population = problem.seed_genomes();
    population.truncate(pop_size);
    while population.len() < pop_size {
        population.push(random_genome(problem.genome_len(), problem.n_cores(), &mut rng));
    }

    // every distinct genome in deterministic first-seen order — the
    // final front is computed over this record, so the result cannot
    // depend on hash-map iteration order or on what a shared fitness
    // memo already contained
    let mut evaluated: Vec<(Vec<u16>, Vec<f64>)> = Vec::new();
    let mut known: HashSet<Vec<u16>> = HashSet::new();

    let mut best_scalar = f64::INFINITY;
    let mut stale = 0usize;

    for _gen in 0..params.generations {
        let _gen_span = crate::obs::span_here("ga", "generation");
        // --- variation: offspring from the current population ---
        // Each offspring remembers its primary (gene-order) parent `a`;
        // since the evaluation pool is population ++ offspring, `a`'s
        // population index doubles as its pool index for the lineage
        // hints handed to `evaluate_with_parents`.
        let mut offspring = Vec::with_capacity(pop_size);
        let mut parents: Vec<Option<usize>> = vec![None; pop_size];
        for _ in 0..pop_size {
            let ai = rng.below(population.len() as u64) as usize;
            let a = &population[ai];
            let b = &population[rng.below(population.len() as u64) as usize];
            let mut child = if rng.unit() < params.crossover_p {
                crossover(a, b, &mut rng)
            } else {
                a.clone()
            };
            if rng.unit() < params.mutation_p {
                mutate(&mut child, problem.n_cores(), &mut rng);
            }
            offspring.push(child);
            parents.push(Some(ai));
        }

        // --- fitness over parents+children, recorded first-seen ---
        let mut pool: Vec<Vec<u16>> = population.clone();
        pool.extend(offspring);
        let points = problem.evaluate_with_parents(&pool, &parents);
        debug_assert_eq!(points.len(), pool.len(), "one objective vector per genome");
        for (g, p) in pool.iter().zip(&points) {
            // check before cloning: surviving parents resurface every
            // generation and are already recorded
            if !known.contains(g) {
                known.insert(g.clone());
                evaluated.push((g.clone(), p.clone()));
            }
        }

        // --- NSGA-II environmental selection ---
        let survivors = select_survivors(&points, pop_size);
        population = survivors.iter().map(|&i| pool[i].clone()).collect();

        if crate::obs::enabled() {
            crate::obs::count(crate::obs::Counter::GaGenerations, 1);
            let front_size =
                fast_non_dominated_sort(&points).first().map_or(0, |f| f.len());
            crate::obs::hist(crate::obs::Hist::GaFrontSize, front_size as u64);
        }

        // --- saturation check on the best scalarized objective ---
        let gen_best = points
            .iter()
            .map(|p| problem.scalarize(p))
            .fold(f64::INFINITY, f64::min);
        if gen_best < best_scalar * 0.999 {
            best_scalar = gen_best;
            stale = 0;
        } else {
            stale += 1;
            if stale >= params.patience {
                break;
            }
        }
    }

    // --- final Pareto front over every genome evaluated ---
    let points: Vec<Vec<f64>> = evaluated.iter().map(|(_, p)| p.clone()).collect();
    let fronts = fast_non_dominated_sort(&points);
    let mut seen = HashSet::new();
    let front = fronts
        .first()
        .map(|f| {
            f.iter()
                .filter(|&&i| {
                    seen.insert(points[i].iter().map(|v| v.to_bits()).collect::<Vec<_>>())
                })
                .copied()
                .collect()
        })
        .unwrap_or_default();
    EvolveOutcome { evaluated, front }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Single objective: the sum of the genes.
    struct SumMin {
        len: usize,
        cores: usize,
        calls: usize,
    }

    impl EvoProblem for SumMin {
        fn genome_len(&self) -> usize {
            self.len
        }
        fn n_cores(&self) -> usize {
            self.cores
        }
        fn evaluate(&mut self, genomes: &[Vec<u16>]) -> Vec<Vec<f64>> {
            self.calls += 1;
            genomes
                .iter()
                .map(|g| vec![g.iter().map(|&v| v as f64).sum()])
                .collect()
        }
    }

    fn params(seed: u64) -> GaParams {
        GaParams {
            population: 16,
            generations: 40,
            patience: 40,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn driver_finds_the_all_zero_optimum() {
        let mut p = SumMin { len: 4, cores: 2, calls: 0 };
        let out = evolve(&mut p, &params(42));
        assert!(!out.front.is_empty());
        let best = &out.evaluated[out.front[0]];
        assert_eq!(best.1[0], 0.0, "16x40 evaluations over a 16-genome space");
        assert_eq!(best.0, vec![0u16; 4]);
        assert!(p.calls > 0);
    }

    #[test]
    fn driver_is_deterministic_for_a_seed() {
        let run = |seed| {
            let mut p = SumMin { len: 6, cores: 3, calls: 0 };
            let out = evolve(&mut p, &params(seed));
            (out.evaluated, out.front)
        };
        let (ea, fa) = run(7);
        let (eb, fb) = run(7);
        assert_eq!(fa, fb);
        assert_eq!(ea.len(), eb.len());
        for ((ga, pa), (gb, pb)) in ea.iter().zip(&eb) {
            assert_eq!(ga, gb);
            assert_eq!(pa, pb);
        }
        // a different seed explores a different trajectory
        let (ec, _) = run(8);
        assert!(
            ea.iter().zip(&ec).any(|(x, y)| x.0 != y.0) || ea.len() != ec.len(),
            "seeds must matter"
        );
    }

    #[test]
    fn record_is_first_seen_unique() {
        let mut p = SumMin { len: 3, cores: 2, calls: 0 };
        let out = evolve(&mut p, &params(3));
        let mut seen = std::collections::HashSet::new();
        for (g, _) in &out.evaluated {
            assert!(seen.insert(g.clone()), "genome {g:?} recorded twice");
        }
        // front indices are valid and non-dominated within the record
        for &i in &out.front {
            assert!(i < out.evaluated.len());
            for &j in &out.front {
                let (a, b) = (&out.evaluated[i].1, &out.evaluated[j].1);
                assert!(!crate::allocator::dominates(a, b) || a == b);
            }
        }
    }

    #[test]
    fn variation_keeps_genomes_valid() {
        let mut rng = XorShift64::new(1);
        let a = random_genome(8, 3, &mut rng);
        let b = random_genome(8, 3, &mut rng);
        assert!(a.iter().all(|&v| v < 3));
        for _ in 0..100 {
            let mut c = crossover(&a, &b, &mut rng);
            mutate(&mut c, 3, &mut rng);
            assert_eq!(c.len(), a.len());
            assert!(c.iter().all(|&v| v < 3), "{c:?}");
        }
    }

    /// The lineage hints handed to `evaluate_with_parents`: the
    /// surviving population leads the batch with no parent, every
    /// offspring points at an in-batch population index, and (modulo
    /// variation) the child actually derives from that genome.
    struct HintCheck {
        inner: SumMin,
        batches: usize,
    }

    impl EvoProblem for HintCheck {
        fn genome_len(&self) -> usize {
            self.inner.genome_len()
        }
        fn n_cores(&self) -> usize {
            self.inner.n_cores()
        }
        fn evaluate(&mut self, genomes: &[Vec<u16>]) -> Vec<Vec<f64>> {
            self.inner.evaluate(genomes)
        }
        fn evaluate_with_parents(
            &mut self,
            genomes: &[Vec<u16>],
            parents: &[Option<usize>],
        ) -> Vec<Vec<f64>> {
            self.batches += 1;
            assert_eq!(genomes.len(), parents.len());
            let pop = genomes.len() / 2;
            for (i, p) in parents.iter().enumerate() {
                match p {
                    None => assert!(i < pop, "only the population rides hint-free"),
                    Some(a) => {
                        assert!(i >= pop, "offspring only in the back half");
                        assert!(*a < pop, "parent must be an in-batch population index");
                    }
                }
            }
            self.evaluate(genomes)
        }
    }

    #[test]
    fn lineage_hints_point_into_the_population() {
        let mut p = HintCheck { inner: SumMin { len: 5, cores: 3, calls: 0 }, batches: 0 };
        let out = evolve(&mut p, &params(11));
        assert!(p.batches > 0, "the driver must route through evaluate_with_parents");
        assert!(!out.front.is_empty());
    }

    #[test]
    fn empty_generations_yield_empty_outcome() {
        let mut p = SumMin { len: 4, cores: 2, calls: 0 };
        let out = evolve(&mut p, &GaParams { generations: 0, ..params(1) });
        assert!(out.evaluated.is_empty());
        assert!(out.front.is_empty());
    }
}
