//! NSGA-II primitives: Pareto dominance, fast non-dominated sorting and
//! crowding distance (Deb et al. [7]).

/// Does `a` Pareto-dominate `b` (all objectives <=, at least one <)?
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort: partitions indices into fronts, best first.
pub fn fast_non_dominated_sort(points: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = points.len();
    let mut dominated_by: Vec<usize> = vec![0; n]; // count dominating me
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&points[i], &points[j]) {
                dominates_list[i].push(j);
                dominated_by[j] += 1;
            } else if dominates(&points[j], &points[i]) {
                dominates_list[j].push(i);
                dominated_by[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> =
        (0..n).filter(|&i| dominated_by[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// NSGA-II (μ+λ) environmental selection: the indices of the
/// `pop_size` survivors of one parents+offspring pool, by
/// non-domination rank first and crowding distance within the
/// splitting front — the selection step shared by the single-model
/// [`Ga`](crate::allocator::Ga) and the scenario-level
/// [`ScenarioGa`](crate::scenario::ScenarioGa).
pub fn select_survivors(points: &[Vec<f64>], pop_size: usize) -> Vec<usize> {
    let fronts = fast_non_dominated_sort(points);
    let mut survivors: Vec<usize> = Vec::with_capacity(pop_size);
    for front in &fronts {
        if survivors.len() + front.len() <= pop_size {
            survivors.extend_from_slice(front);
        } else {
            let d = crowding_distance(front, points);
            let mut order: Vec<usize> = (0..front.len()).collect();
            // total_cmp keeps the comparator a total order even if a
            // distance were NaN (partial_cmp-or-Equal is inconsistent
            // there, which is UB-adjacent for sort_by)
            order.sort_by(|&x, &y| d[y].total_cmp(&d[x]));
            for &w in order.iter().take(pop_size - survivors.len()) {
                survivors.push(front[w]);
            }
            break;
        }
    }
    survivors
}

/// Crowding distance of each member of one front (index-aligned).
/// Boundary points get +inf so they always survive.
pub fn crowding_distance(front: &[usize], points: &[Vec<f64>]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m == 0 {
        return dist;
    }
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let n_obj = points[front[0]].len();
    for obj in 0..n_obj {
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| points[front[a]][obj].total_cmp(&points[front[b]][obj]));
        let lo = points[front[order[0]]][obj];
        let hi = points[front[order[m - 1]]][obj];
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let span = (hi - lo).max(f64::MIN_POSITIVE);
        for w in 1..m - 1 {
            let prev = points[front[order[w - 1]]][obj];
            let next = points[front[order[w + 1]]][obj];
            dist[order[w]] += (next - prev) / span;
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal
    }

    #[test]
    fn sort_known_fronts() {
        let pts = vec![
            vec![1.0, 4.0], // front 0
            vec![2.0, 3.0], // front 0
            vec![3.0, 3.5], // dominated by [2,3]
            vec![4.0, 1.0], // front 0
            vec![5.0, 5.0], // dominated by everything
        ];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts[0], vec![0, 1, 3]);
        assert_eq!(fronts[1], vec![2]);
        assert_eq!(fronts[2], vec![4]);
    }

    #[test]
    fn sort_single_objective_is_total_order() {
        let pts = vec![vec![3.0], vec![1.0], vec![2.0]];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts.len(), 3);
        assert_eq!(fronts[0], vec![1]);
    }

    #[test]
    fn select_survivors_ranks_then_spreads() {
        let pts = vec![
            vec![1.0, 4.0], // front 0
            vec![2.0, 3.0], // front 0
            vec![3.0, 3.5], // front 1
            vec![4.0, 1.0], // front 0
            vec![5.0, 5.0], // front 2
        ];
        // the whole first front fits exactly
        assert_eq!(select_survivors(&pts, 3), vec![0, 1, 3]);
        // splitting the first front keeps the boundary (infinite
        // crowding) points, in deterministic stable-sort order
        assert_eq!(select_survivors(&pts, 2), vec![0, 3]);
        // room for everyone: ranks concatenate
        assert_eq!(select_survivors(&pts, 5), vec![0, 1, 3, 2, 4]);
    }

    #[test]
    fn crowding_boundaries_infinite() {
        let pts = vec![vec![1.0, 4.0], vec![2.0, 3.0], vec![3.0, 2.0], vec![4.0, 1.0]];
        let front: Vec<usize> = (0..4).collect();
        let d = crowding_distance(&front, &pts);
        assert!(d[0].is_infinite());
        assert!(d[3].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
    }

    #[test]
    fn crowding_prefers_spread() {
        // middle point crammed next to index 0 gets lower distance
        let pts = vec![vec![0.0, 10.0], vec![0.5, 9.5], vec![5.0, 5.0], vec![10.0, 0.0]];
        let front: Vec<usize> = (0..4).collect();
        let d = crowding_distance(&front, &pts);
        assert!(d[2] > d[1]);
    }
}
