//! Fuse/cut decisions as a genome axis: co-search of fusion
//! granularity and core allocation.
//!
//! The classic Step 4 GA ([`Ga`](super::Ga)) searches core allocations
//! under ONE fixed CN graph — the fusion regime (all-fuse `Lines(k)` or
//! all-cut `LayerByLayer`) is picked up front and never revisited.
//! [`FusionGa`] widens the genome with one **fuse gene per workload
//! edge** (decoded by [`FusePattern`]): the same (μ+λ) NSGA-II driver
//! ([`evolve`](fn@super::evolve)) now explores mixed patterns where
//! some boundaries stream line-by-line and others fully materialize,
//! jointly with the per-layer core assignment.
//!
//! Genome layout: `[n_dense core genes][n_edges fuse genes]`.  The
//! core prefix expands exactly like the classic genome
//! ([`allocation_from_genome`]); the fuse suffix decodes per
//! [`FusePattern::decode`].  In **pinned** mode
//! ([`FusionGa::pinned`]) the suffix is fixed and the genome carries
//! only the core prefix — a pinned all-fuse (or all-cut) `FusionGa`
//! consumes the RNG exactly like a plain [`Ga`](super::Ga) over the
//! corresponding uniform graph, so the regime searches inside
//! [`Stream::run_fuse_search`](crate::pipeline::Stream::run_fuse_search)
//! reproduce the classic trajectories bit-for-bit
//! (`rust/tests/fusion_axis_equivalence.rs`).
//!
//! Every distinct decoded pattern needs its own Step 1–3
//! precomputation (CN split, dependency graph, cost model); a
//! [`PatternCache`] memoizes those behind
//! [`FusePattern::fingerprint`], shared across the regime and
//! co-search phases.  Schedule metrics stay in the ordinary
//! [`ScheduleCache`] / [`DeltaCache`], keyed with
//! [`compose_fp`]`(topology_fp, pattern_fp)` in place of the raw
//! topology fingerprint — identical allocations under different
//! patterns can never alias, and delta resumes are restricted to
//! same-pattern parents.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use super::allocation_from_genome;
use super::evolve::{evolve, EvoProblem};
use super::ga::{GaParams, Objective};
use super::nsga2::dominates;
use crate::arch::{Accelerator, CoreId};
use crate::cn::fuse::{n_fuse_genes, FusePattern};
use crate::cost::{compose_fp, DeltaCache, ScheduleCache, ScheduleMetrics};
use crate::depgraph::{generate_fused, CnGraph};
use crate::mapping::CostModel;
use crate::scheduler::{SchedulePriority, Scheduler};
use crate::util::{parallel_map_with, thread_count};
use crate::workload::WorkloadGraph;

/// Options of the fusion co-search (carried on
/// [`StreamOpts::fuse`](crate::pipeline::StreamOpts)).
#[derive(Debug, Clone)]
pub struct FuseSearchOpts {
    /// Candidate line granularities for fused segments.  A fuse gene
    /// value `m > 0` fuses its edge at `menu[m - 1]` lines; a 1-entry
    /// menu degenerates to one fuse/cut bit per edge.
    pub menu: Vec<usize>,
}

impl Default for FuseSearchOpts {
    fn default() -> Self {
        FuseSearchOpts { menu: vec![4] }
    }
}

/// The Step 1–3 precomputation of one decoded fuse pattern: the
/// mixed-granularity CN graph and its cost model.  Schedulers borrow
/// from this, so it is shared behind an [`Arc`] via [`PatternCache`].
pub struct PatternCtx {
    pub pattern: FusePattern,
    pub graph: CnGraph,
    pub costs: CostModel,
}

impl PatternCtx {
    /// Run Steps 1–3 under `pattern` (split → fused dependency graph →
    /// cost model), in the exact order of the classic pipeline.
    pub fn build(
        workload: &WorkloadGraph,
        arch: &Accelerator,
        pattern: FusePattern,
    ) -> PatternCtx {
        let cns = pattern.build_cns(workload);
        let graph = generate_fused(workload, cns, &pattern);
        let costs = CostModel::build(workload, &graph.cns, arch);
        PatternCtx { pattern, graph, costs }
    }
}

/// Thread-safe memo of [`PatternCtx`]s keyed by
/// [`FusePattern::fingerprint`] — gene vectors decoding to the same
/// pattern share one precomputed context.  Two workers racing on the
/// same missing fingerprint may both build it; the build is
/// deterministic, so whichever insert lands first wins and the race is
/// benign (the loser's context is dropped).
#[derive(Default)]
pub struct PatternCache {
    map: Mutex<HashMap<u64, Arc<PatternCtx>>>,
}

impl PatternCache {
    pub fn new() -> PatternCache {
        PatternCache::default()
    }

    /// The context for `pattern`, building it (outside the lock) on
    /// first sight.
    pub fn get_or_build(
        &self,
        workload: &WorkloadGraph,
        arch: &Accelerator,
        pattern: FusePattern,
    ) -> Arc<PatternCtx> {
        let fp = pattern.fingerprint();
        if let Some(ctx) = self.map.lock().unwrap().get(&fp) {
            return Arc::clone(ctx);
        }
        let built = Arc::new(PatternCtx::build(workload, arch, pattern));
        let mut map = self.map.lock().unwrap();
        Arc::clone(map.entry(fp).or_insert(built))
    }

    /// Number of distinct patterns precomputed so far.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One Pareto-front member of the co-search.
#[derive(Debug, Clone)]
pub struct FusionResult {
    /// The full genome (`[core genes][fuse genes]`; pinned mode: core
    /// genes only).
    pub genome: Vec<u16>,
    pub core_genes: Vec<u16>,
    pub fuse_genes: Vec<u16>,
    pub allocation: Vec<CoreId>,
    pub metrics: ScheduleMetrics,
    /// Fingerprint of the decoded pattern (the [`PatternCache`] /
    /// schedule-cache key component).
    pub pattern_fp: u64,
    pub n_cut: usize,
    pub n_fused: usize,
}

/// The co-search engine: the classic GA's evaluation machinery
/// (dedup, memoization, delta evaluation, optional lower-bound prune,
/// parallel dispatch) generalized to genomes that select their own CN
/// graph.  See the [module docs](self).
pub struct FusionGa<'a> {
    pub workload: &'a WorkloadGraph,
    pub arch: &'a Accelerator,
    pub priority: SchedulePriority,
    pub objective: Objective,
    pub params: GaParams,
    /// Line-granularity menu for fused segments.
    pub menu: Vec<usize>,
    /// `Some(fuse_genes)`: regime mode — the fuse suffix is fixed and
    /// the genome carries only the core prefix.
    pinned: Option<Vec<u16>>,
    /// Extra seed genomes tried before the heuristics (free mode only;
    /// `run_fuse_search` injects the regime winners here, which is what
    /// makes the co-search front weakly dominate both regimes by
    /// construction).
    extra_seeds: Vec<Vec<u16>>,
    patterns: &'a PatternCache,
    cache: &'a ScheduleCache,
    delta: Option<DeltaCache>,
    pruned: HashSet<Vec<u16>>,
    evaluated_metrics: HashMap<Vec<u16>, ScheduleMetrics>,
}

impl<'a> FusionGa<'a> {
    /// A free co-search over `[core genes][fuse genes]` genomes.  Both
    /// caches are caller-owned so the regime and co-search phases of
    /// one `run_fuse_search` share every precomputation; the same
    /// `STREAM_INCREMENTAL` override as [`Ga::new`](super::Ga::new)
    /// applies.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        workload: &'a WorkloadGraph,
        arch: &'a Accelerator,
        priority: SchedulePriority,
        objective: Objective,
        params: GaParams,
        menu: Vec<usize>,
        patterns: &'a PatternCache,
        cache: &'a ScheduleCache,
    ) -> FusionGa<'a> {
        assert!(!menu.is_empty(), "fuse menu must list at least one line granularity");
        let mut params = params;
        if let Ok(v) = std::env::var("STREAM_INCREMENTAL") {
            match v.as_str() {
                "0" | "off" => (params.incremental, params.lb_prune) = (false, false),
                "1" | "delta" => (params.incremental, params.lb_prune) = (true, false),
                "2" | "prune" => (params.incremental, params.lb_prune) = (true, true),
                _ => {}
            }
        }
        let delta = params
            .incremental
            .then(|| DeltaCache::new((2 * params.population).max(64)));
        FusionGa {
            workload,
            arch,
            priority,
            objective,
            params,
            menu,
            pinned: None,
            extra_seeds: Vec::new(),
            patterns,
            cache,
            delta,
            pruned: HashSet::new(),
            evaluated_metrics: HashMap::new(),
        }
    }

    /// Pin the fuse suffix: the genome degenerates to the core prefix
    /// and the search explores allocations under one fixed pattern —
    /// genome shape, seed heuristics and RNG consumption all match the
    /// plain [`Ga`](super::Ga), so a pinned regime run reproduces the
    /// classic trajectory.
    pub fn pinned(mut self, fuse_genes: Vec<u16>) -> FusionGa<'a> {
        assert_eq!(
            fuse_genes.len(),
            n_fuse_genes(self.workload),
            "one pinned fuse gene per workload edge"
        );
        self.pinned = Some(fuse_genes);
        self
    }

    /// Seed genomes tried before the built-in heuristics (free mode).
    pub fn with_extra_seeds(mut self, seeds: Vec<Vec<u16>>) -> FusionGa<'a> {
        self.extra_seeds = seeds;
        self
    }

    pub fn delta_cache(&self) -> Option<&DeltaCache> {
        self.delta.as_ref()
    }

    pub fn pruned_count(&self) -> usize {
        self.pruned.len()
    }

    fn n_dense(&self) -> usize {
        self.workload.dense_layers().len()
    }

    /// Decode the fuse suffix of a genome (or the pinned suffix).
    fn pattern_of(&self, genome: &[u16]) -> FusePattern {
        let fuse = match &self.pinned {
            Some(p) => p.as_slice(),
            None => &genome[self.n_dense()..],
        };
        FusePattern::decode(self.workload, self.arch, &self.menu, fuse)
    }

    /// The classic heuristic core seeds (ping-pong, each core alone,
    /// per-layer greedy minimum-EDP), with the greedy pass costed
    /// under `greedy_ctx` — gene for gene what
    /// [`Ga::seed_genomes`](super::Ga) produces over the same graph.
    fn core_seed_genomes(&self, greedy_ctx: &PatternCtx) -> Vec<Vec<u16>> {
        let n = self.n_dense();
        let dense_cores = self.arch.dense_cores();
        let k = dense_cores.len();
        let mut seeds: Vec<Vec<u16>> = Vec::new();
        seeds.push((0..n).map(|i| (i % k) as u16).collect());
        for c in 0..k {
            seeds.push(vec![c as u16; n]);
        }
        let mut greedy = Vec::with_capacity(n);
        for lid in self.workload.dense_layers() {
            let cn = &greedy_ctx.graph.cns.layer_cns(lid)[0];
            let best = (0..k)
                .min_by(|&a, &b| {
                    let ca = greedy_ctx.costs.cn_cost(cn, dense_cores[a]).edp();
                    let cb = greedy_ctx.costs.cn_cost(cn, dense_cores[b]).edp();
                    ca.total_cmp(&cb)
                })
                .unwrap_or(0);
            greedy.push(best as u16);
        }
        seeds.push(greedy);
        seeds
    }

    /// Fitness of every genome (order-preserving), mirroring
    /// `Ga::eval_metrics` phase for phase; the only structural
    /// difference is that each job resolves its own [`PatternCtx`] and
    /// keys the caches with the composed fingerprint.
    fn eval_metrics(
        &mut self,
        genomes: &[Vec<u16>],
        parents: &[Option<usize>],
    ) -> Vec<ScheduleMetrics> {
        let n_dense = self.n_dense();
        let archive: Vec<Vec<f64>> = if self.params.lb_prune {
            self.evaluated_metrics
                .iter()
                .filter(|(g, _)| !self.pruned.contains(g.as_slice()))
                .map(|(_, m)| self.objective.values(m))
                .collect()
        } else {
            Vec::new()
        };

        // serial pre-pass: dedup + pattern-context resolution, in
        // first-seen order (the PatternCache build order is therefore
        // deterministic); lineage hints are dropped unless parent and
        // child decode to the SAME pattern — a cross-pattern resume
        // would replay a schedule of a different CN graph
        let mut ctxs: Vec<Arc<PatternCtx>> = Vec::new();
        let mut ctx_of_fp: HashMap<u64, usize> = HashMap::new();
        let mut candidates: Vec<(Vec<u16>, usize, Option<Vec<u16>>)> = Vec::new();
        let mut seen: HashSet<&[u16]> = HashSet::new();
        for (i, g) in genomes.iter().enumerate() {
            if self.evaluated_metrics.contains_key(g) || !seen.insert(g.as_slice()) {
                continue;
            }
            let pattern = self.pattern_of(g);
            let fp = pattern.fingerprint();
            let ci = *ctx_of_fp.entry(fp).or_insert_with(|| {
                ctxs.push(self.patterns.get_or_build(self.workload, self.arch, pattern));
                ctxs.len() - 1
            });
            let parent = parents.get(i).copied().flatten().and_then(|a| {
                let pg = &genomes[a];
                if self.pinned.is_none() && self.pattern_of(pg).fingerprint() != fp {
                    return None;
                }
                Some(pg[..n_dense.min(pg.len())].to_vec())
            });
            candidates.push((g.clone(), ci, parent));
        }

        let scheds: Vec<Scheduler> = ctxs
            .iter()
            .map(|c| Scheduler::new(self.workload, &c.graph, &c.costs, self.arch))
            .collect();
        let topo_fp = self.arch.topology.fingerprint();
        let comp_fps: Vec<u64> =
            ctxs.iter().map(|c| compose_fp(topo_fp, c.pattern.fingerprint())).collect();
        let everies: Vec<usize> = scheds.iter().map(|s| s.snap_interval()).collect();

        // serial lower-bound prune against the pre-batch archive of
        // exactly evaluated points (same semantics as the classic GA)
        let jobs: Vec<(Vec<u16>, usize, Option<Vec<u16>>)> = if self.params.lb_prune {
            let mut jobs = Vec::with_capacity(candidates.len());
            for (g, ci, parent) in candidates {
                let alloc = allocation_from_genome(self.workload, self.arch, &g[..n_dense]);
                let lb = scheds[ci].lower_bounds(&alloc);
                let lbv = self.objective.values(&lb);
                if archive.iter().any(|a| dominates(a, &lbv)) {
                    self.pruned.insert(g.clone());
                    self.evaluated_metrics.insert(g, lb);
                    crate::obs::count(crate::obs::Counter::GaPruned, 1);
                    continue;
                }
                jobs.push((g, ci, parent));
            }
            jobs
        } else {
            candidates
        };

        let (workload, arch, priority) = (self.workload, self.arch, self.priority);
        let cache = self.cache;
        let delta = self.delta.as_ref();
        let threads = thread_count(self.params.threads);
        crate::obs::count(crate::obs::Counter::GaEvals, jobs.len() as u64);
        let results: Vec<(Vec<u16>, ScheduleMetrics)> = parallel_map_with(
            jobs,
            |(g, ci, parent)| {
                let sched = &scheds[ci];
                let fp = comp_fps[ci];
                let alloc = allocation_from_genome(workload, arch, &g[..n_dense]);
                let m = match (cache.get(&alloc, priority, fp), delta) {
                    (Some(m), _) => m,
                    (None, None) => {
                        let m = sched.run(&alloc, priority).metrics;
                        cache.insert(&alloc, priority, fp, m);
                        m
                    }
                    (None, Some(dc)) => {
                        let warm = parent.as_ref().and_then(|pc| {
                            let pa = allocation_from_genome(workload, arch, pc);
                            let e = dc.get(&pa, priority, fp)?;
                            let d = e.segments.divergence(&e.allocation, &alloc);
                            sched.run_resumed_traced(&alloc, priority, &e.segments, d, everies[ci])
                        });
                        let (res, segs) = warm.unwrap_or_else(|| {
                            sched.run_traced(&alloc, priority, everies[ci])
                        });
                        dc.insert(&alloc, priority, fp, res.metrics, segs);
                        cache.insert(&alloc, priority, fp, res.metrics);
                        res.metrics
                    }
                };
                (g, m)
            },
            threads,
        );
        for (g, m) in results {
            self.evaluated_metrics.entry(g).or_insert(m);
        }
        genomes.iter().map(|g| self.evaluated_metrics[g]).collect()
    }

    fn result_for(&self, genome: Vec<u16>, metrics: ScheduleMetrics) -> FusionResult {
        let n_dense = self.n_dense();
        let core_genes = genome[..n_dense].to_vec();
        let fuse_genes = match &self.pinned {
            Some(p) => p.clone(),
            None => genome[n_dense..].to_vec(),
        };
        let pattern = FusePattern::decode(self.workload, self.arch, &self.menu, &fuse_genes);
        FusionResult {
            allocation: allocation_from_genome(self.workload, self.arch, &core_genes),
            pattern_fp: pattern.fingerprint(),
            n_cut: pattern.n_cut(),
            n_fused: pattern.n_fused(),
            genome,
            core_genes,
            fuse_genes,
            metrics,
        }
    }

    /// Run the co-search on the shared evolutionary driver; returns the
    /// final Pareto front (deduplicated), best EDP first.
    pub fn run(&mut self) -> Vec<FusionResult> {
        let params = self.params;
        let outcome = evolve(self, &params);
        let mut results: Vec<FusionResult> = outcome
            .front
            .iter()
            .map(|&i| {
                let genome = outcome.evaluated[i].0.clone();
                let metrics = self.evaluated_metrics[&genome];
                self.result_for(genome, metrics)
            })
            .collect();
        results.sort_by(|a, b| a.metrics.edp().total_cmp(&b.metrics.edp()));
        results
    }
}

impl EvoProblem for FusionGa<'_> {
    fn genome_len(&self) -> usize {
        match self.pinned {
            Some(_) => self.n_dense(),
            None => self.n_dense() + n_fuse_genes(self.workload),
        }
    }

    /// Exclusive gene bound.  Pinned mode matches the plain GA exactly
    /// (RNG equivalence); free mode widens it so random fuse genes span
    /// every cut/fuse choice — both gene kinds decode modulo their own
    /// range, so any value stays valid.
    fn n_cores(&self) -> usize {
        let k = self.arch.dense_cores().len();
        match self.pinned {
            Some(_) => k,
            None => k.max(self.menu.len() + 1),
        }
    }

    /// Pinned mode: exactly the classic heuristics (costed under the
    /// pinned pattern).  Free mode: the caller's extra seeds first —
    /// `run_fuse_search` injects both regime winners here — then each
    /// heuristic core prefix paired with the all-fuse and the all-cut
    /// suffix, so both regimes are reachable from generation zero.
    fn seed_genomes(&self) -> Vec<Vec<u16>> {
        match &self.pinned {
            Some(genes) => {
                let ctx = self.patterns.get_or_build(
                    self.workload,
                    self.arch,
                    FusePattern::decode(self.workload, self.arch, &self.menu, genes),
                );
                self.core_seed_genomes(&ctx)
            }
            None => {
                let all_fuse = FusePattern::genes_all_fuse(self.workload);
                let all_cut = FusePattern::genes_all_cut(self.workload);
                let ctx = self.patterns.get_or_build(
                    self.workload,
                    self.arch,
                    FusePattern::decode(self.workload, self.arch, &self.menu, &all_fuse),
                );
                let mut seeds = self.extra_seeds.clone();
                for core in self.core_seed_genomes(&ctx) {
                    for suffix in [&all_fuse, &all_cut] {
                        let mut g = core.clone();
                        g.extend_from_slice(suffix);
                        seeds.push(g);
                    }
                }
                seeds
            }
        }
    }

    fn evaluate(&mut self, genomes: &[Vec<u16>]) -> Vec<Vec<f64>> {
        self.evaluate_with_parents(genomes, &vec![None; genomes.len()])
    }

    fn evaluate_with_parents(
        &mut self,
        genomes: &[Vec<u16>],
        parents: &[Option<usize>],
    ) -> Vec<Vec<f64>> {
        let metrics = self.eval_metrics(genomes, parents);
        metrics.iter().map(|m| self.objective.values(m)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::models::{tiny_branchy, tiny_segment};

    fn small_params() -> GaParams {
        GaParams { population: 8, generations: 4, ..Default::default() }
    }

    #[test]
    fn pattern_cache_shares_contexts() {
        let w = tiny_segment();
        let arch = presets::hetero_quad();
        let cache = PatternCache::new();
        let p1 = FusePattern::decode(&w, &arch, &[4], &FusePattern::genes_all_fuse(&w));
        let p2 = FusePattern::decode(&w, &arch, &[4], &FusePattern::genes_all_fuse(&w));
        let c1 = cache.get_or_build(&w, &arch, p1);
        let c2 = cache.get_or_build(&w, &arch, p2);
        assert!(Arc::ptr_eq(&c1, &c2), "same pattern must share one context");
        assert_eq!(cache.len(), 1);
        let cut = FusePattern::decode(&w, &arch, &[4], &FusePattern::genes_all_cut(&w));
        let c3 = cache.get_or_build(&w, &arch, cut);
        assert!(!Arc::ptr_eq(&c1, &c3));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn co_search_runs_and_reports_patterns() {
        let w = tiny_branchy();
        let arch = presets::hetero_quad();
        let patterns = PatternCache::new();
        let cache = ScheduleCache::new();
        let mut ga = FusionGa::new(
            &w,
            &arch,
            SchedulePriority::Latency,
            Objective::Edp,
            small_params(),
            vec![4],
            &patterns,
            &cache,
        );
        let front = ga.run();
        assert!(!front.is_empty());
        let n_edges = n_fuse_genes(&w);
        for r in &front {
            assert_eq!(r.core_genes.len(), w.dense_layers().len());
            assert_eq!(r.fuse_genes.len(), n_edges);
            assert_eq!(r.n_cut + r.n_fused, n_edges);
            assert_eq!(r.allocation.len(), w.len());
        }
        assert!(patterns.len() >= 2, "seeds alone visit both regimes");
    }

    #[test]
    fn co_search_deterministic_for_seed() {
        let w = tiny_branchy();
        let arch = presets::hetero_quad();
        let run = || {
            let patterns = PatternCache::new();
            let cache = ScheduleCache::new();
            let mut ga = FusionGa::new(
                &w,
                &arch,
                SchedulePriority::Latency,
                Objective::Edp,
                small_params(),
                vec![4],
                &patterns,
                &cache,
            );
            ga.run()
                .iter()
                .map(|r| (r.genome.clone(), r.metrics.edp().to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn pinned_mode_searches_core_genes_only() {
        let w = tiny_segment();
        let arch = presets::hetero_quad();
        let patterns = PatternCache::new();
        let cache = ScheduleCache::new();
        let mut ga = FusionGa::new(
            &w,
            &arch,
            SchedulePriority::Latency,
            Objective::Edp,
            small_params(),
            vec![4],
            &patterns,
            &cache,
        )
        .pinned(FusePattern::genes_all_cut(&w));
        let front = ga.run();
        assert!(!front.is_empty());
        for r in &front {
            assert_eq!(r.genome.len(), w.dense_layers().len());
            assert_eq!(r.fuse_genes, FusePattern::genes_all_cut(&w));
            assert_eq!(r.n_fused, 0);
        }
    }

    #[test]
    fn extra_seeds_are_recoverable_from_the_record() {
        // inject a specific genome as a seed; it must be evaluated and
        // resolvable in the run's record with exact metrics
        let w = tiny_segment();
        let arch = presets::hetero_quad();
        let patterns = PatternCache::new();
        let cache = ScheduleCache::new();
        let n_edges = n_fuse_genes(&w);
        let seed: Vec<u16> = vec![0u16; w.dense_layers().len()]
            .into_iter()
            .chain(vec![1u16; n_edges])
            .collect();
        let mut ga = FusionGa::new(
            &w,
            &arch,
            SchedulePriority::Latency,
            Objective::Edp,
            small_params(),
            vec![4],
            &patterns,
            &cache,
        )
        .with_extra_seeds(vec![seed.clone()]);
        ga.run();
        assert!(
            ga.evaluated_metrics.contains_key(&seed),
            "injected seed must be evaluated in generation zero"
        );
    }
}
