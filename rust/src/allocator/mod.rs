//! Step 4 — layer–core allocation via a genetic algorithm.
//!
//! The genome assigns every *dense* layer (conv / dwconv / fc) to one of
//! the architecture's dataflow cores; pooling and elementwise layers are
//! pinned to the SIMD core (paper Section V-B).  Selection uses NSGA-II
//! [7] (fast non-dominated sort + crowding distance); variation is an
//! ordered two-point crossover (p = 0.3) and a mutation (p = 0.7) that
//! either bit-flips one gene (reallocating a layer to a different core)
//! or swaps two layers' allocations — exactly the operators of paper
//! Section III-D.  The GA returns the Pareto front of allocations.
//!
//! Fitness evaluation — one full schedule simulation per unseen genome
//! — is data-parallel across [`GaParams::threads`] workers and
//! memoized in a [`crate::cost::ScheduleCache`] (shareable across GA
//! runs via [`Ga::with_cache`]); serial and parallel runs are
//! bit-identical for a fixed seed.  See the [`Ga`] docs.
//!
//! The evolutionary loop itself — population seeding, variation,
//! NSGA-II survival, early stopping, final front extraction — lives
//! once in [`evolve`](fn@evolve): [`Ga`], the scenario-level
//! [`ScenarioGa`](crate::scenario::ScenarioGa) and the fusion
//! co-search [`FusionGa`] are all thin [`EvoProblem`] instantiations
//! of that shared driver.  [`FusionGa`] extends the genome with one
//! fuse/cut gene per workload edge (`[core genes][fuse genes]`),
//! co-optimizing fusion granularity with the allocation; with its
//! fuse genes pinned it reproduces the plain [`Ga`] bit for bit.

mod evolve;
mod fusion;
mod ga;
mod nsga2;

pub use evolve::{evolve, EvoProblem, EvolveOutcome};
pub use fusion::{FuseSearchOpts, FusionGa, FusionResult, PatternCache, PatternCtx};
pub use ga::{manual_allocation, Ga, GaParams, GaResult, Objective};
pub use nsga2::{crowding_distance, dominates, fast_non_dominated_sort, select_survivors};

use crate::arch::{Accelerator, CoreId};
use crate::workload::WorkloadGraph;

/// Expand a dense-layer genome into a per-layer core allocation
/// (pool/add/concat layers pinned to the SIMD core, or to the first
/// dense core if the architecture has none).
///
/// Architectures with **several** SIMD cores (one per chip in the
/// chiplet presets) pin each non-dense layer to the SIMD core on the
/// chip of the nearest *preceding* dense layer's core — the producer's
/// chip — so pooling never forces an inter-chip crossing and a
/// chip-pure genome stays chip-pure.  Single-SIMD architectures keep
/// the exact historical behavior.
///
/// # Examples
///
/// ```
/// use stream::allocator::allocation_from_genome;
/// use stream::arch::presets;
/// use stream::workload::models::tiny_segment;
///
/// let workload = tiny_segment(); // 3 dense layers among 5
/// let arch = presets::hetero_quad();
/// let alloc = allocation_from_genome(&workload, &arch, &[0, 1, 2]);
/// assert_eq!(alloc.len(), workload.len());
/// // non-dense layers are pinned to the SIMD core
/// assert_eq!(alloc[1], arch.simd_core().unwrap());
/// ```
pub fn allocation_from_genome(
    workload: &WorkloadGraph,
    arch: &Accelerator,
    genome: &[u16],
) -> Vec<CoreId> {
    let dense_cores = arch.dense_cores();
    let simd_cores = arch.simd_cores();
    let simd = arch.simd_core().unwrap_or(dense_cores[0]);
    // chip -> its SIMD core (first one, if a chip carries several)
    let simd_of_chip: Vec<Option<CoreId>> = if simd_cores.len() > 1 {
        let mut v = vec![None; arch.topology.n_chips()];
        for &s in &simd_cores {
            let chip = arch.topology.chip_of_core(s);
            if v[chip].is_none() {
                v[chip] = Some(s);
            }
        }
        v
    } else {
        Vec::new()
    };
    let mut gi = 0;
    let mut last_dense: Option<CoreId> = None;
    workload
        .layers()
        .iter()
        .map(|l| {
            if l.op.is_dense() {
                let c = dense_cores[genome[gi] as usize % dense_cores.len()];
                gi += 1;
                last_dense = Some(c);
                c
            } else if simd_cores.len() > 1 {
                let chip = last_dense
                    .map(|c| arch.topology.chip_of_core(c))
                    .unwrap_or_else(|| arch.topology.chip_of_core(simd_cores[0]));
                simd_of_chip[chip].unwrap_or(simd)
            } else {
                simd
            }
        })
        .collect()
}

/// Total gene count of a multi-tenant genome: one gene per dense layer
/// of every tenant, in tenant order (the scenario engine's
/// `(tenant, layer) -> core` encoding).
pub fn genome_len_multi(workloads: &[&WorkloadGraph]) -> usize {
    workloads.iter().map(|w| w.dense_layers().len()).sum()
}

/// Expand a flat multi-tenant genome — tenant 0's dense genes first,
/// then tenant 1's, … ([`genome_len_multi`] genes total) — into one
/// per-layer core allocation per tenant.  Each tenant's segment expands
/// exactly like [`allocation_from_genome`], so a 1-tenant multi genome
/// degenerates to the single-workload encoding.
///
/// # Examples
///
/// ```
/// use stream::allocator::{allocation_from_genome_multi, genome_len_multi};
/// use stream::arch::presets;
/// use stream::workload::models::tiny_segment;
///
/// let a = tiny_segment();
/// let b = tiny_segment();
/// let tenants = [&a, &b];
/// let arch = presets::hetero_quad();
/// assert_eq!(genome_len_multi(&tenants), 6); // 3 dense layers each
/// let allocs = allocation_from_genome_multi(&tenants, &arch, &[0, 1, 2, 3, 0, 1]);
/// assert_eq!(allocs.len(), 2);
/// assert_eq!(allocs[0].len(), a.len());
/// ```
pub fn allocation_from_genome_multi(
    workloads: &[&WorkloadGraph],
    arch: &Accelerator,
    genome: &[u16],
) -> Vec<Vec<CoreId>> {
    let mut out = Vec::with_capacity(workloads.len());
    let mut off = 0usize;
    for w in workloads {
        let n = w.dense_layers().len();
        out.push(allocation_from_genome(w, arch, &genome[off..off + n]));
        off += n;
    }
    assert_eq!(off, genome.len(), "genome length must match the tenants' dense layers");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::models::tiny_segment;

    #[test]
    fn multi_genome_segments_match_single_expansion() {
        let a = tiny_segment();
        let b = tiny_segment();
        let arch = presets::hetero_quad();
        let tenants = [&a, &b];
        let genome = [0u16, 1, 2, 3, 0, 1];
        let allocs = allocation_from_genome_multi(&tenants, &arch, &genome);
        assert_eq!(allocs[0], allocation_from_genome(&a, &arch, &genome[..3]));
        assert_eq!(allocs[1], allocation_from_genome(&b, &arch, &genome[3..]));
    }

    #[test]
    fn genome_expansion() {
        let w = tiny_segment();
        let arch = presets::hetero_quad();
        // 3 dense layers (conv7x7, conv3x3a, conv3x3b)
        let alloc = allocation_from_genome(&w, &arch, &[0, 1, 2]);
        assert_eq!(alloc.len(), w.len());
        let simd = arch.simd_core().unwrap();
        assert_eq!(alloc[1], simd); // maxpool
        assert_eq!(alloc[4], simd); // add
        assert_eq!(alloc[0], CoreId(0));
        assert_eq!(alloc[2], CoreId(1));
        assert_eq!(alloc[3], CoreId(2));
    }

    #[test]
    fn multi_simd_pins_non_dense_to_producer_chip() {
        let w = tiny_segment();
        let arch = presets::chiplet_4x4(); // 4 chips x (4 dense + 1 SIMD)
        // genes 4..7 index chip 1's dense cores (ids 5..9, SIMD id 9)
        let alloc = allocation_from_genome(&w, &arch, &[4, 5, 6]);
        assert_eq!(alloc[0], CoreId(5));
        assert_eq!(alloc[1], CoreId(9), "maxpool follows its producer's chip");
        assert_eq!(alloc[4], CoreId(9), "add follows its producer's chip");
        // chip-pure allocations stay chip-pure
        for c in &alloc {
            assert_eq!(arch.topology.chip_of_core(*c), 1);
        }
    }

    #[test]
    fn genome_wraps_out_of_range() {
        let w = tiny_segment();
        let arch = presets::test_dual(); // 2 dense cores
        let alloc = allocation_from_genome(&w, &arch, &[5, 0, 1]);
        assert_eq!(alloc[0], CoreId(1)); // 5 % 2
    }
}
