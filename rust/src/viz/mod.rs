//! Schedule visualization: text Gantt charts (paper Fig. 10) and JSON
//! export of schedules + memory traces for external plotting.
//!
//! [`scenario_gantt`] renders multi-DNN co-schedules: one glyph per
//! request, a legend mapping glyphs to tenants/releases/deadlines, and
//! a deadline lane marking met (`|`) and missed (`!`) deadlines.
//!
//! The third visual artifact — Chrome/Perfetto `trace_event` JSON of a
//! run (`STREAM_TRACE=trace.json`, open in <https://ui.perfetto.dev>) —
//! lives in [`obs::chrome`](crate::obs::chrome) next to the recorder
//! that feeds it, and is re-exported here so all schedule visualizers
//! share one front door.

pub use crate::obs::chrome::{schedule_trace, scenario_trace, validate_trace, TraceSummary};

use std::fmt::Write as _;

use crate::arch::{Accelerator, LinkId};
use crate::scenario::ScenarioResult;
use crate::scheduler::{CommEvent, DramEvent, ScheduleResult};
use crate::workload::WorkloadGraph;

/// Whether Gantt lanes should be aggregated per chip: multi-chip
/// packages with more cores than fit a readable per-core/per-link
/// chart.  Every single-chip preset (and anything with <= 8 cores)
/// keeps the exact historical byte-for-byte output.
fn aggregate_chips(arch: &Accelerator) -> bool {
    arch.cores.len() > 8 && arch.topology.n_chips() > 1
}

fn fill(lane: &mut [u8], from: usize, to: usize, ch: u8) {
    for c in lane.iter_mut().take(to + 1).skip(from) {
        *c = ch;
    }
}

/// One Gantt lane per interconnect link, occupied by every comm / DRAM
/// event whose route crosses it (shared by [`gantt`] and
/// [`scenario_gantt`]).  At chiplet scale ([`aggregate_chips`]) each
/// chip's intra-chip fabric collapses into one `chipN.noc` lane —
/// `chiplet_16x16`'s 800+ mesh hops are unreadable one-per-lane — while
/// the scarce inter-chip SerDes links keep their individual lanes.
fn link_lanes(
    out: &mut String,
    arch: &Accelerator,
    comms: &[CommEvent],
    drams: &[DramEvent],
    width: usize,
    scale: &dyn Fn(u64) -> usize,
) {
    let topo = &arch.topology;
    let spans_where = |pred: &dyn Fn(&[LinkId]) -> bool| {
        let mut spans: Vec<(u64, u64)> = comms
            .iter()
            .filter(|c| pred(&c.links))
            .map(|c| (c.start, c.end))
            .chain(drams.iter().filter(|d| pred(&d.links)).map(|d| (d.start, d.end)))
            .collect();
        spans.sort_unstable();
        spans
    };
    if aggregate_chips(arch) {
        for chip in 0..topo.n_chips() {
            let mut lane = vec![b'.'; width];
            let on_chip =
                |links: &[LinkId]| links.iter().any(|&l| topo.chip_of_link(l) == Some(chip));
            for (s, e) in spans_where(&on_chip) {
                fill(&mut lane, scale(s), scale(e), b'#');
            }
            let name = format!("chip{chip}.noc");
            let _ = writeln!(out, "{name:>8} |{}|", String::from_utf8_lossy(&lane));
        }
        for id in topo.inter_chip_links() {
            let mut lane = vec![b'.'; width];
            for (s, e) in spans_where(&|links: &[LinkId]| links.contains(&id)) {
                fill(&mut lane, scale(s), scale(e), b'#');
            }
            let name = &topo.links()[id.0].name;
            let _ = writeln!(out, "{name:>8} |{}|", String::from_utf8_lossy(&lane));
        }
        return;
    }
    for (i, link) in topo.links().iter().enumerate() {
        let id = LinkId(i);
        let mut lane = vec![b'.'; width];
        for (s, e) in spans_where(&|links: &[LinkId]| links.contains(&id)) {
            fill(&mut lane, scale(s), scale(e), b'#');
        }
        let _ = writeln!(out, "{:>8} |{}|", link.name, String::from_utf8_lossy(&lane));
    }
}

/// Core lanes collapse per chip once the package outgrows a readable
/// per-core chart (> 32 cores): the chip is the placement granularity
/// the chiplet GA pins to, so one `chipN` lane per chip is the honest
/// summary.  Returns the lane count emitted.
fn core_lanes(
    out: &mut String,
    arch: &Accelerator,
    width: usize,
    scale: &dyn Fn(u64) -> usize,
    placements: &mut dyn Iterator<Item = (crate::arch::CoreId, u64, u64, u8)>,
) -> usize {
    if aggregate_chips(arch) && arch.cores.len() > 32 {
        let n_chips = arch.topology.n_chips();
        let mut lanes = vec![vec![b'.'; width]; n_chips];
        for (core, start, end, glyph) in placements {
            let chip = arch.topology.chip_of_core(core);
            fill(&mut lanes[chip], scale(start), scale(end).max(scale(start)), glyph);
        }
        for (chip, lane) in lanes.iter().enumerate() {
            let name = format!("chip{chip}");
            let _ = writeln!(out, "{name:>8} |{}|", String::from_utf8_lossy(lane));
        }
        return n_chips;
    }
    let mut lanes = vec![vec![b'.'; width]; arch.cores.len()];
    for (core, start, end, glyph) in placements {
        fill(&mut lanes[core.0], scale(start), scale(end).max(scale(start)), glyph);
    }
    for (core, lane) in arch.cores.iter().zip(&lanes) {
        let _ = writeln!(out, "{:>8} |{}|", core.name, String::from_utf8_lossy(lane));
    }
    arch.cores.len()
}

/// Render a proportional ASCII Gantt chart of the schedule: one lane
/// per core plus one lane per interconnect link (shared-bus topologies
/// show the familiar `bus` and `dram0` lanes; meshes show every hop),
/// `width` characters across the makespan.  CN blocks are labeled by
/// layer id (mod 10).
pub fn gantt(
    result: &ScheduleResult,
    workload: &WorkloadGraph,
    arch: &Accelerator,
    width: usize,
) -> String {
    let mut out = String::new();
    let span = result.metrics.latency_cc.max(1) as f64;
    let width = width.max(20);
    let scale = |t: u64| ((t as f64 / span) * (width - 1) as f64) as usize;

    core_lanes(
        &mut out,
        arch,
        width,
        &scale,
        &mut result
            .cns
            .iter()
            .map(|s| (s.core, s.start, s.end, result_layer_digit(workload, result, s.cn.0))),
    );

    link_lanes(&mut out, arch, &result.comms, &result.drams, width, &scale);

    let _ = writeln!(
        out,
        "  t=0 .. {} cc | peak mem {} | energy {}",
        result.metrics.latency_cc,
        crate::cost::fmt_bytes(result.metrics.peak_mem_bytes),
        crate::cost::fmt_energy(result.metrics.energy_pj),
    );
    out
}

fn result_layer_digit(_w: &WorkloadGraph, result: &ScheduleResult, cn_idx: usize) -> u8 {
    // label CN blocks by their layer id's last digit
    let sc = result.cns.iter().find(|s| s.cn.0 == cn_idx);
    match sc {
        Some(_) => {
            // CnId -> layer via position is not stored in ScheduledCn;
            // use the CN id's layer digit embedded by the caller instead.
            b'0' + (cn_idx % 10) as u8
        }
        None => b'?',
    }
}

/// Request glyphs for the scenario Gantt: request `seq` maps to
/// `GLYPHS[seq % GLYPHS.len()]`.
const GLYPHS: &[u8] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789";

fn glyph(request: usize) -> u8 {
    GLYPHS[request % GLYPHS.len()]
}

/// Render a multi-DNN scenario co-schedule: one lane per core with CN
/// blocks **colored by request** (one glyph per request), one lane per
/// interconnect link, a `deadline` lane marking every request's
/// absolute deadline (`|` met, `!` missed), and a legend that maps each
/// glyph to its tenant, release, completion and deadline verdict,
/// followed by the per-tenant tail-latency summary.
pub fn scenario_gantt(result: &ScenarioResult, arch: &Accelerator, width: usize) -> String {
    let mut out = String::new();
    let span = result.metrics.latency_cc.max(1) as f64;
    let width = width.max(20);
    let scale = |t: u64| {
        (((t as f64 / span) * (width - 1) as f64) as usize).min(width - 1)
    };

    core_lanes(
        &mut out,
        arch,
        width,
        &scale,
        &mut result
            .cns
            .iter()
            .map(|s| (s.placed.core, s.placed.start, s.placed.end, glyph(s.request))),
    );

    link_lanes(&mut out, arch, &result.comms, &result.drams, width, &scale);

    // deadline lane: one marker per request with a deadline; deadlines
    // beyond the chart's time axis are legend-only (drawing them at
    // the clamped last column would misplace them), and a miss is
    // never overwritten by a met marker sharing the column
    let mut lane = vec![b'.'; width];
    for o in &result.outcomes {
        if let Some(d) = o.deadline_abs_cc {
            if d > result.metrics.latency_cc {
                continue;
            }
            let col = scale(d);
            if o.missed {
                lane[col] = b'!';
            } else if lane[col] != b'!' {
                lane[col] = b'|';
            }
        }
    }
    let _ = writeln!(out, "{:>8} |{}|", "deadline", String::from_utf8_lossy(&lane));

    // legend: glyph -> request
    let _ = writeln!(out, "legend:");
    for o in &result.outcomes {
        let tenant = &result.tenants[o.tenant];
        let verdict = match (o.deadline_abs_cc, o.missed) {
            (None, _) => "-".to_string(),
            (Some(d), false) => format!("dl {} ok", crate::cost::fmt_cycles(d)),
            (Some(d), true) => format!("dl {} MISS", crate::cost::fmt_cycles(d)),
        };
        let _ = writeln!(
            out,
            "  {} = {} req{}  rel {}  done {}  {}",
            glyph(o.request) as char,
            tenant.name,
            o.request,
            crate::cost::fmt_cycles(o.release_cc),
            crate::cost::fmt_cycles(o.completion_cc),
            verdict,
        );
    }

    for t in &result.tenants {
        let _ = writeln!(
            out,
            "  {:<12} p50 {:>10}  p99 {:>10}  miss {}/{}  {:.1} req/s",
            t.name,
            crate::cost::fmt_cycles(t.p50_cc),
            crate::cost::fmt_cycles(t.p99_cc),
            t.misses,
            t.requests,
            t.throughput_rps,
        );
    }
    let _ = writeln!(
        out,
        "  t=0 .. {} cc | energy {} | peak mem {}",
        result.metrics.latency_cc,
        crate::cost::fmt_energy(result.metrics.energy_pj),
        crate::cost::fmt_bytes(result.metrics.peak_mem_bytes),
    );
    out
}

/// Export a schedule as JSON (for notebook plotting of Fig. 7/10
/// style charts), via the in-tree JSON writer.
pub fn to_json(result: &ScheduleResult) -> String {
    use crate::util::Json;
    use std::collections::BTreeMap;

    let cns: Vec<Json> = result
        .cns
        .iter()
        .map(|s| {
            let mut o = BTreeMap::new();
            o.insert("cn".into(), Json::Num(s.cn.0 as f64));
            o.insert("core".into(), Json::Num(s.core.0 as f64));
            o.insert("start".into(), Json::Num(s.start as f64));
            o.insert("end".into(), Json::Num(s.end as f64));
            Json::Obj(o)
        })
        .collect();
    let comms: Vec<Json> = result
        .comms
        .iter()
        .map(|c| {
            let mut o = BTreeMap::new();
            o.insert("from".into(), Json::Num(c.from_core.0 as f64));
            o.insert("to".into(), Json::Num(c.to_core.0 as f64));
            o.insert("start".into(), Json::Num(c.start as f64));
            o.insert("end".into(), Json::Num(c.end as f64));
            o.insert("bytes".into(), Json::Num(c.bytes as f64));
            o.insert(
                "links".into(),
                Json::Arr(c.links.iter().map(|l| Json::Num(l.0 as f64)).collect()),
            );
            Json::Obj(o)
        })
        .collect();
    let link_stats: Vec<Json> = result
        .link_stats
        .iter()
        .map(|s| {
            let mut o = BTreeMap::new();
            o.insert("busy_cycles".into(), Json::Num(s.busy_cycles as f64));
            o.insert("bytes_moved".into(), Json::Num(s.bytes_moved as f64));
            Json::Obj(o)
        })
        .collect();
    let curve: Vec<Json> = result
        .memtrace
        .total_curve()
        .into_iter()
        .map(|(t, v)| Json::Arr(vec![Json::Num(t as f64), Json::Num(v)]))
        .collect();

    let mut root = BTreeMap::new();
    root.insert("latency_cc".into(), Json::Num(result.metrics.latency_cc as f64));
    root.insert("energy_pj".into(), Json::Num(result.metrics.energy_pj));
    root.insert("peak_mem_bytes".into(), Json::Num(result.metrics.peak_mem_bytes));
    root.insert("cns".into(), Json::Arr(cns));
    root.insert("comms".into(), Json::Arr(comms));
    root.insert("link_stats".into(), Json::Arr(link_stats));
    root.insert("mem_curve".into(), Json::Arr(curve));
    Json::Obj(root).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::pipeline::{Stream, StreamOpts};
    use crate::workload::models::tiny_segment;

    fn result() -> (ScheduleResult, WorkloadGraph, Accelerator) {
        let w = tiny_segment();
        let arch = presets::test_dual();
        let s = Stream::new(
            w.clone(),
            arch.clone(),
            StreamOpts {
                ga: crate::allocator::GaParams { population: 6, generations: 2, ..Default::default() },
                ..Default::default()
            },
        );
        let mut r = s.run().unwrap();
        (r.points.remove(0).result, w, arch)
    }

    #[test]
    fn gantt_renders_all_lanes() {
        let (r, w, arch) = result();
        let g = gantt(&r, &w, &arch, 60);
        assert!(g.contains("bus"));
        assert!(g.contains("dram"));
        assert!(g.contains("peak mem"));
        // one lane per core, one per interconnect link, one footer
        assert_eq!(
            g.lines().count(),
            arch.cores.len() + arch.topology.n_links() + 1
        );
    }

    #[test]
    fn gantt_renders_a_lane_per_mesh_link() {
        let w = tiny_segment();
        let arch = presets::with_noc(presets::test_dual(), "mesh").unwrap();
        let s = Stream::new(
            w.clone(),
            arch.clone(),
            StreamOpts {
                ga: crate::allocator::GaParams { population: 6, generations: 2, ..Default::default() },
                ..Default::default()
            },
        );
        let mut r = s.run().unwrap();
        let r = r.points.remove(0).result;
        let g = gantt(&r, &w, &arch, 60);
        assert_eq!(
            g.lines().count(),
            arch.cores.len() + arch.topology.n_links() + 1
        );
    }

    #[test]
    fn scenario_gantt_has_request_glyphs_legend_and_deadline_lane() {
        use crate::scenario::{self, Arbitration, ScenarioSim};
        let scenario = scenario::tiny_mix();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), Arbitration::Fifo);
        let g = scenario_gantt(&r, &arch, 60);
        assert!(g.contains("legend:"));
        assert!(g.contains("deadline"));
        // one legend line per request, glyphs starting at 'A'
        assert!(g.contains("A = "));
        assert!(g.contains("B = "));
        // lanes: cores + links + deadline lane, then legend/summary
        let framed = g.lines().filter(|l| l.ends_with('|')).count();
        assert_eq!(framed, arch.cores.len() + arch.topology.n_links() + 1);
    }

    #[test]
    fn scenario_gantt_marks_missed_deadlines() {
        use crate::scenario::{self, Arbitration, ScenarioSim};
        let mut scenario = scenario::tiny_mix();
        for t in &mut scenario.tenants {
            t.deadline_cc = Some(1); // impossible: everything misses
        }
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), Arbitration::Edf);
        assert!(r.total_misses() > 0);
        let g = scenario_gantt(&r, &arch, 60);
        assert!(g.contains('!'), "deadline lane must mark misses");
        assert!(g.contains("MISS"), "legend must call out missed requests");
    }

    #[test]
    fn chiplet_gantt_collapses_intra_chip_links() {
        use crate::scenario::{Arbitration, Arrival, Scenario, ScenarioSim, Tenant};
        // chiplet_4x4: 20 cores over 4 chips -> per-core lanes stay
        // (<= 32 cores) but the chips' mesh hops collapse to one
        // chipN.noc lane each; inter-chip SerDes links stay individual
        let arch = presets::chiplet_4x4();
        let scenario = Scenario::new(
            "viz",
            vec![
                Tenant::new("a", "tiny-segment", Arrival::OneShot { at_cc: 0 }),
                Tenant::new("b", "tiny-branchy", Arrival::OneShot { at_cc: 0 }),
            ],
        );
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), Arbitration::Fifo);
        let g = scenario_gantt(&r, &arch, 60);
        assert!(g.contains("chip0.noc"), "aggregated chip fabric lane missing:\n{g}");
        let framed = g.lines().filter(|l| l.ends_with('|')).count();
        let expect = arch.cores.len()
            + arch.topology.n_chips()
            + arch.topology.inter_chip_links().count()
            + 1; // deadline lane
        assert_eq!(framed, expect);
        assert!(
            arch.topology.n_chips() + arch.topology.inter_chip_links().count()
                < arch.topology.n_links(),
            "aggregation must actually shrink the link section"
        );
    }

    #[test]
    fn chiplet_gantt_collapses_core_lanes_past_32_cores() {
        use crate::scenario::{Arbitration, Arrival, Scenario, ScenarioSim, Tenant};
        // chiplet_8x8: 68 cores -> one core lane per chip
        let arch = presets::chiplet_8x8();
        let scenario = Scenario::new(
            "viz8",
            vec![Tenant::new("a", "tiny-segment", Arrival::OneShot { at_cc: 0 })],
        );
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), Arbitration::Fifo);
        let g = scenario_gantt(&r, &arch, 60);
        let framed = g.lines().filter(|l| l.ends_with('|')).count();
        let chips = arch.topology.n_chips();
        let expect = chips + chips + arch.topology.inter_chip_links().count() + 1;
        assert_eq!(framed, expect, "core + link lanes must both collapse per chip:\n{g}");
        assert!(g.contains("   chip0 |"), "aggregated core lane missing:\n{g}");
    }

    #[test]
    fn small_arch_gantt_keeps_per_link_lanes() {
        // the aggregation gate must leave every <= 8-core preset alone
        let (r, w, arch) = result();
        let g = gantt(&r, &w, &arch, 60);
        for link in arch.topology.links() {
            assert!(g.contains(&link.name), "per-link lane {} missing", link.name);
        }
        assert!(!g.contains(".noc |"), "small archs must not aggregate");
    }

    #[test]
    fn json_round_trips() {
        let (r, _, _) = result();
        let j = to_json(&r);
        let v = crate::util::Json::parse(&j).unwrap();
        assert!(v.get("latency_cc").unwrap().as_f64().unwrap() > 0.0);
        assert!(!v.get("cns").unwrap().as_arr().unwrap().is_empty());
    }
}
