//! Schedule visualization: text Gantt charts (paper Fig. 10) and JSON
//! export of schedules + memory traces for external plotting.
//!
//! [`scenario_gantt`] renders multi-DNN co-schedules: one glyph per
//! request, a legend mapping glyphs to tenants/releases/deadlines, and
//! a deadline lane marking met (`|`) and missed (`!`) deadlines.

use std::fmt::Write as _;

use crate::arch::Accelerator;
use crate::scenario::ScenarioResult;
use crate::scheduler::{CommEvent, DramEvent, ScheduleResult};
use crate::workload::WorkloadGraph;

/// One Gantt lane per interconnect link, occupied by every comm / DRAM
/// event whose route crosses it (shared by [`gantt`] and
/// [`scenario_gantt`]).
fn link_lanes(
    out: &mut String,
    arch: &Accelerator,
    comms: &[CommEvent],
    drams: &[DramEvent],
    width: usize,
    scale: &dyn Fn(u64) -> usize,
) {
    for (i, link) in arch.topology.links().iter().enumerate() {
        let id = crate::arch::LinkId(i);
        let mut lane = vec![b'.'; width];
        let spans = comms
            .iter()
            .filter(|c| c.links.contains(&id))
            .map(|c| (c.start, c.end))
            .chain(
                drams
                    .iter()
                    .filter(|d| d.links.contains(&id))
                    .map(|d| (d.start, d.end)),
            );
        for (s, e) in spans {
            for ch in lane.iter_mut().take(scale(e) + 1).skip(scale(s)) {
                *ch = b'#';
            }
        }
        let _ = writeln!(out, "{:>8} |{}|", link.name, String::from_utf8_lossy(&lane));
    }
}

/// Render a proportional ASCII Gantt chart of the schedule: one lane
/// per core plus one lane per interconnect link (shared-bus topologies
/// show the familiar `bus` and `dram0` lanes; meshes show every hop),
/// `width` characters across the makespan.  CN blocks are labeled by
/// layer id (mod 10).
pub fn gantt(
    result: &ScheduleResult,
    workload: &WorkloadGraph,
    arch: &Accelerator,
    width: usize,
) -> String {
    let mut out = String::new();
    let span = result.metrics.latency_cc.max(1) as f64;
    let width = width.max(20);
    let scale = |t: u64| ((t as f64 / span) * (width - 1) as f64) as usize;

    for core in &arch.cores {
        let mut lane = vec![b'.'; width];
        for s in result.cns.iter().filter(|s| s.core == core.id) {
            let (a, b) = (scale(s.start), scale(s.end).max(scale(s.start)));
            let layer = result_layer_digit(workload, result, s.cn.0);
            for c in lane.iter_mut().take(b + 1).skip(a) {
                *c = layer;
            }
        }
        let _ = writeln!(out, "{:>8} |{}|", core.name, String::from_utf8_lossy(&lane));
    }

    link_lanes(&mut out, arch, &result.comms, &result.drams, width, &scale);

    let _ = writeln!(
        out,
        "  t=0 .. {} cc | peak mem {} | energy {}",
        result.metrics.latency_cc,
        crate::cost::fmt_bytes(result.metrics.peak_mem_bytes),
        crate::cost::fmt_energy(result.metrics.energy_pj),
    );
    out
}

fn result_layer_digit(_w: &WorkloadGraph, result: &ScheduleResult, cn_idx: usize) -> u8 {
    // label CN blocks by their layer id's last digit
    let sc = result.cns.iter().find(|s| s.cn.0 == cn_idx);
    match sc {
        Some(_) => {
            // CnId -> layer via position is not stored in ScheduledCn;
            // use the CN id's layer digit embedded by the caller instead.
            b'0' + (cn_idx % 10) as u8
        }
        None => b'?',
    }
}

/// Request glyphs for the scenario Gantt: request `seq` maps to
/// `GLYPHS[seq % GLYPHS.len()]`.
const GLYPHS: &[u8] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789";

fn glyph(request: usize) -> u8 {
    GLYPHS[request % GLYPHS.len()]
}

/// Render a multi-DNN scenario co-schedule: one lane per core with CN
/// blocks **colored by request** (one glyph per request), one lane per
/// interconnect link, a `deadline` lane marking every request's
/// absolute deadline (`|` met, `!` missed), and a legend that maps each
/// glyph to its tenant, release, completion and deadline verdict,
/// followed by the per-tenant tail-latency summary.
pub fn scenario_gantt(result: &ScenarioResult, arch: &Accelerator, width: usize) -> String {
    let mut out = String::new();
    let span = result.metrics.latency_cc.max(1) as f64;
    let width = width.max(20);
    let scale = |t: u64| {
        (((t as f64 / span) * (width - 1) as f64) as usize).min(width - 1)
    };

    for core in &arch.cores {
        let mut lane = vec![b'.'; width];
        for s in result.cns.iter().filter(|s| s.placed.core == core.id) {
            let (a, b) = (scale(s.placed.start), scale(s.placed.end).max(scale(s.placed.start)));
            let g = glyph(s.request);
            for c in lane.iter_mut().take(b + 1).skip(a) {
                *c = g;
            }
        }
        let _ = writeln!(out, "{:>8} |{}|", core.name, String::from_utf8_lossy(&lane));
    }

    link_lanes(&mut out, arch, &result.comms, &result.drams, width, &scale);

    // deadline lane: one marker per request with a deadline; deadlines
    // beyond the chart's time axis are legend-only (drawing them at
    // the clamped last column would misplace them), and a miss is
    // never overwritten by a met marker sharing the column
    let mut lane = vec![b'.'; width];
    for o in &result.outcomes {
        if let Some(d) = o.deadline_abs_cc {
            if d > result.metrics.latency_cc {
                continue;
            }
            let col = scale(d);
            if o.missed {
                lane[col] = b'!';
            } else if lane[col] != b'!' {
                lane[col] = b'|';
            }
        }
    }
    let _ = writeln!(out, "{:>8} |{}|", "deadline", String::from_utf8_lossy(&lane));

    // legend: glyph -> request
    let _ = writeln!(out, "legend:");
    for o in &result.outcomes {
        let tenant = &result.tenants[o.tenant];
        let verdict = match (o.deadline_abs_cc, o.missed) {
            (None, _) => "-".to_string(),
            (Some(d), false) => format!("dl {} ok", crate::cost::fmt_cycles(d)),
            (Some(d), true) => format!("dl {} MISS", crate::cost::fmt_cycles(d)),
        };
        let _ = writeln!(
            out,
            "  {} = {} req{}  rel {}  done {}  {}",
            glyph(o.request) as char,
            tenant.name,
            o.request,
            crate::cost::fmt_cycles(o.release_cc),
            crate::cost::fmt_cycles(o.completion_cc),
            verdict,
        );
    }

    for t in &result.tenants {
        let _ = writeln!(
            out,
            "  {:<12} p50 {:>10}  p99 {:>10}  miss {}/{}  {:.1} req/s",
            t.name,
            crate::cost::fmt_cycles(t.p50_cc),
            crate::cost::fmt_cycles(t.p99_cc),
            t.misses,
            t.requests,
            t.throughput_rps,
        );
    }
    let _ = writeln!(
        out,
        "  t=0 .. {} cc | energy {} | peak mem {}",
        result.metrics.latency_cc,
        crate::cost::fmt_energy(result.metrics.energy_pj),
        crate::cost::fmt_bytes(result.metrics.peak_mem_bytes),
    );
    out
}

/// Export a schedule as JSON (for notebook plotting of Fig. 7/10
/// style charts), via the in-tree JSON writer.
pub fn to_json(result: &ScheduleResult) -> String {
    use crate::util::Json;
    use std::collections::BTreeMap;

    let cns: Vec<Json> = result
        .cns
        .iter()
        .map(|s| {
            let mut o = BTreeMap::new();
            o.insert("cn".into(), Json::Num(s.cn.0 as f64));
            o.insert("core".into(), Json::Num(s.core.0 as f64));
            o.insert("start".into(), Json::Num(s.start as f64));
            o.insert("end".into(), Json::Num(s.end as f64));
            Json::Obj(o)
        })
        .collect();
    let comms: Vec<Json> = result
        .comms
        .iter()
        .map(|c| {
            let mut o = BTreeMap::new();
            o.insert("from".into(), Json::Num(c.from_core.0 as f64));
            o.insert("to".into(), Json::Num(c.to_core.0 as f64));
            o.insert("start".into(), Json::Num(c.start as f64));
            o.insert("end".into(), Json::Num(c.end as f64));
            o.insert("bytes".into(), Json::Num(c.bytes as f64));
            o.insert(
                "links".into(),
                Json::Arr(c.links.iter().map(|l| Json::Num(l.0 as f64)).collect()),
            );
            Json::Obj(o)
        })
        .collect();
    let link_stats: Vec<Json> = result
        .link_stats
        .iter()
        .map(|s| {
            let mut o = BTreeMap::new();
            o.insert("busy_cycles".into(), Json::Num(s.busy_cycles as f64));
            o.insert("bytes_moved".into(), Json::Num(s.bytes_moved as f64));
            Json::Obj(o)
        })
        .collect();
    let curve: Vec<Json> = result
        .memtrace
        .total_curve()
        .into_iter()
        .map(|(t, v)| Json::Arr(vec![Json::Num(t as f64), Json::Num(v)]))
        .collect();

    let mut root = BTreeMap::new();
    root.insert("latency_cc".into(), Json::Num(result.metrics.latency_cc as f64));
    root.insert("energy_pj".into(), Json::Num(result.metrics.energy_pj));
    root.insert("peak_mem_bytes".into(), Json::Num(result.metrics.peak_mem_bytes));
    root.insert("cns".into(), Json::Arr(cns));
    root.insert("comms".into(), Json::Arr(comms));
    root.insert("link_stats".into(), Json::Arr(link_stats));
    root.insert("mem_curve".into(), Json::Arr(curve));
    Json::Obj(root).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::pipeline::{Stream, StreamOpts};
    use crate::workload::models::tiny_segment;

    fn result() -> (ScheduleResult, WorkloadGraph, Accelerator) {
        let w = tiny_segment();
        let arch = presets::test_dual();
        let s = Stream::new(
            w.clone(),
            arch.clone(),
            StreamOpts {
                ga: crate::allocator::GaParams { population: 6, generations: 2, ..Default::default() },
                ..Default::default()
            },
        );
        let mut r = s.run().unwrap();
        (r.points.remove(0).result, w, arch)
    }

    #[test]
    fn gantt_renders_all_lanes() {
        let (r, w, arch) = result();
        let g = gantt(&r, &w, &arch, 60);
        assert!(g.contains("bus"));
        assert!(g.contains("dram"));
        assert!(g.contains("peak mem"));
        // one lane per core, one per interconnect link, one footer
        assert_eq!(
            g.lines().count(),
            arch.cores.len() + arch.topology.n_links() + 1
        );
    }

    #[test]
    fn gantt_renders_a_lane_per_mesh_link() {
        let w = tiny_segment();
        let arch = presets::with_noc(presets::test_dual(), "mesh").unwrap();
        let s = Stream::new(
            w.clone(),
            arch.clone(),
            StreamOpts {
                ga: crate::allocator::GaParams { population: 6, generations: 2, ..Default::default() },
                ..Default::default()
            },
        );
        let mut r = s.run().unwrap();
        let r = r.points.remove(0).result;
        let g = gantt(&r, &w, &arch, 60);
        assert_eq!(
            g.lines().count(),
            arch.cores.len() + arch.topology.n_links() + 1
        );
    }

    #[test]
    fn scenario_gantt_has_request_glyphs_legend_and_deadline_lane() {
        use crate::scenario::{self, Arbitration, ScenarioSim};
        let scenario = scenario::tiny_mix();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), Arbitration::Fifo);
        let g = scenario_gantt(&r, &arch, 60);
        assert!(g.contains("legend:"));
        assert!(g.contains("deadline"));
        // one legend line per request, glyphs starting at 'A'
        assert!(g.contains("A = "));
        assert!(g.contains("B = "));
        // lanes: cores + links + deadline lane, then legend/summary
        let framed = g.lines().filter(|l| l.ends_with('|')).count();
        assert_eq!(framed, arch.cores.len() + arch.topology.n_links() + 1);
    }

    #[test]
    fn scenario_gantt_marks_missed_deadlines() {
        use crate::scenario::{self, Arbitration, ScenarioSim};
        let mut scenario = scenario::tiny_mix();
        for t in &mut scenario.tenants {
            t.deadline_cc = Some(1); // impossible: everything misses
        }
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), Arbitration::Edf);
        assert!(r.total_misses() > 0);
        let g = scenario_gantt(&r, &arch, 60);
        assert!(g.contains('!'), "deadline lane must mark misses");
        assert!(g.contains("MISS"), "legend must call out missed requests");
    }

    #[test]
    fn json_round_trips() {
        let (r, _, _) = result();
        let j = to_json(&r);
        let v = crate::util::Json::parse(&j).unwrap();
        assert!(v.get("latency_cc").unwrap().as_f64().unwrap() > 0.0);
        assert!(!v.get("cns").unwrap().as_arr().unwrap().is_empty());
    }
}
