//! Step 6 — multi-DNN serving scenarios: request streams, deadlines and
//! tail-latency-aware co-scheduling.
//!
//! The Steps 1–5 pipeline answers *"how fast does one inference of one
//! model run?"*.  Real deployments ask a different question: how does a
//! heterogeneous fabric behave under a **stream of requests from
//! several DNNs** sharing cores, NoC links and DRAM ports (Herald's
//! multi-DNN axis, on top of this crate's topology-aware Stream
//! scheduler)?  This module opens that axis:
//!
//! - [`Scenario`] describes N [`Tenant`] models — any
//!   [`workload::models`](crate::workload::models) entry — each with a
//!   deterministic request pattern ([`Arrival`]: one-shot, periodic or
//!   bursty trace), an optional per-request deadline and a priority;
//! - [`ScenarioSim`] instantiates per-request CN graphs (reusing the
//!   Step 1–3 splitting/cost machinery) and co-schedules **all**
//!   requests in one event-driven run over the shared cores, routed
//!   [`LinkSet`](crate::scheduler::resources::LinkSet) and per-core
//!   weight memories (same-tenant requests reuse resident weights);
//!   inter-request [`Arbitration`] (fifo / priority / earliest-deadline
//!   -first) decides who gets the next scheduling decision;
//! - [`ScenarioResult`] reports per-tenant p50/p99 latency,
//!   deadline-miss rate, throughput (req/s at the modeled clock),
//!   aggregate energy and per-core/per-link utilization;
//! - [`ScenarioGa`] co-optimizes the static `(tenant, layer) → core`
//!   partitioning across tenants with the NSGA-II machinery of Step 4.
//!
//! The degenerate 1-tenant / 1-request scenario reproduces
//! [`Scheduler::run`](crate::scheduler::Scheduler::run) **bit-for-bit**
//! (`rust/tests/scenario_equivalence.rs`), so the serving layer is a
//! strict superset of the single-model pipeline.
//!
//! ```no_run
//! use stream::arch::presets;
//! use stream::scenario::{self, Arbitration, ScenarioSim};
//!
//! let scenario = scenario::edge_mix();
//! let arch = presets::by_name("hetero_quad@mesh").unwrap();
//! let sim = ScenarioSim::new(&scenario, &arch).unwrap();
//! let result = sim.run(&sim.greedy_allocations(), Arbitration::Edf);
//! for t in &result.tenants {
//!     println!("{}: p99 {} cc, miss rate {:.0}%", t.name, t.p99_cc, 100.0 * t.miss_rate);
//! }
//! ```

mod engine;
mod opt;
mod result;
mod spec;

pub use crate::scheduler::{Arbitration, FallbackReason};
pub use engine::{ScenarioError, ScenarioRunner, ScenarioSim, StreamingOpts, TenantBuild};
pub use opt::{per_tenant_ga, ScenarioGa, ScenarioGaResult};
pub use result::{
    percentile_cc, LatencyHist, RequestOutcome, ScenarioCn, ScenarioResult, StreamingStats,
    TenantStats, WindowStats,
};
pub use spec::{
    av_pipeline, by_name, duplicate_resnet_x4, edge_mix, llm_serving, tiny_mix, Arrival,
    ArrivalStream, Request, RequestStream, Scenario, Tenant, SCENARIO_NAMES,
};
