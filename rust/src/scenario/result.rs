//! Scenario outputs: per-request outcomes, per-tenant tail-latency
//! statistics and the aggregate schedule metrics.

use crate::arch::CoreId;
use crate::cost::ScheduleMetrics;
use crate::scheduler::{CommEvent, DramEvent, LinkStat, MemTrace, ScheduledCn};

/// One scheduled CN, tagged with the request it belongs to.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioCn {
    /// Request sequence number ([`Request::seq`](super::Request::seq)).
    pub request: usize,
    pub placed: ScheduledCn,
}

/// What happened to one request.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub request: usize,
    pub tenant: usize,
    pub release_cc: u64,
    /// When the request's last CN / off-chip store finished.
    pub completion_cc: u64,
    /// `completion - release`.
    pub latency_cc: u64,
    pub deadline_abs_cc: Option<u64>,
    /// `completion > deadline` (always `false` without a deadline).
    pub missed: bool,
}

/// Tail-latency summary of one tenant's requests.
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub name: String,
    pub requests: usize,
    pub p50_cc: u64,
    pub p99_cc: u64,
    pub mean_cc: f64,
    pub misses: usize,
    /// `misses / requests` (0 when the tenant has no deadline).
    pub miss_rate: f64,
    /// Completed requests per second at the scenario's modeled clock.
    pub throughput_rps: f64,
}

/// Complete scenario outcome: request-tagged schedule, per-tenant
/// statistics and the same aggregate [`ScheduleMetrics`] the
/// single-model scheduler reports (bit-identical for the degenerate
/// 1-tenant / 1-request scenario — see `rust/tests/scenario_equivalence.rs`).
#[derive(Debug)]
pub struct ScenarioResult {
    /// Aggregate metrics over the whole co-schedule (makespan, energy,
    /// peak memory, dense-core utilization).
    pub metrics: ScheduleMetrics,
    pub cns: Vec<ScenarioCn>,
    pub comms: Vec<CommEvent>,
    /// Request tag per [`comms`](Self::comms) entry (index-aligned).
    pub comm_req: Vec<usize>,
    pub drams: Vec<DramEvent>,
    /// Request tag per [`drams`](Self::drams) entry (index-aligned).
    pub dram_req: Vec<usize>,
    /// Per-link occupancy, in the topology's link order.
    pub link_stats: Vec<LinkStat>,
    /// Busy cycles per core, by core id.
    pub core_busy: Vec<u64>,
    pub memtrace: MemTrace,
    pub outcomes: Vec<RequestOutcome>,
    pub tenants: Vec<TenantStats>,
    /// How many chip partitions the simulation core ran concurrently
    /// (1 = sequential; see `STREAM_SIM_THREADS`).  Observational only
    /// — results are bit-identical for every value.
    pub partitions: usize,
    /// Why the simulation ran sequentially; `None` when the
    /// chip-partitioned parallel core engaged.  Deterministic for a
    /// given scenario + thread count, like [`partitions`](Self::partitions).
    pub fallback: Option<crate::scheduler::FallbackReason>,
    /// Flight-recorder summary, attached only when the recorder is
    /// enabled ([`crate::obs::enabled`]); `None` otherwise.
    pub report: Option<Box<crate::obs::RunReport>>,
}

impl ScenarioResult {
    /// Makespan of the whole scenario in cycles.
    pub fn makespan_cc(&self) -> u64 {
        self.metrics.latency_cc
    }

    /// Total deadline misses across tenants.
    pub fn total_misses(&self) -> usize {
        self.outcomes.iter().filter(|o| o.missed).count()
    }

    /// Worst per-tenant p99 latency in cycles.
    pub fn worst_p99_cc(&self) -> u64 {
        self.tenants.iter().map(|t| t.p99_cc).max().unwrap_or(0)
    }

    /// Temporal utilization of one core (busy / makespan).
    pub fn core_util(&self, core: CoreId) -> f64 {
        if self.metrics.latency_cc == 0 {
            return 0.0;
        }
        self.core_busy[core.0] as f64 / self.metrics.latency_cc as f64
    }

    /// Temporal utilization of one link (busy / makespan).
    pub fn link_util(&self, link: usize) -> f64 {
        if self.metrics.latency_cc == 0 {
            return 0.0;
        }
        self.link_stats[link].busy_cycles as f64 / self.metrics.latency_cc as f64
    }

    /// The outcome rows of one tenant, in request order.
    pub fn tenant_outcomes(&self, tenant: usize) -> impl Iterator<Item = &RequestOutcome> {
        self.outcomes.iter().filter(move |o| o.tenant == tenant)
    }
}

/// Nearest-rank percentile (`p` in [0, 100]) of an unsorted latency
/// sample; 0 for an empty sample.
pub fn percentile_cc(latencies: &[u64], p: f64) -> u64 {
    if latencies.is_empty() {
        return 0;
    }
    let mut sorted = latencies.to_vec();
    sorted.sort_unstable();
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let l = [10, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(percentile_cc(&l, 50.0), 50);
        assert_eq!(percentile_cc(&l, 99.0), 100);
        assert_eq!(percentile_cc(&l, 100.0), 100);
        assert_eq!(percentile_cc(&l, 0.0), 10);
        assert_eq!(percentile_cc(&[42], 99.0), 42);
        assert_eq!(percentile_cc(&[], 50.0), 0);
    }
}
