//! Scenario outputs: per-request outcomes, per-tenant tail-latency
//! statistics and the aggregate schedule metrics.

use crate::arch::CoreId;
use crate::cost::ScheduleMetrics;
use crate::scheduler::{CommEvent, DramEvent, LinkStat, MemTrace, ScheduledCn};

/// One scheduled CN, tagged with the request it belongs to.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioCn {
    /// Request sequence number ([`Request::seq`](super::Request::seq)).
    pub request: usize,
    pub placed: ScheduledCn,
}

/// What happened to one request.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub request: usize,
    pub tenant: usize,
    pub release_cc: u64,
    /// When the request's last CN / off-chip store finished.
    pub completion_cc: u64,
    /// `completion - release`.
    pub latency_cc: u64,
    pub deadline_abs_cc: Option<u64>,
    /// `completion > deadline` (always `false` without a deadline).
    pub missed: bool,
}

/// Tail-latency summary of one tenant's requests.
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub name: String,
    pub requests: usize,
    pub p50_cc: u64,
    pub p99_cc: u64,
    pub mean_cc: f64,
    pub misses: usize,
    /// `misses / requests` (0 when the tenant has no deadline).
    pub miss_rate: f64,
    /// Completed requests per second at the scenario's modeled clock.
    pub throughput_rps: f64,
}

/// Complete scenario outcome: request-tagged schedule, per-tenant
/// statistics and the same aggregate [`ScheduleMetrics`] the
/// single-model scheduler reports (bit-identical for the degenerate
/// 1-tenant / 1-request scenario — see `rust/tests/scenario_equivalence.rs`).
#[derive(Debug)]
pub struct ScenarioResult {
    /// Aggregate metrics over the whole co-schedule (makespan, energy,
    /// peak memory, dense-core utilization).
    pub metrics: ScheduleMetrics,
    pub cns: Vec<ScenarioCn>,
    pub comms: Vec<CommEvent>,
    /// Request tag per [`comms`](Self::comms) entry (index-aligned).
    pub comm_req: Vec<usize>,
    pub drams: Vec<DramEvent>,
    /// Request tag per [`drams`](Self::drams) entry (index-aligned).
    pub dram_req: Vec<usize>,
    /// Per-link occupancy, in the topology's link order.
    pub link_stats: Vec<LinkStat>,
    /// Busy cycles per core, by core id.
    pub core_busy: Vec<u64>,
    pub memtrace: MemTrace,
    pub outcomes: Vec<RequestOutcome>,
    pub tenants: Vec<TenantStats>,
    /// How many chip partitions the simulation core ran concurrently
    /// (1 = sequential; see `STREAM_SIM_THREADS`).  Observational only
    /// — results are bit-identical for every value.
    pub partitions: usize,
    /// Why the simulation ran sequentially; `None` when the
    /// chip-partitioned parallel core engaged.  Deterministic for a
    /// given scenario + thread count, like [`partitions`](Self::partitions).
    pub fallback: Option<crate::scheduler::FallbackReason>,
    /// Flight-recorder summary, attached only when the recorder is
    /// enabled ([`crate::obs::enabled`]); `None` otherwise.
    pub report: Option<Box<crate::obs::RunReport>>,
    /// Windowed streaming statistics, attached only by the streamed
    /// serving path ([`ScenarioRunner::run_streamed`]); `None` for the
    /// eager path.
    ///
    /// [`ScenarioRunner::run_streamed`]: super::ScenarioRunner::run_streamed
    pub streaming: Option<StreamingStats>,
}

impl ScenarioResult {
    /// Makespan of the whole scenario in cycles.
    pub fn makespan_cc(&self) -> u64 {
        self.metrics.latency_cc
    }

    /// Total deadline misses across tenants.
    pub fn total_misses(&self) -> usize {
        self.outcomes.iter().filter(|o| o.missed).count()
    }

    /// Worst per-tenant p99 latency in cycles.
    pub fn worst_p99_cc(&self) -> u64 {
        self.tenants.iter().map(|t| t.p99_cc).max().unwrap_or(0)
    }

    /// Temporal utilization of one core (busy / makespan).
    pub fn core_util(&self, core: CoreId) -> f64 {
        if self.metrics.latency_cc == 0 {
            return 0.0;
        }
        self.core_busy[core.0] as f64 / self.metrics.latency_cc as f64
    }

    /// Temporal utilization of one link (busy / makespan).
    pub fn link_util(&self, link: usize) -> f64 {
        if self.metrics.latency_cc == 0 {
            return 0.0;
        }
        self.link_stats[link].busy_cycles as f64 / self.metrics.latency_cc as f64
    }

    /// The outcome rows of one tenant, in request order.
    pub fn tenant_outcomes(&self, tenant: usize) -> impl Iterator<Item = &RequestOutcome> {
        self.outcomes.iter().filter(move |o| o.tenant == tenant)
    }
}

/// Fixed-footprint log2 latency histogram — the streaming path's
/// replacement for whole-run latency collection.  Same bucketing idea
/// as the flight recorder's [`crate::obs::Hist`] (one bucket per
/// leading-zero count), widened to the full `u64` range so
/// million-cycle serving latencies resolve: bucket `b >= 1` holds
/// values in `[2^(b-1), 2^b)`, bucket 0 holds exactly `{0}`.
/// Percentiles resolve to the containing bucket's upper edge (at most
/// a 2x overestimate), clamped to the exact observed maximum.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    counts: [u64; Self::BUCKETS],
    count: u64,
    sum: u64,
    max: u64,
}

impl LatencyHist {
    pub const BUCKETS: usize = 64;

    pub fn new() -> LatencyHist {
        LatencyHist { counts: [0; Self::BUCKETS], count: 0, sum: 0, max: 0 }
    }

    fn bucket(v: u64) -> usize {
        ((64 - v.leading_zeros()) as usize).min(Self::BUCKETS - 1)
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sample mean (0 for an empty histogram).
    pub fn mean_cc(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Exact observed maximum.
    pub fn max_cc(&self) -> u64 {
        self.max
    }

    /// Nearest-rank percentile (`p` in [0, 100]), resolved to the
    /// containing bucket's upper edge and clamped to the observed
    /// maximum; 0 for an empty histogram.
    pub fn percentile_cc(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (((p / 100.0) * self.count as f64).ceil().max(1.0)) as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if c > 0 && seen >= rank {
                let edge = if b == 0 {
                    0
                } else if b >= Self::BUCKETS - 1 {
                    u64::MAX
                } else {
                    (1u64 << b) - 1
                };
                return edge.min(self.max);
            }
        }
        self.max
    }

    /// Fold another histogram in (used to aggregate windows).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }
}

impl Default for LatencyHist {
    fn default() -> LatencyHist {
        LatencyHist::new()
    }
}

/// One completion-time window of a streamed run.
#[derive(Debug, Clone, Default)]
pub struct WindowStats {
    /// Window start (inclusive), in cycles; spans
    /// [`start_cc`](Self::start_cc)` .. start_cc + window_cc`.
    pub start_cc: u64,
    /// Requests whose completion fell inside the window.
    pub completed: u64,
    /// Deadline misses among them.
    pub missed: u64,
    /// Latency histogram of the window's completions.
    pub hist: LatencyHist,
}

impl WindowStats {
    /// `missed / completed` (0 for an empty window).
    pub fn miss_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.missed as f64 / self.completed as f64
        }
    }

    /// Completions per second over the window at the modeled clock.
    pub fn throughput_rps(&self, window_cc: u64, clock_ghz: f64) -> f64 {
        if window_cc == 0 {
            return 0.0;
        }
        let secs = window_cc as f64 / (clock_ghz * 1e9);
        self.completed as f64 / secs
    }
}

/// Windowed streaming statistics: a bounded ring of completion-time
/// windows (each with its own latency histogram and miss counts) plus
/// post-warm-up steady-state aggregates — O(windows + tenants), however
/// long the trace.  Completions arrive in scheduling order, not time
/// order, so the ring tolerates out-of-order recording; only windows
/// evicted off the ring's tail refuse late samples (counted in
/// [`late`](Self::late)).
#[derive(Debug, Clone)]
pub struct StreamingStats {
    /// Window length in cycles.
    pub window_cc: u64,
    /// Completions before this cutoff are excluded from the
    /// steady-state aggregates (they still land in their window).
    pub warmup_cc: u64,
    /// Modeled clock, for throughput conversions.
    pub clock_ghz: f64,
    /// Index of `windows[0]` (window i spans
    /// `i * window_cc .. (i + 1) * window_cc`).
    base_idx: u64,
    /// The retained ring, oldest first; capacity
    /// [`max_windows`](Self::max_windows).
    windows: std::collections::VecDeque<WindowStats>,
    max_windows: usize,
    /// Windows evicted off the ring (their completions remain in the
    /// steady-state aggregates).
    pub dropped_windows: u64,
    /// Completions that landed in an already-evicted window.
    pub late: u64,
    /// Post-warm-up latency histogram over all tenants.
    pub steady: LatencyHist,
    /// Post-warm-up per-tenant latency histograms.
    pub steady_per_tenant: Vec<LatencyHist>,
    /// Post-warm-up deadline misses per tenant.
    pub steady_misses: Vec<u64>,
    /// Live-set accounting from the streaming driver.
    pub admitted: u64,
    pub retired: u64,
    pub live_peak: usize,
    pub inflight_peak: usize,
}

impl StreamingStats {
    pub fn new(
        window_cc: u64,
        warmup_cc: u64,
        max_windows: usize,
        n_tenants: usize,
        clock_ghz: f64,
    ) -> StreamingStats {
        StreamingStats {
            window_cc: window_cc.max(1),
            warmup_cc,
            clock_ghz,
            base_idx: 0,
            windows: std::collections::VecDeque::new(),
            max_windows: max_windows.max(1),
            dropped_windows: 0,
            late: 0,
            steady: LatencyHist::new(),
            steady_per_tenant: vec![LatencyHist::new(); n_tenants],
            steady_misses: vec![0; n_tenants],
            admitted: 0,
            retired: 0,
            live_peak: 0,
            inflight_peak: 0,
        }
    }

    /// Fold one completion in.
    pub fn record(&mut self, tenant: usize, completion_cc: u64, latency_cc: u64, missed: bool) {
        let idx = completion_cc / self.window_cc;
        if self.windows.is_empty() {
            self.base_idx = idx;
            self.windows.push_back(WindowStats {
                start_cc: idx * self.window_cc,
                ..WindowStats::default()
            });
        }
        // completions arrive in scheduling order, not time order: a
        // completion before the ring's base extends the ring backward
        // while capacity allows (only possible before any eviction)
        while idx < self.base_idx
            && self.windows.len() < self.max_windows
            && self.dropped_windows == 0
        {
            self.base_idx -= 1;
            self.windows.push_front(WindowStats {
                start_cc: self.base_idx * self.window_cc,
                ..WindowStats::default()
            });
        }
        if idx < self.base_idx {
            self.late += 1;
        } else {
            while idx >= self.base_idx + self.windows.len() as u64 {
                let next = self.base_idx + self.windows.len() as u64;
                self.windows.push_back(WindowStats {
                    start_cc: next * self.window_cc,
                    ..WindowStats::default()
                });
                if self.windows.len() > self.max_windows {
                    self.windows.pop_front();
                    self.base_idx += 1;
                    self.dropped_windows += 1;
                }
            }
            let w = &mut self.windows[(idx - self.base_idx) as usize];
            w.completed += 1;
            w.missed += u64::from(missed);
            w.hist.record(latency_cc);
        }
        if completion_cc >= self.warmup_cc {
            self.steady.record(latency_cc);
            self.steady_per_tenant[tenant].record(latency_cc);
            self.steady_misses[tenant] += u64::from(missed);
        }
    }

    /// The retained windows, oldest first.
    pub fn windows(&self) -> impl Iterator<Item = &WindowStats> {
        self.windows.iter()
    }

    /// Post-warm-up p99 over all tenants (bucket-resolved).
    pub fn steady_p99_cc(&self) -> u64 {
        self.steady.percentile_cc(99.0)
    }

    /// Post-warm-up throughput in requests per second, measured from
    /// the warm-up cutoff to the last retained window's end.
    pub fn steady_throughput_rps(&self, makespan_cc: u64) -> f64 {
        let span = makespan_cc.saturating_sub(self.warmup_cc);
        if span == 0 {
            return 0.0;
        }
        self.steady.count() as f64 / (span as f64 / (self.clock_ghz * 1e9))
    }
}

/// Nearest-rank percentile (`p` in [0, 100]) of an unsorted latency
/// sample; 0 for an empty sample.
pub fn percentile_cc(latencies: &[u64], p: f64) -> u64 {
    if latencies.is_empty() {
        return 0;
    }
    let mut sorted = latencies.to_vec();
    sorted.sort_unstable();
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let l = [10, 20, 30, 40, 50, 60, 70, 80, 90, 100];
        assert_eq!(percentile_cc(&l, 50.0), 50);
        assert_eq!(percentile_cc(&l, 99.0), 100);
        assert_eq!(percentile_cc(&l, 100.0), 100);
        assert_eq!(percentile_cc(&l, 0.0), 10);
        assert_eq!(percentile_cc(&[42], 99.0), 42);
        assert_eq!(percentile_cc(&[], 50.0), 0);
    }

    #[test]
    fn latency_hist_percentiles_bracket_exact_values() {
        let mut h = LatencyHist::new();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            h.record(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.max_cc(), 100);
        assert!((h.mean_cc() - 55.0).abs() < 1e-9);
        // bucket-resolved: upper edge of the containing power-of-two
        // bucket, so within 2x above the exact nearest-rank value
        let p50 = h.percentile_cc(50.0);
        assert!((50..=100).contains(&p50), "p50 {p50}");
        let p99 = h.percentile_cc(99.0);
        assert!((100..=127).contains(&p99), "p99 {p99}");
        // clamped to the observed max
        assert!(p99 <= h.max_cc().max(p50));
        assert_eq!(LatencyHist::new().percentile_cc(99.0), 0);
    }

    #[test]
    fn latency_hist_merge_matches_combined_recording() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        let mut both = LatencyHist::new();
        for v in [1u64, 5, 9, 1_000] {
            a.record(v);
            both.record(v);
        }
        for v in [2u64, 7_000_000, 42] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.max_cc(), both.max_cc());
        assert_eq!(a.percentile_cc(50.0), both.percentile_cc(50.0));
        assert_eq!(a.percentile_cc(99.0), both.percentile_cc(99.0));
    }

    #[test]
    fn streaming_stats_windows_and_warmup() {
        let mut s = StreamingStats::new(1_000, 2_000, 4, 2, 1.0);
        // warm-up completions land in windows but not steady stats
        s.record(0, 500, 400, false);
        s.record(1, 1_500, 300, true);
        assert_eq!(s.steady.count(), 0);
        // steady completions, out of order across windows
        s.record(0, 3_500, 700, false);
        s.record(0, 2_500, 600, true);
        s.record(1, 3_900, 800, false);
        assert_eq!(s.steady.count(), 3);
        assert_eq!(s.steady_per_tenant[0].count(), 2);
        assert_eq!(s.steady_misses[0], 1);
        let w: Vec<_> = s.windows().collect();
        assert_eq!(w.len(), 4);
        assert_eq!(w[0].start_cc, 0);
        assert_eq!(w[0].completed, 1);
        assert_eq!(w[1].missed, 1);
        assert_eq!(w[2].completed, 1); // the 2_500 completion
        assert_eq!(w[3].completed, 2);
        assert!(w[3].throughput_rps(1_000, 1.0) > 0.0);
    }

    #[test]
    fn streaming_stats_ring_evicts_old_windows() {
        let mut s = StreamingStats::new(100, 0, 3, 1, 1.0);
        for i in 0..10u64 {
            s.record(0, i * 100 + 50, 10, false);
        }
        assert_eq!(s.windows().count(), 3);
        assert_eq!(s.dropped_windows, 7);
        // a completion for an evicted window is counted, not folded
        s.record(0, 50, 10, false);
        assert_eq!(s.late, 1);
        // steady stats still saw everything
        assert_eq!(s.steady.count(), 11);
    }
}
