//! Scenario-level allocation search: NSGA-II over the flat
//! `(tenant, layer) → core` genome.
//!
//! The single-model GA partitions one network's layers across cores;
//! [`ScenarioGa`] co-optimizes the **static core partitioning across
//! tenants** instead.  Its genome concatenates every tenant's dense
//! genes ([`allocation_from_genome_multi`]) and each fitness evaluation
//! is one full [`ScenarioSim::run`] co-schedule, minimized over the
//! serving objectives `(deadline misses, worst per-tenant p99 latency,
//! energy)`.  The evolutionary loop itself is the same shared driver
//! the single-model GA runs on ([`allocator::evolve`](fn@crate::allocator::evolve)):
//! `ScenarioGa` only provides the genome shape, the seed genomes and
//! the co-schedule fitness through the [`EvoProblem`] trait.
//!
//! [`per_tenant_ga`] is the uncoordinated baseline: each tenant runs
//! the classic single-model GA in isolation, blind to its neighbors.

use std::collections::HashMap;

use crate::allocator::{
    allocation_from_genome_multi, evolve, genome_len_multi, manual_allocation, EvoProblem,
    Ga, GaParams, Objective,
};
use crate::arch::CoreId;
use crate::scheduler::{Arbitration, Scheduler};

use super::engine::{ScenarioRunner, ScenarioSim};

/// One Pareto-front member of the scenario search.
#[derive(Debug, Clone)]
pub struct ScenarioGaResult {
    pub genome: Vec<u16>,
    /// Expanded per-tenant allocations.
    pub allocations: Vec<Vec<CoreId>>,
    /// Objective vector `(misses, worst p99 cc, energy pJ)`.
    pub misses: usize,
    pub worst_p99_cc: u64,
    pub energy_pj: f64,
}

/// NSGA-II search over multi-tenant core partitionings.  See the
/// [module docs](self).
pub struct ScenarioGa<'a> {
    sim: &'a ScenarioSim<'a>,
    /// Prebuilt co-scheduler, shared by every fitness evaluation.
    runner: ScenarioRunner<'a>,
    arbitration: Arbitration,
    params: GaParams,
    /// Serving-objective memo per genome (the shared driver keeps the
    /// deterministic first-seen record).
    objectives: HashMap<Vec<u16>, Vec<f64>>,
}

impl<'a> ScenarioGa<'a> {
    pub fn new(
        sim: &'a ScenarioSim<'a>,
        arbitration: Arbitration,
        params: GaParams,
    ) -> ScenarioGa<'a> {
        ScenarioGa {
            sim,
            runner: sim.runner(),
            arbitration,
            params,
            objectives: HashMap::new(),
        }
    }

    /// `(misses, worst p99, energy)` of one genome, memoized.
    fn eval_one(&mut self, genome: &[u16]) -> Vec<f64> {
        if let Some(v) = self.objectives.get(genome) {
            return v.clone();
        }
        let allocs =
            allocation_from_genome_multi(&self.sim.tenant_workloads(), self.sim.arch, genome);
        let r = self.runner.run(&allocs, self.arbitration);
        let v = vec![
            r.total_misses() as f64,
            r.worst_p99_cc() as f64,
            r.metrics.energy_pj,
        ];
        self.objectives.insert(genome.to_vec(), v.clone());
        v
    }

    /// Run the search on the shared evolutionary driver
    /// ([`allocator::evolve`](fn@crate::allocator::evolve)); returns
    /// the Pareto front over the serving objectives, best miss-count
    /// first.
    pub fn run(&mut self) -> Vec<ScenarioGaResult> {
        let params = self.params;
        let outcome = evolve(self, &params);
        let mut results: Vec<ScenarioGaResult> = outcome
            .front
            .iter()
            .map(|&i| {
                let (genome, point) = &outcome.evaluated[i];
                ScenarioGaResult {
                    genome: genome.clone(),
                    allocations: allocation_from_genome_multi(
                        &self.sim.tenant_workloads(),
                        self.sim.arch,
                        genome,
                    ),
                    misses: point[0] as usize,
                    worst_p99_cc: point[1] as u64,
                    energy_pj: point[2],
                }
            })
            .collect();
        results.sort_by(|a, b| {
            (a.misses, a.worst_p99_cc)
                .cmp(&(b.misses, b.worst_p99_cc))
                .then(a.energy_pj.total_cmp(&b.energy_pj))
        });
        results
    }
}

/// The [`ScenarioGa`]'s instantiation of the shared evolutionary
/// driver: the flat multi-tenant genome, serving-objective fitness
/// through one co-schedule per unseen genome, and a `(1 + objective)`
/// product scalarization for the patience check — robust to the
/// frequent all-deadlines-met `misses == 0` case.
impl EvoProblem for ScenarioGa<'_> {
    fn genome_len(&self) -> usize {
        genome_len_multi(&self.sim.tenant_workloads())
    }

    fn n_cores(&self) -> usize {
        self.sim.arch.dense_cores().len()
    }

    /// Seed genomes: the greedy per-tenant baseline, a Herald-style
    /// static tenant partitioning (tenant *t* owns core `t mod k`), a
    /// global ping-pong and each-core-solo assignments.
    fn seed_genomes(&self) -> Vec<Vec<u16>> {
        let n = self.genome_len();
        let k = self.n_cores();
        let mut seeds = vec![encode_allocations(self.sim, &self.sim.greedy_allocations())];
        let mut partitioned = Vec::with_capacity(n);
        for (t, w) in self.sim.tenant_workloads().iter().enumerate() {
            partitioned.extend((0..w.dense_layers().len()).map(|_| (t % k) as u16));
        }
        seeds.push(partitioned);
        seeds.push((0..n).map(|i| (i % k) as u16).collect());
        for c in 0..k {
            seeds.push(vec![c as u16; n]);
        }
        seeds
    }

    fn evaluate(&mut self, genomes: &[Vec<u16>]) -> Vec<Vec<f64>> {
        genomes.iter().map(|g| self.eval_one(g)).collect()
    }

    fn scalarize(&self, point: &[f64]) -> f64 {
        point.iter().map(|v| v + 1.0).product()
    }
}

/// Encode per-tenant allocations back into the flat multi-tenant
/// genome (inverse of [`allocation_from_genome_multi`] for dense
/// layers).
fn encode_allocations(sim: &ScenarioSim, allocs: &[Vec<CoreId>]) -> Vec<u16> {
    let dense = sim.arch.dense_cores();
    let mut genome = Vec::new();
    for (b, a) in sim.builds().iter().zip(allocs) {
        for lid in b.workload.dense_layers() {
            let pos = dense.iter().position(|&c| c == a[lid.0]).unwrap_or(0);
            genome.push(pos as u16);
        }
    }
    genome
}

/// The uncoordinated baseline: each tenant optimized by the classic
/// single-model GA on its own, ignoring the other tenants' traffic.
pub fn per_tenant_ga(sim: &ScenarioSim, params: GaParams) -> Vec<Vec<CoreId>> {
    sim.builds()
        .iter()
        .zip(&sim.scenario.tenants)
        .map(|(b, t)| {
            let sched = Scheduler::new(&b.workload, &b.graph, &b.costs, sim.arch);
            let mut ga = Ga::new(
                &b.workload,
                sim.arch,
                &sched,
                t.pool_priority,
                Objective::Edp,
                params,
            );
            let front = ga.run();
            match front.first() {
                Some(r) => r.allocation.clone(),
                None => manual_allocation(&b.workload, sim.arch, &b.costs, &b.graph.cns, true),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::scenario::spec::{Arrival, Scenario, Tenant};

    fn contended() -> Scenario {
        Scenario::new(
            "contended",
            vec![
                Tenant::new("a", "tiny-segment", Arrival::OneShot { at_cc: 0 })
                    .deadline(2_000_000),
                Tenant::new("b", "tiny-branchy", Arrival::OneShot { at_cc: 0 })
                    .deadline(2_000_000),
            ],
        )
    }

    fn small_params(seed: u64) -> GaParams {
        GaParams { population: 6, generations: 3, seed, ..Default::default() }
    }

    #[test]
    fn scenario_ga_runs_and_is_deterministic() {
        let scenario = contended();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let run = |seed| {
            let mut ga = ScenarioGa::new(&sim, Arbitration::Fifo, small_params(seed));
            let front = ga.run();
            assert!(!front.is_empty());
            (front[0].genome.clone(), front[0].worst_p99_cc, front[0].energy_pj.to_bits())
        };
        assert_eq!(run(11), run(11));
    }

    #[test]
    fn genome_roundtrips_through_encode() {
        let scenario = contended();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let genome: Vec<u16> =
            (0..genome_len_multi(&sim.tenant_workloads())).map(|i| (i % 2) as u16).collect();
        let allocs =
            allocation_from_genome_multi(&sim.tenant_workloads(), sim.arch, &genome);
        assert_eq!(encode_allocations(&sim, &allocs), genome);
    }

    #[test]
    fn per_tenant_ga_gives_one_allocation_per_tenant() {
        let scenario = contended();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let allocs = per_tenant_ga(&sim, small_params(1));
        assert_eq!(allocs.len(), 2);
        for (b, a) in sim.builds().iter().zip(&allocs) {
            assert_eq!(a.len(), b.workload.len());
        }
        // the co-schedule accepts them
        let r = sim.run(&allocs, Arbitration::Edf);
        assert_eq!(r.outcomes.len(), 2);
    }
}
