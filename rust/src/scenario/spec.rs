//! Scenario descriptions: tenants, request patterns, deadlines and the
//! canned scenario library.

use crate::cn::CnGranularity;
use crate::scheduler::SchedulePriority;
use crate::workload::models;
use crate::workload::WorkloadGraph;

/// When a tenant's inference requests arrive, in clock cycles of the
/// modeled accelerator.  All patterns are deterministic so scenario
/// runs (and the GA fitness built on them) are exactly reproducible.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Arrival {
    /// A single request released at `at_cc`.
    OneShot { at_cc: u64 },
    /// `count` requests released every `every_cc` cycles starting at
    /// `offset_cc` (a periodic camera / sensor stream).
    Periodic { every_cc: u64, count: usize, offset_cc: u64 },
    /// An explicit release-time trace (deterministic bursty arrivals).
    Burst { times_cc: Vec<u64> },
}

impl Arrival {
    /// The release times this pattern expands to, ascending.
    pub fn releases(&self) -> Vec<u64> {
        match self {
            Arrival::OneShot { at_cc } => vec![*at_cc],
            Arrival::Periodic { every_cc, count, offset_cc } => {
                let step = (*every_cc).max(1);
                (0..*count).map(|i| *offset_cc + i as u64 * step).collect()
            }
            Arrival::Burst { times_cc } => {
                let mut t = times_cc.clone();
                t.sort_unstable();
                t
            }
        }
    }
}

/// One tenant model sharing the accelerator.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Display name (e.g. `"detector"`).
    pub name: String,
    /// Workload name resolved through [`models::by_name`].
    pub model: String,
    pub arrival: Arrival,
    /// Per-request deadline relative to its release, in cycles.
    pub deadline_cc: Option<u64>,
    /// Arbitration priority (higher wins under
    /// [`Arbitration::Priority`](super::Arbitration::Priority)).
    pub priority: u16,
    /// Intra-request candidate-pool priority (paper Fig. 8 semantics,
    /// per tenant).
    pub pool_priority: SchedulePriority,
}

impl Tenant {
    pub fn new(name: &str, model: &str, arrival: Arrival) -> Tenant {
        Tenant {
            name: name.to_string(),
            model: model.to_string(),
            arrival,
            deadline_cc: None,
            priority: 0,
            pool_priority: SchedulePriority::Latency,
        }
    }

    pub fn deadline(mut self, cc: u64) -> Tenant {
        self.deadline_cc = Some(cc);
        self
    }

    pub fn priority(mut self, p: u16) -> Tenant {
        self.priority = p;
        self
    }

    pub fn pool_priority(mut self, p: SchedulePriority) -> Tenant {
        self.pool_priority = p;
        self
    }

    /// Resolve the tenant's workload graph.
    pub fn workload(&self) -> Option<WorkloadGraph> {
        models::by_name(&self.model)
    }
}

/// A multi-DNN serving scenario: N tenants, each with a request stream,
/// sharing one accelerator's cores, interconnect links and DRAM ports.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub tenants: Vec<Tenant>,
    /// CN granularity applied to every tenant (clamped per-arch like
    /// the single-model pipeline).
    pub granularity: CnGranularity,
    /// Modeled clock in GHz, used only to convert cycle counts into
    /// requests-per-second throughput.
    pub clock_ghz: f64,
}

impl Scenario {
    pub fn new(name: &str, tenants: Vec<Tenant>) -> Scenario {
        Scenario {
            name: name.to_string(),
            tenants,
            granularity: CnGranularity::Lines(4),
            clock_ghz: 1.0,
        }
    }

    /// Total request count across tenants.
    pub fn n_requests(&self) -> usize {
        self.tenants.iter().map(|t| t.arrival.releases().len()).sum()
    }

    /// Expand the tenants' arrival patterns into the request list the
    /// engine schedules: sorted by (release, tenant order), so `seq`
    /// is the FIFO arbitration order.
    pub fn requests(&self) -> Vec<Request> {
        let mut reqs = Vec::new();
        for (t, tenant) in self.tenants.iter().enumerate() {
            for release_cc in tenant.arrival.releases() {
                reqs.push(Request {
                    seq: 0,
                    tenant: t,
                    release_cc,
                    deadline_abs_cc: tenant.deadline_cc.map(|d| release_cc + d),
                });
            }
        }
        reqs.sort_by_key(|r| (r.release_cc, r.tenant));
        for (i, r) in reqs.iter_mut().enumerate() {
            r.seq = i;
        }
        reqs
    }
}

/// One concrete inference request expanded from a tenant's [`Arrival`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Arrival order across the whole scenario (FIFO tie-break key).
    pub seq: usize,
    /// Index into [`Scenario::tenants`].
    pub tenant: usize,
    pub release_cc: u64,
    /// Absolute deadline (`release + deadline_cc`), if any.
    pub deadline_abs_cc: Option<u64>,
}

// ---------------------------------------------------------------------------
// canned scenario library
// ---------------------------------------------------------------------------

/// Edge-device mix: a periodic classifier, a periodic low-priority
/// enhancement net and a bursty high-priority detector — three tenants
/// with different deadlines contending for the same fabric.
pub fn edge_mix() -> Scenario {
    Scenario::new(
        "edge_mix",
        vec![
            Tenant::new(
                "classifier",
                "squeezenet",
                Arrival::Periodic { every_cc: 2_000_000, count: 3, offset_cc: 0 },
            )
            .deadline(4_000_000)
            .priority(1),
            Tenant::new(
                "enhancer",
                "mobilenetv2",
                Arrival::Periodic { every_cc: 1_500_000, count: 4, offset_cc: 250_000 },
            )
            .deadline(3_000_000),
            Tenant::new(
                "detector",
                "tinyyolo",
                Arrival::Burst { times_cc: vec![500_000, 3_500_000] },
            )
            .deadline(12_000_000)
            .priority(2),
        ],
    )
}

/// Autonomous-vehicle pipeline: a hard-deadline perception net and a
/// softer planning net on the same period, phase-shifted.
pub fn av_pipeline() -> Scenario {
    Scenario::new(
        "av_pipeline",
        vec![
            Tenant::new(
                "perception",
                "tinyyolo",
                Arrival::Periodic { every_cc: 8_000_000, count: 3, offset_cc: 0 },
            )
            .deadline(8_000_000)
            .priority(3),
            Tenant::new(
                "planning",
                "resnet18",
                Arrival::Periodic { every_cc: 8_000_000, count: 3, offset_cc: 1_000_000 },
            )
            .deadline(16_000_000)
            .priority(1),
        ],
    )
}

/// Herald-style duplicate co-location: four independent ResNet-18
/// tenants released together, measuring pure multi-instance contention.
pub fn duplicate_resnet_x4() -> Scenario {
    Scenario::new(
        "duplicate_resnet_x4",
        (0..4)
            .map(|i| {
                Tenant::new(&format!("resnet18-{i}"), "resnet18", Arrival::OneShot { at_cc: 0 })
            })
            .collect(),
    )
}

/// LLM serving: decode-step request streams over the `llm_decode`
/// workload.  An interactive stream (tight per-token deadline, high
/// priority — a chat user waiting on the next token) contends with a
/// batch stream (loose deadline — offline summarization) for the same
/// fabric; every request is one single-token decode step whose weight
/// and KV-cache reads make it DRAM-bound, so arbitration and topology
/// decide the tail latency.  Deadlines are sized to the ~4.4 Mcc
/// weight-streaming floor of a cold step on the exploration DRAM port
/// (35.3 MB x 8 / 64 bit/cc): interactive gets ~2x the floor (room for
/// one warm-up fetch plus arbitration jitter, but no slack for sitting
/// behind a whole batch step), batch ~6x (absorbs queueing behind the
/// interactive stream without being vacuous).  The deadline-coverage
/// test in this module pins that both remain *feasible* under EDF on
/// the exploration preset while staying within those multiples.
pub fn llm_serving() -> Scenario {
    Scenario::new(
        "llm_serving",
        vec![
            Tenant::new(
                "interactive",
                "llm-decode",
                Arrival::Periodic { every_cc: 6_000_000, count: 3, offset_cc: 0 },
            )
            .deadline(9_000_000)
            .priority(2),
            Tenant::new(
                "batch",
                "llm-decode",
                Arrival::Burst { times_cc: vec![0, 2_000_000] },
            )
            .deadline(27_000_000)
            .priority(1),
        ],
    )
}

/// Tiny two-tenant mix over the synthetic test networks — fast enough
/// for unit tests and CI smoke runs.
pub fn tiny_mix() -> Scenario {
    Scenario::new(
        "tiny_mix",
        vec![
            Tenant::new(
                "seg",
                "tiny-segment",
                Arrival::Periodic { every_cc: 20_000, count: 3, offset_cc: 0 },
            )
            .deadline(200_000)
            .priority(1),
            Tenant::new("branchy", "tiny-branchy", Arrival::Burst { times_cc: vec![0, 30_000] })
                .deadline(300_000),
        ],
    )
}

/// Look a canned scenario up by CLI name.
pub fn by_name(name: &str) -> Option<Scenario> {
    match name {
        "edge_mix" | "edge-mix" => Some(edge_mix()),
        "av_pipeline" | "av-pipeline" => Some(av_pipeline()),
        "duplicate_resnet_x4" | "duplicate-resnet-x4" => Some(duplicate_resnet_x4()),
        "llm_serving" | "llm-serving" => Some(llm_serving()),
        "tiny_mix" | "tiny-mix" => Some(tiny_mix()),
        _ => None,
    }
}

pub const SCENARIO_NAMES: &[&str] =
    &["edge_mix", "av_pipeline", "duplicate_resnet_x4", "llm_serving", "tiny_mix"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_expansion() {
        assert_eq!(Arrival::OneShot { at_cc: 7 }.releases(), vec![7]);
        assert_eq!(
            Arrival::Periodic { every_cc: 10, count: 3, offset_cc: 5 }.releases(),
            vec![5, 15, 25]
        );
        assert_eq!(Arrival::Burst { times_cc: vec![9, 1, 4] }.releases(), vec![1, 4, 9]);
    }

    #[test]
    fn requests_sorted_and_sequenced() {
        let s = tiny_mix();
        let reqs = s.requests();
        assert_eq!(reqs.len(), s.n_requests());
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.seq, i);
        }
        for pair in reqs.windows(2) {
            assert!(
                (pair[0].release_cc, pair[0].tenant) <= (pair[1].release_cc, pair[1].tenant)
            );
        }
        // deadlines are absolute
        assert_eq!(reqs[0].deadline_abs_cc, Some(reqs[0].release_cc + 200_000));
    }

    #[test]
    fn llm_serving_is_decode_streams_with_deadlines() {
        let s = llm_serving();
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.n_requests(), 5);
        for t in &s.tenants {
            assert_eq!(t.model, "llm-decode");
            assert!(t.deadline_cc.is_some(), "{}: serving SLO required", t.name);
        }
        assert!(s.tenants[0].priority > s.tenants[1].priority, "interactive wins arbitration");
        // every expanded request carries an absolute deadline
        for r in s.requests() {
            assert!(r.deadline_abs_cc.is_some());
        }
    }

    #[test]
    fn llm_serving_deadlines_are_tight_but_feasible() {
        let s = llm_serving();
        // The deadlines sit at small multiples of the analytic cold-step
        // floor: every decode step re-streams the full weight set (no
        // layer fits the per-core weight SRAM), so one step can never
        // beat total-weight-bits / 64 bit/cc on a single DRAM port.
        let wl = s.tenants[0].workload().unwrap();
        let floor_cc = wl.total_weight_bytes() * 8 / 64;
        assert!(
            (4_000_000..5_000_000).contains(&floor_cc),
            "decode-step floor moved: {floor_cc}"
        );
        let interactive = s.tenants[0].deadline_cc.unwrap();
        let batch = s.tenants[1].deadline_cc.unwrap();
        assert!(interactive >= floor_cc, "infeasible by construction");
        assert!(interactive <= 3 * floor_cc, "interactive SLO must bind: {interactive}");
        assert!(batch >= 4 * floor_cc, "batch must absorb queueing: {batch}");
        assert!(batch <= 8 * floor_cc, "batch SLO must bind: {batch}");

        // ... and they are feasible at a real operating point: EDF on
        // the exploration mesh serves every request on time.
        let arch = crate::arch::presets::by_name("hetero_quad@mesh").unwrap();
        let sim = crate::scenario::ScenarioSim::new(&s, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), crate::scenario::Arbitration::Edf);
        assert_eq!(r.total_misses(), 0, "EDF must meet every tightened deadline");
        for t in &r.tenants {
            assert_eq!(t.misses, 0, "{}", t.name);
            assert_eq!(t.miss_rate, 0.0, "{}", t.name);
            // decode steps really are Mcc-scale (the lm_head stream
            // alone is ~2 Mcc), so the deadlines leave little slack
            assert!(t.p50_cc >= 2_000_000, "{}: p50 {} cc", t.name, t.p50_cc);
        }
    }

    #[test]
    fn library_resolves_models() {
        for name in SCENARIO_NAMES {
            let s = by_name(name).unwrap();
            assert!(!s.tenants.is_empty(), "{name}");
            for t in &s.tenants {
                assert!(t.workload().is_some(), "{name}: unknown model {}", t.model);
            }
            assert!(s.n_requests() >= 1, "{name}");
        }
        assert!(by_name("nope").is_none());
    }
}
