//! Scenario descriptions: tenants, request patterns, deadlines and the
//! canned scenario library.

use crate::cn::CnGranularity;
use crate::scheduler::SchedulePriority;
use crate::workload::models;
use crate::workload::WorkloadGraph;

/// When a tenant's inference requests arrive, in clock cycles of the
/// modeled accelerator.  All patterns are deterministic so scenario
/// runs (and the GA fitness built on them) are exactly reproducible.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Arrival {
    /// A single request released at `at_cc`.
    OneShot { at_cc: u64 },
    /// `count` requests released every `every_cc` cycles starting at
    /// `offset_cc` (a periodic camera / sensor stream).
    Periodic { every_cc: u64, count: usize, offset_cc: u64 },
    /// An explicit release-time trace (deterministic bursty arrivals).
    Burst { times_cc: Vec<u64> },
}

impl Arrival {
    /// The release times this pattern expands to, ascending.
    pub fn releases(&self) -> Vec<u64> {
        match self {
            Arrival::OneShot { at_cc } => vec![*at_cc],
            Arrival::Periodic { every_cc, count, offset_cc } => {
                let step = (*every_cc).max(1);
                (0..*count).map(|i| *offset_cc + i as u64 * step).collect()
            }
            Arrival::Burst { times_cc } => {
                let mut t = times_cc.clone();
                t.sort_unstable();
                t
            }
        }
    }
}

/// One tenant model sharing the accelerator.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Display name (e.g. `"detector"`).
    pub name: String,
    /// Workload name resolved through [`models::by_name`].
    pub model: String,
    pub arrival: Arrival,
    /// Per-release jitter bound for [`Arrival::Burst`] times: each burst
    /// release is shifted by a deterministic pseudo-random offset in
    /// `[0, jitter_cc]` drawn from the scenario seed, so long streamed
    /// traces are reproducible yet sweepable.  `0` (the default) leaves
    /// the burst times exactly as written.
    pub jitter_cc: u64,
    /// Per-request deadline relative to its release, in cycles.
    pub deadline_cc: Option<u64>,
    /// Arbitration priority (higher wins under
    /// [`Arbitration::Priority`](super::Arbitration::Priority)).
    pub priority: u16,
    /// Intra-request candidate-pool priority (paper Fig. 8 semantics,
    /// per tenant).
    pub pool_priority: SchedulePriority,
}

impl Tenant {
    pub fn new(name: &str, model: &str, arrival: Arrival) -> Tenant {
        Tenant {
            name: name.to_string(),
            model: model.to_string(),
            arrival,
            jitter_cc: 0,
            deadline_cc: None,
            priority: 0,
            pool_priority: SchedulePriority::Latency,
        }
    }

    pub fn deadline(mut self, cc: u64) -> Tenant {
        self.deadline_cc = Some(cc);
        self
    }

    pub fn priority(mut self, p: u16) -> Tenant {
        self.priority = p;
        self
    }

    pub fn pool_priority(mut self, p: SchedulePriority) -> Tenant {
        self.pool_priority = p;
        self
    }

    pub fn jitter(mut self, cc: u64) -> Tenant {
        self.jitter_cc = cc;
        self
    }

    /// Resolve the tenant's workload graph.
    pub fn workload(&self) -> Option<WorkloadGraph> {
        models::by_name(&self.model)
    }

    /// The tenant's release times with burst jitter applied — the
    /// *canonical* sequence used by both [`Scenario::requests`] and the
    /// lazy [`ArrivalStream`], so eager and streamed paths agree
    /// bit-for-bit.  With `jitter_cc == 0` this is exactly
    /// [`Arrival::releases`].
    pub fn releases_seeded(&self, tenant_idx: usize, seed: u64) -> Vec<u64> {
        let mut times = self.arrival.releases();
        if self.jitter_cc > 0 {
            if let Arrival::Burst { .. } = self.arrival {
                let mut rng = crate::util::XorShift64::new(
                    seed ^ (tenant_idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                for t in &mut times {
                    *t += rng.below(self.jitter_cc + 1);
                }
                times.sort_unstable();
            }
        }
        times
    }
}

/// A multi-DNN serving scenario: N tenants, each with a request stream,
/// sharing one accelerator's cores, interconnect links and DRAM ports.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub tenants: Vec<Tenant>,
    /// CN granularity applied to every tenant (clamped per-arch like
    /// the single-model pipeline).
    pub granularity: CnGranularity,
    /// Modeled clock in GHz, used only to convert cycle counts into
    /// requests-per-second throughput.
    pub clock_ghz: f64,
    /// Seed for deterministic burst jitter (see [`Tenant::jitter_cc`]).
    /// Two runs with the same seed replay the identical trace.
    pub seed: u64,
}

impl Scenario {
    pub fn new(name: &str, tenants: Vec<Tenant>) -> Scenario {
        Scenario {
            name: name.to_string(),
            tenants,
            granularity: CnGranularity::Lines(4),
            clock_ghz: 1.0,
            seed: 0,
        }
    }

    pub fn seed(mut self, seed: u64) -> Scenario {
        self.seed = seed;
        self
    }

    /// Total request count across tenants.
    pub fn n_requests(&self) -> usize {
        self.tenants.iter().map(|t| t.arrival.releases().len()).sum()
    }

    /// Expand the tenants' arrival patterns into the request list the
    /// engine schedules: sorted by (release, tenant order), so `seq`
    /// is the FIFO arbitration order.
    ///
    /// This is the *eager* form — O(total requests) memory.  Long
    /// traces should use [`Scenario::request_stream`], which yields the
    /// identical sequence lazily.
    pub fn requests(&self) -> Vec<Request> {
        let mut reqs = Vec::new();
        for (t, tenant) in self.tenants.iter().enumerate() {
            for release_cc in tenant.releases_seeded(t, self.seed) {
                reqs.push(Request {
                    seq: 0,
                    tenant: t,
                    release_cc,
                    deadline_abs_cc: tenant.deadline_cc.map(|d| release_cc + d),
                });
            }
        }
        reqs.sort_by_key(|r| (r.release_cc, r.tenant));
        for (i, r) in reqs.iter_mut().enumerate() {
            r.seq = i;
        }
        reqs
    }

    /// Pull-based request generator: yields exactly the same requests as
    /// [`Scenario::requests`], in the same `(release, tenant)` order
    /// with the same `seq` numbering, without materializing the trace.
    pub fn request_stream(&self) -> RequestStream {
        RequestStream::new(self)
    }

    /// Grow every tenant's arrival pattern to cover `[0, duration_cc]`:
    /// periodic streams extend their count, burst traces tile their
    /// pattern forward in time, one-shots are left alone.  Used by the
    /// CLI `--duration` flag to turn the canned scenarios into
    /// arbitrarily long serving traces.
    pub fn extend_to(mut self, duration_cc: u64) -> Scenario {
        for t in &mut self.tenants {
            match &mut t.arrival {
                Arrival::OneShot { .. } => {}
                Arrival::Periodic { every_cc, count, offset_cc } => {
                    if duration_cc >= *offset_cc {
                        let step = (*every_cc).max(1);
                        let fit = ((duration_cc - *offset_cc) / step) as usize + 1;
                        *count = (*count).max(fit);
                    }
                }
                Arrival::Burst { times_cc } => {
                    let mut base = times_cc.clone();
                    base.sort_unstable();
                    if base.is_empty() || *base.last().unwrap() >= duration_cc {
                        continue;
                    }
                    // Tile the burst pattern with a stride of its span
                    // plus its mean inter-arrival gap (min 1), so the
                    // tiled trace keeps the original arrival rate.
                    let span = base.last().unwrap() - base[0];
                    let gap = if base.len() > 1 { (span / (base.len() as u64 - 1)).max(1) } else { 1 };
                    let stride = (span + gap).max(1);
                    let mut out = base.clone();
                    let mut shift = stride;
                    'tile: loop {
                        for &b in &base {
                            let t = b + shift;
                            if t > duration_cc {
                                break 'tile;
                            }
                            out.push(t);
                        }
                        shift += stride;
                    }
                    *times_cc = out;
                }
            }
        }
        self
    }

    /// Scale every tenant's arrival *rate* by `factor` (release times
    /// divide by it): `2.0` doubles the request rate, `0.5` halves it.
    /// Used by the CLI `--rate-scale` flag to push a scenario toward
    /// saturation without editing the spec.
    pub fn scale_rate(mut self, factor: f64) -> Scenario {
        assert!(factor > 0.0, "rate-scale must be positive");
        let scale = |cc: u64| -> u64 { (cc as f64 / factor).round() as u64 };
        for t in &mut self.tenants {
            match &mut t.arrival {
                Arrival::OneShot { at_cc } => *at_cc = scale(*at_cc),
                Arrival::Periodic { every_cc, offset_cc, .. } => {
                    *every_cc = scale(*every_cc).max(1);
                    *offset_cc = scale(*offset_cc);
                }
                Arrival::Burst { times_cc } => {
                    for c in times_cc {
                        *c = scale(*c);
                    }
                }
            }
            t.jitter_cc = scale(t.jitter_cc);
        }
        self
    }
}

/// Lazy release-time generator for one tenant: yields the times of
/// [`Tenant::releases_seeded`] in ascending order without materializing
/// periodic streams (burst traces are explicit vectors already).
#[derive(Debug, Clone)]
pub struct ArrivalStream {
    kind: StreamKind,
}

#[derive(Debug, Clone)]
enum StreamKind {
    Done,
    OneShot { at: u64 },
    Periodic { next: u64, step: u64, remaining: usize },
    Burst { times: Vec<u64>, idx: usize },
}

impl ArrivalStream {
    /// Build the stream for tenant `tenant_idx` of a scenario seeded
    /// with `seed` (the jitter inputs of [`Tenant::releases_seeded`]).
    pub fn new(tenant: &Tenant, tenant_idx: usize, seed: u64) -> ArrivalStream {
        let kind = match &tenant.arrival {
            Arrival::OneShot { at_cc } => StreamKind::OneShot { at: *at_cc },
            Arrival::Periodic { every_cc, count, offset_cc } => {
                if *count == 0 {
                    StreamKind::Done
                } else {
                    StreamKind::Periodic {
                        next: *offset_cc,
                        step: (*every_cc).max(1),
                        remaining: *count,
                    }
                }
            }
            Arrival::Burst { .. } => {
                // Jittered-and-resorted burst times must match the eager
                // expansion exactly, so reuse the canonical sequence.
                StreamKind::Burst { times: tenant.releases_seeded(tenant_idx, seed), idx: 0 }
            }
        };
        ArrivalStream { kind }
    }

    /// Next release time without consuming it.
    pub fn peek(&self) -> Option<u64> {
        match &self.kind {
            StreamKind::Done => None,
            StreamKind::OneShot { at } => Some(*at),
            StreamKind::Periodic { next, .. } => Some(*next),
            StreamKind::Burst { times, idx } => times.get(*idx).copied(),
        }
    }
}

impl Iterator for ArrivalStream {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        match &mut self.kind {
            StreamKind::Done => None,
            StreamKind::OneShot { at } => {
                let t = *at;
                self.kind = StreamKind::Done;
                Some(t)
            }
            StreamKind::Periodic { next, step, remaining } => {
                let t = *next;
                *remaining -= 1;
                if *remaining == 0 {
                    self.kind = StreamKind::Done;
                } else {
                    *next = t + *step;
                }
                Some(t)
            }
            StreamKind::Burst { times, idx } => {
                let t = times.get(*idx).copied();
                if t.is_some() {
                    *idx += 1;
                } else {
                    self.kind = StreamKind::Done;
                }
                t
            }
        }
    }
}

/// K-way merge of all tenants' [`ArrivalStream`]s in `(release, tenant)`
/// order with `seq` assigned in pop order — bit-identical to iterating
/// [`Scenario::requests`], in O(tenants) state.
#[derive(Debug, Clone)]
pub struct RequestStream {
    lanes: Vec<(ArrivalStream, Option<u64>)>,
    next_seq: usize,
}

impl RequestStream {
    pub fn new(scenario: &Scenario) -> RequestStream {
        RequestStream {
            lanes: scenario
                .tenants
                .iter()
                .enumerate()
                .map(|(i, t)| (ArrivalStream::new(t, i, scenario.seed), t.deadline_cc))
                .collect(),
            next_seq: 0,
        }
    }

    /// `(release_cc, tenant)` of the next request without consuming it.
    pub fn peek(&self) -> Option<(u64, usize)> {
        self.lanes
            .iter()
            .enumerate()
            .filter_map(|(t, (s, _))| s.peek().map(|cc| (cc, t)))
            .min()
    }

    /// Requests yielded so far.
    pub fn emitted(&self) -> usize {
        self.next_seq
    }
}

impl Iterator for RequestStream {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        let (release_cc, tenant) = self.peek()?;
        self.lanes[tenant].0.next();
        let deadline = self.lanes[tenant].1;
        let seq = self.next_seq;
        self.next_seq += 1;
        Some(Request {
            seq,
            tenant,
            release_cc,
            deadline_abs_cc: deadline.map(|d| release_cc + d),
        })
    }
}

/// One concrete inference request expanded from a tenant's [`Arrival`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Arrival order across the whole scenario (FIFO tie-break key).
    pub seq: usize,
    /// Index into [`Scenario::tenants`].
    pub tenant: usize,
    pub release_cc: u64,
    /// Absolute deadline (`release + deadline_cc`), if any.
    pub deadline_abs_cc: Option<u64>,
}

// ---------------------------------------------------------------------------
// canned scenario library
// ---------------------------------------------------------------------------

/// Edge-device mix: a periodic classifier, a periodic low-priority
/// enhancement net and a bursty high-priority detector — three tenants
/// with different deadlines contending for the same fabric.
pub fn edge_mix() -> Scenario {
    Scenario::new(
        "edge_mix",
        vec![
            Tenant::new(
                "classifier",
                "squeezenet",
                Arrival::Periodic { every_cc: 2_000_000, count: 3, offset_cc: 0 },
            )
            .deadline(4_000_000)
            .priority(1),
            Tenant::new(
                "enhancer",
                "mobilenetv2",
                Arrival::Periodic { every_cc: 1_500_000, count: 4, offset_cc: 250_000 },
            )
            .deadline(3_000_000),
            Tenant::new(
                "detector",
                "tinyyolo",
                Arrival::Burst { times_cc: vec![500_000, 3_500_000] },
            )
            .deadline(12_000_000)
            .priority(2),
        ],
    )
}

/// Autonomous-vehicle pipeline: a hard-deadline perception net and a
/// softer planning net on the same period, phase-shifted.
pub fn av_pipeline() -> Scenario {
    Scenario::new(
        "av_pipeline",
        vec![
            Tenant::new(
                "perception",
                "tinyyolo",
                Arrival::Periodic { every_cc: 8_000_000, count: 3, offset_cc: 0 },
            )
            .deadline(8_000_000)
            .priority(3),
            Tenant::new(
                "planning",
                "resnet18",
                Arrival::Periodic { every_cc: 8_000_000, count: 3, offset_cc: 1_000_000 },
            )
            .deadline(16_000_000)
            .priority(1),
        ],
    )
}

/// Herald-style duplicate co-location: four independent ResNet-18
/// tenants released together, measuring pure multi-instance contention.
pub fn duplicate_resnet_x4() -> Scenario {
    Scenario::new(
        "duplicate_resnet_x4",
        (0..4)
            .map(|i| {
                Tenant::new(&format!("resnet18-{i}"), "resnet18", Arrival::OneShot { at_cc: 0 })
            })
            .collect(),
    )
}

/// LLM serving: decode-step request streams over the `llm_decode`
/// workload.  An interactive stream (tight per-token deadline, high
/// priority — a chat user waiting on the next token) contends with a
/// batch stream (loose deadline — offline summarization) for the same
/// fabric; every request is one single-token decode step whose weight
/// and KV-cache reads make it DRAM-bound, so arbitration and topology
/// decide the tail latency.  Deadlines are sized to the ~4.4 Mcc
/// weight-streaming floor of a cold step on the exploration DRAM port
/// (35.3 MB x 8 / 64 bit/cc): interactive gets ~2x the floor (room for
/// one warm-up fetch plus arbitration jitter, but no slack for sitting
/// behind a whole batch step), batch ~6x (absorbs queueing behind the
/// interactive stream without being vacuous).  The deadline-coverage
/// test in this module pins that both remain *feasible* under EDF on
/// the exploration preset while staying within those multiples.
pub fn llm_serving() -> Scenario {
    Scenario::new(
        "llm_serving",
        vec![
            Tenant::new(
                "interactive",
                "llm-decode",
                Arrival::Periodic { every_cc: 6_000_000, count: 3, offset_cc: 0 },
            )
            .deadline(9_000_000)
            .priority(2),
            Tenant::new(
                "batch",
                "llm-decode",
                Arrival::Burst { times_cc: vec![0, 2_000_000] },
            )
            .deadline(27_000_000)
            .priority(1),
        ],
    )
}

/// Tiny two-tenant mix over the synthetic test networks — fast enough
/// for unit tests and CI smoke runs.
pub fn tiny_mix() -> Scenario {
    Scenario::new(
        "tiny_mix",
        vec![
            Tenant::new(
                "seg",
                "tiny-segment",
                Arrival::Periodic { every_cc: 20_000, count: 3, offset_cc: 0 },
            )
            .deadline(200_000)
            .priority(1),
            Tenant::new("branchy", "tiny-branchy", Arrival::Burst { times_cc: vec![0, 30_000] })
                .deadline(300_000),
        ],
    )
}

/// Look a canned scenario up by CLI name.
pub fn by_name(name: &str) -> Option<Scenario> {
    match name {
        "edge_mix" | "edge-mix" => Some(edge_mix()),
        "av_pipeline" | "av-pipeline" => Some(av_pipeline()),
        "duplicate_resnet_x4" | "duplicate-resnet-x4" => Some(duplicate_resnet_x4()),
        "llm_serving" | "llm-serving" => Some(llm_serving()),
        "tiny_mix" | "tiny-mix" => Some(tiny_mix()),
        _ => None,
    }
}

pub const SCENARIO_NAMES: &[&str] =
    &["edge_mix", "av_pipeline", "duplicate_resnet_x4", "llm_serving", "tiny_mix"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_expansion() {
        assert_eq!(Arrival::OneShot { at_cc: 7 }.releases(), vec![7]);
        assert_eq!(
            Arrival::Periodic { every_cc: 10, count: 3, offset_cc: 5 }.releases(),
            vec![5, 15, 25]
        );
        assert_eq!(Arrival::Burst { times_cc: vec![9, 1, 4] }.releases(), vec![1, 4, 9]);
    }

    #[test]
    fn requests_sorted_and_sequenced() {
        let s = tiny_mix();
        let reqs = s.requests();
        assert_eq!(reqs.len(), s.n_requests());
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.seq, i);
        }
        for pair in reqs.windows(2) {
            assert!(
                (pair[0].release_cc, pair[0].tenant) <= (pair[1].release_cc, pair[1].tenant)
            );
        }
        // deadlines are absolute
        assert_eq!(reqs[0].deadline_abs_cc, Some(reqs[0].release_cc + 200_000));
    }

    #[test]
    fn llm_serving_is_decode_streams_with_deadlines() {
        let s = llm_serving();
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.n_requests(), 5);
        for t in &s.tenants {
            assert_eq!(t.model, "llm-decode");
            assert!(t.deadline_cc.is_some(), "{}: serving SLO required", t.name);
        }
        assert!(s.tenants[0].priority > s.tenants[1].priority, "interactive wins arbitration");
        // every expanded request carries an absolute deadline
        for r in s.requests() {
            assert!(r.deadline_abs_cc.is_some());
        }
    }

    #[test]
    fn llm_serving_deadlines_are_tight_but_feasible() {
        let s = llm_serving();
        // The deadlines sit at small multiples of the analytic cold-step
        // floor: every decode step re-streams the full weight set (no
        // layer fits the per-core weight SRAM), so one step can never
        // beat total-weight-bits / 64 bit/cc on a single DRAM port.
        let wl = s.tenants[0].workload().unwrap();
        let floor_cc = wl.total_weight_bytes() * 8 / 64;
        assert!(
            (4_000_000..5_000_000).contains(&floor_cc),
            "decode-step floor moved: {floor_cc}"
        );
        let interactive = s.tenants[0].deadline_cc.unwrap();
        let batch = s.tenants[1].deadline_cc.unwrap();
        assert!(interactive >= floor_cc, "infeasible by construction");
        assert!(interactive <= 3 * floor_cc, "interactive SLO must bind: {interactive}");
        assert!(batch >= 4 * floor_cc, "batch must absorb queueing: {batch}");
        assert!(batch <= 8 * floor_cc, "batch SLO must bind: {batch}");

        // ... and they are feasible at a real operating point: EDF on
        // the exploration mesh serves every request on time.
        let arch = crate::arch::presets::by_name("hetero_quad@mesh").unwrap();
        let sim = crate::scenario::ScenarioSim::new(&s, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), crate::scenario::Arbitration::Edf);
        assert_eq!(r.total_misses(), 0, "EDF must meet every tightened deadline");
        for t in &r.tenants {
            assert_eq!(t.misses, 0, "{}", t.name);
            assert_eq!(t.miss_rate, 0.0, "{}", t.name);
            // decode steps really are Mcc-scale (the lm_head stream
            // alone is ~2 Mcc), so the deadlines leave little slack
            assert!(t.p50_cc >= 2_000_000, "{}: p50 {} cc", t.name, t.p50_cc);
        }
    }

    #[test]
    fn request_stream_matches_eager_expansion() {
        for name in SCENARIO_NAMES {
            let s = by_name(name).unwrap();
            let eager = s.requests();
            let streamed: Vec<Request> = s.request_stream().collect();
            assert_eq!(eager, streamed, "{name}");
        }
        // ... including with burst jitter engaged
        let mut s = tiny_mix().seed(42);
        s.tenants[1].jitter_cc = 7_000;
        let eager = s.requests();
        let streamed: Vec<Request> = s.request_stream().collect();
        assert_eq!(eager, streamed, "jittered tiny_mix");
    }

    #[test]
    fn arrival_stream_peek_is_consistent() {
        let s = edge_mix();
        let mut rs = s.request_stream();
        let mut n = 0;
        while let Some((cc, t)) = rs.peek() {
            let r = rs.next().unwrap();
            assert_eq!((r.release_cc, r.tenant), (cc, t));
            assert_eq!(r.seq, n);
            n += 1;
        }
        assert_eq!(n, s.n_requests());
        assert_eq!(rs.emitted(), n);
        assert!(rs.next().is_none());
    }

    #[test]
    fn burst_jitter_is_seeded_and_deterministic() {
        let raw = || {
            let mut s = tiny_mix();
            s.tenants[1].jitter_cc = 10_000;
            s
        };
        let a = raw().seed(1).requests();
        let b = raw().seed(1).requests();
        assert_eq!(a, b, "same seed must replay the identical trace");
        let c = raw().seed(2).requests();
        assert_ne!(
            a.iter().map(|r| r.release_cc).collect::<Vec<_>>(),
            c.iter().map(|r| r.release_cc).collect::<Vec<_>>(),
            "different seeds must move the burst times"
        );
        // jitter 0 leaves the spec's times untouched regardless of seed
        let d = tiny_mix().seed(99).requests();
        assert_eq!(d, tiny_mix().requests());
        // jitter only ever delays a release, by at most the bound
        let base = tiny_mix().tenants[1].arrival.releases();
        let jit = {
            let mut t = tiny_mix().tenants[1].clone();
            t.jitter_cc = 10_000;
            t.releases_seeded(1, 1)
        };
        assert_eq!(jit.len(), base.len());
        for (b, j) in base.iter().zip(&jit) {
            // both sides are sorted, so element-wise bounds hold
            assert!(*j >= *b && *j <= *b + 10_000, "{b} -> {j}");
        }
    }

    #[test]
    fn extend_to_grows_periodic_and_tiles_bursts() {
        let s = tiny_mix().extend_to(200_000);
        // periodic: (200_000 - 0) / 20_000 + 1 = 11 releases
        assert_eq!(s.tenants[0].arrival.releases().len(), 11);
        assert_eq!(*s.tenants[0].arrival.releases().last().unwrap(), 200_000);
        // burst [0, 30_000]: span 30k, gap 30k -> stride 60k, tiled to 200k
        let burst = s.tenants[1].arrival.releases();
        assert!(burst.len() > 2, "burst must tile: {burst:?}");
        assert!(*burst.last().unwrap() <= 200_000);
        for w in burst.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // extending to a shorter horizon than the spec is a no-op
        let s2 = tiny_mix().extend_to(1);
        assert_eq!(s2.n_requests(), tiny_mix().n_requests());
    }

    #[test]
    fn scale_rate_compresses_the_trace() {
        let s = tiny_mix().scale_rate(2.0);
        assert_eq!(
            s.tenants[0].arrival,
            Arrival::Periodic { every_cc: 10_000, count: 3, offset_cc: 0 }
        );
        assert_eq!(s.tenants[1].arrival, Arrival::Burst { times_cc: vec![0, 15_000] });
        // deadline SLOs are untouched — only arrivals compress
        assert_eq!(s.tenants[0].deadline_cc, tiny_mix().tenants[0].deadline_cc);
        // scaling down stretches
        let s = tiny_mix().scale_rate(0.5);
        assert_eq!(
            s.tenants[0].arrival,
            Arrival::Periodic { every_cc: 40_000, count: 3, offset_cc: 0 }
        );
    }

    #[test]
    fn library_resolves_models() {
        for name in SCENARIO_NAMES {
            let s = by_name(name).unwrap();
            assert!(!s.tenants.is_empty(), "{name}");
            for t in &s.tenants {
                assert!(t.workload().is_some(), "{name}: unknown model {}", t.model);
            }
            assert!(s.n_requests() >= 1, "{name}");
        }
        assert!(by_name("nope").is_none());
    }
}
