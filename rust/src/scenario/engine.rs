//! The multi-request co-scheduling engine.
//!
//! [`ScenarioSim`] instantiates one CN graph per tenant (reusing the
//! Step 1–3 pipeline stages) and schedules **every request of every
//! tenant in one event-driven run** of the crate's unified simulation
//! core (`crate::scheduler`'s internal `sim` module): all requests
//! share the cores' availability, the routed `LinkSet`, the per-core
//! weight trackers (weights are keyed by a *global* `(tenant, layer)`
//! id, so back-to-back requests of the same tenant reuse resident
//! weights) and the pooled activation capacity.
//!
//! Each request owns a private candidate pool whose candidates are
//! never ready before the request's release; an inter-request
//! [`Arbitration`] policy picks which request gets the next scheduling
//! decision, and the request's own pool then picks the CN under the
//! tenant's Fig. 8 priority.  Arbitration is **causal**: a virtual
//! admission clock gates deadline/priority preference to requests that
//! have actually arrived, so a future release never pre-empts ready
//! work and the engine stays work-conserving.  There is no mirrored
//! scheduler body here — this module only assembles the core's
//! request-tagged outcome into serving statistics, which is why the
//! degenerate 1-tenant / 1-request scenario is **bit-identical** to
//! the single-model scheduler by construction
//! (`rust/tests/scenario_equivalence.rs` keeps pinning it anyway).

use crate::allocator::manual_allocation;
use crate::arch::{Accelerator, CoreId};
use crate::cn::CnSet;
use crate::depgraph::{generate, CnGraph};
use crate::mapping::CostModel;
use crate::scheduler::sim::{global_wgt_fetch, SimContext, SimRequest, SimTenant};
use crate::scheduler::streaming::{simulate_stream, StreamConfig, StreamRequest};
use crate::scheduler::{Arbitration, MemTrace, Scheduler};
use crate::workload::WorkloadGraph;

use super::result::{
    percentile_cc, RequestOutcome, ScenarioCn, ScenarioResult, StreamingStats, TenantStats,
};
use super::spec::Scenario;

/// Errors from scenario construction.
#[derive(Debug)]
pub enum ScenarioError {
    EmptyScenario,
    UnknownModel(String),
}

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScenarioError::EmptyScenario => write!(f, "scenario has no tenants"),
            ScenarioError::UnknownModel(m) => write!(f, "unknown tenant model {m}"),
        }
    }
}

impl std::error::Error for ScenarioError {}

/// Steps 1–3 artifacts of one tenant, shared by every request of that
/// tenant.
pub struct TenantBuild {
    pub workload: WorkloadGraph,
    pub graph: CnGraph,
    pub costs: CostModel,
}

/// A reusable scenario simulator over a fixed (scenario, architecture):
/// tenant graphs and cost models are built once, then [`run`](Self::run)
/// simulates any `(allocations, arbitration)` point — the fitness
/// evaluation of the scenario-level NSGA-II search
/// ([`ScenarioGa`](super::ScenarioGa)).
pub struct ScenarioSim<'a> {
    pub scenario: &'a Scenario,
    pub arch: &'a Accelerator,
    builds: Vec<TenantBuild>,
    /// Global layer-id offset per tenant: `(tenant, layer)` maps to
    /// `LayerId(layer_off[tenant] + layer)` in the shared weight space.
    layer_off: Vec<usize>,
}

impl<'a> ScenarioSim<'a> {
    pub fn new(
        scenario: &'a Scenario,
        arch: &'a Accelerator,
    ) -> Result<ScenarioSim<'a>, ScenarioError> {
        if scenario.tenants.is_empty() {
            return Err(ScenarioError::EmptyScenario);
        }
        let gran = scenario.granularity.for_arch(arch);
        let mut builds = Vec::with_capacity(scenario.tenants.len());
        let mut layer_off = Vec::with_capacity(scenario.tenants.len());
        let mut off = 0usize;
        for t in &scenario.tenants {
            let workload =
                t.workload().ok_or_else(|| ScenarioError::UnknownModel(t.model.clone()))?;
            let cns = CnSet::build(&workload, gran);
            let costs = CostModel::build(&workload, &cns, arch);
            let graph = generate(&workload, cns);
            layer_off.push(off);
            off += workload.len();
            builds.push(TenantBuild { workload, graph, costs });
        }
        Ok(ScenarioSim { scenario, arch, builds, layer_off })
    }

    pub fn builds(&self) -> &[TenantBuild] {
        &self.builds
    }

    /// The tenants' workloads, in tenant order (genome helpers).
    pub fn tenant_workloads(&self) -> Vec<&WorkloadGraph> {
        self.builds.iter().map(|b| &b.workload).collect()
    }

    /// Per-tenant greedy baseline allocations (best-spatial-fit core per
    /// dense layer) — the no-search starting point.
    pub fn greedy_allocations(&self) -> Vec<Vec<CoreId>> {
        self.builds
            .iter()
            .map(|b| manual_allocation(&b.workload, self.arch, &b.costs, &b.graph.cns, true))
            .collect()
    }

    /// Prebuild the per-tenant schedulers (fanout, buffer gates,
    /// weight-fetch tables — all allocation-independent) for repeated
    /// co-schedules: the NSGA-II fitness loop calls
    /// [`ScenarioRunner::run`] once per genome, so this must not be
    /// redone per evaluation.
    pub fn runner(&self) -> ScenarioRunner<'_> {
        let scheds: Vec<Scheduler> = self
            .builds
            .iter()
            .map(|b| Scheduler::new(&b.workload, &b.graph, &b.costs, self.arch))
            .collect();
        // global (tenant, layer) -> DRAM weight-fetch cycles
        let wgt_fetch_g = global_wgt_fetch(&scheds);
        // higher tenant priority => smaller arbitration rank
        let prio_rank: Vec<u64> =
            self.scenario.tenants.iter().map(|t| u64::from(u16::MAX - t.priority)).collect();
        ScenarioRunner { sim: self, scheds, wgt_fetch_g, prio_rank }
    }

    /// One-shot convenience: prebuild a [`ScenarioRunner`] and
    /// co-schedule once.  Callers simulating many allocation points
    /// over the same scenario should hold a runner instead.
    pub fn run(&self, allocs: &[Vec<CoreId>], arbitration: Arbitration) -> ScenarioResult {
        self.runner().run(allocs, arbitration)
    }
}

/// Knobs of the streamed serving path
/// ([`ScenarioRunner::run_streamed`]).
#[derive(Debug, Clone, Copy)]
pub struct StreamingOpts {
    /// Eager admission window: how many lanes beyond the mandatory
    /// (exactness-required) set to keep live.  Any value produces the
    /// identical schedule — it trades peak memory against
    /// admission-scan frequency.
    pub window: usize,
    /// Completion-window length in cycles for the windowed statistics.
    pub window_cc: u64,
    /// How many completion windows to retain (oldest evicted first).
    pub max_windows: usize,
    /// Completions before this cycle are excluded from the steady-state
    /// aggregates (warm-up cutoff).
    pub warmup_cc: u64,
    /// Keep full event logs and per-request outcomes — bit-identical to
    /// the eager path, O(total requests) memory.  When false, events
    /// fold into bounded aggregates as requests retire and the result
    /// carries metrics + windowed stats only.
    pub retain_events: bool,
}

impl Default for StreamingOpts {
    fn default() -> StreamingOpts {
        StreamingOpts {
            window: 64,
            window_cc: 1_000_000,
            max_windows: 64,
            warmup_cc: 0,
            retain_events: false,
        }
    }
}

/// A prepared co-scheduler over one [`ScenarioSim`]: per-tenant
/// [`Scheduler`]s plus the global weight-fetch and priority tables,
/// built once and reused across any number of
/// `(allocations, arbitration)` simulations.
pub struct ScenarioRunner<'s> {
    sim: &'s ScenarioSim<'s>,
    scheds: Vec<Scheduler<'s>>,
    wgt_fetch_g: Vec<u64>,
    prio_rank: Vec<u64>,
}

impl ScenarioRunner<'_> {
    /// Co-schedule every request of every tenant under `allocs` (one
    /// per-layer core allocation per tenant) and `arbitration`: build
    /// the request lanes, hand them to the unified simulation core,
    /// and fold the request-tagged outcome into per-tenant serving
    /// statistics.
    pub fn run(&self, allocs: &[Vec<CoreId>], arbitration: Arbitration) -> ScenarioResult {
        self.run_with_threads(allocs, arbitration, 0)
    }

    /// Like [`run`](Self::run) with an explicit simulation-core worker
    /// count: 0 resolves `STREAM_SIM_THREADS` from the environment, 1
    /// forces the sequential loop, higher values permit the
    /// chip-partitioned parallel core.  Bit-identical results for every
    /// value (pinned by `rust/tests/parallel_sim_equivalence.rs`);
    /// [`ScenarioResult::partitions`] reports what actually ran.
    pub fn run_with_threads(
        &self,
        allocs: &[Vec<CoreId>],
        arbitration: Arbitration,
        sim_threads: usize,
    ) -> ScenarioResult {
        assert_eq!(allocs.len(), self.sim.builds.len(), "one allocation per tenant");
        for (b, a) in self.sim.builds.iter().zip(allocs) {
            assert_eq!(a.len(), b.workload.len(), "allocation per layer");
        }

        let tenants: Vec<SimTenant> = self
            .scheds
            .iter()
            .enumerate()
            .map(|(t, s)| SimTenant {
                sched: s,
                alloc: &allocs[t],
                pool_priority: self.sim.scenario.tenants[t].pool_priority,
                prio_rank: self.prio_rank[t],
                layer_off: self.sim.layer_off[t],
            })
            .collect();
        // requests() is (release, tenant)-sorted with seq == index, so
        // the core's lane indices are exactly the request seqs
        let reqs = self.sim.scenario.requests();
        let requests: Vec<SimRequest> = reqs
            .iter()
            .map(|r| SimRequest {
                tenant: r.tenant,
                release: r.release_cc,
                deadline_abs: r.deadline_abs_cc,
            })
            .collect();

        crate::obs::count(crate::obs::Counter::ScenarioRuns, 1);
        let out = SimContext {
            arch: self.sim.arch,
            tenants: &tenants,
            requests: &requests,
            wgt_fetch_g: &self.wgt_fetch_g,
            arbitration,
            linear_pool: false,
            tag_events: true,
            sim_threads,
        }
        .simulate();
        let report = crate::obs::enabled().then(|| Box::new(out.report(self.sim.arch)));

        // --- per-request / per-tenant serving statistics -----------------
        let cns: Vec<ScenarioCn> = out
            .cns
            .iter()
            .zip(&out.cn_req)
            .map(|(p, &r)| ScenarioCn { request: r, placed: *p })
            .collect();

        let outcomes: Vec<RequestOutcome> = reqs
            .iter()
            .zip(&out.request_end)
            .map(|(r, &end)| RequestOutcome {
                request: r.seq,
                tenant: r.tenant,
                release_cc: r.release_cc,
                completion_cc: end,
                latency_cc: end.saturating_sub(r.release_cc),
                deadline_abs_cc: r.deadline_abs_cc,
                missed: r.deadline_abs_cc.is_some_and(|d| end > d),
            })
            .collect();

        let tenants = self.tenant_stats(&outcomes, out.metrics.latency_cc);

        ScenarioResult {
            metrics: out.metrics,
            cns,
            comms: out.comms,
            comm_req: out.comm_req,
            drams: out.drams,
            dram_req: out.dram_req,
            link_stats: out.link_stats,
            core_busy: out.core_busy,
            memtrace: out.memtrace,
            outcomes,
            tenants,
            partitions: out.partitions,
            fallback: out.fallback,
            report,
            streaming: None,
        }
    }

    /// Streamed serving path: pull requests lazily from the scenario's
    /// [`RequestStream`](super::RequestStream), admit them into the
    /// simulation core only as the virtual clock approaches their
    /// release, and retire each request the moment its last CN
    /// completes — live state is O(admission window + in-flight
    /// requests), however long the trace.  With
    /// [`StreamingOpts::retain_events`] the result is **bit-identical**
    /// to [`run`](Self::run) (pinned by
    /// `rust/tests/streaming_equivalence.rs`); without it, events fold
    /// into bounded aggregates and the per-request `outcomes` / event
    /// logs come back empty, with the windowed statistics in
    /// [`ScenarioResult::streaming`] taking their place.
    pub fn run_streamed(
        &self,
        allocs: &[Vec<CoreId>],
        arbitration: Arbitration,
        opts: &StreamingOpts,
    ) -> ScenarioResult {
        assert_eq!(allocs.len(), self.sim.builds.len(), "one allocation per tenant");
        for (b, a) in self.sim.builds.iter().zip(allocs) {
            assert_eq!(a.len(), b.workload.len(), "allocation per layer");
        }

        let tenants: Vec<SimTenant> = self
            .scheds
            .iter()
            .enumerate()
            .map(|(t, s)| SimTenant {
                sched: s,
                alloc: &allocs[t],
                pool_priority: self.sim.scenario.tenants[t].pool_priority,
                prio_rank: self.prio_rank[t],
                layer_off: self.sim.layer_off[t],
            })
            .collect();

        crate::obs::count(crate::obs::Counter::ScenarioRuns, 1);
        let ctx = SimContext {
            arch: self.sim.arch,
            tenants: &tenants,
            requests: &[],
            wgt_fetch_g: &self.wgt_fetch_g,
            arbitration,
            linear_pool: false,
            tag_events: opts.retain_events,
            sim_threads: 1,
        };
        let cfg = StreamConfig { window: opts.window, retain_events: opts.retain_events };
        let mut stats = StreamingStats::new(
            opts.window_cc,
            opts.warmup_cc,
            opts.max_windows,
            self.sim.scenario.tenants.len(),
            self.sim.scenario.clock_ghz,
        );
        // retained mode keeps per-request rows for the outcome table;
        // bounded mode folds everything into `stats` as requests retire
        let mut retired = Vec::new();
        let stream = self.sim.scenario.request_stream().map(|r| StreamRequest {
            seq: r.seq,
            tenant: r.tenant,
            release: r.release_cc,
            deadline_abs: r.deadline_abs_cc,
        });
        let (out, live) = simulate_stream(&ctx, stream, &cfg, |r| {
            let latency = r.completion.saturating_sub(r.release);
            let missed = r.deadline_abs.is_some_and(|d| r.completion > d);
            stats.record(r.tenant, r.completion, latency, missed);
            if opts.retain_events {
                retired.push(r);
            }
        });
        stats.admitted = live.admitted;
        stats.retired = live.retired;
        stats.live_peak = live.live_peak;
        stats.inflight_peak = live.inflight_peak;
        let report = crate::obs::enabled().then(|| {
            let mut rep = Box::new(out.report(self.sim.arch));
            rep.serving = Some(crate::obs::ServingSummary {
                admitted: stats.admitted,
                retired: stats.retired,
                live_peak: stats.live_peak,
                inflight_peak: stats.inflight_peak,
                window_p99: stats
                    .windows()
                    .map(|w| (w.start_cc, w.completed, w.hist.percentile_cc(99.0)))
                    .collect(),
            });
            rep
        });

        let (cns, outcomes, tenants, memtrace) = if opts.retain_events {
            let cns: Vec<ScenarioCn> = out
                .cns
                .iter()
                .zip(&out.cn_req)
                .map(|(p, &r)| ScenarioCn { request: r, placed: *p })
                .collect();
            retired.sort_unstable_by_key(|r| r.seq);
            let outcomes: Vec<RequestOutcome> = retired
                .iter()
                .map(|r| RequestOutcome {
                    request: r.seq,
                    tenant: r.tenant,
                    release_cc: r.release,
                    completion_cc: r.completion,
                    latency_cc: r.completion.saturating_sub(r.release),
                    deadline_abs_cc: r.deadline_abs,
                    missed: r.deadline_abs.is_some_and(|d| r.completion > d),
                })
                .collect();
            let tenants = self.tenant_stats(&outcomes, out.metrics.latency_cc);
            (cns, outcomes, tenants, out.memtrace)
        } else {
            let tenants = self.tenant_stats_from_hists(&stats, out.metrics.latency_cc);
            (Vec::new(), Vec::new(), tenants, MemTrace::new())
        };

        ScenarioResult {
            metrics: out.metrics,
            cns,
            comms: out.comms,
            comm_req: out.comm_req,
            drams: out.drams,
            dram_req: out.dram_req,
            link_stats: out.link_stats,
            core_busy: out.core_busy,
            memtrace,
            outcomes,
            tenants,
            partitions: out.partitions,
            fallback: out.fallback,
            report,
            streaming: Some(stats),
        }
    }

    /// Exact per-tenant serving statistics from retained per-request
    /// outcome rows (shared by the eager path and the retained streamed
    /// path, so their results agree trivially).
    fn tenant_stats(&self, outcomes: &[RequestOutcome], latency: u64) -> Vec<TenantStats> {
        let seconds = if self.sim.scenario.clock_ghz > 0.0 && latency > 0 {
            latency as f64 / (self.sim.scenario.clock_ghz * 1e9)
        } else {
            0.0
        };
        self.sim
            .scenario
            .tenants
            .iter()
            .enumerate()
            .map(|(t, tenant)| {
                let lats: Vec<u64> = outcomes
                    .iter()
                    .filter(|o| o.tenant == t)
                    .map(|o| o.latency_cc)
                    .collect();
                let misses = outcomes.iter().filter(|o| o.tenant == t && o.missed).count();
                let n = lats.len();
                TenantStats {
                    name: tenant.name.clone(),
                    requests: n,
                    p50_cc: percentile_cc(&lats, 50.0),
                    p99_cc: percentile_cc(&lats, 99.0),
                    mean_cc: if n > 0 {
                        lats.iter().sum::<u64>() as f64 / n as f64
                    } else {
                        0.0
                    },
                    misses,
                    miss_rate: if n > 0 { misses as f64 / n as f64 } else { 0.0 },
                    throughput_rps: if seconds > 0.0 { n as f64 / seconds } else { 0.0 },
                }
            })
            .collect()
    }

    /// Per-tenant serving statistics from the bounded streaming
    /// histograms: post-warm-up samples only, percentiles resolved to
    /// histogram buckets
    /// ([`LatencyHist`](super::LatencyHist) docs spell out the error
    /// bound).  With a zero warm-up cutoff the request counts, means,
    /// misses and throughput match the exact path; only p50/p99 are
    /// bucket-quantized.
    fn tenant_stats_from_hists(&self, stats: &StreamingStats, latency: u64) -> Vec<TenantStats> {
        let span = latency.saturating_sub(stats.warmup_cc);
        let seconds = if self.sim.scenario.clock_ghz > 0.0 && span > 0 {
            span as f64 / (self.sim.scenario.clock_ghz * 1e9)
        } else {
            0.0
        };
        self.sim
            .scenario
            .tenants
            .iter()
            .enumerate()
            .map(|(t, tenant)| {
                let h = &stats.steady_per_tenant[t];
                let n = h.count() as usize;
                let misses = stats.steady_misses[t] as usize;
                TenantStats {
                    name: tenant.name.clone(),
                    requests: n,
                    p50_cc: h.percentile_cc(50.0),
                    p99_cc: h.percentile_cc(99.0),
                    mean_cc: h.mean_cc(),
                    misses,
                    miss_rate: if n > 0 { misses as f64 / n as f64 } else { 0.0 },
                    throughput_rps: if seconds > 0.0 { n as f64 / seconds } else { 0.0 },
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::scenario::spec::{self, Arrival, Tenant};
    use crate::scheduler::DramKind;

    fn two_seg_scenario(release2: u64) -> Scenario {
        Scenario::new(
            "two-seg",
            vec![
                Tenant::new("a", "tiny-segment", Arrival::OneShot { at_cc: 0 }),
                Tenant::new("b", "tiny-segment", Arrival::OneShot { at_cc: release2 }),
            ],
        )
    }

    #[test]
    fn tiny_mix_schedules_every_request() {
        let scenario = spec::tiny_mix();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let allocs = sim.greedy_allocations();
        for arb in [Arbitration::Fifo, Arbitration::Priority, Arbitration::Edf] {
            let r = sim.run(&allocs, arb);
            let expect: usize = sim
                .builds()
                .iter()
                .zip(&scenario.tenants)
                .map(|(b, t)| b.graph.len() * t.arrival.releases().len())
                .sum();
            assert_eq!(r.cns.len(), expect, "{arb}");
            assert_eq!(r.outcomes.len(), scenario.n_requests(), "{arb}");
            assert!(r.metrics.latency_cc > 0, "{arb}");
            // completion never precedes release, dependencies hold
            for o in &r.outcomes {
                assert!(o.completion_cc >= o.release_cc, "{arb}");
            }
            // memory accounting closes
            assert!(r.memtrace.residual().abs() < 1.0, "{arb}");
            // tags align with events
            assert_eq!(r.comms.len(), r.comm_req.len(), "{arb}");
            assert_eq!(r.drams.len(), r.dram_req.len(), "{arb}");
        }
    }

    #[test]
    fn runner_reuse_matches_one_shot_runs() {
        let scenario = spec::tiny_mix();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let allocs = sim.greedy_allocations();
        let runner = sim.runner();
        let a = runner.run(&allocs, Arbitration::Edf);
        let b = runner.run(&allocs, Arbitration::Edf);
        let c = sim.run(&allocs, Arbitration::Edf);
        assert_eq!(a.metrics.latency_cc, b.metrics.latency_cc);
        assert_eq!(a.metrics.latency_cc, c.metrics.latency_cc);
        assert_eq!(a.metrics.energy_pj.to_bits(), c.metrics.energy_pj.to_bits());
        assert_eq!(a.cns.len(), c.cns.len());
    }

    #[test]
    fn release_time_gates_request_start() {
        let arch = presets::test_dual();
        let scenario = two_seg_scenario(1_000_000);
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), Arbitration::Fifo);
        for cn in r.cns.iter().filter(|c| c.request == 1) {
            assert!(cn.placed.start >= 1_000_000, "{:?}", cn.placed);
        }
    }

    #[test]
    fn same_tenant_requests_share_resident_weights() {
        // tiny-branchy's ~1 KB of weights can never thrash the 128 KB
        // weight SRAMs, so the fetch counts are exact
        let arch = presets::test_dual();
        let single = Scenario::new(
            "one",
            vec![Tenant::new("a", "tiny-branchy", Arrival::OneShot { at_cc: 0 })],
        );
        let sim1 = ScenarioSim::new(&single, &arch).unwrap();
        let r1 = sim1.run(&sim1.greedy_allocations(), Arbitration::Fifo);
        let fetches1 =
            r1.drams.iter().filter(|d| d.kind == DramKind::WeightFetch).count();
        assert!(fetches1 > 0);

        // two *different tenants* with the same model do NOT share
        // weights (separate global layer ids) ...
        let double = Scenario::new(
            "two",
            vec![
                Tenant::new("a", "tiny-branchy", Arrival::OneShot { at_cc: 0 }),
                Tenant::new("b", "tiny-branchy", Arrival::OneShot { at_cc: 0 }),
            ],
        );
        let sim2 = ScenarioSim::new(&double, &arch).unwrap();
        let r2 = sim2.run(&sim2.greedy_allocations(), Arbitration::Fifo);
        let fetches2 =
            r2.drams.iter().filter(|d| d.kind == DramKind::WeightFetch).count();
        assert_eq!(fetches2, 2 * fetches1);

        // ... but two requests of the SAME tenant fetch only once
        let burst = Scenario::new(
            "burst",
            vec![Tenant::new(
                "a",
                "tiny-branchy",
                Arrival::Burst { times_cc: vec![0, 0] },
            )],
        );
        let sim3 = ScenarioSim::new(&burst, &arch).unwrap();
        let r3 = sim3.run(&sim3.greedy_allocations(), Arbitration::Fifo);
        let fetches3 =
            r3.drams.iter().filter(|d| d.kind == DramKind::WeightFetch).count();
        assert_eq!(fetches3, fetches1, "second request must reuse resident weights");
    }

    #[test]
    fn priority_arbitration_prefers_high_priority_tenant() {
        let arch = presets::test_dual();
        let mut scenario = two_seg_scenario(0);
        scenario.tenants[1].priority = 5;
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        // full contention: both tenants pinned to the same cores
        let allocs = sim.greedy_allocations();
        let fifo = sim.run(&allocs, Arbitration::Fifo);
        let prio = sim.run(&allocs, Arbitration::Priority);
        let done = |r: &ScenarioResult, t: usize| {
            r.tenant_outcomes(t).map(|o| o.completion_cc).max().unwrap()
        };
        assert!(
            done(&prio, 1) <= done(&fifo, 1),
            "priority must not slow the favored tenant: {} vs {}",
            done(&prio, 1),
            done(&fifo, 1)
        );
    }

    #[test]
    fn streamed_retained_matches_eager_run() {
        let scenario = spec::tiny_mix();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let allocs = sim.greedy_allocations();
        let runner = sim.runner();
        for arb in [Arbitration::Fifo, Arbitration::Priority, Arbitration::Edf] {
            let eager = runner.run_with_threads(&allocs, arb, 1);
            let opts = StreamingOpts { window: 2, retain_events: true, ..Default::default() };
            let streamed = runner.run_streamed(&allocs, arb, &opts);
            assert_eq!(eager.metrics.latency_cc, streamed.metrics.latency_cc, "{arb}");
            assert_eq!(
                eager.metrics.energy_pj.to_bits(),
                streamed.metrics.energy_pj.to_bits(),
                "{arb}"
            );
            assert_eq!(eager.cns.len(), streamed.cns.len(), "{arb}");
            for (a, b) in eager.outcomes.iter().zip(&streamed.outcomes) {
                assert_eq!(a.request, b.request, "{arb}");
                assert_eq!(a.completion_cc, b.completion_cc, "{arb}");
                assert_eq!(a.missed, b.missed, "{arb}");
            }
            let s = streamed.streaming.as_ref().unwrap();
            assert_eq!(s.retired, scenario.n_requests() as u64, "{arb}");
            assert_eq!(s.admitted, s.retired, "{arb}");
        }
    }

    #[test]
    fn streamed_bounded_mode_matches_aggregate_metrics() {
        let scenario = spec::tiny_mix();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let allocs = sim.greedy_allocations();
        let runner = sim.runner();
        let eager = runner.run_with_threads(&allocs, Arbitration::Edf, 1);
        let opts = StreamingOpts {
            window: 2,
            window_cc: 50_000,
            retain_events: false,
            ..Default::default()
        };
        let streamed = runner.run_streamed(&allocs, Arbitration::Edf, &opts);
        // the bounded fold reproduces the aggregate metrics bit-for-bit
        assert_eq!(eager.metrics.latency_cc, streamed.metrics.latency_cc);
        assert_eq!(eager.metrics.energy_pj.to_bits(), streamed.metrics.energy_pj.to_bits());
        assert_eq!(
            eager.metrics.peak_mem_bytes.to_bits(),
            streamed.metrics.peak_mem_bytes.to_bits()
        );
        assert_eq!(eager.link_stats, streamed.link_stats);
        // event logs are folded away
        assert!(streamed.cns.is_empty() && streamed.outcomes.is_empty());
        assert!(streamed.memtrace.events.is_empty());
        // every completion landed in the windowed stats
        let s = streamed.streaming.as_ref().unwrap();
        let windowed: u64 = s.windows().map(|w| w.completed).sum();
        assert_eq!(windowed + s.late, scenario.n_requests() as u64);
        assert_eq!(s.steady.count(), scenario.n_requests() as u64);
        // per-tenant counts/misses match the exact path
        for (a, b) in eager.tenants.iter().zip(&streamed.tenants) {
            assert_eq!(a.requests, b.requests);
            assert_eq!(a.misses, b.misses);
            // bucket-resolved percentiles bracket the exact values
            assert!(b.p99_cc >= a.p99_cc && b.p99_cc <= a.p99_cc.saturating_mul(2).max(1));
        }
    }

    #[test]
    fn unknown_model_is_an_error() {
        let arch = presets::test_dual();
        let s = Scenario::new(
            "bad",
            vec![Tenant::new("x", "nope", Arrival::OneShot { at_cc: 0 })],
        );
        assert!(matches!(
            ScenarioSim::new(&s, &arch),
            Err(ScenarioError::UnknownModel(_))
        ));
        let empty = Scenario::new("empty", vec![]);
        assert!(matches!(
            ScenarioSim::new(&empty, &arch),
            Err(ScenarioError::EmptyScenario)
        ));
    }
}
