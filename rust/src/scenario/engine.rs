//! The multi-request co-scheduling engine.
//!
//! [`ScenarioSim`] instantiates one CN graph per tenant (reusing the
//! Step 1–3 pipeline stages) and schedules **every request of every
//! tenant in one event-driven run**: all requests share the cores'
//! availability, the routed [`LinkSet`], the per-core
//! [`WeightTracker`]s (weights are keyed by a *global* `(tenant,
//! layer)` id, so back-to-back requests of the same tenant reuse
//! resident weights) and the pooled activation capacity.
//!
//! Each request owns a private `CandidatePool` whose candidates are
//! never ready before the request's release; an inter-request
//! [`Arbitration`] policy picks which request gets the next scheduling
//! decision, and the request's own pool then picks the CN under the
//! tenant's Fig. 8 priority.  Arbitration is **causal**: a virtual
//! admission clock (the monotone frontier of earliest candidate
//! readiness) gates deadline/priority preference to requests that have
//! actually arrived, so a future release never pre-empts ready work
//! and the engine stays work-conserving.  With a single one-shot
//! request the arbitration is vacuous and the engine's inner loop is a
//! line-for-line mirror of `Scheduler::run`, which is why the
//! degenerate scenario is **bit-identical** to the single-model
//! scheduler (`rust/tests/scenario_equivalence.rs`).

use crate::allocator::manual_allocation;
use crate::arch::{Accelerator, CoreId, CoreKind};
use crate::cn::{CnId, CnSet};
use crate::cost::{EnergyBreakdown, ScheduleMetrics};
use crate::depgraph::{generate, CnGraph, EdgeKind};
use crate::mapping::CostModel;
use crate::scheduler::peak_and_spill;
use crate::scheduler::pool::CandidatePool;
use crate::scheduler::resources::{LinkSet, WeightTracker};
use crate::scheduler::{
    CommEvent, DramEvent, DramKind, LinkStat, MemTrace, SchedulePriority, ScheduledCn,
    Scheduler,
};
use crate::workload::{LayerId, OpType, WorkloadGraph};

use super::result::{percentile_cc, RequestOutcome, ScenarioCn, ScenarioResult, TenantStats};
use super::spec::Scenario;

/// How the engine decides *which request* gets the next scheduling
/// decision (the per-CN pick within a request still follows the
/// tenant's [`SchedulePriority`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Arbitration {
    /// Requests share resources in readiness order; ties go to the
    /// earlier arrival — fair FCFS processor sharing.
    #[default]
    Fifo,
    /// Strictly serve the highest-[`priority`](super::Tenant::priority)
    /// tenant with work available; readiness breaks ties.
    Priority,
    /// Earliest absolute deadline first; deadline-free requests rank
    /// last, readiness breaks ties.
    Edf,
}

impl Arbitration {
    pub fn by_name(name: &str) -> Option<Arbitration> {
        match name {
            "fifo" => Some(Arbitration::Fifo),
            "priority" => Some(Arbitration::Priority),
            "edf" => Some(Arbitration::Edf),
            _ => None,
        }
    }
}

impl std::fmt::Display for Arbitration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Arbitration::Fifo => write!(f, "fifo"),
            Arbitration::Priority => write!(f, "priority"),
            Arbitration::Edf => write!(f, "edf"),
        }
    }
}

/// Errors from scenario construction.
#[derive(Debug)]
pub enum ScenarioError {
    EmptyScenario,
    UnknownModel(String),
}

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScenarioError::EmptyScenario => write!(f, "scenario has no tenants"),
            ScenarioError::UnknownModel(m) => write!(f, "unknown tenant model {m}"),
        }
    }
}

impl std::error::Error for ScenarioError {}

/// Steps 1–3 artifacts of one tenant, shared by every request of that
/// tenant.
pub struct TenantBuild {
    pub workload: WorkloadGraph,
    pub graph: CnGraph,
    pub costs: CostModel,
}

/// Mutable state of one in-flight request.
struct ReqState {
    seq: usize,
    tenant: usize,
    release: u64,
    deadline_abs: Option<u64>,
    sched: Vec<Option<ScheduledCn>>,
    pending: Vec<usize>,
    pool: CandidatePool,
    /// Completion frontier: last CN end or off-chip store end.
    last_end: u64,
}

/// A reusable scenario simulator over a fixed (scenario, architecture):
/// tenant graphs and cost models are built once, then [`run`](Self::run)
/// simulates any `(allocations, arbitration)` point — the fitness
/// evaluation of the scenario-level NSGA-II search
/// ([`ScenarioGa`](super::ScenarioGa)).
pub struct ScenarioSim<'a> {
    pub scenario: &'a Scenario,
    pub arch: &'a Accelerator,
    builds: Vec<TenantBuild>,
    /// Global layer-id offset per tenant: `(tenant, layer)` maps to
    /// `LayerId(layer_off[tenant] + layer)` in the shared weight space.
    layer_off: Vec<usize>,
}

impl<'a> ScenarioSim<'a> {
    pub fn new(
        scenario: &'a Scenario,
        arch: &'a Accelerator,
    ) -> Result<ScenarioSim<'a>, ScenarioError> {
        if scenario.tenants.is_empty() {
            return Err(ScenarioError::EmptyScenario);
        }
        let gran = scenario.granularity.for_arch(arch);
        let mut builds = Vec::with_capacity(scenario.tenants.len());
        let mut layer_off = Vec::with_capacity(scenario.tenants.len());
        let mut off = 0usize;
        for t in &scenario.tenants {
            let workload =
                t.workload().ok_or_else(|| ScenarioError::UnknownModel(t.model.clone()))?;
            let cns = CnSet::build(&workload, gran);
            let costs = CostModel::build(&workload, &cns, arch);
            let graph = generate(&workload, cns);
            layer_off.push(off);
            off += workload.len();
            builds.push(TenantBuild { workload, graph, costs });
        }
        Ok(ScenarioSim { scenario, arch, builds, layer_off })
    }

    pub fn builds(&self) -> &[TenantBuild] {
        &self.builds
    }

    /// The tenants' workloads, in tenant order (genome helpers).
    pub fn tenant_workloads(&self) -> Vec<&WorkloadGraph> {
        self.builds.iter().map(|b| &b.workload).collect()
    }

    /// Per-tenant greedy baseline allocations (best-spatial-fit core per
    /// dense layer) — the no-search starting point.
    pub fn greedy_allocations(&self) -> Vec<Vec<CoreId>> {
        self.builds
            .iter()
            .map(|b| manual_allocation(&b.workload, self.arch, &b.costs, &b.graph.cns, true))
            .collect()
    }

    /// Prebuild the per-tenant schedulers (fanout, buffer gates,
    /// weight-fetch tables — all allocation-independent) for repeated
    /// co-schedules: the NSGA-II fitness loop calls
    /// [`ScenarioRunner::run`] once per genome, so this must not be
    /// redone per evaluation.
    pub fn runner(&self) -> ScenarioRunner<'_> {
        let scheds: Vec<Scheduler> = self
            .builds
            .iter()
            .map(|b| Scheduler::new(&b.workload, &b.graph, &b.costs, self.arch))
            .collect();
        // global (tenant, layer) -> DRAM weight-fetch cycles
        let mut wgt_fetch_g: Vec<u64> = Vec::new();
        for s in &scheds {
            wgt_fetch_g.extend_from_slice(&s.wgt_fetch_cc);
        }
        // higher tenant priority => smaller arbitration rank
        let prio_rank: Vec<u64> =
            self.scenario.tenants.iter().map(|t| u64::from(u16::MAX - t.priority)).collect();
        ScenarioRunner { sim: self, scheds, wgt_fetch_g, prio_rank }
    }

    /// One-shot convenience: prebuild a [`ScenarioRunner`] and
    /// co-schedule once.  Callers simulating many allocation points
    /// over the same scenario should hold a runner instead.
    pub fn run(&self, allocs: &[Vec<CoreId>], arbitration: Arbitration) -> ScenarioResult {
        self.runner().run(allocs, arbitration)
    }
}

/// A prepared co-scheduler over one [`ScenarioSim`]: per-tenant
/// [`Scheduler`]s plus the global weight-fetch and priority tables,
/// built once and reused across any number of
/// `(allocations, arbitration)` simulations.
pub struct ScenarioRunner<'s> {
    sim: &'s ScenarioSim<'s>,
    scheds: Vec<Scheduler<'s>>,
    wgt_fetch_g: Vec<u64>,
    prio_rank: Vec<u64>,
}

impl ScenarioRunner<'_> {
    /// Co-schedule every request of every tenant under `allocs` (one
    /// per-layer core allocation per tenant) and `arbitration`.
    pub fn run(&self, allocs: &[Vec<CoreId>], arbitration: Arbitration) -> ScenarioResult {
        assert_eq!(allocs.len(), self.sim.builds.len(), "one allocation per tenant");
        for (b, a) in self.sim.builds.iter().zip(allocs) {
            assert_eq!(a.len(), b.workload.len(), "allocation per layer");
        }
        let scheds = &self.scheds;
        let wgt_fetch_g = &self.wgt_fetch_g;
        let prio_rank = &self.prio_rank;

        let topo = &self.sim.arch.topology;
        let n_cores = self.sim.arch.cores.len();
        let mut core_avail = vec![0u64; n_cores];
        let mut core_busy = vec![0u64; n_cores];
        let mut links = LinkSet::new(topo);
        let mut weights: Vec<WeightTracker> =
            self.sim.arch.cores.iter().map(|c| WeightTracker::new(c.wgt_mem_bytes)).collect();
        let mut evicted: Vec<LayerId> = Vec::new();

        let mut reqs: Vec<ReqState> = self
            .sim
            .scenario
            .requests()
            .iter()
            .map(|r| {
                let s = &scheds[r.tenant];
                let n = s.graph.len();
                ReqState {
                    seq: r.seq,
                    tenant: r.tenant,
                    release: r.release_cc,
                    deadline_abs: r.deadline_abs_cc,
                    sched: vec![None; n],
                    pending: (0..n)
                        .map(|i| s.graph.pred_count(CnId(i)) + s.gate_preds[i].len())
                        .collect(),
                    pool: CandidatePool::new(n, n_cores),
                    last_end: r.release_cc,
                }
            })
            .collect();
        for req in reqs.iter_mut() {
            let s = &scheds[req.tenant];
            let off = self.sim.layer_off[req.tenant];
            for i in 0..s.graph.len() {
                if req.pending[i] == 0 {
                    add_candidate(
                        s,
                        req,
                        CnId(i),
                        &weights,
                        &allocs[req.tenant],
                        off,
                        wgt_fetch_g,
                    );
                }
            }
        }

        let mut trace = MemTrace::new();
        let mut cns: Vec<ScenarioCn> = Vec::new();
        let mut comms: Vec<CommEvent> = Vec::new();
        let mut comm_req: Vec<usize> = Vec::new();
        let mut drams: Vec<DramEvent> = Vec::new();
        let mut dram_req: Vec<usize> = Vec::new();
        let mut breakdown = EnergyBreakdown::default();

        let act_cap: f64 = self.sim.arch.cores.iter().map(|c| c.act_mem_bytes as f64).sum();
        let mut act_occ = 0.0f64;

        // Virtual admission clock: monotonically tracks the earliest
        // time any schedulable candidate could start.  Deadline- and
        // priority-preference only applies to requests *released* by
        // `now`, so a future arrival can never pre-empt ready work and
        // leave cores idle (causal, work-conserving arbitration).  The
        // request achieving the global minimum readiness is always
        // released (its readiness is >= its release), so an eligible
        // request always exists.
        let mut now = 0u64;
        let mut cands: Vec<(usize, u64)> = Vec::new(); // (request, min eff)

        loop {
            // --- inter-request arbitration -------------------------------
            cands.clear();
            let mut min_eff = u64::MAX;
            for (ri, r) in reqs.iter_mut().enumerate() {
                if r.pool.len() == 0 {
                    continue;
                }
                let eff = r.pool.peek_min_eff().expect("nonempty pool has a minimum");
                min_eff = min_eff.min(eff);
                cands.push((ri, eff));
            }
            if cands.is_empty() {
                break;
            }
            now = now.max(min_eff);

            let mut best: Option<((u64, u64, u64), usize)> = None;
            for &(ri, eff) in &cands {
                let r = &reqs[ri];
                if r.release > now {
                    continue; // not yet arrived: ineligible for preference
                }
                let key = match arbitration {
                    Arbitration::Fifo => (0, eff, r.seq as u64),
                    Arbitration::Priority => (prio_rank[r.tenant], eff, r.seq as u64),
                    Arbitration::Edf => {
                        (r.deadline_abs.unwrap_or(u64::MAX), eff, r.seq as u64)
                    }
                };
                let better = match best {
                    None => true,
                    Some((k, _)) => key < k,
                };
                if better {
                    best = Some((key, ri));
                }
            }
            let (_, ri) = best.expect("a released request always exists");

            // --- one scheduling decision: a line-for-line mirror of
            // Scheduler::run_impl, over the chosen request's graph ------
            let rekey = {
                let req = &mut reqs[ri];
                let s = &scheds[req.tenant];
                let alloc = &allocs[req.tenant];
                let off = self.sim.layer_off[req.tenant];
                let cn_id = match self.sim.scenario.tenants[req.tenant].pool_priority {
                    SchedulePriority::Latency => req.pool.pop_latency(act_occ, act_cap),
                    SchedulePriority::Memory => req.pool.pop_memory(act_occ, act_cap),
                }
                .expect("arbitration picked a nonempty pool");
                let cn = s.graph.cns.node(cn_id);
                let layer = s.workload.layer(cn.layer);
                let core_id = alloc[cn.layer.0];
                let core = self.sim.arch.core(core_id);

                // 1) incoming data (cross-core edges routed over links);
                //    a request starts no earlier than its release
                let mut data_ready = req.release;
                for e in s.graph.pred_edges(cn_id) {
                    let p = req.sched[e.from.0].expect("pred scheduled");
                    match e.kind {
                        EdgeKind::Order => data_ready = data_ready.max(p.end),
                        EdgeKind::Data => {
                            if p.core == core_id || e.bytes == 0 {
                                data_ready = data_ready.max(p.end);
                            } else {
                                let route = topo.core_route(p.core, core_id);
                                let (cs, ce) = links.transfer(route, p.end, e.bytes);
                                comms.push(CommEvent {
                                    from_core: p.core,
                                    to_core: core_id,
                                    start: cs,
                                    end: ce,
                                    bytes: e.bytes,
                                    links: route.into(),
                                });
                                comm_req.push(req.seq);
                                breakdown.noc_pj +=
                                    e.bytes as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                                trace.push(cs, core_id, e.bytes as f64);
                                act_occ += e.bytes as f64;
                                let pf = s.fanout[s.graph.cns.node(e.from).layer.0];
                                trace.push(ce, p.core, -(e.bytes as f64) / pf);
                                act_occ = (act_occ - e.bytes as f64 / pf).max(0.0);
                                data_ready = data_ready.max(ce);
                            }
                        }
                    }
                }

                // 1b) bounded-buffer gates
                for g in &s.gate_preds[cn_id.0] {
                    data_ready = data_ready.max(req.sched[g.0].expect("gate scheduled").end);
                }

                // 2) weights, keyed by the global (tenant, layer) id so
                //    requests of the same tenant share residency
                let gl = LayerId(off + cn.layer.0);
                let mut weights_ready = 0u64;
                let wbytes = layer.weight_bytes();
                let mut rekey = None;
                if wbytes > 0 {
                    let fetch = weights[core_id.0].require_evicting(gl, wbytes, &mut evicted);
                    if fetch > 0 {
                        let route = topo.dram_load_route(core_id);
                        let (ds, de) = links.transfer(route, req.release, fetch);
                        drams.push(DramEvent {
                            core: core_id,
                            start: ds,
                            end: de,
                            bytes: fetch,
                            kind: DramKind::WeightFetch,
                            links: route.into(),
                        });
                        dram_req.push(req.seq);
                        breakdown.dram_pj +=
                            fetch as f64 * 8.0 * topo.route_dram_pj_per_bit(route);
                        breakdown.noc_pj +=
                            fetch as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                        if let CoreKind::Aimc { weight_load_pj, .. } = core.kind {
                            breakdown.onchip_pj += fetch as f64 * 8.0 * weight_load_pj;
                        }
                        weights_ready = de;
                        // residency on this core changed for EVERY
                        // request watching it; re-keyed after the body
                        // releases this request's borrow
                        rekey = Some((core_id.0, gl));
                    }
                }

                // 3) first-layer input activations from DRAM
                let mut input_ready = 0u64;
                let fresh = s.fresh_in_bytes[cn_id.0];
                if fresh > 0 {
                    let route = topo.dram_load_route(core_id);
                    let (ds, de) = links.transfer(route, req.release, fresh);
                    drams.push(DramEvent {
                        core: core_id,
                        start: ds,
                        end: de,
                        bytes: fresh,
                        kind: DramKind::ActFetch,
                        links: route.into(),
                    });
                    dram_req.push(req.seq);
                    breakdown.dram_pj += fresh as f64 * 8.0 * topo.route_dram_pj_per_bit(route);
                    breakdown.noc_pj += fresh as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                    trace.push(ds, core_id, fresh as f64);
                    act_occ += fresh as f64;
                    input_ready = de;
                }

                // 4) execute
                let cost = s.costs.cn_cost(cn, core_id);
                let start = core_avail[core_id.0]
                    .max(data_ready)
                    .max(weights_ready)
                    .max(input_ready);
                let end = start + cost.compute_cycles;
                core_avail[core_id.0] = end;
                core_busy[core_id.0] += cost.compute_cycles;
                breakdown.mac_pj += cost.mac_energy_pj;
                breakdown.onchip_pj += cost.energy_pj - cost.mac_energy_pj;

                // 5) memory trace
                trace.push(start, core_id, cn.output_bytes as f64);
                act_occ += cn.output_bytes as f64;
                if layer.predecessors.is_empty() {
                    trace.push(end, core_id, -(cn.discard_input_bytes as f64));
                    act_occ = (act_occ - cn.discard_input_bytes as f64).max(0.0);
                } else {
                    for &p in &layer.predecessors {
                        let share = match layer.op {
                            OpType::Concat => {
                                cn.discard_input_bytes as f64 * s.workload.layer(p).k as f64
                                    / layer.c as f64
                            }
                            _ => cn.discard_input_bytes as f64,
                        };
                        let p_core = alloc[p.0];
                        if p_core == core_id {
                            trace.push(end, core_id, -share / s.fanout[p.0]);
                            act_occ = (act_occ - share / s.fanout[p.0]).max(0.0);
                        } else {
                            trace.push(end, core_id, -share);
                            act_occ = (act_occ - share).max(0.0);
                        }
                    }
                }

                // 6) sink outputs stream to DRAM
                if s.workload.successors(cn.layer).is_empty() {
                    let route = topo.dram_store_route(core_id);
                    let (ds, de) = links.transfer(route, end, cn.output_bytes);
                    drams.push(DramEvent {
                        core: core_id,
                        start: ds,
                        end: de,
                        bytes: cn.output_bytes,
                        kind: DramKind::ActStore,
                        links: route.into(),
                    });
                    dram_req.push(req.seq);
                    breakdown.dram_pj +=
                        cn.output_bytes as f64 * 8.0 * topo.route_dram_pj_per_bit(route);
                    breakdown.noc_pj +=
                        cn.output_bytes as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                    trace.push(de, core_id, -(cn.output_bytes as f64));
                    act_occ = (act_occ - cn.output_bytes as f64).max(0.0);
                    req.last_end = req.last_end.max(de);
                }

                let placed = ScheduledCn { cn: cn_id, core: core_id, start, end };
                req.sched[cn_id.0] = Some(placed);
                req.last_end = req.last_end.max(end);
                cns.push(ScenarioCn { request: req.seq, placed });

                // 7) release successors within this request
                for e in s.graph.succ_edges(cn_id) {
                    req.pending[e.to.0] -= 1;
                    if req.pending[e.to.0] == 0 {
                        add_candidate(s, req, e.to, &weights, alloc, off, wgt_fetch_g);
                    }
                }
                for &g in &s.gate_succs[cn_id.0] {
                    req.pending[g.0] -= 1;
                    if req.pending[g.0] == 0 {
                        add_candidate(s, req, g, &weights, alloc, off, wgt_fetch_g);
                    }
                }
                rekey
            };

            // --- propagate a residency change to every request's pool ---
            if let Some((core, fetched)) = rekey {
                let evicted = &evicted;
                for r in reqs.iter_mut() {
                    r.pool.rekey_core(core, |l| {
                        if l == fetched {
                            Some(0)
                        } else if evicted.contains(&l) {
                            Some(wgt_fetch_g[l.0])
                        } else {
                            None
                        }
                    });
                }
            }
        }

        debug_assert!(
            reqs.iter().all(|r| r.sched.iter().all(|s| s.is_some())),
            "all CNs of all requests scheduled"
        );

        // --- aggregate metrics, exactly like Scheduler::run_impl --------
        let compute_end = cns.iter().map(|c| c.placed.end).max().unwrap_or(0);
        let io_end = drams
            .iter()
            .map(|d| d.end)
            .chain(comms.iter().map(|c| c.end))
            .max()
            .unwrap_or(0);
        let latency = compute_end.max(io_end);

        let dense_busy: u64 = self
            .sim
            .arch
            .cores
            .iter()
            .filter(|c| !c.is_simd())
            .map(|c| core_busy[c.id.0])
            .sum();
        let dense_count =
            self.sim.arch.cores.iter().filter(|c| !c.is_simd()).count() as f64;
        let avg_core_util = if latency > 0 {
            dense_busy as f64 / (latency as f64 * dense_count)
        } else {
            0.0
        };

        let (peak, spill_bytes) = peak_and_spill(&trace, self.sim.arch);
        let mut latency = latency;
        if spill_bytes > 0.5 {
            breakdown.dram_pj += 2.0 * spill_bytes * 8.0 * topo.spill_dram_pj_per_bit();
            let extra_port = (2.0 * spill_bytes * 8.0 / topo.dram_bw_bits() as f64) as u64;
            let dram_busy = topo
                .dram_channel_links()
                .map(|l| links.busy_cycles(l))
                .max()
                .unwrap_or(0);
            latency = latency.max(dram_busy + extra_port);
        }

        let metrics = ScheduleMetrics {
            latency_cc: latency,
            energy_pj: breakdown.total(),
            peak_mem_bytes: peak,
            breakdown,
            avg_core_util,
        };

        let link_stats = links
            .stats()
            .into_iter()
            .map(|(busy_cycles, bytes_moved)| LinkStat { busy_cycles, bytes_moved })
            .collect();

        // --- per-request / per-tenant serving statistics -----------------
        let outcomes: Vec<RequestOutcome> = reqs
            .iter()
            .map(|r| RequestOutcome {
                request: r.seq,
                tenant: r.tenant,
                release_cc: r.release,
                completion_cc: r.last_end,
                latency_cc: r.last_end.saturating_sub(r.release),
                deadline_abs_cc: r.deadline_abs,
                missed: r.deadline_abs.is_some_and(|d| r.last_end > d),
            })
            .collect();

        let seconds = if self.sim.scenario.clock_ghz > 0.0 && latency > 0 {
            latency as f64 / (self.sim.scenario.clock_ghz * 1e9)
        } else {
            0.0
        };
        let tenants: Vec<TenantStats> = self
            .sim
            .scenario
            .tenants
            .iter()
            .enumerate()
            .map(|(t, tenant)| {
                let lats: Vec<u64> = outcomes
                    .iter()
                    .filter(|o| o.tenant == t)
                    .map(|o| o.latency_cc)
                    .collect();
                let misses = outcomes.iter().filter(|o| o.tenant == t && o.missed).count();
                let n = lats.len();
                TenantStats {
                    name: tenant.name.clone(),
                    requests: n,
                    p50_cc: percentile_cc(&lats, 50.0),
                    p99_cc: percentile_cc(&lats, 99.0),
                    mean_cc: if n > 0 {
                        lats.iter().sum::<u64>() as f64 / n as f64
                    } else {
                        0.0
                    },
                    misses,
                    miss_rate: if n > 0 { misses as f64 / n as f64 } else { 0.0 },
                    throughput_rps: if seconds > 0.0 { n as f64 / seconds } else { 0.0 },
                }
            })
            .collect();

        ScenarioResult {
            metrics,
            cns,
            comms,
            comm_req,
            drams,
            dram_req,
            link_stats,
            core_busy,
            memtrace: trace,
            outcomes,
            tenants,
        }
    }
}

/// Mirror of `Scheduler::add_candidate` over one request's state:
/// readiness defaults to the request's release, and weight residency is
/// looked up under the global `(tenant, layer)` id.
fn add_candidate(
    s: &Scheduler,
    req: &mut ReqState,
    id: CnId,
    weights: &[WeightTracker],
    alloc: &[CoreId],
    layer_off: usize,
    wgt_fetch_g: &[u64],
) {
    let ready = s
        .graph
        .pred_edges(id)
        .map(|e| req.sched[e.from.0].expect("pred scheduled").end)
        .chain(
            s.gate_preds[id.0]
                .iter()
                .map(|g| req.sched[g.0].expect("gate scheduled").end),
        )
        .max()
        .unwrap_or(req.release);
    let cn = s.graph.cns.node(id);
    let core = alloc[cn.layer.0];
    let gl = LayerId(layer_off + cn.layer.0);
    let fetch = wgt_fetch_g[gl.0];
    let eff = if fetch == 0 || weights[core.0].is_resident(gl) { ready } else { ready + fetch };
    req.pool.insert(id, gl, cn.idx, ready, eff, cn.output_bytes, core.0, fetch > 0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::scenario::spec::{self, Arrival, Tenant};

    fn two_seg_scenario(release2: u64) -> Scenario {
        Scenario::new(
            "two-seg",
            vec![
                Tenant::new("a", "tiny-segment", Arrival::OneShot { at_cc: 0 }),
                Tenant::new("b", "tiny-segment", Arrival::OneShot { at_cc: release2 }),
            ],
        )
    }

    #[test]
    fn tiny_mix_schedules_every_request() {
        let scenario = spec::tiny_mix();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let allocs = sim.greedy_allocations();
        for arb in [Arbitration::Fifo, Arbitration::Priority, Arbitration::Edf] {
            let r = sim.run(&allocs, arb);
            let expect: usize = sim
                .builds()
                .iter()
                .zip(&scenario.tenants)
                .map(|(b, t)| b.graph.len() * t.arrival.releases().len())
                .sum();
            assert_eq!(r.cns.len(), expect, "{arb}");
            assert_eq!(r.outcomes.len(), scenario.n_requests(), "{arb}");
            assert!(r.metrics.latency_cc > 0, "{arb}");
            // completion never precedes release, dependencies hold
            for o in &r.outcomes {
                assert!(o.completion_cc >= o.release_cc, "{arb}");
            }
            // memory accounting closes
            assert!(r.memtrace.residual().abs() < 1.0, "{arb}");
            // tags align with events
            assert_eq!(r.comms.len(), r.comm_req.len(), "{arb}");
            assert_eq!(r.drams.len(), r.dram_req.len(), "{arb}");
        }
    }

    #[test]
    fn runner_reuse_matches_one_shot_runs() {
        let scenario = spec::tiny_mix();
        let arch = presets::test_dual();
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let allocs = sim.greedy_allocations();
        let runner = sim.runner();
        let a = runner.run(&allocs, Arbitration::Edf);
        let b = runner.run(&allocs, Arbitration::Edf);
        let c = sim.run(&allocs, Arbitration::Edf);
        assert_eq!(a.metrics.latency_cc, b.metrics.latency_cc);
        assert_eq!(a.metrics.latency_cc, c.metrics.latency_cc);
        assert_eq!(a.metrics.energy_pj.to_bits(), c.metrics.energy_pj.to_bits());
        assert_eq!(a.cns.len(), c.cns.len());
    }

    #[test]
    fn release_time_gates_request_start() {
        let arch = presets::test_dual();
        let scenario = two_seg_scenario(1_000_000);
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        let r = sim.run(&sim.greedy_allocations(), Arbitration::Fifo);
        for cn in r.cns.iter().filter(|c| c.request == 1) {
            assert!(cn.placed.start >= 1_000_000, "{:?}", cn.placed);
        }
    }

    #[test]
    fn same_tenant_requests_share_resident_weights() {
        // tiny-branchy's ~1 KB of weights can never thrash the 128 KB
        // weight SRAMs, so the fetch counts are exact
        let arch = presets::test_dual();
        let single = Scenario::new(
            "one",
            vec![Tenant::new("a", "tiny-branchy", Arrival::OneShot { at_cc: 0 })],
        );
        let sim1 = ScenarioSim::new(&single, &arch).unwrap();
        let r1 = sim1.run(&sim1.greedy_allocations(), Arbitration::Fifo);
        let fetches1 =
            r1.drams.iter().filter(|d| d.kind == DramKind::WeightFetch).count();
        assert!(fetches1 > 0);

        // two *different tenants* with the same model do NOT share
        // weights (separate global layer ids) ...
        let double = Scenario::new(
            "two",
            vec![
                Tenant::new("a", "tiny-branchy", Arrival::OneShot { at_cc: 0 }),
                Tenant::new("b", "tiny-branchy", Arrival::OneShot { at_cc: 0 }),
            ],
        );
        let sim2 = ScenarioSim::new(&double, &arch).unwrap();
        let r2 = sim2.run(&sim2.greedy_allocations(), Arbitration::Fifo);
        let fetches2 =
            r2.drams.iter().filter(|d| d.kind == DramKind::WeightFetch).count();
        assert_eq!(fetches2, 2 * fetches1);

        // ... but two requests of the SAME tenant fetch only once
        let burst = Scenario::new(
            "burst",
            vec![Tenant::new(
                "a",
                "tiny-branchy",
                Arrival::Burst { times_cc: vec![0, 0] },
            )],
        );
        let sim3 = ScenarioSim::new(&burst, &arch).unwrap();
        let r3 = sim3.run(&sim3.greedy_allocations(), Arbitration::Fifo);
        let fetches3 =
            r3.drams.iter().filter(|d| d.kind == DramKind::WeightFetch).count();
        assert_eq!(fetches3, fetches1, "second request must reuse resident weights");
    }

    #[test]
    fn priority_arbitration_prefers_high_priority_tenant() {
        let arch = presets::test_dual();
        let mut scenario = two_seg_scenario(0);
        scenario.tenants[1].priority = 5;
        let sim = ScenarioSim::new(&scenario, &arch).unwrap();
        // full contention: both tenants pinned to the same cores
        let allocs = sim.greedy_allocations();
        let fifo = sim.run(&allocs, Arbitration::Fifo);
        let prio = sim.run(&allocs, Arbitration::Priority);
        let done = |r: &ScenarioResult, t: usize| {
            r.tenant_outcomes(t).map(|o| o.completion_cc).max().unwrap()
        };
        assert!(
            done(&prio, 1) <= done(&fifo, 1),
            "priority must not slow the favored tenant: {} vs {}",
            done(&prio, 1),
            done(&fifo, 1)
        );
    }

    #[test]
    fn unknown_model_is_an_error() {
        let arch = presets::test_dual();
        let s = Scenario::new(
            "bad",
            vec![Tenant::new("x", "nope", Arrival::OneShot { at_cc: 0 })],
        );
        assert!(matches!(
            ScenarioSim::new(&s, &arch),
            Err(ScenarioError::UnknownModel(_))
        ));
        let empty = Scenario::new("empty", vec![]);
        assert!(matches!(
            ScenarioSim::new(&empty, &arch),
            Err(ScenarioError::EmptyScenario)
        ));
    }
}
