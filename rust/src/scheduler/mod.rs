//! Step 5 — multi-core CN scheduling + activation memory tracing.
//!
//! An event-driven list scheduler places every CN on its allocated core,
//! modeling (paper Section III-E):
//!
//! 1. **Inter-core communication**: a communication node is inserted for
//!    every producer→consumer data edge crossing cores; the transfer is
//!    routed over the architecture's interconnect
//!    [`Topology`](crate::arch::Topology) and occupies **every** link of
//!    its route first-come-first-serve at the route's bottleneck
//!    bandwidth ([`resources::LinkSet`]).  A `shared_bus` topology
//!    reduces to the paper's single-bus model.
//! 2. **Off-chip fetching**: layer weights not resident in a core's
//!    weight SRAM are fetched through the nearest DRAM port's shared
//!    channel (plus any NoC hops on the way in), evicting older weights
//!    FIFO ([`resources::WeightTracker`]); the first layer's input
//!    activations and the last layer's outputs also route to the
//!    nearest port.
//!
//! The scheduler keeps a candidate pool of CNs whose predecessors are
//! all scheduled and picks the next one by the configured priority
//! (Fig. 8): **latency** — the candidate whose predecessors finished
//! earliest; **memory** — the candidate from the deepest layer.
//! Selection is O(log n) per pick: the pool keeps lazily-invalidated
//! binary heaps per priority order plus per-core ready buckets that are
//! re-keyed when a core's weight residency changes (see [`Scheduler`]
//! and the internal `pool` module).  [`Scheduler::run`] takes `&self`,
//! and all per-run mutable state ([`resources::LinkSet`],
//! [`resources::WeightTracker`], the pool) is local to the call, so one
//! prebuilt scheduler can serve any number of GA fitness workers
//! concurrently.
//!
//! The event loop itself is the crate's **unified simulation core**
//! (the internal `sim` module): [`Scheduler::run`] instantiates it
//! with a single request lane released at t = 0, and the Step 6
//! scenario engine ([`crate::scenario`]) instantiates the *same body*
//! with one lane per request of every tenant, under an inter-request
//! [`Arbitration`] policy.  One inner loop serves both paths; the
//! degenerate case is pinned bit-for-bit against the frozen reference
//! engines (`rust/tests/sim_core_fuzz.rs`,
//! `rust/tests/topology_equivalence.rs`).
//!
//! For the GA's incremental fitness path ("delta evaluation"),
//! [`Scheduler::run_traced`] additionally freezes resumable
//! checkpoints of the in-flight state ([`SimSnapshot`]) and records
//! per-layer first-observation indices ([`ScheduleSegments`]);
//! [`Scheduler::run_resumed_traced`] then replays a mutated child
//! allocation from the deepest checkpoint preceding its divergence
//! point, bit-identical to a cold run, and
//! [`Scheduler::lower_bounds`] supplies admissible objective floors
//! for the search's early-abort.
//!
//! Step 5.2: once start/end times are known, activation memory usage is
//! traced from the CNs' discardable-input / generated-output attributes
//! ([`memtrace`]).

mod engine;
pub mod memtrace;
pub(crate) mod parsim;
pub(crate) mod pool;
#[cfg(any(test, feature = "reference-engines"))]
mod reference;
pub mod resources;
pub(crate) mod sim;
pub(crate) mod streaming;

pub use engine::{schedule, ScheduledCn, Scheduler};
pub use memtrace::{MemEvent, MemTrace};
pub use sim::{Arbitration, FallbackReason, ScheduleSegments, SimSnapshot};
pub use streaming::{LiveStats, RetiredRequest, StreamConfig, StreamRequest};

use crate::arch::{CoreId, LinkId};
use crate::cost::ScheduleMetrics;

/// Scheduling priority of the candidate pool (paper Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulePriority {
    /// Pick the candidate whose predecessors finished earliest —
    /// maximizes core utilization, best latency.
    #[default]
    Latency,
    /// Pick the candidate from the deepest layer — consume data as soon
    /// as possible for minimal activation footprint.
    Memory,
}

/// One scheduled communication node (inter-core transfer).
#[derive(Debug, Clone)]
pub struct CommEvent {
    pub from_core: CoreId,
    pub to_core: CoreId,
    pub start: u64,
    pub end: u64,
    pub bytes: u64,
    /// The interconnect links the transfer occupied, in route order.
    pub links: Box<[LinkId]>,
}

/// One scheduled DRAM transfer (weight fetch / act fetch / output
/// store), routed through the core's nearest DRAM port.
#[derive(Debug, Clone)]
pub struct DramEvent {
    pub core: CoreId,
    pub start: u64,
    pub end: u64,
    pub bytes: u64,
    pub kind: DramKind,
    /// The links the transfer occupied (DRAM channel + any NoC hops).
    pub links: Box<[LinkId]>,
}

/// Occupancy counters of one interconnect link over a whole schedule
/// (indexes match [`Topology::links`](crate::arch::Topology::links)).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LinkStat {
    pub busy_cycles: u64,
    pub bytes_moved: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DramKind {
    /// Weight-position operand load.  For weighted layers this happens
    /// at most once per residency window; for a streamed-B `MatMul`
    /// (LLM-decode KV read) it recurs on every CN — zero resident
    /// weights are never amortized.
    WeightFetch,
    ActFetch,
    ActStore,
}

/// Complete schedule: per-CN placement/timing, resource events, metrics
/// and the activation memory trace.
#[derive(Debug)]
pub struct ScheduleResult {
    pub cns: Vec<ScheduledCn>,
    pub comms: Vec<CommEvent>,
    pub drams: Vec<DramEvent>,
    /// Per-link occupancy, in the topology's link order.
    pub link_stats: Vec<LinkStat>,
    pub metrics: ScheduleMetrics,
    pub memtrace: MemTrace,
    /// Flight-recorder summary, attached only when the recorder is
    /// enabled ([`crate::obs::enabled`]); `None` otherwise, keeping the
    /// untraced result bit-identical.
    pub report: Option<Box<crate::obs::RunReport>>,
}

impl ScheduleResult {
    pub fn latency(&self) -> u64 {
        self.metrics.latency_cc
    }

    pub fn energy(&self) -> f64 {
        self.metrics.energy_pj
    }

    pub fn edp(&self) -> f64 {
        self.metrics.edp()
    }

    pub fn peak_mem(&self) -> f64 {
        self.metrics.peak_mem_bytes
    }
}
