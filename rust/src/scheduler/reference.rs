//! Frozen reference engines, compiled only for tests and under the
//! `reference-engines` feature (the self dev-dependency enables it for
//! every test/bench target, so release builds of the library carry no
//! dead pinning code).
//!
//! - [`Scheduler::run_reference`] — the unified core driven by the
//!   seed's O(n) linear candidate scan instead of the heaps;
//! - [`Scheduler::run_legacy_routed`] — a verbatim copy of the
//!   pre-unification routed single-request engine
//!   (`Scheduler::run_impl` as of PR 3), the oracle that pins the
//!   unified core's routed semantics: unlike `run_reference`, it
//!   shares none of the **loop body** with `SimContext::simulate`, so
//!   a regression in the core's event loop cannot cancel out of the
//!   comparison (`rust/tests/sim_core_fuzz.rs`).  The shared
//!   primitives (`CandidatePool`, `LinkSet`, `WeightTracker`,
//!   `peak_and_spill`) are *not* covered by that independence — they
//!   are pinned separately: the pool by its own linear-scan fuzz
//!   oracle, links/trackers by `run_legacy_bus` on shared-bus
//!   topologies;
//! - [`Scheduler::run_legacy_bus`] — a verbatim copy of the
//!   pre-topology scalar-bus engine, the anchor of
//!   `rust/tests/topology_equivalence.rs`.
//!
//! These engines are frozen **pre-transformer**: the legacy copies do
//! not model the streamed-B `MatMul` DRAM fetch (the KV read), so
//! pinning sweeps must keep using CNN fixtures only.  `run_reference`
//! (which drives the live core) remains valid on every workload.

use crate::arch::{CoreId, CoreKind, LinkId};
use crate::cn::CnId;
use crate::cost::{EnergyBreakdown, ScheduleMetrics};
use crate::depgraph::EdgeKind;
use crate::workload::{LayerId, OpType};

use super::engine::{p_layer, peak_and_spill, ScheduledCn, Scheduler};
use super::memtrace::MemTrace;
use super::pool::CandidatePool;
use super::resources::{FcfsLink, LinkSet, WeightTracker};
use super::{
    CommEvent, DramEvent, DramKind, LinkStat, SchedulePriority, ScheduleResult,
};

impl Scheduler<'_> {
    /// The seed's O(n)-scan candidate selection — bit-identical results
    /// to [`run`](Self::run), kept for equivalence tests and as the
    /// `hotpath` bench baseline.
    #[doc(hidden)]
    pub fn run_reference(
        &self,
        allocation: &[CoreId],
        priority: SchedulePriority,
    ) -> ScheduleResult {
        self.run_sim(allocation, priority, true)
    }

    /// The pre-unification routed single-request engine, verbatim
    /// (`Scheduler::run_impl` with the heap pool, as of PR 3): the
    /// frozen oracle for the unified core's routed semantics on *any*
    /// topology.  Shares no **loop body** with `SimContext::simulate`,
    /// so the bit-identity comparison in `rust/tests/sim_core_fuzz.rs`
    /// is non-circular for the event loop itself (the shared
    /// `pool`/`resources` primitives are pinned by their own oracles —
    /// see the module docs).  Not part of the public API.
    #[doc(hidden)]
    pub fn run_legacy_routed(
        &self,
        allocation: &[CoreId],
        priority: SchedulePriority,
    ) -> ScheduleResult {
        let n = self.graph.len();
        assert_eq!(allocation.len(), self.workload.len(), "allocation per layer");

        let topo = &self.arch.topology;
        let mut core_avail = vec![0u64; self.arch.cores.len()];
        let mut core_busy = vec![0u64; self.arch.cores.len()];
        let mut links = LinkSet::new(topo);
        let mut weights: Vec<WeightTracker> =
            self.arch.cores.iter().map(|c| WeightTracker::new(c.wgt_mem_bytes)).collect();
        let mut evicted: Vec<LayerId> = Vec::new();

        let mut sched: Vec<Option<ScheduledCn>> = vec![None; n];
        let mut pending: Vec<usize> = (0..n)
            .map(|i| self.graph.pred_count(CnId(i)) + self.gate_preds[i].len())
            .collect();
        let mut pool = CandidatePool::new(n, self.arch.cores.len());
        for i in 0..n {
            if pending[i] == 0 {
                self.add_candidate_legacy(CnId(i), &sched, &weights, allocation, &mut pool);
            }
        }

        let mut trace = MemTrace::new();
        let mut comms: Vec<CommEvent> = Vec::new();
        let mut drams: Vec<DramEvent> = Vec::new();
        let mut breakdown = EnergyBreakdown::default();
        let mut scheduled_order = Vec::with_capacity(n);

        let act_cap: f64 = self.arch.cores.iter().map(|c| c.act_mem_bytes as f64).sum();
        let mut act_occ = 0.0f64;

        loop {
            let picked = match priority {
                SchedulePriority::Latency => pool.pop_latency(act_occ, act_cap),
                SchedulePriority::Memory => pool.pop_memory(act_occ, act_cap),
            };
            let Some(cn_id) = picked else { break };
            let cn = self.graph.cns.node(cn_id);
            let layer = self.workload.layer(cn.layer);
            let core_id = allocation[cn.layer.0];
            let core = self.arch.core(core_id);

            // 1) incoming data: same-core preds gate by finish time;
            //    cross-core preds need a routed communication node that
            //    occupies every interconnect link between the two cores
            let mut data_ready = 0u64;
            for e in self.graph.pred_edges(cn_id) {
                let p = sched[e.from.0].expect("pred scheduled");
                match e.kind {
                    EdgeKind::Order => data_ready = data_ready.max(p.end),
                    EdgeKind::Data => {
                        if p.core == core_id || e.bytes == 0 {
                            data_ready = data_ready.max(p.end);
                        } else {
                            let route = topo.core_route(p.core, core_id);
                            let (cs, ce) = links.transfer(route, p.end, e.bytes);
                            comms.push(CommEvent {
                                from_core: p.core,
                                to_core: core_id,
                                start: cs,
                                end: ce,
                                bytes: e.bytes,
                                links: route.into(),
                            });
                            breakdown.noc_pj +=
                                e.bytes as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                            trace.push(cs, core_id, e.bytes as f64);
                            act_occ += e.bytes as f64;
                            let pf = self.fanout[p_layer(self.graph, e.from).0];
                            trace.push(ce, p.core, -(e.bytes as f64) / pf);
                            act_occ = (act_occ - e.bytes as f64 / pf).max(0.0);
                            data_ready = data_ready.max(ce);
                        }
                    }
                }
            }

            // 1b) buffer gates: wait for the gating consumer CNs
            for g in &self.gate_preds[cn_id.0] {
                data_ready = data_ready.max(sched[g.0].expect("gate scheduled").end);
            }

            // 2) weights: fetch through the nearest DRAM port if not
            //    resident (channel + any NoC hops into the core)
            let mut weights_ready = 0u64;
            let wbytes = layer.weight_bytes();
            if wbytes > 0 {
                let fetch = weights[core_id.0].require_evicting(cn.layer, wbytes, &mut evicted);
                if fetch > 0 {
                    let route = topo.dram_load_route(core_id);
                    let (ds, de) = links.transfer(route, 0, fetch);
                    drams.push(DramEvent {
                        core: core_id,
                        start: ds,
                        end: de,
                        bytes: fetch,
                        kind: DramKind::WeightFetch,
                        links: route.into(),
                    });
                    breakdown.dram_pj += fetch as f64 * 8.0 * topo.route_dram_pj_per_bit(route);
                    breakdown.noc_pj += fetch as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                    if let CoreKind::Aimc { weight_load_pj, .. } = core.kind {
                        breakdown.onchip_pj += fetch as f64 * 8.0 * weight_load_pj;
                    }
                    weights_ready = de;
                    let fetched_layer = cn.layer;
                    let evicted = &evicted;
                    pool.rekey_core(core_id.0, |l| {
                        if l == fetched_layer {
                            Some(0)
                        } else if evicted.contains(&l) {
                            Some(self.wgt_fetch_cc[l.0])
                        } else {
                            None
                        }
                    });
                }
            }

            // 3) first-layer input activations come from DRAM
            let mut input_ready = 0u64;
            let fresh = self.fresh_in_bytes[cn_id.0];
            if fresh > 0 {
                let route = topo.dram_load_route(core_id);
                let (ds, de) = links.transfer(route, 0, fresh);
                drams.push(DramEvent {
                    core: core_id,
                    start: ds,
                    end: de,
                    bytes: fresh,
                    kind: DramKind::ActFetch,
                    links: route.into(),
                });
                breakdown.dram_pj += fresh as f64 * 8.0 * topo.route_dram_pj_per_bit(route);
                breakdown.noc_pj += fresh as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                trace.push(ds, core_id, fresh as f64);
                act_occ += fresh as f64;
                input_ready = de;
            }

            // 4) execute
            let cost = self.costs.cn_cost(cn, core_id);
            let start = core_avail[core_id.0]
                .max(data_ready)
                .max(weights_ready)
                .max(input_ready);
            let end = start + cost.compute_cycles;
            core_avail[core_id.0] = end;
            core_busy[core_id.0] += cost.compute_cycles;
            breakdown.mac_pj += cost.mac_energy_pj;
            breakdown.onchip_pj += cost.energy_pj - cost.mac_energy_pj;

            // 5) memory trace: outputs allocated at start
            trace.push(start, core_id, cn.output_bytes as f64);
            act_occ += cn.output_bytes as f64;

            if layer.predecessors.is_empty() {
                trace.push(end, core_id, -(cn.discard_input_bytes as f64));
                act_occ = (act_occ - cn.discard_input_bytes as f64).max(0.0);
            } else {
                for &p in &layer.predecessors {
                    let share = match layer.op {
                        OpType::Concat => {
                            cn.discard_input_bytes as f64 * self.workload.layer(p).k as f64
                                / layer.c as f64
                        }
                        _ => cn.discard_input_bytes as f64,
                    };
                    let p_core = allocation[p.0];
                    if p_core == core_id {
                        trace.push(end, core_id, -share / self.fanout[p.0]);
                        act_occ = (act_occ - share / self.fanout[p.0]).max(0.0);
                    } else {
                        trace.push(end, core_id, -share);
                        act_occ = (act_occ - share).max(0.0);
                    }
                }
            }

            // 6) sink outputs stream to DRAM via the nearest port
            if self.workload.successors(cn.layer).is_empty() {
                let route = topo.dram_store_route(core_id);
                let (ds, de) = links.transfer(route, end, cn.output_bytes);
                drams.push(DramEvent {
                    core: core_id,
                    start: ds,
                    end: de,
                    bytes: cn.output_bytes,
                    kind: DramKind::ActStore,
                    links: route.into(),
                });
                breakdown.dram_pj +=
                    cn.output_bytes as f64 * 8.0 * topo.route_dram_pj_per_bit(route);
                breakdown.noc_pj +=
                    cn.output_bytes as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                trace.push(de, core_id, -(cn.output_bytes as f64));
                act_occ = (act_occ - cn.output_bytes as f64).max(0.0);
            }

            let placed = ScheduledCn { cn: cn_id, core: core_id, start, end };
            sched[cn_id.0] = Some(placed);
            scheduled_order.push(placed);

            // 7) release successors (data/order edges + buffer gates)
            for e in self.graph.succ_edges(cn_id) {
                pending[e.to.0] -= 1;
                if pending[e.to.0] == 0 {
                    self.add_candidate_legacy(e.to, &sched, &weights, allocation, &mut pool);
                }
            }
            for &g in &self.gate_succs[cn_id.0] {
                pending[g.0] -= 1;
                if pending[g.0] == 0 {
                    self.add_candidate_legacy(g, &sched, &weights, allocation, &mut pool);
                }
            }
        }

        debug_assert!(sched.iter().all(|s| s.is_some()), "all CNs scheduled");

        let compute_end = scheduled_order.iter().map(|s| s.end).max().unwrap_or(0);
        let io_end = drams
            .iter()
            .map(|d| d.end)
            .chain(comms.iter().map(|c| c.end))
            .max()
            .unwrap_or(0);
        let latency = compute_end.max(io_end);

        let dense_busy: u64 = self
            .arch
            .cores
            .iter()
            .filter(|c| !c.is_simd())
            .map(|c| core_busy[c.id.0])
            .sum();
        let dense_count = self.arch.cores.iter().filter(|c| !c.is_simd()).count() as f64;
        let avg_core_util = if latency > 0 {
            dense_busy as f64 / (latency as f64 * dense_count)
        } else {
            0.0
        };

        let (peak, spill_bytes) = peak_and_spill(&trace, self.arch);
        let mut latency = latency;
        if spill_bytes > 0.5 {
            breakdown.dram_pj += 2.0 * spill_bytes * 8.0 * topo.spill_dram_pj_per_bit();
            let extra_port = (2.0 * spill_bytes * 8.0 / topo.dram_bw_bits() as f64) as u64;
            let dram_busy = topo
                .dram_channel_links()
                .map(|l| links.busy_cycles(l))
                .max()
                .unwrap_or(0);
            latency = latency.max(dram_busy + extra_port);
        }

        let metrics = ScheduleMetrics {
            latency_cc: latency,
            energy_pj: breakdown.total(),
            peak_mem_bytes: peak,
            breakdown,
            avg_core_util,
        };

        let link_stats = links
            .stats()
            .into_iter()
            .map(|(busy_cycles, bytes_moved)| LinkStat { busy_cycles, bytes_moved })
            .collect();

        ScheduleResult {
            cns: scheduled_order,
            comms,
            drams,
            link_stats,
            metrics,
            memtrace: trace,
            report: None,
        }
    }

    /// The pre-topology scheduler, verbatim: one scalar FCFS bus and one
    /// scalar FCFS DRAM port, no routing.  Only valid on a
    /// [`shared_bus`](crate::arch::Topology::shared_bus) topology
    /// (panics otherwise).  `rust/tests/topology_equivalence.rs` pins
    /// the routed path against this bit-for-bit; it is not part of the
    /// public API.
    #[doc(hidden)]
    pub fn run_legacy_bus(
        &self,
        allocation: &[CoreId],
        priority: SchedulePriority,
    ) -> ScheduleResult {
        let (bus_bw, bus_pj, dram_bw, dram_pj) = self
            .arch
            .topology
            .as_shared_bus()
            .expect("run_legacy_bus requires a shared-bus topology");
        // in the shared_bus constructor the bus is link 0, the DRAM
        // channel link 1 — events carry them so results compare fully
        let bus_link: Box<[LinkId]> = Box::new([LinkId(0)]);
        let dram_link: Box<[LinkId]> = Box::new([LinkId(1)]);

        let n = self.graph.len();
        assert_eq!(allocation.len(), self.workload.len(), "allocation per layer");

        let mut core_avail = vec![0u64; self.arch.cores.len()];
        let mut core_busy = vec![0u64; self.arch.cores.len()];
        let mut bus = FcfsLink::new(bus_bw);
        let mut dram = FcfsLink::new(dram_bw);
        let mut weights: Vec<WeightTracker> =
            self.arch.cores.iter().map(|c| WeightTracker::new(c.wgt_mem_bytes)).collect();
        let mut evicted: Vec<LayerId> = Vec::new();

        let mut sched: Vec<Option<ScheduledCn>> = vec![None; n];
        let mut pending: Vec<usize> = (0..n)
            .map(|i| self.graph.pred_count(CnId(i)) + self.gate_preds[i].len())
            .collect();
        let mut pool = CandidatePool::new(n, self.arch.cores.len());
        for i in 0..n {
            if pending[i] == 0 {
                self.add_candidate_legacy(CnId(i), &sched, &weights, allocation, &mut pool);
            }
        }

        let mut trace = MemTrace::new();
        let mut comms: Vec<CommEvent> = Vec::new();
        let mut drams: Vec<DramEvent> = Vec::new();
        let mut breakdown = EnergyBreakdown::default();
        let mut scheduled_order = Vec::with_capacity(n);

        let act_cap: f64 = self.arch.cores.iter().map(|c| c.act_mem_bytes as f64).sum();
        let mut act_occ = 0.0f64;

        loop {
            let picked = match priority {
                SchedulePriority::Latency => pool.pop_latency(act_occ, act_cap),
                SchedulePriority::Memory => pool.pop_memory(act_occ, act_cap),
            };
            let Some(cn_id) = picked else { break };
            let cn = self.graph.cns.node(cn_id);
            let layer = self.workload.layer(cn.layer);
            let core_id = allocation[cn.layer.0];
            let core = self.arch.core(core_id);

            let mut data_ready = 0u64;
            for e in self.graph.pred_edges(cn_id) {
                let p = sched[e.from.0].expect("pred scheduled");
                match e.kind {
                    EdgeKind::Order => data_ready = data_ready.max(p.end),
                    EdgeKind::Data => {
                        if p.core == core_id || e.bytes == 0 {
                            data_ready = data_ready.max(p.end);
                        } else {
                            let (cs, ce) = bus.transfer(p.end, e.bytes);
                            comms.push(CommEvent {
                                from_core: p.core,
                                to_core: core_id,
                                start: cs,
                                end: ce,
                                bytes: e.bytes,
                                links: bus_link.clone(),
                            });
                            breakdown.noc_pj += e.bytes as f64 * 8.0 * bus_pj;
                            trace.push(cs, core_id, e.bytes as f64);
                            act_occ += e.bytes as f64;
                            let pf = self.fanout[p_layer(self.graph, e.from).0];
                            trace.push(ce, p.core, -(e.bytes as f64) / pf);
                            act_occ = (act_occ - e.bytes as f64 / pf).max(0.0);
                            data_ready = data_ready.max(ce);
                        }
                    }
                }
            }

            for g in &self.gate_preds[cn_id.0] {
                data_ready = data_ready.max(sched[g.0].expect("gate scheduled").end);
            }

            let mut weights_ready = 0u64;
            let wbytes = layer.weight_bytes();
            if wbytes > 0 {
                let fetch = weights[core_id.0].require_evicting(cn.layer, wbytes, &mut evicted);
                if fetch > 0 {
                    let (ds, de) = dram.transfer(0, fetch);
                    drams.push(DramEvent {
                        core: core_id,
                        start: ds,
                        end: de,
                        bytes: fetch,
                        kind: DramKind::WeightFetch,
                        links: dram_link.clone(),
                    });
                    breakdown.dram_pj += fetch as f64 * 8.0 * dram_pj;
                    if let CoreKind::Aimc { weight_load_pj, .. } = core.kind {
                        breakdown.onchip_pj += fetch as f64 * 8.0 * weight_load_pj;
                    }
                    weights_ready = de;
                    let fetched_layer = cn.layer;
                    let evicted = &evicted;
                    pool.rekey_core(core_id.0, |l| {
                        if l == fetched_layer {
                            Some(0)
                        } else if evicted.contains(&l) {
                            Some(self.wgt_fetch_cc[l.0])
                        } else {
                            None
                        }
                    });
                }
            }

            let mut input_ready = 0u64;
            let fresh = self.fresh_in_bytes[cn_id.0];
            if fresh > 0 {
                let (ds, de) = dram.transfer(0, fresh);
                drams.push(DramEvent {
                    core: core_id,
                    start: ds,
                    end: de,
                    bytes: fresh,
                    kind: DramKind::ActFetch,
                    links: dram_link.clone(),
                });
                breakdown.dram_pj += fresh as f64 * 8.0 * dram_pj;
                trace.push(ds, core_id, fresh as f64);
                act_occ += fresh as f64;
                input_ready = de;
            }

            let cost = self.costs.cn_cost(cn, core_id);
            let start = core_avail[core_id.0]
                .max(data_ready)
                .max(weights_ready)
                .max(input_ready);
            let end = start + cost.compute_cycles;
            core_avail[core_id.0] = end;
            core_busy[core_id.0] += cost.compute_cycles;
            breakdown.mac_pj += cost.mac_energy_pj;
            breakdown.onchip_pj += cost.energy_pj - cost.mac_energy_pj;

            trace.push(start, core_id, cn.output_bytes as f64);
            act_occ += cn.output_bytes as f64;

            if layer.predecessors.is_empty() {
                trace.push(end, core_id, -(cn.discard_input_bytes as f64));
                act_occ = (act_occ - cn.discard_input_bytes as f64).max(0.0);
            } else {
                for &p in &layer.predecessors {
                    let share = match layer.op {
                        OpType::Concat => {
                            cn.discard_input_bytes as f64 * self.workload.layer(p).k as f64
                                / layer.c as f64
                        }
                        _ => cn.discard_input_bytes as f64,
                    };
                    let p_core = allocation[p.0];
                    if p_core == core_id {
                        trace.push(end, core_id, -share / self.fanout[p.0]);
                        act_occ = (act_occ - share / self.fanout[p.0]).max(0.0);
                    } else {
                        trace.push(end, core_id, -share);
                        act_occ = (act_occ - share).max(0.0);
                    }
                }
            }

            if self.workload.successors(cn.layer).is_empty() {
                let (ds, de) = dram.transfer(end, cn.output_bytes);
                drams.push(DramEvent {
                    core: core_id,
                    start: ds,
                    end: de,
                    bytes: cn.output_bytes,
                    kind: DramKind::ActStore,
                    links: dram_link.clone(),
                });
                breakdown.dram_pj += cn.output_bytes as f64 * 8.0 * dram_pj;
                trace.push(de, core_id, -(cn.output_bytes as f64));
                act_occ = (act_occ - cn.output_bytes as f64).max(0.0);
            }

            let placed = ScheduledCn { cn: cn_id, core: core_id, start, end };
            sched[cn_id.0] = Some(placed);
            scheduled_order.push(placed);

            for e in self.graph.succ_edges(cn_id) {
                pending[e.to.0] -= 1;
                if pending[e.to.0] == 0 {
                    self.add_candidate_legacy(e.to, &sched, &weights, allocation, &mut pool);
                }
            }
            for &g in &self.gate_succs[cn_id.0] {
                pending[g.0] -= 1;
                if pending[g.0] == 0 {
                    self.add_candidate_legacy(g, &sched, &weights, allocation, &mut pool);
                }
            }
        }

        debug_assert!(sched.iter().all(|s| s.is_some()), "all CNs scheduled");

        let compute_end = scheduled_order.iter().map(|s| s.end).max().unwrap_or(0);
        let io_end = drams
            .iter()
            .map(|d| d.end)
            .chain(comms.iter().map(|c| c.end))
            .max()
            .unwrap_or(0);
        let latency = compute_end.max(io_end);

        let dense_busy: u64 = self
            .arch
            .cores
            .iter()
            .filter(|c| !c.is_simd())
            .map(|c| core_busy[c.id.0])
            .sum();
        let dense_count = self.arch.cores.iter().filter(|c| !c.is_simd()).count() as f64;
        let avg_core_util = if latency > 0 {
            dense_busy as f64 / (latency as f64 * dense_count)
        } else {
            0.0
        };

        let (peak, spill_bytes) = peak_and_spill(&trace, self.arch);
        let mut latency = latency;
        if spill_bytes > 0.5 {
            breakdown.dram_pj += 2.0 * spill_bytes * 8.0 * dram_pj;
            let extra_port = (2.0 * spill_bytes * 8.0 / dram_bw.max(1) as f64) as u64;
            latency = latency.max(dram.busy_cycles + extra_port);
        }

        let metrics = ScheduleMetrics {
            latency_cc: latency,
            energy_pj: breakdown.total(),
            peak_mem_bytes: peak,
            breakdown,
            avg_core_util,
        };

        let link_stats = vec![
            LinkStat { busy_cycles: bus.busy_cycles, bytes_moved: bus.bytes_moved },
            LinkStat { busy_cycles: dram.busy_cycles, bytes_moved: dram.bytes_moved },
        ];

        ScheduleResult {
            cns: scheduled_order,
            comms,
            drams,
            link_stats,
            metrics,
            memtrace: trace,
            report: None,
        }
    }

    /// The legacy engine's candidate registration (local layer ids, no
    /// release floor) — frozen alongside [`run_legacy_bus`](Self::run_legacy_bus).
    fn add_candidate_legacy(
        &self,
        id: CnId,
        sched: &[Option<ScheduledCn>],
        weights: &[WeightTracker],
        allocation: &[CoreId],
        pool: &mut CandidatePool,
    ) {
        let ready = self
            .graph
            .pred_edges(id)
            .map(|e| sched[e.from.0].expect("pred scheduled").end)
            .chain(self.gate_preds[id.0].iter().map(|g| sched[g.0].expect("gate scheduled").end))
            .max()
            .unwrap_or(0);
        let cn = self.graph.cns.node(id);
        let core = allocation[cn.layer.0];
        let fetch = self.wgt_fetch_cc[cn.layer.0];
        let eff = if fetch == 0 || weights[core.0].is_resident(cn.layer) {
            ready
        } else {
            ready + fetch
        };
        pool.insert(id, cn.layer, cn.idx, ready, eff, cn.output_bytes, core.0, fetch > 0);
    }
}
