//! Step 5.2 — activation memory usage tracing.
//!
//! Events are (time, core, delta-bytes); the trace accumulates the total
//! on-chip activation footprint across cores, whose maximum is the peak
//! memory usage (paper Fig. 7 bottom).
//!
//! Accounting rules (Section III-F):
//! - a CN's output space is **allocated on its core when the CN starts**;
//! - inputs that no later CN needs are **freed when the CN finishes**
//!   (the discardable-input attribute);
//! - for an inter-core transfer, space is allocated in the consuming
//!   core when the communication starts, and the producer's copy is
//!   freed when the communication concludes;
//! - when a producer feeds several consumer *layers*, the frees against
//!   the producer-side allocation are scaled by 1/fanout so the single
//!   physical buffer is released exactly once.

use crate::arch::CoreId;

/// One memory-delta event.
#[derive(Debug, Clone, Copy)]
pub struct MemEvent {
    pub time: u64,
    pub core: CoreId,
    pub delta: f64,
}

/// Collected trace with peak computation.
#[derive(Debug, Clone, Default)]
pub struct MemTrace {
    pub events: Vec<MemEvent>,
}

impl MemTrace {
    pub fn new() -> MemTrace {
        MemTrace { events: Vec::new() }
    }

    pub fn push(&mut self, time: u64, core: CoreId, delta: f64) {
        if delta != 0.0 {
            self.events.push(MemEvent { time, core, delta });
        }
    }

    /// Time-sorted running total across all cores.
    pub fn total_curve(&self) -> Vec<(u64, f64)> {
        let mut ev: Vec<&MemEvent> = self.events.iter().collect();
        // frees before allocs at the same timestamp: a buffer handed
        // over at time t must not be counted twice
        ev.sort_by(|a, b| {
            a.time.cmp(&b.time).then(
                a.delta.partial_cmp(&b.delta).unwrap_or(std::cmp::Ordering::Equal),
            )
        });
        let mut curve = Vec::with_capacity(ev.len() + 1);
        let mut total = 0.0;
        curve.push((0, 0.0));
        for e in ev {
            total += e.delta;
            curve.push((e.time, total));
        }
        curve
    }

    /// Peak of the total curve in bytes.
    pub fn peak(&self) -> f64 {
        self.total_curve().iter().map(|(_, v)| *v).fold(0.0, f64::max)
    }

    /// Residual footprint at the end of the schedule (should be ~0 for
    /// a complete run whose outputs are stored off-chip).
    pub fn residual(&self) -> f64 {
        self.events.iter().map(|e| e.delta).sum()
    }

    /// Per-core peak (diagnostics / per-core capacity checks).
    pub fn core_peak(&self, core: CoreId) -> f64 {
        let mut ev: Vec<&MemEvent> = self.events.iter().filter(|e| e.core == core).collect();
        ev.sort_by(|a, b| {
            a.time.cmp(&b.time).then(
                a.delta.partial_cmp(&b.delta).unwrap_or(std::cmp::Ordering::Equal),
            )
        });
        let mut peak = 0.0f64;
        let mut total = 0.0;
        for e in ev {
            total += e.delta;
            peak = peak.max(total);
        }
        peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_and_residual() {
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), 100.0);
        t.push(5, CoreId(1), 50.0);
        t.push(10, CoreId(0), -100.0);
        t.push(12, CoreId(1), -50.0);
        assert_eq!(t.peak(), 150.0);
        assert_eq!(t.residual(), 0.0);
    }

    #[test]
    fn same_time_free_before_alloc() {
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), 100.0);
        // hand-over at t=10: free then alloc -> peak must stay 100
        t.push(10, CoreId(0), -100.0);
        t.push(10, CoreId(1), 100.0);
        assert_eq!(t.peak(), 100.0);
    }

    #[test]
    fn per_core_peak() {
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), 10.0);
        t.push(1, CoreId(1), 90.0);
        t.push(2, CoreId(0), -10.0);
        assert_eq!(t.core_peak(CoreId(0)), 10.0);
        assert_eq!(t.core_peak(CoreId(1)), 90.0);
    }

    #[test]
    fn zero_deltas_ignored() {
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), 0.0);
        assert!(t.events.is_empty());
    }

    #[test]
    fn total_curve_is_time_ordered_with_running_total() {
        let mut t = MemTrace::new();
        // pushed out of order: the curve must still be time-sorted
        t.push(20, CoreId(1), 30.0);
        t.push(0, CoreId(0), 100.0);
        t.push(10, CoreId(0), -40.0);
        let curve = t.total_curve();
        assert_eq!(curve, vec![(0, 0.0), (0, 100.0), (10, 60.0), (20, 90.0)]);
        assert_eq!(t.peak(), 100.0);
    }

    #[test]
    fn interleaved_cores_accumulate_into_one_pool() {
        // peak-activation accounting is pooled across cores (paper
        // Fig. 7: "total memory usage of all three cores"), so
        // staggered per-core peaks must combine, not max
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), 60.0);
        t.push(1, CoreId(1), 50.0);
        t.push(2, CoreId(2), 40.0);
        t.push(3, CoreId(0), -60.0);
        assert_eq!(t.peak(), 150.0);
        assert_eq!(t.core_peak(CoreId(0)), 60.0);
        assert_eq!(t.core_peak(CoreId(1)), 50.0);
        assert_eq!(t.core_peak(CoreId(2)), 40.0);
        assert_eq!(t.residual(), 90.0);
    }

    #[test]
    fn handover_frees_producer_copy_exactly_once() {
        // a producer feeding two consumer layers frees 1/fanout per
        // consumer finish: the physical buffer is released exactly once
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), 100.0); // producer output
        t.push(5, CoreId(0), -50.0); // consumer A done (fanout 2)
        t.push(9, CoreId(0), -50.0); // consumer B done
        assert_eq!(t.peak(), 100.0);
        assert!(t.residual().abs() < 1e-9);
    }

    #[test]
    fn core_peak_with_equal_timestamps_frees_first() {
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), 80.0);
        t.push(4, CoreId(0), -80.0);
        t.push(4, CoreId(0), 80.0); // swap at t=4 must not double-count
        assert_eq!(t.core_peak(CoreId(0)), 80.0);
    }
}
