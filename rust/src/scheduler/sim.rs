//! The unified event-driven simulation core.
//!
//! Exactly **one** inner scheduling loop exists in the crate:
//! [`SimContext::step`], driven to completion by
//! [`SimContext::simulate`].  The one-shot scheduler
//! ([`Scheduler::run`]) instantiates it with a single request lane
//! released at t = 0, and the multi-DNN scenario engine
//! (`crate::scenario::ScenarioSim`) instantiates it with one lane per
//! request of every tenant.  Everything that differs between the two
//! callers is captured by the context:
//!
//! - **release floor / admission clock** — a lane's candidates are
//!   never ready before its request's release, and a causal virtual
//!   admission clock gates deadline/priority preference to requests
//!   that have actually arrived, so arbitration stays work-conserving;
//! - **global `(tenant, layer)` weight ids** — each lane's layers map
//!   into a shared weight-residency space at [`SimTenant::layer_off`],
//!   so same-tenant requests reuse resident weights while distinct
//!   tenants never alias (the one-shot path uses offset 0);
//! - **inter-request arbitration** — [`Arbitration`] picks which lane
//!   gets the next scheduling decision (fifo / priority / edf via the
//!   pool's `peek_min_eff`); with a single lane it is vacuous;
//! - **event tagging** — every CN, communication and DRAM event
//!   carries its lane index ([`SimOutcome`]), which the scenario layer
//!   turns into per-request serving statistics and the one-shot layer
//!   discards.
//!
//! # Checkpoint / resume (delta evaluation)
//!
//! All mutable simulation state lives in one [`SimState`], which is
//! `Clone`: freezing a copy between two scheduling decisions yields a
//! [`SimSnapshot`] from which the run can be resumed — under the *same*
//! context it replays the remaining decisions bit-for-bit (pinned by
//! the snapshot/resume sweep in `rust/tests/sim_core_fuzz.rs`), and
//! under a context whose core allocation differs only in layers the
//! prefix never observed it reproduces that allocation's cold run
//! bit-for-bit (the GA's incremental fitness path, pinned by
//! `rust/tests/delta_equivalence.rs`).
//!
//! "Never observed" is made precise by insertion visibility: a
//! candidate inserted during decision `j` can first influence decision
//! `j + 1` (init-time insertions are visible from decision 0).  A
//! [`SimRecorder`] threads through the loop — [`NoRecord`] keeps the
//! normal path zero-cost, [`TouchTracer`] records per layer the minimum
//! visibility index of its candidates.  Every read of a layer's core
//! assignment happens either when one of its CNs is inserted or
//! scheduled (at a decision index `>=` its visibility) or when a
//! consumer CN is scheduled (whose own visibility is strictly later),
//! so decisions before `min(touch(changed layers))` are independent of
//! the change — [`ScheduleSegments::divergence`] computes exactly that
//! bound, and [`ScheduleSegments::resume_point`] picks the deepest
//! snapshot strictly before it (strict, because a candidate of a
//! changed layer inserted *at* the divergence decision would bake the
//! old core's readiness into the snapshot's pool).
//!
//! The degenerate single-lane instantiation is pinned **bit-for-bit**
//! against the frozen reference engines: `rust/tests/sim_core_fuzz.rs`
//! and the unit test `heap_pool_matches_reference_scan` pin it to the
//! seed's O(n) linear scan (`Scheduler::run_reference`),
//! `rust/tests/topology_equivalence.rs` pins it to the pre-topology
//! scalar-bus engine, and `rust/tests/scenario_equivalence.rs` pins the
//! scenario wrapper to the one-shot wrapper.

use std::sync::Arc;

use crate::arch::{Accelerator, CoreId, CoreKind};
use crate::cn::CnId;
use crate::cost::{EnergyBreakdown, ScheduleMetrics};
use crate::depgraph::EdgeKind;
use crate::workload::{LayerId, OpType};

use super::engine::{peak_and_spill, ScheduledCn, Scheduler};
use super::memtrace::MemTrace;
use super::pool::CandidatePool;
use super::resources::{LinkSet, WeightTracker};
use super::{CommEvent, DramEvent, DramKind, LinkStat, SchedulePriority};

/// How the engine decides *which request* gets the next scheduling
/// decision (the per-CN pick within a request still follows the
/// tenant's [`SchedulePriority`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Arbitration {
    /// Requests share resources in readiness order; ties go to the
    /// earlier arrival — fair FCFS processor sharing.
    #[default]
    Fifo,
    /// Strictly serve the highest-
    /// [`priority`](crate::scenario::Tenant::priority) tenant with work
    /// available; readiness breaks ties.
    Priority,
    /// Earliest absolute deadline first; deadline-free requests rank
    /// last, readiness breaks ties.
    Edf,
}

impl Arbitration {
    pub fn by_name(name: &str) -> Option<Arbitration> {
        match name {
            "fifo" => Some(Arbitration::Fifo),
            "priority" => Some(Arbitration::Priority),
            "edf" => Some(Arbitration::Edf),
            _ => None,
        }
    }
}

impl std::fmt::Display for Arbitration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Arbitration::Fifo => write!(f, "fifo"),
            Arbitration::Priority => write!(f, "priority"),
            Arbitration::Edf => write!(f, "edf"),
        }
    }
}

/// Why [`SimContext::simulate`] ran its sequential loop instead of the
/// chip-partitioned parallel core (`super::parsim`).  Returned in
/// [`SimOutcome::fallback`] (`None` means the parallel core engaged),
/// so callers and tests can assert on the *reason* instead of
/// inferring it from partition counts.  The reason is a deterministic
/// function of the context and the recorded per-chip data — never of
/// thread timing — matching the parallel core's exactness contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FallbackReason {
    /// The effective worker count was 1 (including every
    /// single-threaded caller that never attempts the parallel core).
    SequentialConfig,
    /// The topology has a single chip — nothing to partition.
    SingleChip,
    /// Fewer than two request lanes.
    SingleRequest,
    /// The context cannot be replayed: linear-scan pool or event
    /// tagging off (the merge needs per-decision lane tags).
    UntracedEvents,
    /// Some lane's allocation spans chips (or routes off-chip).
    StraddlingAllocation,
    /// All lanes landed on one chip.
    FewActiveChips,
    /// The activation-headroom certificate failed: the summed per-chip
    /// occupancy peaks plus the largest CN output exceed the pooled
    /// capacity, so the memory-full coupling cannot be proven inert.
    HeadroomViolated,
    /// Replaying the sequential arbitration over the recorded decision
    /// streams diverged from a chip's local pick.
    MergeMismatch,
}

impl std::fmt::Display for FallbackReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            FallbackReason::SequentialConfig => "sequential config",
            FallbackReason::SingleChip => "single chip",
            FallbackReason::SingleRequest => "single request",
            FallbackReason::UntracedEvents => "untraced events",
            FallbackReason::StraddlingAllocation => "straddling allocation",
            FallbackReason::FewActiveChips => "fewer than two active chips",
            FallbackReason::HeadroomViolated => "headroom certificate violated",
            FallbackReason::MergeMismatch => "merge pick mismatch",
        };
        write!(f, "{s}")
    }
}

/// One tenant lane of the unified core: a prebuilt [`Scheduler`] plus
/// everything request-independent the core needs about that tenant.
pub struct SimTenant<'a> {
    pub sched: &'a Scheduler<'a>,
    /// Core per layer of this tenant's workload.
    pub alloc: &'a [CoreId],
    /// Intra-request candidate-pool priority (paper Fig. 8).
    pub pool_priority: SchedulePriority,
    /// Arbitration rank under [`Arbitration::Priority`] (lower wins).
    pub prio_rank: u64,
    /// Global layer-id offset into the shared weight-residency space:
    /// this tenant's layer `l` is weight-tracked as
    /// `LayerId(layer_off + l)`.
    pub layer_off: usize,
}

/// One request lane: an inference of [`tenant`](Self::tenant)'s model
/// released at [`release`](Self::release).
pub struct SimRequest {
    /// Index into [`SimContext::tenants`].
    pub tenant: usize,
    pub release: u64,
    /// Absolute deadline, if any (the [`Arbitration::Edf`] key; the
    /// core itself never blocks on it).
    pub deadline_abs: Option<u64>,
}

/// Everything that parameterizes one simulation.  See the
/// [module docs](self).
pub struct SimContext<'a> {
    pub arch: &'a Accelerator,
    pub tenants: &'a [SimTenant<'a>],
    /// Request lanes, in arrival (seq) order; lane indices tag every
    /// event of the outcome.
    pub requests: &'a [SimRequest],
    /// Global `(tenant, layer)`-indexed DRAM weight-fetch cycle table
    /// ([`global_wgt_fetch`]); the one-shot path passes the tenant's
    /// own per-layer table.
    pub wgt_fetch_g: &'a [u64],
    pub arbitration: Arbitration,
    /// Use the seed's O(n) linear candidate scan instead of the heaps
    /// (the `run_reference` pinning path).
    pub linear_pool: bool,
    /// Record per-event request tags ([`SimOutcome::cn_req`] and
    /// friends).  The scenario wrapper needs them for its serving
    /// statistics; the one-shot wrapper drops them, so its hot path
    /// (one GA fitness evaluation per unseen genome) skips the
    /// bookkeeping entirely and the tag vectors come back empty.
    pub tag_events: bool,
    /// Worker threads for the partition-parallel simulation core
    /// (`super::parsim`): 0 resolves `STREAM_SIM_THREADS` from the
    /// environment at [`simulate`](Self::simulate) time (default 1 =
    /// sequential).  Values above 1 *permit* chip-partitioned parallel
    /// execution; the result is bit-identical to the sequential loop
    /// for every value (the parallel core falls back to sequential
    /// whenever its exactness conditions fail).
    pub sim_threads: usize,
}

/// What one simulation produced, request-tagged.  The one-shot wrapper
/// drops the tags; the scenario wrapper aggregates them into serving
/// statistics.
pub struct SimOutcome {
    /// Every scheduled CN, in scheduling order.
    pub cns: Vec<ScheduledCn>,
    /// Request lane per [`cns`](Self::cns) entry (index-aligned).
    pub cn_req: Vec<usize>,
    pub comms: Vec<CommEvent>,
    /// Request lane per [`comms`](Self::comms) entry.
    pub comm_req: Vec<usize>,
    pub drams: Vec<DramEvent>,
    /// Request lane per [`drams`](Self::drams) entry.
    pub dram_req: Vec<usize>,
    /// Per-link occupancy, in the topology's link order.
    pub link_stats: Vec<LinkStat>,
    pub metrics: ScheduleMetrics,
    pub memtrace: MemTrace,
    /// Busy cycles per core, by core id.
    pub core_busy: Vec<u64>,
    /// Per-request completion frontier (last CN end or off-chip store
    /// end), in request order.
    pub request_end: Vec<u64>,
    /// How many chip partitions ran concurrently to produce this
    /// outcome: 1 for the sequential loop (including parallel-core
    /// fallbacks), the busy-chip count when the partition-parallel core
    /// engaged.  Purely observational — outcomes are bit-identical
    /// either way.
    pub partitions: usize,
    /// DRAM weight fetches performed (per-core trackers summed);
    /// identical for the sequential and parallel paths.
    pub weight_fetches: u64,
    /// FIFO weight evictions performed; identical for the sequential
    /// and parallel paths.
    pub weight_evictions: u64,
    /// Why the simulation ran sequentially; `None` when the
    /// chip-partitioned parallel core engaged.
    pub fallback: Option<FallbackReason>,
}

impl SimOutcome {
    /// Build the flight-recorder [`RunReport`](crate::obs::RunReport)
    /// for this outcome: engine totals, the busiest links (top 8, named
    /// from the topology), and a snapshot of the global
    /// counters/histograms at report time.
    pub(crate) fn report(&self, arch: &Accelerator) -> crate::obs::RunReport {
        let makespan = self.metrics.latency_cc;
        let mut idx: Vec<usize> = (0..self.link_stats.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(self.link_stats[i].busy_cycles));
        let links = idx
            .into_iter()
            .take(8)
            .filter(|&i| self.link_stats[i].busy_cycles > 0)
            .map(|i| crate::obs::LinkLoad {
                name: arch.topology.links()[i].name.clone(),
                busy_cc: self.link_stats[i].busy_cycles,
                bytes: self.link_stats[i].bytes_moved,
                util: if makespan > 0 {
                    self.link_stats[i].busy_cycles as f64 / makespan as f64
                } else {
                    0.0
                },
            })
            .collect();
        let mut r = crate::obs::RunReport {
            decisions: self.cns.len() as u64,
            comm_transfers: self.comms.len() as u64,
            dram_transfers: self.drams.len() as u64,
            weight_fetches: self.weight_fetches,
            weight_evictions: self.weight_evictions,
            partitions: self.partitions,
            // a one-shot / inline sequential loop leaves `fallback`
            // unset; in a report, partitions == 1 always means the
            // sequential loop ran
            fallback: self
                .fallback
                .or((self.partitions <= 1).then_some(FallbackReason::SequentialConfig)),
            makespan_cc: makespan,
            links,
            ..Default::default()
        };
        r.capture_globals();
        r
    }
}

/// Concatenate per-tenant DRAM weight-fetch tables into the global
/// `(tenant, layer)`-indexed table the core consumes; tenant *t*'s
/// layers start at the sum of the preceding tenants' layer counts
/// (= [`SimTenant::layer_off`]).
pub fn global_wgt_fetch(scheds: &[Scheduler]) -> Vec<u64> {
    let mut g = Vec::new();
    for s in scheds {
        g.extend_from_slice(&s.wgt_fetch_cc);
    }
    g
}

/// Observes candidate-pool insertions during a simulation.  The
/// recorder is a monomorphized type parameter of the loop, so the
/// no-op [`NoRecord`] keeps the normal (non-traced) path free of any
/// bookkeeping cost.
pub(crate) trait SimRecorder {
    /// A CN of global layer `gl` entered a candidate pool; the first
    /// scheduling decision that can observe it has index
    /// `visible_from`.
    fn inserted(&mut self, gl: LayerId, visible_from: usize);
}

/// The zero-cost recorder of the normal path.
pub(crate) struct NoRecord;

impl SimRecorder for NoRecord {
    #[inline(always)]
    fn inserted(&mut self, _gl: LayerId, _visible_from: usize) {}
}

/// Records, per global layer, the minimum insertion-visibility index of
/// its candidates — the earliest scheduling decision that could depend
/// on that layer's core assignment.
pub(crate) struct TouchTracer {
    pub touch: Vec<usize>,
}

impl TouchTracer {
    pub fn new(n_layers: usize) -> TouchTracer {
        TouchTracer { touch: vec![usize::MAX; n_layers] }
    }
}

impl SimRecorder for TouchTracer {
    #[inline]
    fn inserted(&mut self, gl: LayerId, visible_from: usize) {
        if visible_from < self.touch[gl.0] {
            self.touch[gl.0] = visible_from;
        }
    }
}

/// Mutable state of one in-flight request lane.
#[derive(Clone)]
pub(crate) struct Lane {
    pub(crate) tenant: usize,
    /// Arrival sequence number of the request this lane serves.  On the
    /// eager path it equals the lane's position in `SimState::lanes`;
    /// the streaming driver (`super::streaming`) retires lanes by
    /// `swap_remove`, so every arbitration key and event tag reads this
    /// carried value instead of the (unstable) vector position.
    pub(crate) seq: usize,
    pub(crate) release: u64,
    /// Absolute deadline carried from the request (the EDF key).
    pub(crate) deadline_abs: Option<u64>,
    pub(crate) sched: Vec<Option<ScheduledCn>>,
    pub(crate) pending: Vec<usize>,
    pub(crate) pool: CandidatePool,
    /// Completion frontier: last CN end or off-chip store end.
    pub(crate) last_end: u64,
}

/// The complete mutable state of one in-flight simulation: every
/// resource clock, event log, candidate pool and counter the loop
/// touches.  `Clone` freezes it into a resumable checkpoint
/// ([`SimSnapshot`]); nothing outside this struct (and the immutable
/// [`SimContext`]) influences a decision, so a clone resumes
/// bit-identically.
#[derive(Clone)]
pub(crate) struct SimState {
    pub(crate) core_avail: Vec<u64>,
    pub(crate) core_busy: Vec<u64>,
    pub(crate) links: LinkSet,
    pub(crate) weights: Vec<WeightTracker>,
    pub(crate) evicted: Vec<LayerId>,
    pub(crate) lanes: Vec<Lane>,
    pub(crate) trace: MemTrace,
    pub(crate) cns: Vec<ScheduledCn>,
    pub(crate) cn_req: Vec<usize>,
    pub(crate) comms: Vec<CommEvent>,
    pub(crate) comm_req: Vec<usize>,
    pub(crate) drams: Vec<DramEvent>,
    pub(crate) dram_req: Vec<usize>,
    pub(crate) breakdown: EnergyBreakdown,
    pub(crate) act_cap: f64,
    pub(crate) act_occ: f64,
    /// Virtual admission clock (see [`SimContext::step`]).
    pub(crate) now: u64,
    /// Scratch for the arbitration scan; contents are dead between
    /// steps.
    pub(crate) cands: Vec<(usize, u64)>,
    /// Scheduling decisions executed so far.
    pub(crate) decisions: usize,
}

impl SimState {
    /// Any lane still has schedulable candidates.
    pub(crate) fn has_work(&self) -> bool {
        self.lanes.iter().any(|l| l.pool.len() > 0)
    }

    pub(crate) fn decisions(&self) -> usize {
        self.decisions
    }
}

/// An opaque resumable checkpoint of one in-flight simulation, frozen
/// between two scheduling decisions.  The module docs of
/// `scheduler::sim` spell out when a snapshot taken under one core
/// allocation may be resumed under another.
#[derive(Clone)]
pub struct SimSnapshot {
    pub(crate) state: SimState,
}

impl SimSnapshot {
    /// Number of scheduling decisions already executed in this state.
    pub fn decisions(&self) -> usize {
        self.state.decisions
    }
}

/// The divergence-tracking byproduct of a traced run
/// (`Scheduler::run_traced`): per-layer first-observation indices plus
/// a grid of resumable snapshots.  This is what the GA's delta cache
/// stores per simulated parent genome.
#[derive(Clone)]
pub struct ScheduleSegments {
    /// Per (global) layer: index of the first scheduling decision that
    /// could observe a candidate of that layer (`usize::MAX` if none
    /// ever pooled — impossible for complete runs, but kept total).
    pub(crate) touch: Vec<usize>,
    /// Snapshots in increasing decision order, `Arc`-shared so a child
    /// run inherits its parent's prefix without copying.
    pub(crate) snaps: Vec<Arc<SimSnapshot>>,
}

impl ScheduleSegments {
    /// Index of the first scheduling decision that could depend on any
    /// layer whose core differs between allocations `a` and `b` —
    /// decisions before it are bit-identical under either allocation.
    /// `usize::MAX` when the allocations are effectively identical.
    pub fn divergence(&self, a: &[CoreId], b: &[CoreId]) -> usize {
        assert_eq!(a.len(), b.len(), "allocations over the same layers");
        assert_eq!(a.len(), self.touch.len(), "one touch index per layer");
        let mut d = usize::MAX;
        for (l, (x, y)) in a.iter().zip(b).enumerate() {
            if x != y {
                d = d.min(self.touch[l]);
            }
        }
        d
    }

    /// The deepest snapshot whose decision count is **strictly** below
    /// `divergence` (strict: a candidate of a changed layer inserted at
    /// the divergence decision itself would bake the old core's
    /// readiness into the pool).  `None` when no snapshot qualifies —
    /// the caller falls back to a cold run.
    pub fn resume_point(&self, divergence: usize) -> Option<&Arc<SimSnapshot>> {
        self.snaps
            .iter()
            .filter(|s| s.decisions() < divergence)
            .max_by_key(|s| s.decisions())
    }

    /// All snapshots, in increasing decision order.
    pub fn snapshots(&self) -> &[Arc<SimSnapshot>] {
        &self.snaps
    }
}

impl SimContext<'_> {
    /// Run the event-driven co-schedule over every lane.
    ///
    /// With an effective [`sim_threads`](Self::sim_threads) above 1 the
    /// partition-parallel core (`super::parsim`) is tried first: lanes
    /// are partitioned by the chip of their allocation, each chip's
    /// sub-simulation runs on its own worker thread, and the
    /// per-partition outcomes are merged by replaying the sequential
    /// arbitration over the recorded decision streams.  Whenever the
    /// parallel core cannot prove the merge exact it reports a typed
    /// [`FallbackReason`] and the sequential loop below runs instead,
    /// so the outcome is **bit-identical** for every thread count
    /// (pinned by `rust/tests/parallel_sim_equivalence.rs`).
    pub fn simulate(&self) -> SimOutcome {
        let threads = if self.sim_threads > 0 {
            self.sim_threads
        } else {
            crate::util::sim_thread_count()
        };
        let fallback = if threads > 1 {
            match super::parsim::try_parallel(self, threads) {
                Ok(out) => {
                    crate::obs::count(crate::obs::Counter::ParsimEngaged, 1);
                    return out;
                }
                Err(reason) => {
                    crate::obs::count(crate::obs::Counter::ParsimFallbacks, 1);
                    reason
                }
            }
        } else {
            FallbackReason::SequentialConfig
        };
        let _span = crate::obs::span_here("sim", "simulate");
        let mut rec = NoRecord;
        let mut st = self.init(&mut rec);
        while st.has_work() {
            self.step(&mut st, &mut rec);
        }
        let mut out = self.finish(st);
        out.fallback = Some(fallback);
        out
    }

    /// Build the initial [`SimState`]: fresh resource clocks and every
    /// zero-predecessor CN pooled (insertion visibility 0).
    pub(crate) fn init<R: SimRecorder>(&self, rec: &mut R) -> SimState {
        self.init_owned(rec, None)
    }

    /// Like [`init`](Self::init), but when `owned` is given, only the
    /// lanes it marks get their zero-predecessor CNs pooled — the
    /// others exist with permanently empty pools, so [`has_work`] and
    /// the arbitration scan skip them.  This is how the
    /// partition-parallel core (`super::parsim`) builds one sub-state
    /// per chip over the *same* lane indexing as the sequential run.
    ///
    /// [`has_work`]: SimState::has_work
    pub(crate) fn init_owned<R: SimRecorder>(
        &self,
        rec: &mut R,
        owned: Option<&[bool]>,
    ) -> SimState {
        let n_cores = self.arch.cores.len();
        let weights: Vec<WeightTracker> =
            self.arch.cores.iter().map(|c| WeightTracker::new(c.wgt_mem_bytes)).collect();

        let mut lanes: Vec<Lane> = self
            .requests
            .iter()
            .enumerate()
            .map(|(seq, r)| {
                let s = self.tenants[r.tenant].sched;
                let n = s.graph.len();
                Lane {
                    tenant: r.tenant,
                    seq,
                    release: r.release,
                    deadline_abs: r.deadline_abs,
                    sched: vec![None; n],
                    pending: (0..n)
                        .map(|i| s.graph.pred_count(CnId(i)) + s.gate_preds[i].len())
                        .collect(),
                    pool: CandidatePool::new(n, n_cores),
                    last_end: r.release,
                }
            })
            .collect();
        let total_cns: usize = lanes.iter().map(|l| l.sched.len()).sum();
        for (ri, lane) in lanes.iter_mut().enumerate() {
            if owned.is_some_and(|o| !o[ri]) {
                continue;
            }
            let t = &self.tenants[lane.tenant];
            for i in 0..t.sched.graph.len() {
                if lane.pending[i] == 0 {
                    add_candidate(t, lane, CnId(i), &weights, self.wgt_fetch_g, rec, 0);
                }
            }
        }

        // Pooled activation occupancy in scheduling order, used for
        // backpressure: producers are not scheduled arbitrarily far
        // ahead of their consumers when the on-chip activation capacity
        // would overflow (the pool's memory-full fallback then drains
        // the deepest ready CNs first).
        let act_cap: f64 = self.arch.cores.iter().map(|c| c.act_mem_bytes as f64).sum();

        SimState {
            core_avail: vec![0u64; n_cores],
            core_busy: vec![0u64; n_cores],
            links: LinkSet::new(&self.arch.topology),
            weights,
            evicted: Vec::new(),
            lanes,
            trace: MemTrace::new(),
            cns: Vec::with_capacity(total_cns),
            cn_req: Vec::with_capacity(if self.tag_events { total_cns } else { 0 }),
            comms: Vec::new(),
            comm_req: Vec::new(),
            drams: Vec::new(),
            dram_req: Vec::new(),
            breakdown: EnergyBreakdown::default(),
            act_cap,
            act_occ: 0.0,
            now: 0,
            cands: Vec::new(),
            decisions: 0,
        }
    }

    /// Execute one scheduling decision, returning the position (in
    /// `st.lanes`) of the lane that received it.  The caller guarantees
    /// [`SimState::has_work`]; candidates inserted here become visible
    /// from decision `st.decisions + 1`.
    pub(crate) fn step<R: SimRecorder>(&self, st: &mut SimState, rec: &mut R) -> usize {
        let topo = &self.arch.topology;
        let SimState {
            core_avail,
            core_busy,
            links,
            weights,
            evicted,
            lanes,
            trace,
            cns,
            cn_req,
            comms,
            comm_req,
            drams,
            dram_req,
            breakdown,
            act_cap,
            act_occ,
            now,
            cands,
            decisions,
        } = st;
        let act_cap = *act_cap;
        // candidates inserted during this decision first influence the
        // next one
        let vis = *decisions + 1;

        // With a single lane the arbitration below always picks lane 0,
        // so the one-shot path (the GA's per-fitness hot loop) skips the
        // heap peek and key construction entirely; the pool pop itself
        // discards the stale heap entries the peek would have, so the
        // picks are identical.
        let ri = if lanes.len() == 1 {
            0
        } else {
            // --- inter-request arbitration ---------------------------
            cands.clear();
            let mut min_eff = u64::MAX;
            for (ri, l) in lanes.iter_mut().enumerate() {
                if l.pool.len() == 0 {
                    continue;
                }
                let eff = l.pool.peek_min_eff().expect("nonempty pool has a minimum");
                min_eff = min_eff.min(eff);
                cands.push((ri, eff));
            }
            debug_assert!(!cands.is_empty(), "step called with work available");
            // Virtual admission clock: monotonically tracks the
            // earliest time any schedulable candidate could start.
            // Deadline- and priority-preference only applies to
            // requests *released* by `now`, so a future arrival can
            // never pre-empt ready work and leave cores idle (causal,
            // work-conserving arbitration).  The request achieving the
            // global minimum readiness is always released (its
            // readiness is >= its release), so an eligible request
            // always exists.
            *now = (*now).max(min_eff);

            let mut best: Option<((u64, u64, u64), usize)> = None;
            for &(ri, eff) in cands.iter() {
                let l = &lanes[ri];
                if l.release > *now {
                    continue; // not yet arrived: ineligible for preference
                }
                // Keys read the lane-carried seq/deadline (not the
                // vector position), so the streaming driver's lane
                // retirement cannot perturb arbitration.  On the eager
                // path seq == position, so nothing changes there.
                let key = match self.arbitration {
                    Arbitration::Fifo => (0, eff, l.seq as u64),
                    Arbitration::Priority => {
                        (self.tenants[l.tenant].prio_rank, eff, l.seq as u64)
                    }
                    Arbitration::Edf => (l.deadline_abs.unwrap_or(u64::MAX), eff, l.seq as u64),
                };
                let better = match best {
                    None => true,
                    Some((k, _)) => key < k,
                };
                if better {
                    best = Some((key, ri));
                }
            }
            best.expect("a released request always exists").1
        };

        // --- one scheduling decision over the chosen lane's graph ---
        let rekey = {
            let lane = &mut lanes[ri];
            // event tags carry the request's seq (== position on the
            // eager path) so streamed retained runs tag identically
            let seq = lane.seq;
            let t = &self.tenants[lane.tenant];
            let s = t.sched;
            let alloc = t.alloc;
            let cn_id = if self.linear_pool {
                lane.pool.pop_linear(t.pool_priority, *act_occ, act_cap)
            } else {
                match t.pool_priority {
                    SchedulePriority::Latency => lane.pool.pop_latency(*act_occ, act_cap),
                    SchedulePriority::Memory => lane.pool.pop_memory(*act_occ, act_cap),
                }
            }
            .expect("arbitration picked a nonempty pool");
            let cn = s.graph.cns.node(cn_id);
            let layer = s.workload.layer(cn.layer);
            let core_id = alloc[cn.layer.0];
            let core = self.arch.core(core_id);

            // 1) incoming data: same-core preds gate by finish time;
            //    cross-core preds need a routed communication node
            //    occupying every interconnect link between the two
            //    cores; a request starts no earlier than its release
            let mut data_ready = lane.release;
            for e in s.graph.pred_edges(cn_id) {
                let p = lane.sched[e.from.0].expect("pred scheduled");
                match e.kind {
                    EdgeKind::Order => data_ready = data_ready.max(p.end),
                    EdgeKind::Data => {
                        if p.core == core_id || e.bytes == 0 {
                            data_ready = data_ready.max(p.end);
                        } else {
                            let route = topo.core_route(p.core, core_id);
                            let (cs, ce) = links.transfer(route, p.end, e.bytes);
                            comms.push(CommEvent {
                                from_core: p.core,
                                to_core: core_id,
                                start: cs,
                                end: ce,
                                bytes: e.bytes,
                                links: route.into(),
                            });
                            if self.tag_events {
                                comm_req.push(seq);
                            }
                            breakdown.noc_pj +=
                                e.bytes as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                            // consumer-side copy allocated at comm start
                            trace.push(cs, core_id, e.bytes as f64);
                            *act_occ += e.bytes as f64;
                            // producer copy freed once the transfer ends
                            let pf = s.fanout[s.graph.cns.node(e.from).layer.0];
                            trace.push(ce, p.core, -(e.bytes as f64) / pf);
                            *act_occ = (*act_occ - e.bytes as f64 / pf).max(0.0);
                            data_ready = data_ready.max(ce);
                        }
                    }
                }
            }

            // 1b) bounded-buffer gates: wait for the gating consumers
            for g in &s.gate_preds[cn_id.0] {
                data_ready = data_ready.max(lane.sched[g.0].expect("gate scheduled").end);
            }

            // 2) the weight-position operand, fetched through the
            //    nearest DRAM port.  Resident weights go through the
            //    per-core tracker keyed by the global (tenant, layer)
            //    id (so requests of the same tenant share residency,
            //    and a fetch rekeys every lane's pool); a MatMul
            //    without an in-graph B producer instead streams its
            //    B operand (the LLM-decode KV-cache read) on EVERY
            //    CN — zero resident weights, so it bypasses the
            //    tracker, never rekeys, never amortizes, and leaves
            //    no memory-trace footprint (consumed on the fly).
            let gl = LayerId(t.layer_off + cn.layer.0);
            let mut weights_ready = 0u64;
            let mut rekey = None;
            let fetch = if layer.streams_b_from_dram() {
                layer.matmul_b_bytes()
            } else {
                let wbytes = layer.weight_bytes();
                if wbytes > 0 {
                    let f = weights[core_id.0].require_evicting(gl, wbytes, evicted);
                    if f > 0 {
                        // residency on this core changed for EVERY
                        // lane watching it; re-keyed after this
                        // lane's borrow is released
                        rekey = Some((core_id.0, gl));
                    }
                    f
                } else {
                    0
                }
            };
            if fetch > 0 {
                let route = topo.dram_load_route(core_id);
                let (ds, de) = links.transfer(route, lane.release, fetch);
                drams.push(DramEvent {
                    core: core_id,
                    start: ds,
                    end: de,
                    bytes: fetch,
                    kind: DramKind::WeightFetch,
                    links: route.into(),
                });
                if self.tag_events {
                    dram_req.push(seq);
                }
                breakdown.dram_pj += fetch as f64 * 8.0 * topo.route_dram_pj_per_bit(route);
                breakdown.noc_pj += fetch as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                if let CoreKind::Aimc { weight_load_pj, .. } = core.kind {
                    // an analog array must (re)program the operand
                    // before it can multiply by it
                    breakdown.onchip_pj += fetch as f64 * 8.0 * weight_load_pj;
                }
                weights_ready = de;
            }

            // 3) first-layer input activations come from DRAM
            let mut input_ready = 0u64;
            let fresh = s.fresh_in_bytes[cn_id.0];
            if fresh > 0 {
                let route = topo.dram_load_route(core_id);
                let (ds, de) = links.transfer(route, lane.release, fresh);
                drams.push(DramEvent {
                    core: core_id,
                    start: ds,
                    end: de,
                    bytes: fresh,
                    kind: DramKind::ActFetch,
                    links: route.into(),
                });
                if self.tag_events {
                    dram_req.push(seq);
                }
                breakdown.dram_pj += fresh as f64 * 8.0 * topo.route_dram_pj_per_bit(route);
                breakdown.noc_pj += fresh as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                trace.push(ds, core_id, fresh as f64);
                *act_occ += fresh as f64;
                input_ready = de;
            }

            // 4) execute
            let cost = s.costs.cn_cost(cn, core_id);
            let start = core_avail[core_id.0]
                .max(data_ready)
                .max(weights_ready)
                .max(input_ready);
            let end = start + cost.compute_cycles;
            core_avail[core_id.0] = end;
            core_busy[core_id.0] += cost.compute_cycles;
            breakdown.mac_pj += cost.mac_energy_pj;
            breakdown.onchip_pj += cost.energy_pj - cost.mac_energy_pj;

            // 5) memory trace: outputs allocated at start,
            //    discardable inputs freed at finish per producer
            trace.push(start, core_id, cn.output_bytes as f64);
            *act_occ += cn.output_bytes as f64;
            if layer.predecessors.is_empty() {
                trace.push(end, core_id, -(cn.discard_input_bytes as f64));
                *act_occ = (*act_occ - cn.discard_input_bytes as f64).max(0.0);
            } else {
                for (pi, &p) in layer.predecessors.iter().enumerate() {
                    let share = match layer.op {
                        OpType::Concat => {
                            cn.discard_input_bytes as f64 * s.workload.layer(p).k as f64
                                / layer.c as f64
                        }
                        // MatMul operand B: streamed in once for
                        // the whole layer (its bytes ride the first
                        // CN's edges), held while the layer runs,
                        // and released evenly across the CNs
                        OpType::MatMul if pi > 0 => {
                            s.workload.layer(p).output_bytes() as f64
                                / s.graph.cns.layer_cns(cn.layer).len() as f64
                        }
                        _ => cn.discard_input_bytes as f64,
                    };
                    let p_core = alloc[p.0];
                    if p_core == core_id {
                        // shared physical buffer on the producer's core
                        trace.push(end, core_id, -share / s.fanout[p.0]);
                        *act_occ = (*act_occ - share / s.fanout[p.0]).max(0.0);
                    } else {
                        // our private copy from the communication
                        trace.push(end, core_id, -share);
                        *act_occ = (*act_occ - share).max(0.0);
                    }
                }
            }

            // 6) sink outputs stream to DRAM via the nearest port
            if s.workload.successors(cn.layer).is_empty() {
                let route = topo.dram_store_route(core_id);
                let (ds, de) = links.transfer(route, end, cn.output_bytes);
                drams.push(DramEvent {
                    core: core_id,
                    start: ds,
                    end: de,
                    bytes: cn.output_bytes,
                    kind: DramKind::ActStore,
                    links: route.into(),
                });
                if self.tag_events {
                    dram_req.push(seq);
                }
                breakdown.dram_pj +=
                    cn.output_bytes as f64 * 8.0 * topo.route_dram_pj_per_bit(route);
                breakdown.noc_pj +=
                    cn.output_bytes as f64 * 8.0 * topo.route_noc_pj_per_bit(route);
                trace.push(de, core_id, -(cn.output_bytes as f64));
                *act_occ = (*act_occ - cn.output_bytes as f64).max(0.0);
                lane.last_end = lane.last_end.max(de);
            }

            let placed = ScheduledCn { cn: cn_id, core: core_id, start, end };
            lane.sched[cn_id.0] = Some(placed);
            lane.last_end = lane.last_end.max(end);
            cns.push(placed);
            if self.tag_events {
                cn_req.push(seq);
            }

            // 7) release successors within this lane (data/order
            //    edges + buffer gates)
            for e in s.graph.succ_edges(cn_id) {
                lane.pending[e.to.0] -= 1;
                if lane.pending[e.to.0] == 0 {
                    add_candidate(t, lane, e.to, weights, self.wgt_fetch_g, rec, vis);
                }
            }
            for &g in &s.gate_succs[cn_id.0] {
                lane.pending[g.0] -= 1;
                if lane.pending[g.0] == 0 {
                    add_candidate(t, lane, g, weights, self.wgt_fetch_g, rec, vis);
                }
            }
            rekey
        };

        // --- propagate a residency change to every lane's pool ------
        if let Some((core, fetched)) = rekey {
            let evicted = &*evicted;
            for l in lanes.iter_mut() {
                l.pool.rekey_core(core, |gl| {
                    if gl == fetched {
                        Some(0)
                    } else if evicted.contains(&gl) {
                        Some(self.wgt_fetch_g[gl.0])
                    } else {
                        None
                    }
                });
            }
        }

        *decisions += 1;
        ri
    }

    /// Aggregate a drained [`SimState`] into the outcome.
    pub(crate) fn finish(&self, st: SimState) -> SimOutcome {
        debug_assert!(
            st.lanes.iter().all(|l| l.sched.iter().all(|s| s.is_some())),
            "all CNs of all requests scheduled"
        );
        let request_end = st.lanes.iter().map(|l| l.last_end).collect();
        let multi_lane = st.lanes.len() > 1;
        self.assemble_outcome(st, request_end, multi_lane)
    }

    /// Shared back half of [`finish`](Self::finish): aggregate metrics
    /// over a drained state whose per-request completion frontier is
    /// supplied by the caller.  The streaming driver
    /// (`super::streaming`) retires lanes as their requests complete, so
    /// it collects `request_end` at retirement time (in seq order) and
    /// passes `multi_lane` for the whole run rather than for the final
    /// (possibly shrunken) live set.
    pub(crate) fn assemble_outcome(
        &self,
        st: SimState,
        request_end: Vec<u64>,
        multi_lane: bool,
    ) -> SimOutcome {
        let SimState {
            core_busy,
            links,
            trace,
            cns,
            cn_req,
            comms,
            comm_req,
            drams,
            dram_req,
            mut breakdown,
            weights,
            decisions,
            ..
        } = st;

        // --- aggregate metrics ------------------------------------------
        let compute_end = cns.iter().map(|s| s.end).max().unwrap_or(0);
        let io_end = drams
            .iter()
            .map(|d| d.end)
            .chain(comms.iter().map(|c| c.end))
            .max()
            .unwrap_or(0);
        let latency = compute_end.max(io_end);
        let avg_core_util = self.core_utilization(&core_busy, latency);

        // Peak memory + activation-spill accounting in a single
        // time-ordered pass (post-scheduling, like the paper's
        // memory-usage tracing).  Activation bytes that land above the
        // pooled SRAM capacity must take a round trip through DRAM:
        // charge store+reload energy and extend the makespan to the
        // DRAM-port-bound floor.
        let (peak, spill_bytes) = peak_and_spill(&trace, self.arch);
        let latency = self.apply_spill(&links, &mut breakdown, latency, spill_bytes);

        let metrics = ScheduleMetrics {
            latency_cc: latency,
            energy_pj: breakdown.total(),
            peak_mem_bytes: peak,
            breakdown,
            avg_core_util,
        };

        let link_stats: Vec<LinkStat> = links
            .stats()
            .into_iter()
            .map(|(busy_cycles, bytes_moved)| LinkStat { busy_cycles, bytes_moved })
            .collect();

        let weight_fetches: u64 = weights.iter().map(|w| w.fetches).sum();
        let weight_evictions: u64 = weights.iter().map(|w| w.evictions).sum();

        self.count_run_obs(
            decisions,
            multi_lane,
            comms.len() as u64,
            drams.len() as u64,
            weight_fetches,
            weight_evictions,
            latency,
            &link_stats,
        );

        SimOutcome {
            cns,
            cn_req,
            comms,
            comm_req,
            drams,
            dram_req,
            link_stats,
            metrics,
            memtrace: trace,
            core_busy,
            request_end,
            partitions: 1,
            weight_fetches,
            weight_evictions,
            fallback: None,
        }
    }

    /// Dense-core utilization over a makespan (shared by the eager and
    /// streaming aggregation paths).
    pub(crate) fn core_utilization(&self, core_busy: &[u64], latency: u64) -> f64 {
        let dense_busy: u64 = self
            .arch
            .cores
            .iter()
            .filter(|c| !c.is_simd())
            .map(|c| core_busy[c.id.0])
            .sum();
        let dense_count = self.arch.cores.iter().filter(|c| !c.is_simd()).count() as f64;
        if latency > 0 {
            dense_busy as f64 / (latency as f64 * dense_count)
        } else {
            0.0
        }
    }

    /// Charge the DRAM round trip for activation bytes spilled above
    /// the pooled SRAM capacity and extend the makespan to the
    /// DRAM-port-bound floor.  Identical formula for the eager and
    /// streaming paths (the streaming driver folds its memory trace
    /// incrementally but reaches the same `spill_bytes`).
    pub(crate) fn apply_spill(
        &self,
        links: &LinkSet,
        breakdown: &mut EnergyBreakdown,
        latency: u64,
        spill_bytes: f64,
    ) -> u64 {
        let topo = &self.arch.topology;
        let mut latency = latency;
        if spill_bytes > 0.5 {
            breakdown.dram_pj += 2.0 * spill_bytes * 8.0 * topo.spill_dram_pj_per_bit();
            let extra_port = (2.0 * spill_bytes * 8.0 / topo.dram_bw_bits() as f64) as u64;
            let dram_busy = topo
                .dram_channel_links()
                .map(|l| links.busy_cycles(l))
                .max()
                .unwrap_or(0);
            latency = latency.max(dram_busy + extra_port);
        }
        latency
    }

    /// Flight-recorder aggregation: one block per *run*, never per
    /// step, so the engine hot loop carries no instrumentation.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn count_run_obs(
        &self,
        decisions: usize,
        multi_lane: bool,
        comms: u64,
        drams: u64,
        weight_fetches: u64,
        weight_evictions: u64,
        latency: u64,
        link_stats: &[LinkStat],
    ) {
        if crate::obs::enabled() {
            use crate::obs::Counter as C;
            crate::obs::count(C::SimRuns, 1);
            crate::obs::count(C::SimDecisions, decisions as u64);
            if multi_lane {
                crate::obs::count(C::ArbitrationPicks, decisions as u64);
            }
            crate::obs::count(C::CommTransfers, comms);
            crate::obs::count(C::DramTransfers, drams);
            crate::obs::count(C::WeightFetches, weight_fetches);
            crate::obs::count(C::WeightEvictions, weight_evictions);
            if latency > 0 {
                for s in link_stats {
                    let pct = s.busy_cycles.saturating_mul(100) / latency;
                    crate::obs::hist(crate::obs::Hist::LinkBusyPct, pct);
                }
            }
        }
    }
}

/// Register a CN whose predecessors (and buffer gates) are all
/// scheduled as a candidate of its lane's pool.
///
/// `ready` is the time the last predecessor finished, floored at the
/// lane's release; the *effective* readiness additionally charges the
/// layer's DRAM weight-fetch time when the weights are not resident on
/// its allocated core (under the global `(tenant, layer)` id) — this
/// keeps CNs of a resident layer running back to back and avoids
/// weight thrash when several layers share a core.  CNs with a nonzero
/// fetch are watched in the pool's per-core bucket so residency
/// changes re-key them.  `vis` is the insertion-visibility index
/// reported to the recorder (see [`SimRecorder`]).
pub(super) fn add_candidate<R: SimRecorder>(
    t: &SimTenant,
    lane: &mut Lane,
    id: CnId,
    weights: &[WeightTracker],
    wgt_fetch_g: &[u64],
    rec: &mut R,
    vis: usize,
) {
    let s = t.sched;
    let ready = s
        .graph
        .pred_edges(id)
        .map(|e| lane.sched[e.from.0].expect("pred scheduled").end)
        .chain(
            s.gate_preds[id.0]
                .iter()
                .map(|g| lane.sched[g.0].expect("gate scheduled").end),
        )
        .max()
        .unwrap_or(lane.release);
    let cn = s.graph.cns.node(id);
    let core = t.alloc[cn.layer.0];
    let gl = LayerId(t.layer_off + cn.layer.0);
    let fetch = wgt_fetch_g[gl.0];
    let eff = if fetch == 0 || weights[core.0].is_resident(gl) { ready } else { ready + fetch };
    lane.pool.insert(id, gl, cn.idx, ready, eff, cn.output_bytes, core.0, fetch > 0);
    rec.inserted(gl, vis);
}
