//! Bounded-memory streaming serving driver.
//!
//! The eager scenario path expands every request of a run into a lane
//! up front ([`SimContext::init`]) and keeps every completed request's
//! events alive until [`SimContext::finish`] — O(total requests) live
//! state, which caps how long a serving trace can be simulated.  This
//! module drives the *same* [`SimContext::step`] loop over a lazily
//! admitted live set instead:
//!
//! - **admission** — requests are pulled from an arrival stream (in
//!   `(release, seq)` order) and injected as lanes only once the
//!   simulation actually needs them.  Injection is *mandatory* for
//!   every pending request with `release <= H`, where
//!   `H = max(now, m)` and `m` is the minimum effective readiness over
//!   the live pools: any lane still un-injected has
//!   `eff >= release > H >= m`, so it can neither lower the step's
//!   `min_eff` (the virtual-clock update) nor be eligible for
//!   preference (`release > now`) nor win a pick — the eager run would
//!   make the identical decision without it.  On top of the mandatory
//!   set, lanes are admitted early while the live set is smaller than
//!   the configured window (early admission is always exact: the eager
//!   path holds every lane from t = 0);
//! - **retirement** — a lane whose pool empties has scheduled its last
//!   CN (an incomplete lane always holds a ready candidate: the
//!   topologically first unscheduled CN has all predecessors scheduled,
//!   so it was pooled when its last predecessor completed).  The lane is
//!   `swap_remove`d, its completion folded through a caller callback,
//!   and its pool/schedule/event buffers freed — live state is
//!   O(live lanes x model size).  Arbitration keys and event tags read
//!   the lane-carried `seq`, so positions may shuffle freely;
//! - **event folding** (untraced mode) — completed CN/comm/DRAM events
//!   reduce to running end-time maxima and counts, and the memory
//!   trace is folded through an incremental [`peak_and_spill`]
//!   accumulator for every event older than the *frontier*
//!   `F = min(live releases, next arrival)`: every future event is
//!   pushed while scheduling a lane released at or after `F`, so the
//!   chunk of events below `F` is final.  Chunk-wise stable sorting +
//!   accumulation reproduces the eager path's single global pass
//!   bit-for-bit (strict time partition between chunks).
//!
//! With an unbounded window over a finite trace the driver injects
//! everything up front in seq order and replays the eager path's
//! decisions exactly — pinned by `rust/tests/streaming_equivalence.rs`
//! across every canned scenario and arbitration.
//!
//! One subtlety: [`SimContext::step`] skips the virtual-clock update on
//! its single-lane fast path.  The eager multi-request run never has a
//! single lane (lanes are never removed), but the streamed live set can
//! shrink to one — the driver re-applies `now = max(now, min_eff)`
//! before such steps so the clock evolves identically.
//!
//! [`peak_and_spill`]: super::engine::peak_and_spill

use crate::cn::CnId;

use super::memtrace::MemTrace;
use super::pool::CandidatePool;
use super::sim::{add_candidate, FallbackReason, Lane, NoRecord, SimContext, SimOutcome, SimState};
use super::LinkStat;
use crate::cost::ScheduleMetrics;

/// One request pulled from the arrival stream, in `(release, seq)`
/// order (the order [`Scenario::requests`] materializes).
///
/// [`Scenario::requests`]: crate::scenario::Scenario::requests
#[derive(Debug, Clone, Copy)]
pub struct StreamRequest {
    pub seq: usize,
    pub tenant: usize,
    pub release: u64,
    pub deadline_abs: Option<u64>,
}

/// A retired request's folded outcome, delivered to the caller the
/// moment its last CN completes.
#[derive(Debug, Clone, Copy)]
pub struct RetiredRequest {
    pub seq: usize,
    pub tenant: usize,
    pub release: u64,
    pub deadline_abs: Option<u64>,
    /// Completion frontier: last CN end or off-chip store end.
    pub completion: u64,
}

/// Streaming-driver knobs.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    /// Eager admission window: target size of the live set beyond the
    /// mandatory injections.  `0` admits only when the exactness rule
    /// demands it; `usize::MAX` reproduces the eager path's
    /// inject-everything-up-front behavior.  Every value yields the
    /// identical schedule — the window trades peak memory against
    /// admission-scan frequency.
    pub window: usize,
    /// Keep full event logs (CNs, comms, DRAMs, tags, memory trace) for
    /// a complete [`SimOutcome`] — O(total requests) memory, used by
    /// the equivalence tests and event-consuming reports.  When false,
    /// events fold into running aggregates and the outcome carries
    /// metrics/link stats only.
    pub retain_events: bool,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig { window: 64, retain_events: false }
    }
}

/// Live-set accounting of one streamed run.
#[derive(Debug, Clone, Copy, Default)]
pub struct LiveStats {
    /// Requests injected as lanes.
    pub admitted: u64,
    /// Requests retired (equals `admitted` after a complete run).
    pub retired: u64,
    /// High-water mark of the live lane set — the memory bound: peak
    /// live state is `live_peak` x model size, independent of trace
    /// length.
    pub live_peak: usize,
    /// High-water mark of the *arrived* live subset (release within the
    /// admission horizon) — the genuinely in-flight requests; the
    /// remainder of `live_peak` is eager admission, bounded by the
    /// window.
    pub inflight_peak: usize,
}

/// Fold decisions between admission scans in untraced mode: the scan is
/// O(live set), so batching keeps the amortized driver overhead small,
/// while event buffers stay bounded by the work a batch can generate.
const FOLD_EVERY: usize = 4096;

/// Drive a full streamed simulation: `stream` yields requests in
/// `(release, seq)` order, `on_retire` observes every completion.
/// `ctx.requests` must be empty (lanes come from the stream); with
/// `retain_events` the returned outcome is bit-identical to the eager
/// path's [`SimContext::simulate`] over the expanded request list.
pub(crate) fn simulate_stream<I, F>(
    ctx: &SimContext,
    stream: I,
    cfg: &StreamConfig,
    mut on_retire: F,
) -> (SimOutcome, LiveStats)
where
    I: Iterator<Item = StreamRequest>,
    F: FnMut(RetiredRequest),
{
    assert!(ctx.requests.is_empty(), "streamed lanes come from the stream");
    let _span = crate::obs::span_here("sim", "simulate_stream");
    let mut rec = NoRecord;
    let mut st = ctx.init(&mut rec);
    let mut stream = stream.peekable();
    let mut stats = LiveStats::default();
    let mut fold = FoldAcc::new(ctx);
    // retained mode: per-request completion frontier, collected at
    // retirement and re-sorted into seq order for the outcome
    let mut ends: Vec<(usize, u64)> = Vec::new();
    let mut multi = false;

    loop {
        // --- admission ------------------------------------------------
        while let Some(next) = stream.peek().copied() {
            let m = live_min_eff(&mut st);
            let mandatory = match m {
                // empty live set: forced, else no progress is possible
                None => true,
                Some(m) => next.release <= st.now.max(m),
            };
            if !mandatory {
                if st.lanes.len() >= cfg.window {
                    break;
                }
                // eager admissions never grow the live set past the
                // window; only mandatory (truly in-flight) ones can
                debug_assert!(st.lanes.len() < cfg.window);
            }
            stream.next();
            inject(ctx, &mut st, next, &mut rec);
            stats.admitted += 1;
        }
        stats.live_peak = stats.live_peak.max(st.lanes.len());
        if st.lanes.is_empty() {
            debug_assert!(stream.peek().is_none(), "admission always makes progress");
            break;
        }
        multi = multi || stats.admitted > 1 || stream.peek().is_some();

        // --- one decision ----------------------------------------------
        // The eager multi-request run always has >= 2 lanes, so step's
        // single-lane fast path never fires there; re-apply the
        // virtual-clock update it would skip when our live set is 1.
        if multi && st.lanes.len() == 1 {
            if let Some(eff) = st.lanes[0].pool.peek_min_eff() {
                st.now = st.now.max(eff);
            }
        }
        let arrived = st.lanes.iter().filter(|l| l.release <= st.now).count();
        stats.inflight_peak = stats.inflight_peak.max(arrived);
        let picked = ctx.step(&mut st, &mut rec);

        // --- retirement ------------------------------------------------
        if st.lanes[picked].pool.len() == 0 {
            let lane = st.lanes.swap_remove(picked);
            debug_assert!(
                lane.sched.iter().all(|s| s.is_some()),
                "empty pool implies a completed request"
            );
            stats.retired += 1;
            if cfg.retain_events {
                ends.push((lane.seq, lane.last_end));
            }
            on_retire(RetiredRequest {
                seq: lane.seq,
                tenant: lane.tenant,
                release: lane.release,
                deadline_abs: lane.deadline_abs,
                completion: lane.last_end,
            });
        }

        // --- bounded event folding -------------------------------------
        if !cfg.retain_events && st.decisions() % FOLD_EVERY == 0 {
            let frontier = fold_frontier(&st, stream.peek());
            fold.fold(&mut st, frontier);
        }
    }

    if crate::obs::enabled() {
        use crate::obs::Counter as C;
        crate::obs::count(C::ServingAdmitted, stats.admitted);
        crate::obs::count(C::ServingRetired, stats.retired);
        crate::obs::count_max(C::ServingLivePeak, stats.live_peak as u64);
    }

    let mut out = if cfg.retain_events {
        ends.sort_unstable();
        debug_assert!(ends.iter().enumerate().all(|(i, &(s, _))| i == s), "one end per seq");
        let request_end = ends.into_iter().map(|(_, e)| e).collect();
        ctx.assemble_outcome(st, request_end, multi)
    } else {
        fold.fold(&mut st, u64::MAX);
        assemble_folded(ctx, st, &fold, multi)
    };
    // The streaming driver is sequential by construction; stamp the
    // same fallback reason the eager path reports for `sim_threads ==
    // 1` so retained-mode outcomes stay field-for-field identical.
    out.fallback = Some(FallbackReason::SequentialConfig);
    (out, stats)
}

/// Minimum effective readiness over the live pools (the `m` of the
/// admission rule); `None` when the live set is empty.
fn live_min_eff(st: &mut SimState) -> Option<u64> {
    st.lanes
        .iter_mut()
        .filter_map(|l| l.pool.peek_min_eff())
        .min()
}

/// Inject one request as a fresh lane: identical construction to
/// [`SimContext::init`], but against the *current* weight residency —
/// which is exactly what the eager path's insert-then-rekey history
/// produces for this lane's candidates at this point in the run.
fn inject(ctx: &SimContext, st: &mut SimState, r: StreamRequest, rec: &mut NoRecord) {
    let t = &ctx.tenants[r.tenant];
    let s = t.sched;
    let n = s.graph.len();
    let mut lane = Lane {
        tenant: r.tenant,
        seq: r.seq,
        release: r.release,
        deadline_abs: r.deadline_abs,
        sched: vec![None; n],
        pending: (0..n)
            .map(|i| s.graph.pred_count(CnId(i)) + s.gate_preds[i].len())
            .collect(),
        pool: CandidatePool::new(n, ctx.arch.cores.len()),
        last_end: r.release,
    };
    let vis = st.decisions();
    for i in 0..n {
        if lane.pending[i] == 0 {
            add_candidate(t, &mut lane, CnId(i), &st.weights, ctx.wgt_fetch_g, rec, vis);
        }
    }
    st.lanes.push(lane);
}

/// Every future event's timestamp is at least the release of the lane
/// whose scheduling pushes it, so events strictly below the minimum
/// release over the live set and the next pending arrival are final.
fn fold_frontier(st: &SimState, next: Option<&StreamRequest>) -> u64 {
    st.lanes
        .iter()
        .map(|l| l.release)
        .chain(next.map(|r| r.release))
        .min()
        .unwrap_or(u64::MAX)
}

/// Running aggregates replacing the retained event logs in untraced
/// mode — everything [`SimContext::assemble_outcome`] derives from the
/// full vectors, accumulated incrementally.
struct FoldAcc {
    compute_end: u64,
    io_end: u64,
    n_comms: u64,
    n_drams: u64,
    /// Pooled activation capacity (the `cap` of `peak_and_spill`).
    cap: f64,
    occ: f64,
    peak: f64,
    spilled: f64,
}

impl FoldAcc {
    fn new(ctx: &SimContext) -> FoldAcc {
        FoldAcc {
            compute_end: 0,
            io_end: 0,
            n_comms: 0,
            n_drams: 0,
            cap: ctx.arch.cores.iter().map(|c| c.act_mem_bytes as f64).sum(),
            occ: 0.0,
            peak: 0.0,
            spilled: 0.0,
        }
    }

    /// Drain the state's event buffers into the aggregates.  CN, comm
    /// and DRAM events only contribute end-time maxima and counts, so
    /// they drain completely; memory-trace events participate in a
    /// time-ordered accumulation, so only the final chunk strictly
    /// below `frontier` folds (see the module docs for why chunk-wise
    /// folding is bit-exact).
    fn fold(&mut self, st: &mut SimState, frontier: u64) {
        for c in st.cns.drain(..) {
            self.compute_end = self.compute_end.max(c.end);
        }
        for c in st.comms.drain(..) {
            self.io_end = self.io_end.max(c.end);
            self.n_comms += 1;
        }
        for d in st.drams.drain(..) {
            self.io_end = self.io_end.max(d.end);
            self.n_drams += 1;
        }
        self.fold_trace(&mut st.trace, frontier);
    }

    /// Fold the memory-trace chunk strictly below `frontier`, exactly
    /// mirroring `peak_and_spill`'s stable `(time, delta)` sort and
    /// accumulation order.
    fn fold_trace(&mut self, trace: &mut MemTrace, frontier: u64) {
        let events = std::mem::take(&mut trace.events);
        let mut chunk: Vec<(u64, f64)> = Vec::new();
        for e in events {
            if e.time < frontier {
                chunk.push((e.time, e.delta));
            } else {
                trace.events.push(e);
            }
        }
        chunk.sort_by(|a, b| {
            a.0.cmp(&b.0).then(a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
        });
        for &(_, d) in &chunk {
            if d > 0.0 {
                let over = (self.occ + d - self.cap).max(0.0) - (self.occ - self.cap).max(0.0);
                self.spilled += over;
            }
            self.occ += d;
            self.peak = self.peak.max(self.occ);
        }
    }
}

/// The untraced-mode counterpart of [`SimContext::assemble_outcome`]:
/// metrics from the folded aggregates, empty event logs.
fn assemble_folded(
    ctx: &SimContext,
    st: SimState,
    fold: &FoldAcc,
    multi_lane: bool,
) -> SimOutcome {
    let SimState { core_busy, links, mut breakdown, weights, decisions, .. } = st;

    let latency = fold.compute_end.max(fold.io_end);
    let avg_core_util = ctx.core_utilization(&core_busy, latency);
    let latency = ctx.apply_spill(&links, &mut breakdown, latency, fold.spilled);

    let metrics = ScheduleMetrics {
        latency_cc: latency,
        energy_pj: breakdown.total(),
        peak_mem_bytes: fold.peak,
        breakdown,
        avg_core_util,
    };
    let link_stats: Vec<LinkStat> = links
        .stats()
        .into_iter()
        .map(|(busy_cycles, bytes_moved)| LinkStat { busy_cycles, bytes_moved })
        .collect();
    let weight_fetches: u64 = weights.iter().map(|w| w.fetches).sum();
    let weight_evictions: u64 = weights.iter().map(|w| w.evictions).sum();

    ctx.count_run_obs(
        decisions,
        multi_lane,
        fold.n_comms,
        fold.n_drams,
        weight_fetches,
        weight_evictions,
        latency,
        &link_stats,
    );

    SimOutcome {
        cns: Vec::new(),
        cn_req: Vec::new(),
        comms: Vec::new(),
        comm_req: Vec::new(),
        drams: Vec::new(),
        dram_req: Vec::new(),
        link_stats,
        metrics,
        memtrace: MemTrace::new(),
        core_busy,
        request_end: Vec::new(),
        partitions: 1,
        weight_fetches,
        weight_evictions,
        fallback: None,
    }
}
