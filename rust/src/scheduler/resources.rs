//! Shared-resource models: the routed interconnect link set and the
//! per-core weight-memory tracker with FIFO eviction.
//!
//! The seed carried two byte-identical FCFS resources (`Bus` and
//! `DramPort`); they are deduplicated into one [`FcfsLink`] primitive,
//! and the topology refactor generalizes the pair to a [`LinkSet`] —
//! one `FcfsLink` per [`Topology`](crate::arch::Topology) link, where a
//! transfer reserves **every** link on its route.

use std::collections::VecDeque;

use crate::arch::{LinkId, Topology};
use crate::workload::LayerId;

/// One first-come-first-serve interconnect link (paper Section III-E1's
/// shared-bus semantics, reused for every link of a routed topology).
///
/// Transfers are served in scheduling order; the link is a single
/// shared resource, so a transfer starts at `max(data_ready, free_at)`
/// and occupies the link for `ceil(bytes * 8 / bandwidth)` cycles.
///
/// All resource models ([`FcfsLink`], [`LinkSet`], [`WeightTracker`])
/// are plain-data and `Clone`: `Scheduler::run` builds a fresh set per
/// call, so concurrent per-genome simulations share nothing mutable —
/// `Clone` additionally lets callers snapshot/fork resource state
/// (e.g. for what-if probes) without reconstructing it.
#[derive(Debug, Clone)]
pub struct FcfsLink {
    bw_bits: u64,
    free_at: u64,
    pub busy_cycles: u64,
    pub bytes_moved: u64,
}

impl FcfsLink {
    pub fn new(bw_bits: u64) -> FcfsLink {
        FcfsLink { bw_bits: bw_bits.max(1), free_at: 0, busy_cycles: 0, bytes_moved: 0 }
    }

    /// Schedule a transfer that becomes ready at `ready`; returns
    /// (start, end).
    pub fn transfer(&mut self, ready: u64, bytes: u64) -> (u64, u64) {
        let start = ready.max(self.free_at);
        let dur = (bytes * 8).div_ceil(self.bw_bits);
        let end = start + dur;
        self.free_at = end;
        self.busy_cycles += dur;
        self.bytes_moved += bytes;
        (start, end)
    }

    pub fn free_at(&self) -> u64 {
        self.free_at
    }
}

/// The scheduler's view of a whole interconnect: one [`FcfsLink`] per
/// topology link.  A routed transfer starts when its data is ready
/// *and* every link along the route is free, runs at the route's
/// bottleneck bandwidth, and occupies all its links until it ends —
/// so multi-hop mesh/ring transfers contend with everything they
/// cross, and a `shared_bus` topology reduces exactly to the seed's
/// single-bus + single-DRAM-port behavior.
#[derive(Debug, Clone)]
pub struct LinkSet {
    links: Vec<FcfsLink>,
}

impl LinkSet {
    pub fn new(topology: &Topology) -> LinkSet {
        LinkSet {
            links: topology.links().iter().map(|l| FcfsLink::new(l.bw_bits)).collect(),
        }
    }

    /// Schedule a transfer over `route`; returns (start, end).
    pub fn transfer(&mut self, route: &[LinkId], ready: u64, bytes: u64) -> (u64, u64) {
        debug_assert!(!route.is_empty(), "transfer over an empty route");
        let mut start = ready;
        let mut bw = u64::MAX;
        for l in route {
            start = start.max(self.links[l.0].free_at);
            bw = bw.min(self.links[l.0].bw_bits);
        }
        let dur = (bytes * 8).div_ceil(bw.max(1));
        let end = start + dur;
        for l in route {
            let link = &mut self.links[l.0];
            link.free_at = end;
            link.busy_cycles += dur;
            link.bytes_moved += bytes;
        }
        (start, end)
    }

    pub fn busy_cycles(&self, link: LinkId) -> u64 {
        self.links[link.0].busy_cycles
    }

    pub fn bytes_moved(&self, link: LinkId) -> u64 {
        self.links[link.0].bytes_moved
    }

    pub fn free_at(&self, link: LinkId) -> u64 {
        self.links[link.0].free_at
    }

    /// Per-link (busy_cycles, bytes_moved) snapshot, in link-id order.
    pub fn stats(&self) -> Vec<(u64, u64)> {
        self.links.iter().map(|l| (l.busy_cycles, l.bytes_moved)).collect()
    }

    /// Copy one link's complete state (clock + counters) from another
    /// set.  The partition-parallel merge reassembles a global
    /// [`LinkSet`] by adopting each link from the chip partition that
    /// owns it (links of idle chips and untouched inter-chip links keep
    /// their fresh state).
    pub(crate) fn adopt_link(&mut self, other: &LinkSet, l: LinkId) {
        self.links[l.0] = other.links[l.0].clone();
    }
}

/// Per-core on-chip weight-memory tracker (paper Section III-E2).
///
/// Weights are kept per layer; when a CN of a layer whose weights are
/// not resident is scheduled, the fetch is charged and older layers'
/// weights are evicted first-in-first-out until the new set fits.
#[derive(Debug, Clone)]
pub struct WeightTracker {
    capacity: u64,
    used: u64,
    resident: VecDeque<(LayerId, u64)>,
    pub fetches: u64,
    pub fetched_bytes: u64,
    pub evictions: u64,
}

impl WeightTracker {
    pub fn new(capacity: u64) -> WeightTracker {
        WeightTracker {
            capacity,
            used: 0,
            resident: VecDeque::new(),
            fetches: 0,
            fetched_bytes: 0,
            evictions: 0,
        }
    }

    pub fn is_resident(&self, layer: LayerId) -> bool {
        self.resident.iter().any(|(l, _)| *l == layer)
    }

    /// Ensure `layer`'s weights (`bytes`) are on-chip.  Returns the
    /// number of bytes that must be fetched from DRAM (0 if resident).
    ///
    /// A weight set larger than the whole memory still becomes the
    /// (sole) resident set after evicting everything else — the memory
    /// is dedicated to it and its weights stream through exactly once —
    /// so consecutive CNs of that layer do not refetch (paper Section
    /// III-E2: the fetch node is inserted when the weights are not
    /// on-chip; afterwards they are).
    pub fn require(&mut self, layer: LayerId, bytes: u64) -> u64 {
        let mut evicted = Vec::new();
        self.require_evicting(layer, bytes, &mut evicted)
    }

    /// Like [`require`](Self::require), but records which layers were
    /// FIFO-evicted into `evicted` (cleared first).  The scheduler uses
    /// the list to re-key the effective readiness of candidate CNs
    /// whose weights just left (or entered) this core's memory.
    pub fn require_evicting(
        &mut self,
        layer: LayerId,
        bytes: u64,
        evicted: &mut Vec<LayerId>,
    ) -> u64 {
        evicted.clear();
        if bytes == 0 || self.is_resident(layer) {
            return 0;
        }
        self.fetches += 1;
        self.fetched_bytes += bytes;
        let occupancy = bytes.min(self.capacity);
        while self.used + occupancy > self.capacity {
            match self.resident.pop_front() {
                Some((l, freed)) => {
                    self.used -= freed;
                    self.evictions += 1;
                    evicted.push(l);
                }
                None => break,
            }
        }
        self.resident.push_back((layer, occupancy));
        self.used += occupancy;
        bytes
    }

    pub fn used(&self) -> u64 {
        self.used
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_fcfs_contention() {
        let mut bus = FcfsLink::new(128); // 16 bytes/cc
        let (s1, e1) = bus.transfer(0, 1600); // 100 cc
        assert_eq!((s1, e1), (0, 100));
        // ready at 10 but bus busy until 100
        let (s2, e2) = bus.transfer(10, 160);
        assert_eq!((s2, e2), (100, 110));
        // ready later than free
        let (s3, _) = bus.transfer(500, 16);
        assert_eq!(s3, 500);
        assert_eq!(bus.bytes_moved, 1600 + 160 + 16);
    }

    #[test]
    fn link_rounding_up() {
        let mut p = FcfsLink::new(64);
        let (_, e) = p.transfer(0, 1); // 8 bits / 64 -> 1 cycle min
        assert_eq!(e, 1);
    }

    #[test]
    fn linkset_occupies_every_route_link() {
        // 4-core ring: 0 -> 2 crosses two clockwise links
        let topo = Topology::ring(4, 128, 0.05, 64, 3.7);
        let mut links = LinkSet::new(&topo);
        let route: Vec<LinkId> =
            topo.core_route(crate::arch::CoreId(0), crate::arch::CoreId(2)).to_vec();
        assert_eq!(route.len(), 2);
        let (s, e) = links.transfer(&route, 0, 1600); // 100 cc at 128 b/cc
        assert_eq!((s, e), (0, 100));
        for l in &route {
            assert_eq!(links.busy_cycles(*l), 100);
            assert_eq!(links.bytes_moved(*l), 1600);
            assert_eq!(links.free_at(*l), 100);
        }
        // a transfer sharing the first hop (0 -> 1) waits for it...
        let hop: Vec<LinkId> =
            topo.core_route(crate::arch::CoreId(0), crate::arch::CoreId(1)).to_vec();
        assert_eq!(hop, route[..1].to_vec());
        let (s2, _) = links.transfer(&hop, 10, 16);
        assert_eq!(s2, 100, "shared first hop serializes");
        // ...while a disjoint hop (2 -> 3) does not
        let far: Vec<LinkId> =
            topo.core_route(crate::arch::CoreId(2), crate::arch::CoreId(3)).to_vec();
        let (s3, _) = links.transfer(&far, 10, 16);
        assert_eq!(s3, 10, "disjoint links run in parallel");
    }

    #[test]
    fn linkset_runs_at_bottleneck_bandwidth() {
        // mesh DRAM load: 64 b/cc channel feeding 128 b/cc hops
        let topo = Topology::mesh2d(4, 2, 128, 0.05, 64, 3.7, 1);
        let mut links = LinkSet::new(&topo);
        let route: Vec<LinkId> = topo.dram_load_route(crate::arch::CoreId(3)).to_vec();
        assert!(route.len() > 1);
        let (s, e) = links.transfer(&route, 0, 800); // 6400 bits / 64 = 100 cc
        assert_eq!((s, e), (0, 100));
    }

    #[test]
    fn weights_fifo_eviction() {
        let mut w = WeightTracker::new(100);
        assert_eq!(w.require(LayerId(0), 60), 60);
        assert_eq!(w.require(LayerId(1), 30), 30);
        assert!(w.is_resident(LayerId(0)));
        // hit: no fetch
        assert_eq!(w.require(LayerId(0), 60), 0);
        // needs 50 -> evict L0 (FIFO head)
        assert_eq!(w.require(LayerId(2), 50), 50);
        assert!(!w.is_resident(LayerId(0)));
        assert!(w.is_resident(LayerId(1)));
        assert!(w.is_resident(LayerId(2)));
        assert_eq!(w.evictions, 1);
        assert_eq!(w.used(), 80);
    }

    #[test]
    fn oversized_weights_dedicate_the_memory() {
        let mut w = WeightTracker::new(100);
        assert_eq!(w.require(LayerId(1), 40), 40);
        // a 500-byte set evicts everything and occupies the whole memory
        assert_eq!(w.require(LayerId(0), 500), 500);
        assert!(w.is_resident(LayerId(0)));
        assert!(!w.is_resident(LayerId(1)));
        assert_eq!(w.used(), 100);
        // consecutive CNs of the same layer hit
        assert_eq!(w.require(LayerId(0), 500), 0);
        assert_eq!(w.fetches, 2);
    }

    #[test]
    fn require_evicting_reports_victims() {
        let mut w = WeightTracker::new(100);
        let mut evicted = Vec::new();
        assert_eq!(w.require_evicting(LayerId(0), 60, &mut evicted), 60);
        assert!(evicted.is_empty());
        assert_eq!(w.require_evicting(LayerId(1), 30, &mut evicted), 30);
        assert!(evicted.is_empty());
        // needs 90 -> evicts L0 then L1
        assert_eq!(w.require_evicting(LayerId(2), 90, &mut evicted), 90);
        assert_eq!(evicted, vec![LayerId(0), LayerId(1)]);
        // a hit clears the list and evicts nothing
        assert_eq!(w.require_evicting(LayerId(2), 90, &mut evicted), 0);
        assert!(evicted.is_empty());
    }

    #[test]
    fn zero_byte_weights_free() {
        let mut w = WeightTracker::new(100);
        assert_eq!(w.require(LayerId(0), 0), 0);
        assert_eq!(w.fetches, 0);
    }
}
