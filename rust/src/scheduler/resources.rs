//! Shared-resource models: the inter-core bus, the off-chip DRAM port
//! and the per-core weight-memory tracker with FIFO eviction.

use std::collections::VecDeque;

use crate::workload::LayerId;

/// First-come-first-serve shared bus (paper Section III-E1).
///
/// Communication nodes are served in scheduling order; the bus is a
/// single shared resource, so a transfer starts at
/// `max(data_ready, bus_free)` and occupies the bus for
/// `ceil(bytes * 8 / bandwidth)` cycles.
///
/// All resource models ([`Bus`], [`DramPort`], [`WeightTracker`]) are
/// plain-data and `Clone`: `Scheduler::run` builds a fresh set per
/// call, so concurrent per-genome simulations share nothing mutable —
/// `Clone` additionally lets callers snapshot/fork resource state
/// (e.g. for what-if probes) without reconstructing it.
#[derive(Debug, Clone)]
pub struct Bus {
    bw_bits: u64,
    free_at: u64,
    pub busy_cycles: u64,
    pub bytes_moved: u64,
}

impl Bus {
    pub fn new(bw_bits: u64) -> Bus {
        Bus { bw_bits: bw_bits.max(1), free_at: 0, busy_cycles: 0, bytes_moved: 0 }
    }

    /// Schedule a transfer that becomes ready at `ready`; returns
    /// (start, end).
    pub fn transfer(&mut self, ready: u64, bytes: u64) -> (u64, u64) {
        let start = ready.max(self.free_at);
        let dur = (bytes * 8).div_ceil(self.bw_bits);
        let end = start + dur;
        self.free_at = end;
        self.busy_cycles += dur;
        self.bytes_moved += bytes;
        (start, end)
    }

    pub fn free_at(&self) -> u64 {
        self.free_at
    }
}

/// Shared DRAM port, same FCFS semantics as the bus.
#[derive(Debug, Clone)]
pub struct DramPort {
    bw_bits: u64,
    free_at: u64,
    pub busy_cycles: u64,
    pub bytes_moved: u64,
}

impl DramPort {
    pub fn new(bw_bits: u64) -> DramPort {
        DramPort { bw_bits: bw_bits.max(1), free_at: 0, busy_cycles: 0, bytes_moved: 0 }
    }

    pub fn transfer(&mut self, ready: u64, bytes: u64) -> (u64, u64) {
        let start = ready.max(self.free_at);
        let dur = (bytes * 8).div_ceil(self.bw_bits);
        let end = start + dur;
        self.free_at = end;
        self.busy_cycles += dur;
        self.bytes_moved += bytes;
        (start, end)
    }
}

/// Per-core on-chip weight-memory tracker (paper Section III-E2).
///
/// Weights are kept per layer; when a CN of a layer whose weights are
/// not resident is scheduled, the fetch is charged and older layers'
/// weights are evicted first-in-first-out until the new set fits.
#[derive(Debug, Clone)]
pub struct WeightTracker {
    capacity: u64,
    used: u64,
    resident: VecDeque<(LayerId, u64)>,
    pub fetches: u64,
    pub fetched_bytes: u64,
    pub evictions: u64,
}

impl WeightTracker {
    pub fn new(capacity: u64) -> WeightTracker {
        WeightTracker {
            capacity,
            used: 0,
            resident: VecDeque::new(),
            fetches: 0,
            fetched_bytes: 0,
            evictions: 0,
        }
    }

    pub fn is_resident(&self, layer: LayerId) -> bool {
        self.resident.iter().any(|(l, _)| *l == layer)
    }

    /// Ensure `layer`'s weights (`bytes`) are on-chip.  Returns the
    /// number of bytes that must be fetched from DRAM (0 if resident).
    ///
    /// A weight set larger than the whole memory still becomes the
    /// (sole) resident set after evicting everything else — the memory
    /// is dedicated to it and its weights stream through exactly once —
    /// so consecutive CNs of that layer do not refetch (paper Section
    /// III-E2: the fetch node is inserted when the weights are not
    /// on-chip; afterwards they are).
    pub fn require(&mut self, layer: LayerId, bytes: u64) -> u64 {
        let mut evicted = Vec::new();
        self.require_evicting(layer, bytes, &mut evicted)
    }

    /// Like [`require`](Self::require), but records which layers were
    /// FIFO-evicted into `evicted` (cleared first).  The scheduler uses
    /// the list to re-key the effective readiness of candidate CNs
    /// whose weights just left (or entered) this core's memory.
    pub fn require_evicting(
        &mut self,
        layer: LayerId,
        bytes: u64,
        evicted: &mut Vec<LayerId>,
    ) -> u64 {
        evicted.clear();
        if bytes == 0 || self.is_resident(layer) {
            return 0;
        }
        self.fetches += 1;
        self.fetched_bytes += bytes;
        let occupancy = bytes.min(self.capacity);
        while self.used + occupancy > self.capacity {
            match self.resident.pop_front() {
                Some((l, freed)) => {
                    self.used -= freed;
                    self.evictions += 1;
                    evicted.push(l);
                }
                None => break,
            }
        }
        self.resident.push_back((layer, occupancy));
        self.used += occupancy;
        bytes
    }

    pub fn used(&self) -> u64 {
        self.used
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bus_fcfs_contention() {
        let mut bus = Bus::new(128); // 16 bytes/cc
        let (s1, e1) = bus.transfer(0, 1600); // 100 cc
        assert_eq!((s1, e1), (0, 100));
        // ready at 10 but bus busy until 100
        let (s2, e2) = bus.transfer(10, 160);
        assert_eq!((s2, e2), (100, 110));
        // ready later than free
        let (s3, _) = bus.transfer(500, 16);
        assert_eq!(s3, 500);
        assert_eq!(bus.bytes_moved, 1600 + 160 + 16);
    }

    #[test]
    fn dram_rounding_up() {
        let mut p = DramPort::new(64);
        let (_, e) = p.transfer(0, 1); // 8 bits / 64 -> 1 cycle min
        assert_eq!(e, 1);
    }

    #[test]
    fn weights_fifo_eviction() {
        let mut w = WeightTracker::new(100);
        assert_eq!(w.require(LayerId(0), 60), 60);
        assert_eq!(w.require(LayerId(1), 30), 30);
        assert!(w.is_resident(LayerId(0)));
        // hit: no fetch
        assert_eq!(w.require(LayerId(0), 60), 0);
        // needs 50 -> evict L0 (FIFO head)
        assert_eq!(w.require(LayerId(2), 50), 50);
        assert!(!w.is_resident(LayerId(0)));
        assert!(w.is_resident(LayerId(1)));
        assert!(w.is_resident(LayerId(2)));
        assert_eq!(w.evictions, 1);
        assert_eq!(w.used(), 80);
    }

    #[test]
    fn oversized_weights_dedicate_the_memory() {
        let mut w = WeightTracker::new(100);
        assert_eq!(w.require(LayerId(1), 40), 40);
        // a 500-byte set evicts everything and occupies the whole memory
        assert_eq!(w.require(LayerId(0), 500), 500);
        assert!(w.is_resident(LayerId(0)));
        assert!(!w.is_resident(LayerId(1)));
        assert_eq!(w.used(), 100);
        // consecutive CNs of the same layer hit
        assert_eq!(w.require(LayerId(0), 500), 0);
        assert_eq!(w.fetches, 2);
    }

    #[test]
    fn require_evicting_reports_victims() {
        let mut w = WeightTracker::new(100);
        let mut evicted = Vec::new();
        assert_eq!(w.require_evicting(LayerId(0), 60, &mut evicted), 60);
        assert!(evicted.is_empty());
        assert_eq!(w.require_evicting(LayerId(1), 30, &mut evicted), 30);
        assert!(evicted.is_empty());
        // needs 90 -> evicts L0 then L1
        assert_eq!(w.require_evicting(LayerId(2), 90, &mut evicted), 90);
        assert_eq!(evicted, vec![LayerId(0), LayerId(1)]);
        // a hit clears the list and evicts nothing
        assert_eq!(w.require_evicting(LayerId(2), 90, &mut evicted), 0);
        assert!(evicted.is_empty());
    }

    #[test]
    fn zero_byte_weights_free() {
        let mut w = WeightTracker::new(100);
        assert_eq!(w.require(LayerId(0), 0), 0);
        assert_eq!(w.fetches, 0);
    }
}
