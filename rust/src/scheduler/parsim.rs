//! The partition-parallel simulation core: run one chip's lanes per
//! worker thread, then merge the recorded decision streams into the
//! exact sequential outcome.
//!
//! # Why lane-granular chip partitioning
//!
//! On a hierarchical (multi-chip) [`Topology`], a request lane whose
//! entire allocation sits on one chip touches only that chip's
//! resources: its cores' availability clocks, its weight trackers, its
//! intra-chip links and its DRAM port (hierarchical topologies restrict
//! the nearest-port search to same-chip ports).  Lanes on different
//! chips therefore interact through exactly two global couplings:
//!
//! 1. **arbitration** — the virtual admission clock `now` and the pick
//!    of which lane gets the next decision, and
//! 2. **pooled activation occupancy** (`act_occ`) — the memory-full
//!    fallback of the candidate pools.
//!
//! Both are handled *exactly*, not approximately:
//!
//! - Each chip's sub-simulation reuses the sequential
//!   [`SimContext::step`] unchanged over a state whose foreign lanes
//!   have permanently empty pools, and records per decision the
//!   arbitration *fronts* (each own lane's `peek_min_eff`), the picked
//!   lane and the event-log watermarks.  The merge then **replays the
//!   sequential arbitration** over the recorded fronts — same admission
//!   clock, same eligibility rule, same key — and verifies that every
//!   global pick equals the owning chip's recorded local pick.  Any
//!   mismatch aborts to the sequential loop.
//! - After the chip runs, a **headroom check** proves the occupancy
//!   coupling inert: if the sum of the chips' clamped occupancy peaks
//!   plus the largest single CN output fits the pooled activation
//!   capacity, then every pool pop in *both* the per-chip runs and the
//!   sequential interleaving sees `fits() == true` (the global clamped
//!   occupancy never exceeds the sum of the local ones), so every pop
//!   is pure key-order and the decision bodies coincide.  If the check
//!   fails, the parallel result is discarded and the sequential loop
//!   runs.
//!
//! Every fallback trigger is a deterministic function of the recorded
//! per-chip data — never of thread timing — so the outcome is
//! **bit-identical for every `STREAM_SIM_THREADS` value** (pinned by
//! `rust/tests/parallel_sim_equivalence.rs`).  The merged energy
//! breakdown is re-derived by replaying the per-decision event slices
//! in global order, reproducing the sequential float-summation order
//! exactly.
//!
//! [`Topology`]: crate::arch::Topology

use crate::arch::{CoreId, CoreKind, LinkId, Topology};
use crate::cn::CnId;
use crate::cost::EnergyBreakdown;
use crate::scheduler::memtrace::{MemEvent, MemTrace};
use crate::util::parallel_map_with;

use super::resources::{LinkSet, WeightTracker};
use super::sim::{FallbackReason, NoRecord, SimContext, SimOutcome, SimState};
use super::DramKind;

/// One recorded scheduling decision of a chip's sub-simulation.
struct StepRec {
    /// `(lane, peek_min_eff)` of every own nonempty lane *before* the
    /// decision — the chip's contribution to the arbitration front.
    fronts: Vec<(usize, u64)>,
    /// The lane the local arbitration picked.
    picked: usize,
    /// Event-log watermarks *after* the decision (cumulative lengths;
    /// the CN log grows by exactly one per decision, so its watermark
    /// is the decision index).
    comms_len: usize,
    drams_len: usize,
    trace_len: usize,
}

/// A completed chip sub-simulation: final state + decision recording.
struct ChipRun {
    state: SimState,
    steps: Vec<StepRec>,
}

/// Attempt the chip-partitioned parallel simulation.  Returns a typed
/// [`FallbackReason`] whenever exactness cannot be established — not
/// chip-partitionable, fewer than two busy chips, activation headroom
/// exceeded, or an arbitration-replay mismatch — and the caller runs
/// the sequential loop instead.
pub(crate) fn try_parallel(
    ctx: &SimContext,
    threads: usize,
) -> Result<SimOutcome, FallbackReason> {
    let topo = &ctx.arch.topology;
    if threads < 2 {
        return Err(FallbackReason::SequentialConfig);
    }
    if topo.n_chips() < 2 {
        return Err(FallbackReason::SingleChip);
    }
    if ctx.requests.len() < 2 {
        return Err(FallbackReason::SingleRequest);
    }
    if ctx.linear_pool || !ctx.tag_events {
        return Err(FallbackReason::UntracedEvents);
    }

    // --- partition lanes by the chip of their allocation -------------
    let chip_of_tenant: Vec<Option<usize>> =
        ctx.tenants.iter().map(|t| chip_of_alloc(topo, t.alloc)).collect();
    let mut chip_of_lane = Vec::with_capacity(ctx.requests.len());
    for r in ctx.requests {
        chip_of_lane
            .push(chip_of_tenant[r.tenant].ok_or(FallbackReason::StraddlingAllocation)?);
    }
    // busy chips in first-appearance (lane) order; slot = run index
    let mut chip_slot: Vec<Option<usize>> = vec![None; topo.n_chips()];
    let mut busy: Vec<usize> = Vec::new();
    for &c in &chip_of_lane {
        if chip_slot[c].is_none() {
            chip_slot[c] = Some(busy.len());
            busy.push(c);
        }
    }
    if busy.len() < 2 {
        return Err(FallbackReason::FewActiveChips);
    }
    let run_of_lane: Vec<usize> =
        chip_of_lane.iter().map(|&c| chip_slot[c].expect("busy chip")).collect();

    // --- run each chip's sub-simulation on its own worker ------------
    let masks: Vec<Vec<bool>> = busy
        .iter()
        .map(|&chip| chip_of_lane.iter().map(|&c| c == chip).collect())
        .collect();
    let runs: Vec<ChipRun> =
        parallel_map_with(masks, |owned| run_chip(ctx, &owned), threads.min(busy.len()));

    // --- headroom: the occupancy coupling must be provably inert -----
    let act_cap: f64 = ctx.arch.cores.iter().map(|c| c.act_mem_bytes as f64).sum();
    let mut max_out = 0u64;
    let mut seen = vec![false; ctx.tenants.len()];
    for r in ctx.requests {
        if std::mem::replace(&mut seen[r.tenant], true) {
            continue;
        }
        let g = ctx.tenants[r.tenant].sched.graph;
        for i in 0..g.len() {
            max_out = max_out.max(g.cns.node(CnId(i)).output_bytes);
        }
    }
    let peaks: f64 = runs.iter().map(|r| clamped_peak(&r.state.trace.events)).sum();
    if peaks + max_out as f64 > act_cap {
        return Err(FallbackReason::HeadroomViolated);
    }

    // --- deterministic merge: replay the sequential arbitration ------
    let _merge_span = crate::obs::span_here("parsim", "merge");
    let total: usize = runs.iter().map(|r| r.steps.len()).sum();
    let mut ptr = vec![0usize; runs.len()];
    let mut consumed = vec![(0usize, 0usize, 0usize); runs.len()];
    let mut now = 0u64;
    let mut act_occ = 0.0f64;
    let mut bd = EnergyBreakdown::default();
    let mut cns = Vec::with_capacity(total);
    let mut cn_req = Vec::with_capacity(total);
    let mut comms = Vec::new();
    let mut comm_req = Vec::new();
    let mut drams = Vec::new();
    let mut dram_req = Vec::new();
    let mut events: Vec<MemEvent> = Vec::new();

    for _ in 0..total {
        // the union of the chips' current fronts is exactly the
        // sequential arbitration scan's candidate list (a chip's front
        // is constant between its own decisions: only a chip's own
        // decisions mutate its lanes' pools)
        let mut min_eff = u64::MAX;
        let mut best: Option<((u64, u64, u64), usize)> = None;
        for (j, run) in runs.iter().enumerate() {
            if ptr[j] < run.steps.len() {
                for &(_, eff) in &run.steps[ptr[j]].fronts {
                    min_eff = min_eff.min(eff);
                }
            }
        }
        now = now.max(min_eff);
        for (j, run) in runs.iter().enumerate() {
            if ptr[j] >= run.steps.len() {
                continue;
            }
            for &(ri, eff) in &run.steps[ptr[j]].fronts {
                if ctx.requests[ri].release > now {
                    continue; // not yet arrived: ineligible for preference
                }
                let key = match ctx.arbitration {
                    super::Arbitration::Fifo => (0, eff, ri as u64),
                    super::Arbitration::Priority => {
                        (ctx.tenants[ctx.requests[ri].tenant].prio_rank, eff, ri as u64)
                    }
                    super::Arbitration::Edf => {
                        (ctx.requests[ri].deadline_abs.unwrap_or(u64::MAX), eff, ri as u64)
                    }
                };
                let better = match best {
                    None => true,
                    Some((k, _)) => key < k,
                };
                if better {
                    best = Some((key, ri));
                }
            }
        }
        let ri = best.ok_or(FallbackReason::MergeMismatch)?.1;
        let j = run_of_lane[ri];
        let run = &runs[j];
        let rec = &run.steps[ptr[j]];
        if rec.picked != ri {
            // a lane was globally eligible earlier than its chip knew
            // (cross-chip admission-clock advance): the local stream
            // diverges from the sequential one — abort to sequential
            return Err(FallbackReason::MergeMismatch);
        }

        // consume this decision's event slices in sequential order,
        // re-deriving the energy breakdown with the sequential
        // float-summation order (per field: comm NoC adds, then DRAM
        // adds in push order, then the execute adds)
        let placed = run.state.cns[ptr[j]];
        let (c0, d0, t0) = consumed[j];
        for c in &run.state.comms[c0..rec.comms_len] {
            bd.noc_pj += c.bytes as f64 * 8.0 * topo.route_noc_pj_per_bit(&c.links);
            comms.push(c.clone());
            comm_req.push(ri);
        }
        for d in &run.state.drams[d0..rec.drams_len] {
            bd.dram_pj += d.bytes as f64 * 8.0 * topo.route_dram_pj_per_bit(&d.links);
            bd.noc_pj += d.bytes as f64 * 8.0 * topo.route_noc_pj_per_bit(&d.links);
            if d.kind == DramKind::WeightFetch {
                if let CoreKind::Aimc { weight_load_pj, .. } = ctx.arch.core(d.core).kind {
                    bd.onchip_pj += d.bytes as f64 * 8.0 * weight_load_pj;
                }
            }
            drams.push(d.clone());
            dram_req.push(ri);
        }
        let t = &ctx.tenants[ctx.requests[ri].tenant];
        let cost = t.sched.costs.cn_cost(t.sched.graph.cns.node(placed.cn), placed.core);
        bd.mac_pj += cost.mac_energy_pj;
        bd.onchip_pj += cost.energy_pj - cost.mac_energy_pj;
        for e in &run.state.trace.events[t0..rec.trace_len] {
            if e.delta > 0.0 {
                act_occ += e.delta;
            } else {
                act_occ = (act_occ + e.delta).max(0.0);
            }
            events.push(*e);
        }
        cns.push(placed);
        cn_req.push(ri);
        consumed[j] = (rec.comms_len, rec.drams_len, rec.trace_len);
        ptr[j] += 1;
    }
    debug_assert!(
        ptr.iter().zip(&runs).all(|(&p, r)| p == r.steps.len()),
        "merge consumed every chip's decisions"
    );

    // --- reassemble the global end state and finish as usual ---------
    let n_cores = ctx.arch.cores.len();
    let mut core_avail = vec![0u64; n_cores];
    let mut core_busy = vec![0u64; n_cores];
    for c in 0..n_cores {
        if let Some(j) = chip_slot[topo.chip_of_core(CoreId(c))] {
            core_avail[c] = runs[j].state.core_avail[c];
            core_busy[c] = runs[j].state.core_busy[c];
        }
    }
    let mut links = LinkSet::new(topo);
    for l in 0..topo.n_links() {
        // inter-chip links (owner None) are never crossed by chip-pure
        // lanes — in the sequential run either — and keep fresh state
        if let Some(j) = topo.chip_of_link(LinkId(l)).and_then(|chip| chip_slot[chip]) {
            links.adopt_link(&runs[j].state.links, LinkId(l));
        }
    }
    let lanes = (0..ctx.requests.len())
        .map(|ri| runs[run_of_lane[ri]].state.lanes[ri].clone())
        .collect();
    // Each core belongs to exactly one chip and chip-pure lanes never
    // touch foreign cores, so a core's sequential weight-tracker end
    // state is exactly its owning chip's — adopt it (counters
    // included); cores of idle chips keep a fresh tracker, as in the
    // sequential run.  The global eviction *order* interleaves chips
    // and is not reconstructed; the merged state is terminal, so only
    // per-tracker contents and totals matter.
    let weights: Vec<WeightTracker> = ctx
        .arch
        .cores
        .iter()
        .enumerate()
        .map(|(c, core)| match chip_slot[topo.chip_of_core(CoreId(c))] {
            Some(j) => runs[j].state.weights[c].clone(),
            None => WeightTracker::new(core.wgt_mem_bytes),
        })
        .collect();
    let evicted = runs.iter().flat_map(|r| r.state.evicted.iter().copied()).collect();
    let merged = SimState {
        core_avail,
        core_busy,
        links,
        weights,
        evicted,
        lanes,
        trace: MemTrace { events },
        cns,
        cn_req,
        comms,
        comm_req,
        drams,
        dram_req,
        breakdown: bd,
        act_cap,
        act_occ,
        now,
        cands: Vec::new(),
        decisions: total,
    };
    let mut out = ctx.finish(merged);
    out.partitions = runs.len();
    Ok(out)
}

/// Drive one chip's sub-simulation with the unchanged sequential
/// [`SimContext::step`], recording the arbitration front before and the
/// pick + event watermarks after every decision.
fn run_chip(ctx: &SimContext, owned: &[bool]) -> ChipRun {
    let _span = crate::obs::span_here("parsim", "chip");
    let mut rec = NoRecord;
    let mut st = ctx.init_owned(&mut rec, Some(owned));
    let mut steps = Vec::new();
    while st.has_work() {
        let mut fronts = Vec::new();
        for (ri, l) in st.lanes.iter_mut().enumerate() {
            if l.pool.len() > 0 {
                fronts.push((ri, l.pool.peek_min_eff().expect("nonempty pool has a minimum")));
            }
        }
        let picked = ctx.step(&mut st, &mut rec);
        steps.push(StepRec {
            fronts,
            picked,
            comms_len: st.comms.len(),
            drams_len: st.drams.len(),
            trace_len: st.trace.events.len(),
        });
    }
    ChipRun { state: st, steps }
}

/// The single chip hosting an allocation's every core — with every
/// route the simulation can take (core→core, core→DRAM) verified to
/// stay on that chip — or `None` when the allocation spans chips (or
/// a custom chip map routes off-chip).
fn chip_of_alloc(topo: &Topology, alloc: &[CoreId]) -> Option<usize> {
    let mut cores: Vec<CoreId> = alloc.to_vec();
    cores.sort_unstable();
    cores.dedup();
    let chip = topo.chip_of_core(*cores.first()?);
    if cores.iter().any(|&c| topo.chip_of_core(c) != chip) {
        return None;
    }
    let on_chip =
        |route: &[LinkId]| route.iter().all(|l| topo.chip_of_link(*l) == Some(chip));
    for &c in &cores {
        if !on_chip(topo.dram_load_route(c)) || !on_chip(topo.dram_store_route(c)) {
            return None;
        }
        for &d in &cores {
            if c != d && !on_chip(topo.core_route(c, d)) {
                return None;
            }
        }
    }
    Some(chip)
}

/// Peak of the clamped occupancy replay over a chip's memory-trace
/// events in **push order** — exactly the `act_occ` trajectory the
/// sequential loop maintains (additions unclamped, subtractions clamped
/// at zero; zero deltas never reach the trace and are no-ops on the
/// occupancy either).
fn clamped_peak(events: &[MemEvent]) -> f64 {
    let mut occ = 0.0f64;
    let mut peak = 0.0f64;
    for e in events {
        if e.delta > 0.0 {
            occ += e.delta;
        } else {
            occ = (occ + e.delta).max(0.0);
        }
        peak = peak.max(occ);
    }
    peak
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn chip_of_alloc_requires_purity() {
        let arch = presets::chiplet_4x4();
        let topo = &arch.topology;
        // chip 1's dense cores + its SIMD core
        let pure = [CoreId(5), CoreId(6), CoreId(9)];
        assert_eq!(chip_of_alloc(topo, &pure), Some(1));
        // one core from chip 0 breaks purity
        let mixed = [CoreId(0), CoreId(6), CoreId(9)];
        assert_eq!(chip_of_alloc(topo, &mixed), None);
        // single-chip (flat) topologies are trivially chip 0
        let flat = presets::hetero_quad();
        let all: Vec<CoreId> = flat.cores.iter().map(|c| c.id).collect();
        assert_eq!(chip_of_alloc(&flat.topology, &all), Some(0));
    }

    #[test]
    fn clamped_peak_replays_the_occupancy() {
        use crate::arch::CoreId;
        let mk = |deltas: &[f64]| -> Vec<MemEvent> {
            deltas.iter().map(|&d| MemEvent { time: 0, core: CoreId(0), delta: d }).collect()
        };
        assert_eq!(clamped_peak(&mk(&[100.0, -40.0, 30.0])), 100.0);
        // clamping: the over-free is swallowed, later allocs rebuild
        assert_eq!(clamped_peak(&mk(&[50.0, -80.0, 60.0])), 60.0);
        assert_eq!(clamped_peak(&[]), 0.0);
    }
}
