//! The event-driven list scheduler (paper Fig. 7/8 semantics), with
//! communication routed over the architecture's interconnect topology.
//!
//! Since the request-context refactor the inner loop itself lives in
//! [`super::sim`]: [`Scheduler::run`] is the degenerate single-request
//! instantiation of the unified core that the multi-DNN scenario
//! engine also drives.  This module keeps the allocation-independent
//! precomputation ([`Scheduler::new`]) and the one-shot result
//! assembly.

use std::sync::Arc;

use crate::arch::{Accelerator, CoreId, LinkId};
use crate::cn::CnId;
use crate::cost::ScheduleMetrics;
use crate::depgraph::{CnGraph, EdgeKind};
use crate::mapping::CostModel;
use crate::scheduler::memtrace::MemTrace;
use crate::scheduler::sim::{
    Arbitration, NoRecord, ScheduleSegments, SimContext, SimOutcome, SimRequest, SimSnapshot,
    SimTenant, TouchTracer,
};
use crate::scheduler::{SchedulePriority, ScheduleResult};
use crate::workload::{OpType, WorkloadGraph};

/// Placement and timing of one scheduled CN.
#[derive(Debug, Clone, Copy)]
pub struct ScheduledCn {
    pub cn: CnId,
    pub core: CoreId,
    pub start: u64,
    pub end: u64,
}

/// Reusable scheduler over a fixed (workload, graph, costs, arch).
///
/// The GA calls [`Scheduler::run`] once per fitness evaluation with a
/// different layer-core allocation, so everything allocation-independent
/// is precomputed here.
pub struct Scheduler<'a> {
    pub workload: &'a WorkloadGraph,
    pub graph: &'a CnGraph,
    pub costs: &'a CostModel,
    pub arch: &'a Accelerator,
    /// #consumer layers per layer (producer-buffer free scaling).
    pub(super) fanout: Vec<f64>,
    /// fresh input bytes each source-layer CN must fetch from DRAM.
    pub(super) fresh_in_bytes: Vec<u64>,
    /// Per-layer DRAM weight-fetch cycles (cached off the candidate
    /// selection hot loop; see EXPERIMENTS.md §Perf).
    pub(super) wgt_fetch_cc: Vec<u64>,
    /// Bounded-buffer gates: `gate_preds[p]` lists consumer CNs that
    /// must finish before producer CN `p` may start (streaming
    /// backpressure so producers cannot run arbitrarily far ahead of a
    /// slow consumer and flood the activation memory).
    pub(super) gate_preds: Vec<Vec<CnId>>,
    pub(super) gate_succs: Vec<Vec<CnId>>,
}

impl<'a> Scheduler<'a> {
    pub fn new(
        workload: &'a WorkloadGraph,
        graph: &'a CnGraph,
        costs: &'a CostModel,
        arch: &'a Accelerator,
    ) -> Scheduler<'a> {
        let fanout = workload
            .layers()
            .iter()
            .map(|l| (workload.successors(l.id).len() as f64).max(1.0))
            .collect();

        // fresh (non-halo) input rows per CN, for source-layer DRAM
        // fetches: rows in [prev.in_hi, my.in_hi)
        let mut fresh_in_bytes = vec![0u64; graph.len()];
        for layer in workload.layers() {
            if !layer.predecessors.is_empty() {
                continue;
            }
            let row_bytes =
                (layer.c * layer.in_width()) as u64 * layer.act_bits as u64 / 8;
            let cns = graph.cns.layer_cns(layer.id);
            let mut prev_hi = 0i64;
            for cn in cns {
                let fresh = (cn.in_rect.hi[1] - prev_hi.max(cn.in_rect.lo[1])).max(0) as u64;
                fresh_in_bytes[cn.id.0] = fresh * row_bytes;
                prev_hi = prev_hi.max(cn.in_rect.hi[1]);
            }
        }

        // --- bounded-buffer gates ---
        // Per producer layer, allow roughly an equal share of the pooled
        // activation capacity as in-flight output rows; beyond that a
        // producer CN waits for the consumer CN whose input window lies
        // entirely below the buffered region.  Gate edges point from a
        // deeper-layer CN to a shallower-layer CN whose output range is
        // strictly above the gate's input window, so they can never
        // close a cycle with the (forward) data edges.
        let act_cap: u64 = arch.cores.iter().map(|c| c.act_mem_bytes).sum();
        let budget = act_cap / (2 * workload.len().max(1) as u64).max(1);
        let mut gate_preds: Vec<Vec<CnId>> = vec![Vec::new(); graph.len()];
        let mut gate_succs: Vec<Vec<CnId>> = vec![Vec::new(); graph.len()];
        for layer in workload.layers() {
            let succs = workload.successors(layer.id);
            if succs.is_empty() {
                continue;
            }
            let row_bytes = (layer.k * layer.ox * layer.act_bits / 8).max(1) as i64;
            let pcns = graph.cns.layer_cns(layer.id);
            let cn_lines = pcns.first().map(|c| c.out_lines()).unwrap_or(1) as i64;
            let buf_rows = ((budget as i64) / row_bytes).max(2 * cn_lines);
            if buf_rows >= layer.oy as i64 {
                continue; // whole output fits in the budget: no gating
            }
            for &cons_id in succs {
                let cons = workload.layer(cons_id);
                // A MatMul's B operand is broadcast: EVERY consumer CN
                // depends on EVERY producer CN, so a gate from this
                // producer back to any consumer CN would close a cycle
                // with the B data edges (and backpressure is moot — the
                // whole matrix must exist before the GEMM starts).
                if cons.op == OpType::MatMul
                    && cons.predecessors.iter().skip(1).any(|p| *p == layer.id)
                {
                    continue;
                }
                let ccns = graph.cns.layer_cns(cons_id);
                if ccns.len() < 2 {
                    continue; // single-CN consumers (e.g. FC) gate nothing
                }
                // A materialized (cut) fuse boundary behaves like the
                // MatMul B operand above: every consumer CN data-depends
                // on the producer's LAST CN, so a gate from any producer
                // CN back to a consumer CN would close a cycle — and
                // backpressure is moot, the full tensor is spilled
                // anyway.  Detect it from the graph: >=2 producer CNs
                // whose data edges into this consumer all leave the last
                // producer CN.
                if pcns.len() >= 2 {
                    let last = pcns.last().map(|c| c.id);
                    let mut any_edge = false;
                    let mut all_from_last = true;
                    for pcn in pcns {
                        for e in graph.succ_edges(pcn.id) {
                            if e.kind != EdgeKind::Data
                                || graph.cns.node(e.to).layer != cons_id
                            {
                                continue;
                            }
                            any_edge = true;
                            if Some(pcn.id) != last {
                                all_from_last = false;
                            }
                        }
                        if !all_from_last {
                            break;
                        }
                    }
                    if any_edge && all_from_last {
                        continue;
                    }
                }
                for pcn in pcns {
                    let gate_row = pcn.out_rect.lo[1] - buf_rows;
                    if gate_row <= 0 {
                        continue;
                    }
                    // largest consumer CN whose window ends at/below gate_row
                    let j = ccns.partition_point(|c| c.in_rect.hi[1] <= gate_row);
                    if j == 0 {
                        continue;
                    }
                    let gate = ccns[j - 1].id;
                    gate_preds[pcn.id.0].push(gate);
                    gate_succs[gate.0].push(pcn.id);
                }
            }
        }

        // Heuristic readiness penalty for non-resident weights: the
        // fetch time at the topology's aggregate off-chip bandwidth
        // (allocation-independent, so it can be precomputed; the actual
        // fetch is routed per core at schedule time).  A MatMul whose B
        // operand streams from DRAM (LLM-decode KV read) pays the same
        // penalty for its B bytes — and since it is never resident, the
        // penalty never amortizes away.
        let wgt_fetch_cc = workload
            .layers()
            .iter()
            .map(|l| {
                let bytes =
                    if l.streams_b_from_dram() { l.matmul_b_bytes() } else { l.weight_bytes() };
                (bytes * 8).div_ceil(arch.topology.dram_bw_bits())
            })
            .collect();

        Scheduler {
            workload,
            graph,
            costs,
            arch,
            fanout,
            fresh_in_bytes,
            wgt_fetch_cc,
            gate_preds,
            gate_succs,
        }
    }

    /// Schedule under `allocation` (a core per layer) and `priority`.
    ///
    /// `&self` + per-call resource state means one prebuilt scheduler
    /// serves any number of threads concurrently (the parallel GA
    /// fitness path relies on this).
    ///
    /// # Examples
    ///
    /// ```
    /// use stream::arch::presets;
    /// use stream::cn::{CnGranularity, CnSet};
    /// use stream::depgraph::generate;
    /// use stream::mapping::CostModel;
    /// use stream::scheduler::{schedule, SchedulePriority};
    /// use stream::workload::models::tiny_segment;
    ///
    /// let workload = tiny_segment();
    /// let arch = presets::test_dual();
    /// let cns = CnSet::build(&workload, CnGranularity::Lines(4));
    /// let costs = CostModel::build(&workload, &cns, &arch);
    /// let graph = generate(&workload, CnSet::build(&workload, CnGranularity::Lines(4)));
    ///
    /// // everything on core 0, SIMD layers on the SIMD core
    /// let simd = arch.simd_core().unwrap();
    /// let alloc: Vec<_> = workload
    ///     .layers()
    ///     .iter()
    ///     .map(|l| if l.op.is_dense() { stream::arch::CoreId(0) } else { simd })
    ///     .collect();
    /// let result = schedule(&workload, &graph, &costs, &arch, &alloc, SchedulePriority::Latency);
    /// assert_eq!(result.cns.len(), graph.len());
    /// assert!(result.latency() > 0);
    /// ```
    pub fn run(&self, allocation: &[CoreId], priority: SchedulePriority) -> ScheduleResult {
        self.run_sim(allocation, priority, false)
    }

    /// The degenerate single-request instantiation of the unified
    /// simulation core (`super::sim`): one lane released at t = 0 with
    /// layer offset 0, vacuous FIFO arbitration, and event tagging off
    /// (`tag_events: false` — nothing here reads the tags, so the hot
    /// path never records them).  `linear_pool` selects the seed's
    /// O(n) candidate scan — the `run_reference` pinning path.
    pub(super) fn run_sim(
        &self,
        allocation: &[CoreId],
        priority: SchedulePriority,
        linear_pool: bool,
    ) -> ScheduleResult {
        self.with_ctx(allocation, priority, linear_pool, |ctx| self.assemble(ctx.simulate()))
    }

    /// Build the one-shot [`SimContext`] (single lane at t = 0, layer
    /// offset 0, FIFO, tags off) and hand it to `f`.  The tenant and
    /// request arrays borrow from this frame, hence the closure shape.
    fn with_ctx<T>(
        &self,
        allocation: &[CoreId],
        priority: SchedulePriority,
        linear_pool: bool,
        f: impl FnOnce(&SimContext) -> T,
    ) -> T {
        assert_eq!(allocation.len(), self.workload.len(), "allocation per layer");
        let tenants = [SimTenant {
            sched: self,
            alloc: allocation,
            pool_priority: priority,
            prio_rank: 0,
            layer_off: 0,
        }];
        let requests = [SimRequest { tenant: 0, release: 0, deadline_abs: None }];
        let ctx = SimContext {
            arch: self.arch,
            tenants: &tenants,
            requests: &requests,
            wgt_fetch_g: &self.wgt_fetch_cc,
            arbitration: Arbitration::Fifo,
            linear_pool,
            tag_events: false,
            // single-lane runs have nothing to partition; keep the
            // one-shot GA hot path out of the env lookup entirely
            sim_threads: 1,
        };
        f(&ctx)
    }

    /// Drop the (empty) request tags of a one-shot outcome, attaching
    /// the flight-recorder report when the recorder is enabled.
    fn assemble(&self, out: SimOutcome) -> ScheduleResult {
        let report = crate::obs::enabled().then(|| Box::new(out.report(self.arch)));
        ScheduleResult {
            cns: out.cns,
            comms: out.comms,
            drams: out.drams,
            link_stats: out.link_stats,
            metrics: out.metrics,
            memtrace: out.memtrace,
            report,
        }
    }

    /// Default decision-count spacing between resumable snapshots of a
    /// traced run: ~8 segments per schedule, floored so tiny graphs
    /// don't snapshot every step.
    pub fn snap_interval(&self) -> usize {
        (self.graph.len() / 8).max(8)
    }

    /// Like [`Scheduler::run`], but also return the divergence-tracking
    /// [`ScheduleSegments`] — per-layer first-observation indices plus
    /// resumable [`SimSnapshot`]s every `every` scheduling decisions
    /// (and one of the pristine initial state).  The result is
    /// bit-identical to `run`; the segments feed
    /// [`Scheduler::run_resumed_traced`] for genomes derived from this
    /// allocation.
    pub fn run_traced(
        &self,
        allocation: &[CoreId],
        priority: SchedulePriority,
        every: usize,
    ) -> (ScheduleResult, ScheduleSegments) {
        assert!(every >= 1, "snapshot interval must be positive");
        self.with_ctx(allocation, priority, false, |ctx| {
            let _span = crate::obs::span_here("sim", "run_traced");
            crate::obs::count(crate::obs::Counter::DeltaColdRuns, 1);
            let mut rec = TouchTracer::new(self.workload.len());
            let mut st = ctx.init(&mut rec);
            let mut snaps = vec![Arc::new(SimSnapshot { state: st.clone() })];
            while st.has_work() {
                ctx.step(&mut st, &mut rec);
                if st.has_work() && st.decisions() % every == 0 {
                    snaps.push(Arc::new(SimSnapshot { state: st.clone() }));
                }
            }
            crate::obs::count(crate::obs::Counter::SnapshotsTaken, snaps.len() as u64);
            let result = self.assemble(ctx.finish(st));
            (result, ScheduleSegments { touch: rec.touch, snaps })
        })
    }

    /// Resume a checkpointed simulation to completion under
    /// `allocation`.  Bit-identical to the uninterrupted run when the
    /// snapshot was taken under the same allocation (pinned by the
    /// fuzz sweep in `rust/tests/sim_core_fuzz.rs`), or under one whose
    /// changed layers all have first-observation indices beyond the
    /// snapshot's decision count (the delta-evaluation contract —
    /// pinned by `rust/tests/delta_equivalence.rs`).
    pub fn run_resumed(
        &self,
        allocation: &[CoreId],
        priority: SchedulePriority,
        snap: &SimSnapshot,
    ) -> ScheduleResult {
        self.with_ctx(allocation, priority, false, |ctx| {
            let _span = crate::obs::span_here("sim", "run_resumed");
            let mut rec = NoRecord;
            let mut st = snap.state.clone();
            while st.has_work() {
                ctx.step(&mut st, &mut rec);
            }
            self.assemble(ctx.finish(st))
        })
    }

    /// The delta-evaluation hot path: re-simulate `allocation` from the
    /// deepest of the parent's snapshots strictly before `divergence`
    /// (see [`ScheduleSegments::resume_point`]), producing both the
    /// (bit-identical-to-cold) result and the child's own
    /// [`ScheduleSegments`] so it can in turn serve as a parent.
    /// Returns `None` when no snapshot precedes the divergence — the
    /// caller falls back to [`Scheduler::run_traced`].
    pub fn run_resumed_traced(
        &self,
        allocation: &[CoreId],
        priority: SchedulePriority,
        parent: &ScheduleSegments,
        divergence: usize,
        every: usize,
    ) -> Option<(ScheduleResult, ScheduleSegments)> {
        assert!(every >= 1, "snapshot interval must be positive");
        let snap = parent.resume_point(divergence)?;
        let s = snap.decisions();
        Some(self.with_ctx(allocation, priority, false, |ctx| {
            let _span = crate::obs::span_here("sim", "run_resumed_traced");
            crate::obs::count(crate::obs::Counter::DeltaResumes, 1);
            crate::obs::hist(crate::obs::Hist::ResumeDepth, s as u64);
            let mut rec = TouchTracer::new(self.workload.len());
            let mut st = snap.state.clone();
            // Inherit the shared prefix: snapshots at or before the
            // resume point are bit-identical states of the child's own
            // cold run (every candidate they hold has visibility <= s
            // < divergence, hence belongs to an unchanged layer).
            let mut snaps: Vec<Arc<SimSnapshot>> = parent
                .snaps
                .iter()
                .filter(|p| p.decisions() <= s)
                .cloned()
                .collect();
            let inherited = snaps.len();
            while st.has_work() {
                ctx.step(&mut st, &mut rec);
                if st.has_work() && st.decisions() % every == 0 && st.decisions() > s {
                    snaps.push(Arc::new(SimSnapshot { state: st.clone() }));
                }
            }
            crate::obs::count(
                crate::obs::Counter::SnapshotsTaken,
                (snaps.len() - inherited) as u64,
            );
            let result = self.assemble(ctx.finish(st));
            // The replayed suffix recorded insertions with visibility
            // > s; prefix insertions (visibility <= s) are identical to
            // the parent's, so merge them in.
            let mut touch = rec.touch;
            for (l, t) in touch.iter_mut().enumerate() {
                if parent.touch[l] <= s {
                    *t = (*t).min(parent.touch[l]);
                }
            }
            (result, ScheduleSegments { touch, snaps })
        }))
    }

    /// Cheap admissible floors on the three objective metrics of *any*
    /// schedule of `allocation`, priority-independent:
    ///
    /// - **latency**: the busiest core's summed compute cycles, or the
    ///   busiest link's summed mandatory-transfer cycles (per-link
    ///   `ceil(bits / bw)` floors — each actual transfer occupies every
    ///   route link at the *bottleneck* bandwidth for at least that
    ///   long, and FCFS busy intervals are disjoint), whichever is
    ///   larger;
    /// - **energy**: exact per-CN compute energy plus the wire energy
    ///   of the mandatory traffic (source-layer fetches, one weight
    ///   fetch per weighted layer, per-CN streamed-B reads, sink
    ///   stores, cross-core data edges) — scaled by `1 - 1e-9` so
    ///   float-summation ordering can never push the floor above the
    ///   simulated value;
    /// - **peak memory**: the largest single CN output (its buffer is
    ///   live the moment the CN starts), with a small absolute margin
    ///   for the trace's fractional-share rounding.
    ///
    /// Used by the GA's early-abort: a genome whose floors are already
    /// dominated by an evaluated point cannot reach the Pareto front
    /// (admissibility pinned by `rust/tests/delta_equivalence.rs`).
    /// Only the three objective fields are meaningful; the energy
    /// breakdown and utilization of the returned metrics stay zero.
    pub fn lower_bounds(&self, allocation: &[CoreId]) -> ScheduleMetrics {
        assert_eq!(allocation.len(), self.workload.len(), "allocation per layer");
        let topo = &self.arch.topology;
        let mut core_cc = vec![0u64; self.arch.cores.len()];
        let mut link_cc = vec![0u64; topo.n_links()];
        let mut energy = 0.0f64;
        let mut max_out = 0u64;

        // floor-charge one transfer: per-link cycles + wire energy
        let charge = |route: &[LinkId], bytes: u64, link_cc: &mut [u64]| -> f64 {
            for l in route {
                let bw = topo.link(*l).bw_bits.max(1);
                link_cc[l.0] += (bytes * 8).div_ceil(bw);
            }
            bytes as f64
                * 8.0
                * (topo.route_dram_pj_per_bit(route) + topo.route_noc_pj_per_bit(route))
        };

        for layer in self.workload.layers() {
            let core_id = allocation[layer.id.0];
            let core = self.arch.core(core_id);
            let cns = self.graph.cns.layer_cns(layer.id);
            let sink = self.workload.successors(layer.id).is_empty();
            for cn in cns {
                let cost = self.costs.cn_cost(cn, core_id);
                core_cc[core_id.0] += cost.compute_cycles;
                energy += cost.energy_pj;
                max_out = max_out.max(cn.output_bytes);
                let fresh = self.fresh_in_bytes[cn.id.0];
                if fresh > 0 {
                    energy += charge(topo.dram_load_route(core_id), fresh, &mut link_cc);
                }
                if sink {
                    energy +=
                        charge(topo.dram_store_route(core_id), cn.output_bytes, &mut link_cc);
                }
            }
            // weight traffic: a streamed B operand is re-read per CN
            // and never resident; resident weights are fetched at least
            // once (the layer's first CN always misses)
            let wfetches = if layer.streams_b_from_dram() {
                Some((layer.matmul_b_bytes(), cns.len() as u64))
            } else if layer.weight_bytes() > 0 {
                Some((layer.weight_bytes(), 1))
            } else {
                None
            };
            if let Some((bytes, times)) = wfetches {
                for _ in 0..times {
                    energy += charge(topo.dram_load_route(core_id), bytes, &mut link_cc);
                    if let crate::arch::CoreKind::Aimc { weight_load_pj, .. } = core.kind {
                        energy += bytes as f64 * 8.0 * weight_load_pj;
                    }
                }
            }
        }

        // every cross-core data edge must cross the interconnect
        for e in &self.graph.edges {
            if e.kind != EdgeKind::Data || e.bytes == 0 {
                continue;
            }
            let from = allocation[self.graph.cns.node(e.from).layer.0];
            let to = allocation[self.graph.cns.node(e.to).layer.0];
            if from != to {
                energy += charge(topo.core_route(from, to), e.bytes, &mut link_cc);
            }
        }

        let latency_cc = core_cc
            .iter()
            .chain(link_cc.iter())
            .copied()
            .max()
            .unwrap_or(0);
        ScheduleMetrics {
            latency_cc,
            energy_pj: energy * (1.0 - 1e-9),
            peak_mem_bytes: ((max_out as f64) - 2.0).max(0.0) * (1.0 - 1e-6),
            ..ScheduleMetrics::default()
        }
    }
}

/// Producer layer of a CN (used by the frozen legacy-bus reference).
#[cfg(any(test, feature = "reference-engines"))]
pub(super) fn p_layer(graph: &CnGraph, cn: CnId) -> crate::workload::LayerId {
    graph.cns.node(cn).layer
}

/// Peak total activation memory and the bytes allocated above the
/// accelerator's pooled activation-SRAM capacity, from one time-ordered
/// pass over the memory trace (frees before allocs at equal
/// timestamps).  Overflow bytes spill to DRAM and must be reloaded —
/// the fusion advantage of paper Figs. 14/15 in one number.  Capacity
/// is pooled across cores, matching the paper's total-usage trace
/// semantics (Fig. 7: "total memory usage of all three cores").
pub(super) fn peak_and_spill(trace: &MemTrace, arch: &Accelerator) -> (f64, f64) {
    let cap: f64 = arch.cores.iter().map(|c| c.act_mem_bytes as f64).sum();
    let mut evs: Vec<(u64, f64)> =
        trace.events.iter().map(|e| (e.time, e.delta)).collect();
    evs.sort_by(|a, b| {
        a.0.cmp(&b.0).then(a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
    });
    let mut spilled = 0.0f64;
    let mut occ = 0.0f64;
    let mut peak = 0.0f64;
    for &(_, d) in &evs {
        if d > 0.0 {
            let over = (occ + d - cap).max(0.0) - (occ - cap).max(0.0);
            spilled += over;
        }
        occ += d;
        peak = peak.max(occ);
    }
    (peak, spilled)
}

/// One-shot convenience wrapper.
pub fn schedule(
    workload: &WorkloadGraph,
    graph: &CnGraph,
    costs: &CostModel,
    arch: &Accelerator,
    allocation: &[CoreId],
    priority: SchedulePriority,
) -> ScheduleResult {
    Scheduler::new(workload, graph, costs, arch).run(allocation, priority)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cn::{CnGranularity, CnSet};
    use crate::depgraph::generate;
    use crate::scheduler::DramKind;
    use crate::workload::models::{tiny_branchy, tiny_segment};

    fn setup(
        gran: CnGranularity,
    ) -> (WorkloadGraph, CnGraph, CostModel, Accelerator) {
        let w = tiny_segment();
        let arch = presets::test_dual();
        let cns = CnSet::build(&w, gran);
        let costs = CostModel::build(&w, &cns, &arch);
        let g = generate(&w, CnSet::build(&w, gran));
        (w, g, costs, arch)
    }

    fn simd_alloc(w: &WorkloadGraph, arch: &Accelerator, dense: CoreId) -> Vec<CoreId> {
        let simd = arch.simd_core().unwrap();
        w.layers()
            .iter()
            .map(|l| if l.op.is_dense() { dense } else { simd })
            .collect()
    }

    #[test]
    fn single_core_schedule_is_sequential() {
        let (w, g, costs, arch) = setup(CnGranularity::LayerByLayer);
        let alloc = simd_alloc(&w, &arch, CoreId(0));
        let r = schedule(&w, &g, &costs, &arch, &alloc, SchedulePriority::Latency);
        assert_eq!(r.cns.len(), g.len());
        // no two CNs overlap on the same core
        for a in &r.cns {
            for b in &r.cns {
                if a.cn != b.cn && a.core == b.core {
                    assert!(a.end <= b.start || b.end <= a.start, "{a:?} vs {b:?}");
                }
            }
        }
    }

    #[test]
    fn dependencies_respected() {
        let (w, g, costs, arch) = setup(CnGranularity::Lines(4));
        let alloc = simd_alloc(&w, &arch, CoreId(0));
        let r = schedule(&w, &g, &costs, &arch, &alloc, SchedulePriority::Latency);
        let time: std::collections::HashMap<usize, (u64, u64)> =
            r.cns.iter().map(|s| (s.cn.0, (s.start, s.end))).collect();
        for e in &g.edges {
            let (_, p_end) = time[&e.from.0];
            let (c_start, _) = time[&e.to.0];
            assert!(c_start >= p_end, "edge {:?} violated", e);
        }
    }

    #[test]
    fn fused_beats_layer_by_layer_on_memory() {
        let (w, g_f, costs_f, arch) = setup(CnGranularity::Lines(4));
        let (_, g_l, costs_l, _) = setup(CnGranularity::LayerByLayer);
        let alloc = simd_alloc(&w, &arch, CoreId(0));
        let fused = schedule(&w, &g_f, &costs_f, &arch, &alloc, SchedulePriority::Latency);
        let lbl = schedule(&w, &g_l, &costs_l, &arch, &alloc, SchedulePriority::Latency);
        assert!(
            fused.peak_mem() < 0.7 * lbl.peak_mem(),
            "fused {} vs lbl {}",
            fused.peak_mem(),
            lbl.peak_mem()
        );
    }

    #[test]
    fn memory_priority_trades_latency_for_memory() {
        let (w, g, costs, arch) = setup(CnGranularity::Lines(4));
        // split the convs across two cores to create real choice
        let simd = arch.simd_core().unwrap();
        let alloc: Vec<CoreId> = w
            .layers()
            .iter()
            .map(|l| {
                if !l.op.is_dense() {
                    simd
                } else if l.id.0 <= 1 {
                    CoreId(0)
                } else {
                    CoreId(1)
                }
            })
            .collect();
        let lat = schedule(&w, &g, &costs, &arch, &alloc, SchedulePriority::Latency);
        let mem = schedule(&w, &g, &costs, &arch, &alloc, SchedulePriority::Memory);
        assert!(mem.peak_mem() <= lat.peak_mem() * 1.05, "{} vs {}", mem.peak_mem(), lat.peak_mem());
        assert!(lat.latency() <= mem.latency(), "{} vs {}", lat.latency(), mem.latency());
    }

    #[test]
    fn cross_core_comm_appears() {
        let (w, g, costs, arch) = setup(CnGranularity::Lines(4));
        let simd = arch.simd_core().unwrap();
        // alternate dense layers between cores
        let alloc: Vec<CoreId> = w
            .layers()
            .iter()
            .map(|l| {
                if !l.op.is_dense() {
                    simd
                } else {
                    CoreId(l.id.0 % 2)
                }
            })
            .collect();
        let r = schedule(&w, &g, &costs, &arch, &alloc, SchedulePriority::Latency);
        assert!(!r.comms.is_empty());
        assert!(r.metrics.breakdown.noc_pj > 0.0);
        // bus transfers never overlap (FCFS single resource)
        let mut sorted = r.comms.clone();
        sorted.sort_by_key(|c| c.start);
        for pair in sorted.windows(2) {
            assert!(pair[0].end <= pair[1].start);
        }
        // on the shared bus every comm occupies exactly the bus link,
        // and the link counters account for all communicated bytes
        let total: u64 = sorted.iter().map(|c| c.bytes).sum();
        assert!(sorted.iter().all(|c| c.links.len() == 1));
        assert_eq!(r.link_stats[c_bus(&arch)].bytes_moved, total);
    }

    fn c_bus(arch: &Accelerator) -> usize {
        arch.topology
            .links()
            .iter()
            .position(|l| l.kind == crate::arch::LinkKind::Noc)
            .unwrap()
    }

    #[test]
    fn memtrace_residual_near_zero() {
        let (w, g, costs, arch) = setup(CnGranularity::Lines(4));
        let alloc = simd_alloc(&w, &arch, CoreId(0));
        let r = schedule(&w, &g, &costs, &arch, &alloc, SchedulePriority::Latency);
        let resid = r.memtrace.residual().abs();
        assert!(resid < 1.0, "residual {resid}");
    }

    #[test]
    fn weight_fetches_happen_once_per_layer_when_fitting() {
        let (w, g, costs, arch) = setup(CnGranularity::Lines(4));
        let alloc = simd_alloc(&w, &arch, CoreId(0));
        let r = schedule(&w, &g, &costs, &arch, &alloc, SchedulePriority::Latency);
        let n_weight_fetches =
            r.drams.iter().filter(|d| d.kind == DramKind::WeightFetch).count();
        // 3 conv layers with weights, all fit -> exactly 3 fetches
        assert_eq!(n_weight_fetches, 3);
    }

    /// The heap-backed candidate pool must reproduce the seed's linear
    /// scan bit-for-bit: same placements, same metrics, across
    /// granularities, allocations and priorities.
    #[test]
    fn heap_pool_matches_reference_scan() {
        for gran in [CnGranularity::LayerByLayer, CnGranularity::Lines(2), CnGranularity::Lines(4)]
        {
            let (w, g, costs, arch) = setup(gran);
            let simd = arch.simd_core().unwrap();
            let allocs: Vec<Vec<CoreId>> = vec![
                simd_alloc(&w, &arch, CoreId(0)),
                simd_alloc(&w, &arch, CoreId(1)),
                // alternate dense layers across cores (cross-core comms)
                w.layers()
                    .iter()
                    .map(|l| if l.op.is_dense() { CoreId(l.id.0 % 2) } else { simd })
                    .collect(),
            ];
            let sched = Scheduler::new(&w, &g, &costs, &arch);
            for alloc in &allocs {
                for pr in [SchedulePriority::Latency, SchedulePriority::Memory] {
                    let a = sched.run(alloc, pr);
                    let b = sched.run_reference(alloc, pr);
                    assert_eq!(a.metrics.latency_cc, b.metrics.latency_cc);
                    assert_eq!(a.metrics.energy_pj.to_bits(), b.metrics.energy_pj.to_bits());
                    assert_eq!(
                        a.metrics.peak_mem_bytes.to_bits(),
                        b.metrics.peak_mem_bytes.to_bits()
                    );
                    assert_eq!(a.cns.len(), b.cns.len());
                    for (x, y) in a.cns.iter().zip(&b.cns) {
                        assert_eq!((x.cn, x.core, x.start, x.end), (y.cn, y.core, y.start, y.end));
                    }
                }
            }
        }
    }

    /// Satellite coverage: the bounded-buffer gate edges built in
    /// `Scheduler::new` must exist under memory pressure, always point
    /// from a deeper-layer consumer CN back to a shallower-layer
    /// producer CN whose pending output lies above the gate's window
    /// (so they can never close a cycle with the forward data edges),
    /// and stay internally consistent with their reverse index.
    #[test]
    fn bounded_buffer_gates_constructed_and_consistent() {
        let w = tiny_segment();
        let mut arch = presets::test_dual();
        for c in &mut arch.cores {
            c.act_mem_bytes = 2 * 1024; // starve the activation budget
        }
        let gran = CnGranularity::Lines(2);
        let cns = CnSet::build(&w, gran);
        let costs = CostModel::build(&w, &cns, &arch);
        let g = generate(&w, CnSet::build(&w, gran));
        let s = Scheduler::new(&w, &g, &costs, &arch);

        let n_gates: usize = s.gate_preds.iter().map(|v| v.len()).sum();
        assert!(n_gates > 0, "tiny activation memory must gate producers");
        assert_eq!(
            n_gates,
            s.gate_succs.iter().map(|v| v.len()).sum::<usize>(),
            "forward and reverse gate indexes must agree"
        );
        for (p, gates) in s.gate_preds.iter().enumerate() {
            let pcn = g.cns.node(CnId(p));
            for gate in gates {
                let gcn = g.cns.node(*gate);
                assert!(
                    gcn.layer > pcn.layer,
                    "gate {:?} (layer {:?}) must be deeper than producer {:?} (layer {:?})",
                    gate,
                    gcn.layer,
                    pcn.id,
                    pcn.layer
                );
                assert!(
                    gcn.in_rect.hi[1] < pcn.out_rect.lo[1],
                    "gating consumer window must end below the producer's pending rows"
                );
                assert!(
                    s.gate_succs[gate.0].contains(&pcn.id),
                    "reverse index must list the gated producer"
                );
            }
        }

        // the gated graph still schedules to completion, for both
        // priorities and both pool implementations
        let alloc = simd_alloc(&w, &arch, CoreId(0));
        for pr in [SchedulePriority::Latency, SchedulePriority::Memory] {
            let a = s.run(&alloc, pr);
            let b = s.run_reference(&alloc, pr);
            assert_eq!(a.cns.len(), g.len());
            assert_eq!(a.metrics.latency_cc, b.metrics.latency_cc);
        }
    }

    /// A MatMul's B producer must never be buffer-gated by the GEMM's
    /// CNs: every GEMM CN data-depends on every B-producer CN, so a
    /// gate edge would deadlock the schedule.  Starve the activation
    /// memory (the regime that builds gates aggressively) and check
    /// the attention chain still schedules to completion.
    #[test]
    fn matmul_b_producer_is_never_gated() {
        use crate::workload::models::vit_stack;
        let w = vit_stack("gate-stack", 32, 16, 32, 1);
        let mut arch = presets::test_dual();
        for c in &mut arch.cores {
            c.act_mem_bytes = 512; // force gating everywhere possible
        }
        let gran = CnGranularity::Lines(2);
        let cns = CnSet::build(&w, gran);
        let costs = CostModel::build(&w, &cns, &arch);
        let g = generate(&w, CnSet::build(&w, gran));
        let s = Scheduler::new(&w, &g, &costs, &arch);

        // no gate edge points from a B-producer CN back to its GEMM
        for layer in w.layers() {
            for &succ in w.successors(layer.id) {
                let cons = w.layer(succ);
                if cons.op != crate::workload::OpType::MatMul
                    || !cons.predecessors.iter().skip(1).any(|p| *p == layer.id)
                {
                    continue;
                }
                for pcn in g.cns.layer_cns(layer.id) {
                    for gate in &s.gate_preds[pcn.id.0] {
                        assert_ne!(
                            g.cns.node(*gate).layer,
                            succ,
                            "B producer {} gated by its GEMM {}",
                            layer.name,
                            cons.name
                        );
                    }
                }
            }
        }

        // and the starved schedule still completes
        let alloc = simd_alloc(&w, &arch, CoreId(0));
        let r = s.run(&alloc, SchedulePriority::Latency);
        assert_eq!(r.cns.len(), g.len());
    }

    #[test]
    fn roomy_memory_builds_no_gates() {
        let w = tiny_segment();
        let mut arch = presets::test_dual();
        for c in &mut arch.cores {
            c.act_mem_bytes = 64 * 1024 * 1024; // every output fits whole
        }
        let gran = CnGranularity::Lines(4);
        let cns = CnSet::build(&w, gran);
        let costs = CostModel::build(&w, &cns, &arch);
        let g = generate(&w, CnSet::build(&w, gran));
        let s = Scheduler::new(&w, &g, &costs, &arch);
        assert!(s.gate_preds.iter().all(|v| v.is_empty()));
        assert!(s.gate_succs.iter().all(|v| v.is_empty()));
    }

    /// Satellite coverage: the single-pass peak + spill accounting.
    #[test]
    fn peak_and_spill_accounting() {
        let arch = presets::test_dual(); // pooled act capacity 320 KB
        let cap: f64 = arch.cores.iter().map(|c| c.act_mem_bytes as f64).sum();

        // under capacity: peak tracked, nothing spills
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), cap - 10.0);
        t.push(5, CoreId(0), -(cap - 10.0));
        let (peak, spill) = peak_and_spill(&t, &arch);
        assert_eq!(peak, cap - 10.0);
        assert_eq!(spill, 0.0);

        // overflowing alloc spills exactly the overshoot
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), cap);
        t.push(1, CoreId(1), 100.0);
        t.push(2, CoreId(0), -cap);
        let (peak, spill) = peak_and_spill(&t, &arch);
        assert_eq!(peak, cap + 100.0);
        assert_eq!(spill, 100.0);

        // same-timestamp free+alloc must net out (free sorts first)
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), cap);
        t.push(3, CoreId(0), -cap);
        t.push(3, CoreId(1), cap);
        let (peak, spill) = peak_and_spill(&t, &arch);
        assert_eq!(peak, cap);
        assert_eq!(spill, 0.0);

        // repeated overshoot spills every round trip
        let mut t = MemTrace::new();
        t.push(0, CoreId(0), cap);
        t.push(1, CoreId(0), 50.0);
        t.push(2, CoreId(0), -50.0);
        t.push(3, CoreId(0), 50.0);
        let (_, spill) = peak_and_spill(&t, &arch);
        assert_eq!(spill, 100.0);
    }

    #[test]
    fn mesh_topology_schedules_with_multi_hop_comms() {
        let w = tiny_segment();
        let arch = presets::by_name("hetero@mesh").unwrap();
        let gran = CnGranularity::Lines(4);
        let cns = CnSet::build(&w, gran);
        let costs = CostModel::build(&w, &cns, &arch);
        let g = generate(&w, CnSet::build(&w, gran));
        let simd = arch.simd_core().unwrap();
        // spread dense layers over all four dense cores
        let alloc: Vec<CoreId> = w
            .layers()
            .iter()
            .map(|l| if l.op.is_dense() { CoreId(l.id.0 % 4) } else { simd })
            .collect();
        let r = schedule(&w, &g, &costs, &arch, &alloc, SchedulePriority::Latency);
        assert_eq!(r.cns.len(), g.len());
        assert!(
            r.comms.iter().any(|c| c.links.len() > 1),
            "a 5-core mesh must route some transfer over multiple hops"
        );
        // every event's bytes are accounted on every link it crossed
        for c in &r.comms {
            for l in c.links.iter() {
                assert!(r.link_stats[l.0].bytes_moved >= c.bytes);
            }
        }
        // dependencies still respected under multi-hop contention
        let time: std::collections::HashMap<usize, (u64, u64)> =
            r.cns.iter().map(|s| (s.cn.0, (s.start, s.end))).collect();
        for e in &g.edges {
            assert!(time[&e.to.0].0 >= time[&e.from.0].1);
        }
    }

    #[test]
    fn branchy_workload_schedules() {
        let w = tiny_branchy();
        let arch = presets::test_dual();
        let cns = CnSet::build(&w, CnGranularity::Lines(2));
        let costs = CostModel::build(&w, &cns, &arch);
        let g = generate(&w, CnSet::build(&w, CnGranularity::Lines(2)));
        let simd = arch.simd_core().unwrap();
        let alloc: Vec<CoreId> = w
            .layers()
            .iter()
            .map(|l| if l.op.is_dense() { CoreId(l.id.0 % 2) } else { simd })
            .collect();
        for pr in [SchedulePriority::Latency, SchedulePriority::Memory] {
            let r = schedule(&w, &g, &costs, &arch, &alloc, pr);
            assert_eq!(r.cns.len(), g.len());
            assert!(r.latency() > 0);
        }
    }

    /// Every observable of two results, bit-for-bit.
    fn assert_identical(a: &ScheduleResult, b: &ScheduleResult) {
        assert_eq!(a.metrics.latency_cc, b.metrics.latency_cc);
        assert_eq!(a.metrics.energy_pj.to_bits(), b.metrics.energy_pj.to_bits());
        assert_eq!(a.metrics.peak_mem_bytes.to_bits(), b.metrics.peak_mem_bytes.to_bits());
        assert_eq!(a.metrics.avg_core_util.to_bits(), b.metrics.avg_core_util.to_bits());
        assert_eq!(a.cns.len(), b.cns.len());
        for (x, y) in a.cns.iter().zip(&b.cns) {
            assert_eq!((x.cn, x.core, x.start, x.end), (y.cn, y.core, y.start, y.end));
        }
        assert_eq!(a.comms.len(), b.comms.len());
        for (x, y) in a.comms.iter().zip(&b.comms) {
            assert_eq!(
                (x.from_core, x.to_core, x.start, x.end, x.bytes),
                (y.from_core, y.to_core, y.start, y.end, y.bytes)
            );
            assert_eq!(x.links, y.links);
        }
        assert_eq!(a.drams.len(), b.drams.len());
        for (x, y) in a.drams.iter().zip(&b.drams) {
            assert_eq!(
                (x.core, x.start, x.end, x.bytes, x.kind),
                (y.core, y.start, y.end, y.bytes, y.kind)
            );
            assert_eq!(x.links, y.links);
        }
        assert_eq!(a.link_stats, b.link_stats);
        assert_eq!(a.memtrace.events.len(), b.memtrace.events.len());
    }

    /// Tentpole pin: traced-run snapshots replay bit-identically —
    /// resumed under the same allocation from every snapshot, resumed
    /// under a child allocation from the divergence point, and resumed
    /// again from the child's own (partly inherited) segments.
    #[test]
    fn delta_resume_is_bit_identical() {
        let (w, g, costs, arch) = setup(CnGranularity::Lines(2));
        let simd = arch.simd_core().unwrap();
        let s = Scheduler::new(&w, &g, &costs, &arch);
        let parent = simd_alloc(&w, &arch, CoreId(0));
        // children: each dense layer moved alone, plus an alternating mix
        let mut children: Vec<Vec<CoreId>> = Vec::new();
        for l in w.layers().iter().filter(|l| l.op.is_dense()) {
            let mut c = parent.clone();
            c[l.id.0] = CoreId(1);
            children.push(c);
        }
        children.push(
            w.layers()
                .iter()
                .map(|l| if l.op.is_dense() { CoreId(l.id.0 % 2) } else { simd })
                .collect(),
        );

        for pr in [SchedulePriority::Latency, SchedulePriority::Memory] {
            for every in [1usize, 3, s.snap_interval()] {
                let (base, segs) = s.run_traced(&parent, pr, every);
                let cold = s.run(&parent, pr);
                assert_identical(&base, &cold);
                assert!(segs.snapshots().len() > 1, "interval {every} snapshotted nothing");
                for snap in segs.snapshots() {
                    assert_identical(&s.run_resumed(&parent, pr, snap), &cold);
                }
                let mut resumed = 0;
                for c in &children {
                    let d = segs.divergence(&parent, c);
                    assert!(d > 0, "no dense layer is visible before the first decision");
                    let cold_c = s.run(c, pr);
                    if let Some((warm, child_segs)) = s.run_resumed_traced(c, pr, &segs, d, every)
                    {
                        resumed += 1;
                        assert_identical(&warm, &cold_c);
                        // the child's segments must serve as a parent too
                        let d2 = child_segs.divergence(c, &parent);
                        if let Some((back, _)) =
                            s.run_resumed_traced(&parent, pr, &child_segs, d2, every)
                        {
                            assert_identical(&back, &cold);
                        }
                    }
                }
                if every == 1 {
                    assert_eq!(resumed, children.len(), "every=1 must always find a snapshot");
                }
            }
        }
    }

    /// The early-abort floors must never exceed what simulation reports
    /// (spot admissibility; the randomized sweep lives in
    /// `rust/tests/delta_equivalence.rs`).
    #[test]
    fn lower_bounds_never_exceed_simulation() {
        for gran in [CnGranularity::LayerByLayer, CnGranularity::Lines(2)] {
            let (w, g, costs, arch) = setup(gran);
            let simd = arch.simd_core().unwrap();
            let s = Scheduler::new(&w, &g, &costs, &arch);
            let allocs: Vec<Vec<CoreId>> = vec![
                simd_alloc(&w, &arch, CoreId(0)),
                simd_alloc(&w, &arch, CoreId(1)),
                w.layers()
                    .iter()
                    .map(|l| if l.op.is_dense() { CoreId(l.id.0 % 2) } else { simd })
                    .collect(),
            ];
            for alloc in &allocs {
                let lb = s.lower_bounds(alloc);
                assert!(lb.latency_cc > 0, "floors must be nontrivial");
                assert!(lb.energy_pj > 0.0);
                for pr in [SchedulePriority::Latency, SchedulePriority::Memory] {
                    let r = s.run(alloc, pr);
                    assert!(lb.latency_cc <= r.metrics.latency_cc);
                    assert!(lb.energy_pj <= r.metrics.energy_pj);
                    assert!(lb.peak_mem_bytes <= r.metrics.peak_mem_bytes);
                }
            }
        }
    }
}
