//! The scheduler's candidate pool: O(log n) selection via lazy binary
//! heaps plus per-core ready buckets.
//!
//! The seed implementation kept ready CNs in a flat `Vec` and ran an
//! O(n) scan per pick — O(n²) per schedule, the dominant cost once the
//! GA multiplies it by population × generations.  This pool keeps three
//! heaps over the same candidates, all with **lazy invalidation**
//! (entries are validated against the slot table when popped, never
//! removed eagerly):
//!
//! - `lat` — min-heap on `(effective_ready, layer, idx)`, the
//!   [`SchedulePriority::Latency`] order.  *Effective* readiness adds
//!   the layer's DRAM weight-fetch time when its weights are not
//!   resident on its core; residency changes re-key affected entries
//!   (see below), so a popped entry is discarded as stale when its
//!   stored key no longer matches the slot's current value.
//! - `depth` — max-heap on `(layer, -idx)`, the
//!   [`SchedulePriority::Memory`] order and the drain order used by
//!   both priorities when no candidate's output fits in the pooled
//!   activation capacity.
//! - `minout` — min-heap on `output_bytes`, giving the O(log n)
//!   "does *anything* still fit" feasibility test that the seed
//!   answered with a full scan.
//!
//! **Per-core ready buckets** (`by_core`) index the pooled CNs with a
//! nonzero weight fetch by their allocated core.  When a weight fetch
//! on core *c* changes residency (the fetched layer becomes resident,
//! FIFO-evicted layers stop being resident), the scheduler calls
//! [`CandidatePool::rekey_core`] and only bucket *c* is re-keyed —
//! stale heap entries are left behind and dropped lazily on pop.
//!
//! Every candidate always owns at least one heap entry carrying its
//! *current* key (insert pushes one; every re-key pushes one), so the
//! first popped entry that matches its slot is the true optimum; keys
//! are unique because `(layer, idx)` identifies a CN.  This makes the
//! heap path pick-for-pick identical to the linear reference scan
//! ([`CandidatePool::pop_linear`], kept for the equivalence tests and
//! the `hotpath` bench baseline).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cn::CnId;
use crate::scheduler::SchedulePriority;
use crate::workload::LayerId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// Not (yet) a candidate: predecessors pending, slot unused.
    Out,
    /// In the pool, selectable.
    In,
    /// Picked and scheduled; heap leftovers are stale.
    Done,
}

#[derive(Debug, Clone, Copy)]
struct Slot {
    ready: u64,
    /// ready + weight-fetch time when the layer's weights are not
    /// resident on its core; kept current by [`CandidatePool::rekey_core`].
    eff: u64,
    out_bytes: u64,
    layer: usize,
    idx: usize,
    state: State,
}

const EMPTY_SLOT: Slot =
    Slot { ready: 0, eff: 0, out_bytes: 0, layer: 0, idx: 0, state: State::Out };

/// See the [module docs](self).
///
/// `Clone` is part of the contract: the delta-evaluation snapshots
/// (`super::sim::SimSnapshot`) clone in-flight pools, and
/// `BinaryHeap`'s `Clone` preserves the backing vector verbatim, so a
/// cloned pool pops in exactly the same order as the original
/// (`clone_pops_identically` below pins this).
#[derive(Debug, Clone)]
pub(crate) struct CandidatePool {
    lat: BinaryHeap<Reverse<(u64, usize, usize, usize)>>, // (eff, layer, idx, cn)
    depth: BinaryHeap<(usize, Reverse<usize>, usize)>,    // (layer, -idx, cn)
    minout: BinaryHeap<Reverse<(u64, usize)>>,            // (out_bytes, cn)
    slots: Vec<Slot>,
    by_core: Vec<Vec<usize>>,
    len: usize,
}

impl CandidatePool {
    pub fn new(n_cns: usize, n_cores: usize) -> CandidatePool {
        CandidatePool {
            lat: BinaryHeap::with_capacity(n_cns),
            depth: BinaryHeap::with_capacity(n_cns),
            minout: BinaryHeap::with_capacity(n_cns),
            slots: vec![EMPTY_SLOT; n_cns],
            by_core: vec![Vec::new(); n_cores],
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Add a CN whose predecessors are all scheduled.  `watch_core` is
    /// set for CNs with a nonzero weight fetch: their effective
    /// readiness depends on the weight residency of `core`.
    #[allow(clippy::too_many_arguments)]
    pub fn insert(
        &mut self,
        cn: CnId,
        layer: LayerId,
        idx: usize,
        ready: u64,
        eff: u64,
        out_bytes: u64,
        core: usize,
        watch_core: bool,
    ) {
        let i = cn.0;
        debug_assert_eq!(self.slots[i].state, State::Out, "CN inserted twice");
        self.slots[i] =
            Slot { ready, eff, out_bytes, layer: layer.0, idx, state: State::In };
        self.lat.push(Reverse((eff, layer.0, idx, i)));
        self.depth.push((layer.0, Reverse(idx), i));
        self.minout.push(Reverse((out_bytes, i)));
        if watch_core {
            self.by_core[core].push(i);
        }
        self.len += 1;
        crate::obs::count(crate::obs::Counter::PoolPushes, 1);
    }

    /// Smallest *current* effective readiness among pooled candidates,
    /// without removing anything — the unified core's inter-request
    /// arbitration signal.  Stale `lat` leftovers (taken CNs, superseded
    /// re-keys) are popped on the way; every live candidate always owns
    /// one entry carrying its current key, so the first valid top is the
    /// true minimum.
    pub fn peek_min_eff(&mut self) -> Option<u64> {
        while let Some(&Reverse((eff, _, _, cn))) = self.lat.peek() {
            if self.slots[cn].state == State::In && eff == self.slots[cn].eff {
                return Some(eff);
            }
            self.lat.pop();
        }
        None
    }

    fn fits(&self, cn: usize, act_occ: f64, act_cap: f64) -> bool {
        act_occ + self.slots[cn].out_bytes as f64 <= act_cap
    }

    /// O(log n) feasibility: does any pooled CN's output still fit in
    /// the activation capacity?  (Pops stale `minout` leftovers.)
    fn any_fits(&mut self, act_occ: f64, act_cap: f64) -> bool {
        while let Some(&Reverse((out, cn))) = self.minout.peek() {
            if self.slots[cn].state == State::In {
                return act_occ + out as f64 <= act_cap;
            }
            self.minout.pop();
        }
        false
    }

    fn take(&mut self, cn: usize) -> CnId {
        self.slots[cn].state = State::Done;
        self.len -= 1;
        crate::obs::count(crate::obs::Counter::PoolPops, 1);
        CnId(cn)
    }

    /// Deepest-layer, smallest-idx candidate — the drain order under
    /// memory pressure and the base order of the Memory priority.  When
    /// `respect_fit` is set, non-fitting candidates are skipped (and
    /// restored afterwards).
    fn pop_deepest(&mut self, act_occ: f64, act_cap: f64, respect_fit: bool) -> CnId {
        let mut stash: Vec<(usize, Reverse<usize>, usize)> = Vec::new();
        let picked = loop {
            let e = self.depth.pop().expect("pool not empty");
            let cn = e.2;
            if self.slots[cn].state != State::In {
                continue; // stale leftover of an already-picked CN
            }
            if respect_fit && !self.fits(cn, act_occ, act_cap) {
                stash.push(e);
                continue;
            }
            break cn;
        };
        self.depth.extend(stash);
        self.take(picked)
    }

    /// Pop under [`SchedulePriority::Memory`]: the deepest ready CN
    /// whose output fits, or — when nothing fits — the deepest ready CN
    /// outright (its discards free the most upstream data).
    pub fn pop_memory(&mut self, act_occ: f64, act_cap: f64) -> Option<CnId> {
        if self.len() == 0 {
            return None;
        }
        let respect_fit = self.any_fits(act_occ, act_cap);
        Some(self.pop_deepest(act_occ, act_cap, respect_fit))
    }

    /// Pop under [`SchedulePriority::Latency`]: minimum effective
    /// readiness among fitting candidates; same memory-full drain as
    /// the Memory priority otherwise.
    pub fn pop_latency(&mut self, act_occ: f64, act_cap: f64) -> Option<CnId> {
        if self.len() == 0 {
            return None;
        }
        if !self.any_fits(act_occ, act_cap) {
            return Some(self.pop_deepest(act_occ, act_cap, false));
        }
        let mut stash: Vec<Reverse<(u64, usize, usize, usize)>> = Vec::new();
        let picked = loop {
            let e = self.lat.pop().expect("a fitting candidate exists");
            let Reverse((eff, _, _, cn)) = e;
            if self.slots[cn].state != State::In || eff != self.slots[cn].eff {
                continue; // taken, or re-keyed since this entry was pushed
            }
            if !self.fits(cn, act_occ, act_cap) {
                stash.push(e);
                continue;
            }
            break cn;
        };
        self.lat.extend(stash);
        Some(self.take(picked))
    }

    /// Weight residency on `core` changed: re-key the effective
    /// readiness of that core's bucket.  `extra_of(layer)` returns
    /// `Some(extra_cycles)` for layers whose residency changed (0 when
    /// the layer just became resident, its DRAM fetch time when it was
    /// just evicted) and `None` for unaffected layers.
    pub fn rekey_core<F: Fn(LayerId) -> Option<u64>>(&mut self, core: usize, extra_of: F) {
        let mut bucket = std::mem::take(&mut self.by_core[core]);
        bucket.retain(|&cn| self.slots[cn].state == State::In);
        for &cn in &bucket {
            if let Some(extra) = extra_of(LayerId(self.slots[cn].layer)) {
                let new_eff = self.slots[cn].ready + extra;
                if new_eff != self.slots[cn].eff {
                    self.slots[cn].eff = new_eff;
                    self.lat.push(Reverse((
                        new_eff,
                        self.slots[cn].layer,
                        self.slots[cn].idx,
                        cn,
                    )));
                }
            }
        }
        self.by_core[core] = bucket;
    }

    /// The seed's O(n) scan, byte-for-byte the same selection rule —
    /// kept as the reference implementation for the heap-equivalence
    /// tests and the `hotpath` bench baseline.
    pub fn pop_linear(
        &mut self,
        priority: SchedulePriority,
        act_occ: f64,
        act_cap: f64,
    ) -> Option<CnId> {
        if self.len() == 0 {
            return None;
        }
        let pooled: Vec<usize> = (0..self.slots.len())
            .filter(|&i| self.slots[i].state == State::In)
            .collect();
        let any_fits = pooled.iter().any(|&i| self.fits(i, act_occ, act_cap));
        let best = if !any_fits {
            *pooled
                .iter()
                .max_by_key(|&&i| (self.slots[i].layer, Reverse(self.slots[i].idx)))
                .unwrap()
        } else {
            match priority {
                SchedulePriority::Latency => *pooled
                    .iter()
                    .filter(|&&i| self.fits(i, act_occ, act_cap))
                    .min_by_key(|&&i| {
                        (self.slots[i].eff, self.slots[i].layer, self.slots[i].idx)
                    })
                    .unwrap(),
                SchedulePriority::Memory => *pooled
                    .iter()
                    .filter(|&&i| self.fits(i, act_occ, act_cap))
                    .max_by_key(|&&i| {
                        (
                            self.slots[i].layer,
                            Reverse(self.slots[i].idx),
                            Reverse(self.slots[i].ready),
                        )
                    })
                    .unwrap(),
            }
        };
        Some(self.take(best))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    fn pool_with(cands: &[(usize, usize, u64, u64)]) -> CandidatePool {
        // (layer, idx, ready/eff, out_bytes); no weight-fetch watching
        let mut p = CandidatePool::new(cands.len(), 2);
        for (i, &(layer, idx, ready, out)) in cands.iter().enumerate() {
            p.insert(CnId(i), LayerId(layer), idx, ready, ready, out, 0, false);
        }
        p
    }

    #[test]
    fn memory_priority_pops_deepest_first() {
        let mut p = pool_with(&[(0, 0, 5, 1), (1, 3, 9, 1), (1, 1, 7, 1), (2, 0, 8, 1)]);
        let order: Vec<usize> = std::iter::from_fn(|| p.pop_memory(0.0, 1e9)).map(|c| c.0).collect();
        // deepest layer first; within a layer, smallest idx first
        assert_eq!(order, vec![3, 2, 1, 0]);
    }

    #[test]
    fn latency_priority_pops_earliest_ready() {
        let mut p = pool_with(&[(0, 0, 5, 1), (1, 0, 3, 1), (2, 0, 4, 1), (3, 0, 3, 1)]);
        let order: Vec<usize> = std::iter::from_fn(|| p.pop_latency(0.0, 1e9)).map(|c| c.0).collect();
        // eff 3 (layer 1) before eff 3 (layer 3): layer breaks the tie
        assert_eq!(order, vec![1, 3, 2, 0]);
    }

    #[test]
    fn memory_full_drains_deepest() {
        // nothing fits: capacity 10, occupancy 8, outputs 4
        let mut p = pool_with(&[(0, 0, 1, 4), (2, 1, 9, 4), (2, 0, 9, 4)]);
        assert_eq!(p.pop_latency(8.0, 10.0).unwrap().0, 2);
        // with room, the earliest-ready shallow CN wins again
        assert_eq!(p.pop_latency(0.0, 10.0).unwrap().0, 0);
    }

    #[test]
    fn fitting_filter_skips_large_outputs() {
        // CN 0 ready first but too large; CN 1 fits
        let mut p = pool_with(&[(0, 0, 1, 100), (0, 1, 2, 1)]);
        assert_eq!(p.pop_latency(5.0, 10.0).unwrap().0, 1);
        // stash restored: CN 0 still poppable once occupancy drops
        assert_eq!(p.pop_latency(0.0, 200.0).unwrap().0, 0);
        assert!(p.pop_latency(0.0, 200.0).is_none());
    }

    #[test]
    fn rekey_core_changes_latency_order() {
        let mut p = CandidatePool::new(2, 2);
        // CN 0: ready 0 but weights not resident -> eff 100, watched on core 1
        p.insert(CnId(0), LayerId(0), 0, 0, 100, 1, 1, true);
        // CN 1: ready 10, resident
        p.insert(CnId(1), LayerId(1), 0, 10, 10, 1, 0, false);
        // before the event, CN 1 wins; then layer 0 becomes resident on core 1
        p.rekey_core(1, |l| if l == LayerId(0) { Some(0) } else { None });
        assert_eq!(p.pop_latency(0.0, 1e9).unwrap().0, 0);
        assert_eq!(p.pop_latency(0.0, 1e9).unwrap().0, 1);
    }

    #[test]
    fn rekey_eviction_pushes_candidate_back() {
        let mut p = CandidatePool::new(2, 1);
        // both resident initially
        p.insert(CnId(0), LayerId(0), 0, 5, 5, 1, 0, true);
        p.insert(CnId(1), LayerId(1), 0, 6, 6, 1, 0, true);
        // layer 0 evicted: its fetch costs 50 cycles
        p.rekey_core(0, |l| if l == LayerId(0) { Some(50) } else { None });
        assert_eq!(p.pop_latency(0.0, 1e9).unwrap().0, 1);
        assert_eq!(p.pop_latency(0.0, 1e9).unwrap().0, 0);
    }

    #[test]
    fn peek_min_eff_tracks_rekeys_and_takes() {
        let mut p = CandidatePool::new(2, 1);
        p.insert(CnId(0), LayerId(0), 0, 5, 5, 1, 0, true);
        p.insert(CnId(1), LayerId(1), 0, 9, 9, 1, 0, false);
        assert_eq!(p.peek_min_eff(), Some(5));
        // evicting layer 0 re-keys CN 0 to 5 + 50: CN 1 is now minimal
        p.rekey_core(0, |l| if l == LayerId(0) { Some(50) } else { None });
        assert_eq!(p.peek_min_eff(), Some(9));
        assert_eq!(p.pop_latency(0.0, 1e9).unwrap().0, 1);
        assert_eq!(p.peek_min_eff(), Some(55));
        assert_eq!(p.pop_latency(0.0, 1e9).unwrap().0, 0);
        assert_eq!(p.peek_min_eff(), None);
    }

    /// A cloned pool must pop identically to the original — the
    /// snapshot/resume path of the delta evaluator clones pools
    /// mid-flight, so `BinaryHeap`'s vector-preserving `Clone` is a
    /// correctness dependency, not a convenience.
    #[test]
    fn clone_pops_identically() {
        let mut rng = XorShift64::new(0xBEEF);
        for round in 0..50 {
            let n = 3 + (rng.below(20) as usize);
            let mut p = CandidatePool::new(n, 2);
            let mut idx_in_layer = [0usize; 4];
            for i in 0..n {
                let layer = rng.below(4) as usize;
                let idx = idx_in_layer[layer];
                idx_in_layer[layer] += 1;
                let ready = rng.below(80);
                let fetch = if rng.unit() < 0.5 { rng.below(30) + 1 } else { 0 };
                p.insert(
                    CnId(i),
                    LayerId(layer),
                    idx,
                    ready,
                    ready + fetch,
                    rng.below(40) + 1,
                    i % 2,
                    fetch > 0,
                );
            }
            // pop a prefix, re-key a core, then clone mid-flight
            for _ in 0..rng.below(n as u64 / 2 + 1) {
                p.pop_latency(0.0, 1e9);
            }
            let extra = rng.below(60);
            p.rekey_core(0, |l| if l == LayerId(1) { Some(extra) } else { None });
            let mut q = p.clone();
            assert_eq!(p.len(), q.len());
            for pr in [SchedulePriority::Latency, SchedulePriority::Memory] {
                let mut a = p.clone();
                let mut b = q.clone();
                loop {
                    assert_eq!(a.peek_min_eff(), b.peek_min_eff(), "round {round}");
                    let (x, y) = match pr {
                        SchedulePriority::Latency => {
                            (a.pop_latency(10.0, 35.0), b.pop_latency(10.0, 35.0))
                        }
                        SchedulePriority::Memory => {
                            (a.pop_memory(10.0, 35.0), b.pop_memory(10.0, 35.0))
                        }
                    };
                    assert_eq!(x, y, "round {round}");
                    if x.is_none() {
                        break;
                    }
                }
            }
        }
    }

    /// The load-bearing test: the heap path and the seed's linear scan
    /// agree pick-for-pick under randomized candidates, occupancies and
    /// residency re-key events, for both priorities.
    #[test]
    fn heap_matches_linear_reference_fuzz() {
        for priority in [SchedulePriority::Latency, SchedulePriority::Memory] {
            let mut rng = XorShift64::new(0xC0FFEE);
            for round in 0..200 {
                let n = 2 + (rng.below(30) as usize);
                let n_layers = 1 + (rng.below(6) as usize);
                // unique (layer, idx) pairs; random ready/eff/out
                let mut idx_in_layer = vec![0usize; n_layers];
                let cands: Vec<(usize, usize, u64, u64, u64, bool)> = (0..n)
                    .map(|_| {
                        let layer = rng.below(n_layers as u64) as usize;
                        let idx = idx_in_layer[layer];
                        idx_in_layer[layer] += 1;
                        let ready = rng.below(100);
                        let fetch = if rng.unit() < 0.5 { rng.below(40) + 1 } else { 0 };
                        let out = rng.below(50) + 1;
                        (layer, idx, ready, ready + fetch, out, fetch > 0)
                    })
                    .collect();

                let build = || {
                    let mut p = CandidatePool::new(n, 2);
                    for (i, &(layer, idx, ready, eff, out, watch)) in cands.iter().enumerate()
                    {
                        p.insert(CnId(i), LayerId(layer), idx, ready, eff, out, i % 2, watch);
                    }
                    p
                };
                let mut heap = build();
                let mut linear = build();

                let cap = 30.0 + rng.below(60) as f64;
                let mut occ = 0.0f64;
                let mut events = XorShift64::new(round + 1);
                for _ in 0..n {
                    // occasionally flip residency of a random layer on a
                    // random core (same event applied to both pools)
                    if events.unit() < 0.4 {
                        let layer = LayerId(events.below(n_layers as u64) as usize);
                        let core = events.below(2) as usize;
                        let extra = events.below(60);
                        let f = |l: LayerId| if l == layer { Some(extra) } else { None };
                        heap.rekey_core(core, f);
                        linear.rekey_core(core, f);
                    }
                    let a = match priority {
                        SchedulePriority::Latency => heap.pop_latency(occ, cap),
                        SchedulePriority::Memory => heap.pop_memory(occ, cap),
                    };
                    let b = linear.pop_linear(priority, occ, cap);
                    assert_eq!(a, b, "round {round}, occ {occ}, cap {cap}");
                    occ = (occ + events.below(25) as f64 - 10.0).max(0.0);
                }
                assert!(heap.pop_linear(priority, occ, cap).is_none());
            }
        }
    }
}
