//! Per-(CN, core) energy & latency extraction.
//!
//! Access-count model (ZigZag-lite): each operand's SRAM traffic is its
//! footprint times a *refetch factor* — how often the temporal mapping
//! must re-stream it because the PE-local register file cannot hold the
//! full reuse window:
//!
//! - activations are re-read once per output-channel slice that doesn't
//!   fit the spatial K-unroll times the RF's psum depth ([`REG_K`]);
//! - weights are re-read once per output-pixel tile beyond the spatial
//!   OX/OY-unroll times the RF's pixel-streaming window ([`REG_PIX`]).
//!
//! This captures the first-order dataflow asymmetries (a `C|K` core
//! streams pixels through stationary weights; an `OX|F` core streams
//! weights through stationary rows) without a full temporal-mapping
//! search, and it is exactly the kind of cost ZigZag/LOMA would return
//! as the optimum of that search.

use std::collections::HashMap;

use crate::arch::{Accelerator, Core, CoreId, CoreKind};
use crate::cn::{CnSet, ComputationNode};
use crate::workload::{Dim, Layer, OpType, WorkloadGraph};

use super::spatial::{spatial_utilization, temporal_iterations};

/// Psum slots per PE register file (output channels kept resident).
const REG_K: usize = 8;
/// Output pixels streamed per weight residency window.
const REG_PIX: usize = 64;

/// Cost of executing one CN on one core.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CnCost {
    /// Cycles the PE array / SIMD unit is busy (incl. bandwidth stalls).
    pub compute_cycles: u64,
    /// Core-internal energy: MACs + SRAM accesses (pJ).
    pub energy_pj: f64,
    /// MAC-only share of `energy_pj` (for the Fig. 15 breakdown).
    pub mac_energy_pj: f64,
    /// Spatial utilization of the PE array in (0, 1].
    pub spatial_util: f64,
}

impl CnCost {
    /// Energy-delay product contribution (pJ x cycles).
    pub fn edp(&self) -> f64 {
        self.energy_pj * self.compute_cycles as f64
    }
}

/// Memoized cost model over a fixed (workload, architecture) pair.
///
/// Costs depend only on `(layer, core, out_lines, in_rows)`, so the
/// table stays small (a few entries per layer-core pair) regardless of
/// CN count; lookups on the GA/scheduler hot path are hash-map reads.
pub struct CostModel {
    table: HashMap<(usize, usize, u32, u32), CnCost>,
}

impl CostModel {
    /// Precompute every (CN shape, core) combination of the set.
    pub fn build(workload: &WorkloadGraph, cns: &CnSet, arch: &Accelerator) -> CostModel {
        let mut table = HashMap::new();
        for cn in &cns.nodes {
            let layer = workload.layer(cn.layer);
            for core in &arch.cores {
                let key = Self::key(cn, core.id);
                table
                    .entry(key)
                    .or_insert_with(|| compute_cost(layer, cn, core));
            }
        }
        CostModel { table }
    }

    fn key(cn: &ComputationNode, core: CoreId) -> (usize, usize, u32, u32) {
        let out_lines = (cn.out_rect.hi[1] - cn.out_rect.lo[1]) as u32;
        let in_rows = (cn.in_rect.hi[1] - cn.in_rect.lo[1]) as u32;
        (cn.layer.0, core.0, out_lines, in_rows)
    }

    /// Cost of `cn` on `core` (must be a combination seen at build time).
    pub fn cn_cost(&self, cn: &ComputationNode, core: CoreId) -> CnCost {
        self.table[&Self::key(cn, core)]
    }

    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }
}

/// Analytic cost of one CN on one core.
pub fn compute_cost(layer: &Layer, cn: &ComputationNode, core: &Core) -> CnCost {
    match core.kind {
        CoreKind::Simd { lanes, op_pj } => simd_cost(layer, cn, core, lanes, op_pj),
        _ => dense_cost(layer, cn, core),
    }
}

fn dense_cost(layer: &Layer, cn: &ComputationNode, core: &Core) -> CnCost {
    let lines = cn.out_lines();
    let df = &core.dataflow;
    let util = spatial_utilization(layer, lines, df);
    let iters = temporal_iterations(layer, lines, df);

    let macs = cn.macs;
    let in_elems = cn.in_rect.volume();
    let out_elems = cn.out_rect.volume();
    let wgt_elems = match layer.op {
        OpType::Conv => (layer.k * layer.c * layer.fy * layer.fx) as u64,
        OpType::DwConv => (layer.k * layer.fy * layer.fx) as u64,
        OpType::Fc => (layer.k * layer.c) as u64,
        // MatMul: the B operand occupies the weight position of the
        // dataflow — same reuse structure as FC weights — but it is a
        // *streamed activation* (read below at activation precision
        // from the activation SRAM, not the weight SRAM).
        OpType::MatMul => (layer.k * layer.c) as u64,
        _ => 0,
    };
    let streamed_b = layer.op == OpType::MatMul;
    let wgt_bits = (if streamed_b { layer.act_bits } else { layer.wgt_bits }) as u64;

    // refetch factors from the register-file reuse windows
    let k_slices = layer.k.div_ceil(df.unroll(Dim::K) * REG_K).max(1) as u64;
    let pix_per_window = (df.unroll(Dim::OX) * df.unroll(Dim::OY) * REG_PIX).max(1);
    let out_pix = (lines * layer.ox).max(1);
    // Weight streaming continues across back-to-back CNs of the same
    // layer on a core, so the weight-read count is pro-rated by the CN's
    // share of the layer's output pixels (fractional windows) rather
    // than ceil'd per CN — otherwise fine granularities would be charged
    // n_CNs x the layer's weight traffic, which no real core pays.
    let layer_pix = (layer.oy * layer.ox).max(1) as f64;
    let pix_tiles_f =
        (out_pix as f64 / pix_per_window as f64).max(out_pix as f64 / layer_pix);

    let act_reads = in_elems * k_slices;
    let wgt_reads = (wgt_elems as f64 * pix_tiles_f).ceil() as u64;
    let out_writes = out_elems;

    // energy
    let mac_e = macs as f64 * core.mac_pj();
    let act_e = act_reads as f64 * core.act_read_pj(layer.act_bits as u64);
    let wgt_e = wgt_reads as f64
        * if streamed_b {
            core.act_read_pj(layer.act_bits as u64)
        } else {
            core.wgt_read_pj(layer.wgt_bits as u64)
        };
    let out_e = out_writes as f64 * core.act_write_pj(layer.act_bits as u64);
    let energy = mac_e + act_e + wgt_e + out_e;

    // latency: ideal temporal iterations, stretched by SRAM bandwidth.
    // AiMC arrays apply multi-bit activations bit-serially on the DACs
    // (2 bits per cycle in the Jia et al. class of designs), so their
    // temporal iterations scale with act_bits / 2.
    let bit_serial = match core.kind {
        CoreKind::Aimc { act_bits_per_cycle, .. } => {
            (layer.act_bits as u64).div_ceil(act_bits_per_cycle.max(1) as u64).max(1)
        }
        _ => 1,
    };
    let traffic_bits = act_reads * layer.act_bits as u64
        + wgt_reads * wgt_bits
        + out_writes * layer.act_bits as u64;
    let ideal = (iters * bit_serial).max(1);
    let mem_cycles = traffic_bits.div_ceil(core.sram_bw_bits.max(1));
    let compute_cycles = ideal.max(mem_cycles);

    CnCost {
        compute_cycles,
        energy_pj: energy,
        mac_energy_pj: mac_e,
        spatial_util: util,
    }
}

fn simd_cost(layer: &Layer, cn: &ComputationNode, core: &Core, lanes: usize, op_pj: f64) -> CnCost {
    // ops: window ops for pool, element ops for add / gelu, two-pass
    // element ops for layernorm / softmax (folded into cn.macs),
    // pure copy for concat
    let ops = match layer.op {
        OpType::Concat => cn.out_rect.volume(), // copy traffic only
        _ => cn.macs.max(cn.out_rect.volume()),
    };
    let out_elems = cn.out_rect.volume();
    let reads = ops;
    let writes = out_elems;

    let ideal = ops.div_ceil(lanes as u64).max(1);
    let traffic_bits = (reads + writes) * layer.act_bits as u64;
    let mem_cycles = traffic_bits.div_ceil(core.sram_bw_bits.max(1));
    let compute_cycles = ideal.max(mem_cycles);

    let e = ops as f64 * op_pj
        + reads as f64 * core.act_read_pj(layer.act_bits as u64)
        + writes as f64 * core.act_write_pj(layer.act_bits as u64);

    CnCost {
        compute_cycles,
        energy_pj: e,
        mac_energy_pj: ops as f64 * op_pj,
        spatial_util: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cn::{CnGranularity, CnSet};
    use crate::workload::models::{resnet18_first_segment, tiny_linear};
    use crate::workload::{LayerBuilder, LayerId};

    fn seg_model() -> (crate::workload::WorkloadGraph, CnSet, Accelerator) {
        let w = resnet18_first_segment();
        let arch = presets::hetero_quad();
        let cns = CnSet::build(&w, CnGranularity::Lines(4));
        (w, cns, arch)
    }

    #[test]
    fn table_is_compact() {
        let (w, cns, arch) = seg_model();
        let m = CostModel::build(&w, &cns, &arch);
        // <= 3 shapes per layer (first/interior/last) x 5 cores x 5 layers
        assert!(m.len() <= 3 * 5 * 5, "{}", m.len());
        assert!(!m.is_empty());
    }

    #[test]
    fn lookup_matches_direct_compute() {
        let (w, cns, arch) = seg_model();
        let m = CostModel::build(&w, &cns, &arch);
        for cn in &cns.nodes {
            for core in &arch.cores {
                let got = m.cn_cost(cn, core.id);
                let want = compute_cost(w.layer(cn.layer), cn, core);
                assert_eq!(got, want);
            }
        }
    }

    #[test]
    fn mismatched_dataflow_is_slower() {
        // depthwise conv on C|K core vs OX|F core
        let dw = {
            let mut l = LayerBuilder::new("dw", crate::workload::OpType::DwConv)
                .k(64)
                .c(64)
                .spatial(56, 64)
                .filter(3, 3)
                .pad(1)
                .build();
            l.id = LayerId(0);
            l
        };
        let cns = crate::cn::split_layer(&dw, CnGranularity::LayerByLayer);
        let arch = presets::hetero_quad();
        let ck_core = &arch.cores[2]; // C 32 | K 32
        let oxf_core = &arch.cores[0]; // OX 64 | FX 4 | FY 4
        let c_ck = compute_cost(&dw, &cns[0], ck_core);
        let c_oxf = compute_cost(&dw, &cns[0], oxf_core);
        // memory traffic caps the gap, but the mismatch must still cost
        // well over 1.5x in latency and >10x in spatial utilization
        assert!(
            c_ck.compute_cycles as f64 > 1.5 * c_oxf.compute_cycles as f64,
            "{} vs {}",
            c_ck.compute_cycles,
            c_oxf.compute_cycles
        );
        assert!(c_oxf.spatial_util > 10.0 * c_ck.spatial_util);
    }

    #[test]
    fn energy_scales_with_cn_size() {
        let (w, cns, arch) = seg_model();
        let m = CostModel::build(&w, &cns, &arch);
        let layer0 = cns.layer_cns(LayerId(0));
        let c_small = m.cn_cost(&layer0[1], crate::arch::CoreId(2));
        // a whole-layer CN must cost ~n_cns x one interior CN
        let whole = CnSet::build(&w, CnGranularity::LayerByLayer);
        let c_big = m_build_single(&w, &whole, &arch, crate::arch::CoreId(2));
        assert!(c_big.energy_pj > 10.0 * c_small.energy_pj);
    }

    fn m_build_single(
        w: &crate::workload::WorkloadGraph,
        cns: &CnSet,
        arch: &Accelerator,
        core: crate::arch::CoreId,
    ) -> CnCost {
        let m = CostModel::build(w, cns, arch);
        m.cn_cost(&cns.nodes[0], core)
    }

    #[test]
    fn simd_core_handles_pool() {
        let (w, cns, arch) = seg_model();
        let m = CostModel::build(&w, &cns, &arch);
        let simd = arch.simd_core().unwrap();
        let pool_cn = &cns.layer_cns(LayerId(1))[0];
        let c = m.cn_cost(pool_cn, simd);
        assert!(c.compute_cycles > 0);
        assert!(c.energy_pj > 0.0);
    }

    /// A sequence-length-1 MatMul must cost **bit-identically** to the
    /// equivalent FC layer on a core whose activation and weight SRAMs
    /// are the same size (test_dual: 128 KB each) at equal precisions:
    /// same MACs, same operand-element counts, same refetch structure,
    /// and the B operand's per-read energy equals the weight's because
    /// `sram_read_pj` sees identical arguments.
    #[test]
    fn seq1_matmul_costs_equal_fc() {
        let arch = presets::test_dual();
        let mut fc = LayerBuilder::new("fc", crate::workload::OpType::Fc).k(64).c(32).build();
        fc.id = LayerId(0);
        let mut mm = LayerBuilder::new("mm", crate::workload::OpType::MatMul)
            .k(64)
            .c(32)
            .spatial(1, 1)
            .build();
        mm.id = LayerId(0);
        let fc_cns = crate::cn::split_layer(&fc, CnGranularity::Lines(1));
        let mm_cns = crate::cn::split_layer(&mm, CnGranularity::Lines(1));
        assert_eq!(fc_cns.len(), 1);
        assert_eq!(mm_cns.len(), 1);
        for core in arch.cores.iter().filter(|c| !c.is_simd()) {
            let a = compute_cost(&fc, &fc_cns[0], core);
            let b = compute_cost(&mm, &mm_cns[0], core);
            assert_eq!(a.compute_cycles, b.compute_cycles, "{}", core.name);
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{}", core.name);
            assert_eq!(a.mac_energy_pj.to_bits(), b.mac_energy_pj.to_bits());
            assert_eq!(a.spatial_util.to_bits(), b.spatial_util.to_bits());
        }
    }

    #[test]
    fn matmul_b_read_energy_prices_off_act_sram() {
        // growing ONLY the weight SRAM changes FC cost (weight reads
        // get pricier) but must leave MatMul cost untouched: its B
        // operand is an activation and never touches the weight SRAM
        let small = presets::test_dual().cores[0].clone();
        let mut big = small.clone();
        big.wgt_mem_bytes = 8 * 1024 * 1024;
        let mut mm = LayerBuilder::new("mm", crate::workload::OpType::MatMul)
            .k(64)
            .c(64)
            .spatial(8, 1)
            .build();
        mm.id = LayerId(0);
        let mut fc = LayerBuilder::new("fc", crate::workload::OpType::Fc).k(64).c(64).build();
        fc.id = LayerId(0);
        let mm_cn = crate::cn::split_layer(&mm, CnGranularity::LayerByLayer);
        let fc_cn = crate::cn::split_layer(&fc, CnGranularity::LayerByLayer);
        let mm_small = compute_cost(&mm, &mm_cn[0], &small);
        let mm_big = compute_cost(&mm, &mm_cn[0], &big);
        assert_eq!(mm_small.energy_pj.to_bits(), mm_big.energy_pj.to_bits());
        let fc_small = compute_cost(&fc, &fc_cn[0], &small);
        let fc_big = compute_cost(&fc, &fc_cn[0], &big);
        assert!(fc_big.energy_pj > fc_small.energy_pj);
    }

    #[test]
    fn total_macs_conserved_through_costs() {
        let w = tiny_linear();
        let cns = CnSet::build(&w, CnGranularity::Lines(2));
        let total: u64 = cns.nodes.iter().map(|c| c.macs).sum();
        let direct: u64 = w.layers().iter().map(|l| l.macs()).sum();
        assert_eq!(total, direct);
    }
}
