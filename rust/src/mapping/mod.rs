//! Step 3 — intra-core mapping cost extraction (ZigZag-lite).
//!
//! For every unique (CN shape, core) combination, this module derives
//! the energy, latency and utilization of executing the CN on the core,
//! following the analytic structure of ZigZag [28] with the uniform
//! latency model of Mei et al. (DATE'22) [29]:
//!
//! - **Spatial utilization** ([`spatial_utilization`]): loop bounds that
//!   do not fill the core's spatial unrolling leave PEs idle — computed
//!   exactly from per-dimension `ceil` edge effects.
//! - **Temporal access counts** ([`CostModel`]): per-operand SRAM traffic is
//!   the MAC count divided by the spatial reuse of that operand (the
//!   product of the unrollings of the dims the operand does not index),
//!   mirroring the dataflow-dependent reuse ZigZag extracts from the
//!   full temporal-mapping search.
//! - **Latency** = compute cycles under utilization x bandwidth-stall
//!   factor, plus on/off-loading cycles through the core's local port.
//!
//! Costs are memoized per (layer, core, CN-line-count) — all interior
//! CNs of a layer share a shape, so a workload needs only a handful of
//! evaluations per layer-core pair (the paper's "unique CN-core
//! combinations").

mod cost;
mod spatial;

pub use cost::{CnCost, CostModel};
pub use spatial::{spatial_utilization, temporal_iterations};
