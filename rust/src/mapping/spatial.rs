//! Spatial utilization: how well a CN's loop bounds fill a core's
//! spatially unrolled PE array.

use crate::arch::Dataflow;
use crate::workload::{Dim, Layer};

/// All seven loop dims in canonical order.
pub const ALL_DIMS: [Dim; 7] = [Dim::B, Dim::K, Dim::C, Dim::OY, Dim::OX, Dim::FY, Dim::FX];

/// Loop bound of dim `d` for a CN spanning `lines` output rows of
/// `layer` (everything else full).
pub fn cn_dim(layer: &Layer, lines: usize, d: Dim) -> usize {
    match d {
        Dim::OY => lines.min(layer.oy),
        _ => layer.dim(d),
    }
}

/// Temporal iteration count: cycles the PE array needs for the CN,
/// assuming one spatial wavefront per cycle (ZigZag's ideal temporal
/// mapping).  Each dim contributes `ceil(bound / unroll)`.
pub fn temporal_iterations(layer: &Layer, lines: usize, df: &Dataflow) -> u64 {
    let mut iters: u64 = 1;
    for d in ALL_DIMS {
        let bound = cn_dim(layer, lines, d) as u64;
        let unroll = df.unroll(d) as u64;
        iters *= bound.div_ceil(unroll);
    }
    iters
}

/// Spatial utilization in (0, 1]: actual MACs over PE-cycles consumed.
///
/// A `C 32 | K 32` core running a depthwise layer (C-bound 1) uses 1/32
/// of its rows — exactly the dataflow mismatch the paper's heterogeneous
/// architectures exploit.
pub fn spatial_utilization(layer: &Layer, lines: usize, df: &Dataflow) -> f64 {
    let macs: u64 = ALL_DIMS.iter().map(|&d| cn_dim(layer, lines, d) as u64).product();
    let cycles = temporal_iterations(layer, lines, df);
    let pes = df.pe_count() as u64;
    macs as f64 / (cycles * pes) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{LayerBuilder, OpType};

    fn conv(k: usize, c: usize, oy: usize, ox: usize, f: usize) -> Layer {
        LayerBuilder::new("c", OpType::Conv).k(k).c(c).spatial(oy, ox).filter(f, f).build()
    }

    #[test]
    fn perfect_fit_is_full_utilization() {
        let df = Dataflow::new(&[(Dim::C, 32), (Dim::K, 32)]);
        let l = conv(64, 64, 28, 28, 3);
        assert!((spatial_utilization(&l, 28, &df) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn undersized_channels_waste_pes() {
        let df = Dataflow::new(&[(Dim::C, 32), (Dim::K, 32)]);
        let l = conv(16, 16, 28, 28, 3); // fills 16/32 x 16/32 = 1/4
        assert!((spatial_utilization(&l, 28, &df) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn depthwise_on_ck_core_is_terrible() {
        let df = Dataflow::new(&[(Dim::C, 32), (Dim::K, 32)]);
        let l = LayerBuilder::new("dw", OpType::DwConv)
            .k(64)
            .c(64)
            .spatial(28, 28)
            .filter(3, 3)
            .build();
        // C bound is 1 for depthwise -> utilization 1/32
        let u = spatial_utilization(&l, 28, &df);
        assert!((u - 1.0 / 32.0).abs() < 1e-9, "{u}");
    }

    #[test]
    fn depthwise_on_spatial_core_is_fine() {
        let df = Dataflow::new(&[(Dim::OX, 64), (Dim::FX, 4), (Dim::FY, 4)]);
        let l = LayerBuilder::new("dw", OpType::DwConv)
            .k(64)
            .c(64)
            .spatial(56, 64)
            .filter(3, 3)
            .build();
        // OX 64/64 full, FY/FX 3/4
        let u = spatial_utilization(&l, 56, &df);
        assert!(u > 0.5, "{u}");
    }

    #[test]
    fn edge_effects() {
        let df = Dataflow::new(&[(Dim::K, 32)]);
        let l = conv(33, 1, 1, 1, 1); // 33 -> ceil = 2 iters of 32
        let u = spatial_utilization(&l, 1, &df);
        assert!((u - 33.0 / 64.0).abs() < 1e-9);
    }

    #[test]
    fn temporal_iterations_counts() {
        let df = Dataflow::new(&[(Dim::C, 32), (Dim::K, 32)]);
        let l = conv(64, 64, 28, 28, 3);
        // K: 2, C: 2, OY: 28, OX: 28, FY: 3, FX: 3
        assert_eq!(temporal_iterations(&l, 28, &df), 2 * 2 * 28 * 28 * 9);
    }

    #[test]
    fn fewer_lines_fewer_iterations() {
        let df = Dataflow::new(&[(Dim::C, 32), (Dim::K, 32)]);
        let l = conv(64, 64, 28, 28, 3);
        assert_eq!(
            temporal_iterations(&l, 4, &df) * 7,
            temporal_iterations(&l, 28, &df)
        );
    }
}
