//! CN splitting: layers -> line-granular computation nodes.

use super::attrs::extract_attributes;
use super::{CnGranularity, CnId, CnSet, ComputationNode};
use crate::rtree::Rect;
use crate::workload::{Layer, OpType, WorkloadGraph};

/// Split one layer into CNs at the given granularity.
///
/// Returns CNs with layer-local indices; the caller (usually
/// [`split_workload`]) assigns global ids and attributes.
pub fn split_layer(layer: &Layer, gran: CnGranularity) -> Vec<ComputationNode> {
    let lines = match gran {
        CnGranularity::LayerByLayer => layer.oy,
        // Layer topology awareness: no spatial locality -> single CN.
        CnGranularity::Lines(_) if !layer.op.has_spatial_locality() => layer.oy,
        CnGranularity::Lines(l) => l.max(1).min(layer.oy),
    };

    let n_cns = layer.oy.div_ceil(lines);
    // Exact MAC apportionment: prefix-difference of the floor shares,
    // `macs(rows) = floor(total * rows / oy)`, so per-CN MACs telescope
    // to exactly `layer.macs()` even when `oy` does not divide it (the
    // remainder lands on the CNs where the fractional share crosses an
    // integer).  When `oy | total` every share is exact and this equals
    // the plain proportional split.
    let total_macs = layer.macs();
    let macs_before =
        |rows: usize| -> u64 { (total_macs as u128 * rows as u128 / layer.oy as u128) as u64 };
    let mut cns = Vec::with_capacity(n_cns);
    for idx in 0..n_cns {
        let o_lo = idx * lines;
        let o_hi = ((idx + 1) * lines).min(layer.oy);
        let out_rect = Rect::chw(0..layer.k as i64, o_lo as i64..o_hi as i64, 0..layer.ox as i64);
        let in_rect = input_rect(layer, o_lo, o_hi);

        let macs = macs_before(o_hi) - macs_before(o_lo);
        cns.push(ComputationNode {
            id: CnId(usize::MAX), // assigned by split_workload
            layer: layer.id,
            idx,
            out_rect,
            in_rect,
            macs,
            input_bytes: 0,
            output_bytes: 0,
            discard_input_bytes: 0,
            final_output_bytes: 0,
        });
    }
    extract_attributes(layer, &mut cns);
    cns
}

/// Input region (C, IY, IX) a block of output lines `[o_lo, o_hi)` needs,
/// clipped to the valid (unpadded) input tensor.
pub(crate) fn input_rect(layer: &Layer, o_lo: usize, o_hi: usize) -> Rect {
    match layer.op {
        OpType::Add | OpType::Concat | OpType::LayerNorm | OpType::Softmax | OpType::Gelu => {
            // elementwise / copy / per-row reduction: same rows as the
            // output
            Rect::chw(0..layer.c as i64, o_lo as i64..o_hi as i64, 0..layer.ox as i64)
        }
        OpType::Fc => Rect::chw(0..layer.c as i64, 0..1, 0..1),
        // MatMul falls through to the generic window: with fy = fx =
        // stride = 1 and pad = 0 that is exactly "operand-A rows map
        // 1:1 to output rows" (operand B is not part of the input
        // window; it rides the weight position of the dataflow).
        _ => {
            let s = layer.stride as i64;
            let pad = layer.pad as i64;
            let fy = layer.fy as i64;
            let ih = layer.in_height() as i64;
            let iw = layer.in_width() as i64;
            let i_lo = (o_lo as i64 * s - pad).max(0);
            let i_hi = ((o_hi as i64 - 1) * s - pad + fy).min(ih).max(i_lo);
            Rect::chw(0..layer.c as i64, i_lo..i_hi, 0..iw)
        }
    }
}

/// Split every layer of the workload and extract the Fig. 5 attributes.
pub fn split_workload(workload: &WorkloadGraph, gran: CnGranularity) -> CnSet {
    split_workload_mixed(workload, &vec![gran; workload.len()])
}

/// Mixed-granularity split: one [`CnGranularity`] per layer (indexed by
/// `LayerId`).  This is Step 1 under a decoded fuse/cut pattern
/// ([`crate::cn::fuse::FusePattern`]): layers inside a fused segment
/// split at their segment's line granularity, layers on fully cut
/// boundaries stay single-CN.  A uniform granularity vector reproduces
/// [`split_workload`] node for node.
pub fn split_workload_mixed(workload: &WorkloadGraph, grans: &[CnGranularity]) -> CnSet {
    assert_eq!(grans.len(), workload.len(), "one granularity per layer");
    let mut nodes = Vec::new();
    let mut per_layer = Vec::with_capacity(workload.len());
    for layer in workload.layers() {
        let first = nodes.len();
        let mut cns = split_layer(layer, grans[layer.id.0]);
        // assign global ids in order
        for (i, cn) in cns.iter_mut().enumerate() {
            cn.id = CnId(first + i);
        }
        per_layer.push((first, cns.len()));
        nodes.extend(cns);
    }
    CnSet { nodes, per_layer }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::{resnet18, tiny_segment};
    use crate::workload::{LayerBuilder, LayerId};

    fn conv_layer() -> Layer {
        let mut l = LayerBuilder::new("c", OpType::Conv)
            .k(64)
            .c(3)
            .spatial(56, 56)
            .filter(7, 7)
            .stride(2)
            .pad(3)
            .build();
        l.id = LayerId(0);
        l
    }

    #[test]
    fn layer_by_layer_is_one_cn() {
        let cns = split_layer(&conv_layer(), CnGranularity::LayerByLayer);
        assert_eq!(cns.len(), 1);
        assert_eq!(cns[0].out_lines(), 56);
        assert_eq!(cns[0].macs, conv_layer().macs());
    }

    #[test]
    fn line_split_counts() {
        let cns = split_layer(&conv_layer(), CnGranularity::Lines(4));
        assert_eq!(cns.len(), 14);
        assert!(cns.iter().all(|c| c.out_lines() == 4));
        let total: u64 = cns.iter().map(|c| c.macs).sum();
        assert_eq!(total, conv_layer().macs());
    }

    #[test]
    fn uneven_split_last_cn_smaller() {
        let mut l = conv_layer();
        l.oy = 30;
        let cns = split_layer(&l, CnGranularity::Lines(8));
        assert_eq!(cns.len(), 4);
        assert_eq!(cns.last().unwrap().out_lines(), 6);
    }

    #[test]
    fn fc_never_splits() {
        let mut l = LayerBuilder::new("fc", OpType::Fc).k(10).c(100).build();
        l.id = LayerId(0);
        let cns = split_layer(&l, CnGranularity::Lines(1));
        assert_eq!(cns.len(), 1);
    }

    #[test]
    fn matmul_splits_by_token_rows() {
        // scores GEMM over 196 tokens: unlike FC, it splits fine-grain
        let mut l = LayerBuilder::new("scores", OpType::MatMul)
            .k(196)
            .c(192)
            .spatial(196, 1)
            .build();
        l.id = LayerId(0);
        let cns = split_layer(&l, CnGranularity::Lines(4));
        assert_eq!(cns.len(), 49);
        let total: u64 = cns.iter().map(|c| c.macs).sum();
        assert_eq!(total, l.macs());
        // A-operand windows map 1:1 to output rows (no halo)
        for cn in &cns {
            assert_eq!(cn.in_rect.lo[1], cn.out_rect.lo[1]);
            assert_eq!(cn.in_rect.hi[1], cn.out_rect.hi[1]);
        }
        // discardable inputs partition operand A exactly
        let disc: u64 = cns.iter().map(|c| c.discard_input_bytes).sum();
        assert_eq!(disc, l.input_bytes());
    }

    #[test]
    fn softmax_splits_like_elementwise() {
        let mut l = LayerBuilder::new("sm", OpType::Softmax)
            .k(196)
            .c(196)
            .spatial(196, 1)
            .build();
        l.id = LayerId(0);
        let cns = split_layer(&l, CnGranularity::Lines(8));
        assert_eq!(cns.len(), 196usize.div_ceil(8));
        for cn in &cns {
            let rows = (cn.in_rect.lo[1], cn.in_rect.hi[1]);
            assert_eq!(rows, (cn.out_rect.lo[1], cn.out_rect.hi[1]));
        }
    }

    #[test]
    fn input_rect_halo() {
        let l = conv_layer(); // 7x7 s2 p3, in 112 (511->112? in_height = 55*2+7-6 = 111)
        // first CN rows 0..4: input rows max(0, -3) .. 3*2-3+7 = 10
        let r = input_rect(&l, 0, 4);
        assert_eq!(r.lo[1], 0);
        assert_eq!(r.hi[1], 10);
        // middle CN rows 4..8: 4*2-3=5 .. 7*2-3+7=18
        let r = input_rect(&l, 4, 8);
        assert_eq!((r.lo[1], r.hi[1]), (5, 18));
    }

    #[test]
    fn input_rect_clips_to_valid() {
        let l = conv_layer();
        let last = input_rect(&l, 52, 56);
        assert_eq!(last.hi[1], l.in_height() as i64);
    }

    #[test]
    fn workload_split_ids_contiguous() {
        let set = split_workload(&tiny_segment(), CnGranularity::Lines(4));
        for (i, cn) in set.nodes.iter().enumerate() {
            assert_eq!(cn.id.0, i);
        }
        // conv7x7 at 56 rows -> 14 CNs, pool 28 -> 7, convs 7+7, add 7
        assert_eq!(set.len(), 14 + 7 + 7 + 7 + 7);
        assert_eq!(set.layer_cns(LayerId(0)).len(), 14);
        assert_eq!(set.layer_cns(LayerId(4)).len(), 7);
    }

    #[test]
    fn mac_apportionment_exact_for_every_op() {
        use crate::workload::PoolKind;
        // every op type, several (oy, lines) combinations incl. uneven
        // splits: per-CN MACs must sum exactly to the layer total (the
        // prefix-difference split never truncates a remainder away)
        let ops = [
            OpType::Conv,
            OpType::DwConv,
            OpType::Fc,
            OpType::MatMul,
            OpType::Pool(PoolKind::Max),
            OpType::Add,
            OpType::Concat,
            OpType::LayerNorm,
            OpType::Softmax,
            OpType::Gelu,
        ];
        for op in ops {
            for oy in [1usize, 7, 30, 56] {
                let mut b = LayerBuilder::new("x", op).k(24).c(24).spatial(oy, 5);
                if matches!(op, OpType::Conv | OpType::DwConv | OpType::Pool(_)) {
                    b = b.filter(3, 3).pad(1);
                }
                let mut l = b.build();
                l.id = LayerId(0);
                for lines in [1usize, 2, 3, 4, 7, oy] {
                    let grans = [
                        CnGranularity::Lines(lines),
                        CnGranularity::LayerByLayer,
                    ];
                    for gran in grans {
                        let cns = split_layer(&l, gran);
                        let total: u64 = cns.iter().map(|c| c.macs).sum();
                        assert_eq!(
                            total,
                            l.macs(),
                            "{op:?} oy={oy} lines={lines} gran={gran:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn mixed_split_uniform_matches_split_workload() {
        let w = tiny_segment();
        let uniform = split_workload(&w, CnGranularity::Lines(4));
        let mixed = split_workload_mixed(&w, &vec![CnGranularity::Lines(4); w.len()]);
        assert_eq!(uniform.len(), mixed.len());
        for (a, b) in uniform.nodes.iter().zip(&mixed.nodes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.layer, b.layer);
            assert_eq!(a.out_rect, b.out_rect);
            assert_eq!(a.in_rect, b.in_rect);
            assert_eq!(a.macs, b.macs);
            assert_eq!(a.discard_input_bytes, b.discard_input_bytes);
            assert_eq!(a.final_output_bytes, b.final_output_bytes);
        }
        assert_eq!(uniform.per_layer, mixed.per_layer);
    }

    #[test]
    fn mixed_split_honors_per_layer_granularity() {
        let w = tiny_segment();
        let mut grans = vec![CnGranularity::Lines(4); w.len()];
        grans[0] = CnGranularity::LayerByLayer; // conv7x7 materializes
        let set = split_workload_mixed(&w, &grans);
        assert_eq!(set.layer_cns(LayerId(0)).len(), 1);
        assert_eq!(set.layer_cns(LayerId(1)).len(), 7); // pool 28 rows / 4
        // ids stay globally contiguous across the mixed boundary
        for (i, cn) in set.nodes.iter().enumerate() {
            assert_eq!(cn.id.0, i);
        }
    }

    #[test]
    fn resnet18_cn_counts_scale_with_granularity() {
        let coarse = split_workload(&resnet18(), CnGranularity::LayerByLayer);
        let fine = split_workload(&resnet18(), CnGranularity::Lines(1));
        assert_eq!(coarse.len(), resnet18().len());
        assert!(fine.len() > 10 * coarse.len());
    }
}
