//! Fig. 5 attribute extraction: discardable inputs & newly generated
//! final outputs, plus plain input/output byte counts per CN.

use super::ComputationNode;
use crate::workload::Layer;

/// Fill in the byte-count attributes of a layer's CNs (in outer-CN loop
/// order).
///
/// - `input_bytes` / `output_bytes`: the valid-region activation
///   footprints of the CN.
/// - `discard_input_bytes` (Fig. 5, red): input rows used by this CN and
///   by **no later CN of the same layer** — they can be freed when the
///   CN finishes.  Because consecutive CNs share halo rows, interior CNs
///   discard `lines * stride` rows while the first/last CNs differ.
/// - `final_output_bytes` (Fig. 5, green): output bytes that are final
///   the moment the CN finishes.  With the channel reduction (C) kept
///   inside every CN, *all* produced outputs are final.
pub fn extract_attributes(layer: &Layer, cns: &mut [ComputationNode]) {
    let act_b = layer.act_bits as u64;
    let in_w = layer.in_width() as u64;
    let c = layer.c as u64;

    let n = cns.len();
    for i in 0..n {
        let in_rows = (cns[i].in_rect.hi[1] - cns[i].in_rect.lo[1]) as u64;
        let out_elems = cns[i].out_rect.volume();

        cns[i].input_bytes = c * in_rows * in_w * act_b / 8;
        cns[i].output_bytes = out_elems * act_b / 8;
        cns[i].final_output_bytes = cns[i].output_bytes;

        // rows needed by the *next* CN of this layer start at its in_lo;
        // everything strictly below that is exclusively ours.
        let discard_hi = if i + 1 < n {
            cns[i + 1].in_rect.lo[1]
        } else {
            cns[i].in_rect.hi[1]
        };
        let discard_rows = (discard_hi - cns[i].in_rect.lo[1]).max(0) as u64;
        // rows before our own window were discarded by predecessors
        cns[i].discard_input_bytes = c * discard_rows.min(in_rows) * in_w * act_b / 8;
    }
}

#[cfg(test)]
mod tests {
    use crate::cn::{split_layer, CnGranularity};
    use crate::workload::{LayerBuilder, LayerId, OpType};

    fn conv3x3_same(oy: usize) -> crate::workload::Layer {
        let mut l = LayerBuilder::new("c", OpType::Conv)
            .k(4)
            .c(2)
            .spatial(oy, 8)
            .filter(3, 3)
            .pad(1)
            .build();
        l.id = LayerId(0);
        l
    }

    #[test]
    fn conservation_inputs() {
        // summed discardable inputs == total layer input bytes
        let l = conv3x3_same(16);
        let cns = split_layer(&l, CnGranularity::Lines(4));
        let total_discard: u64 = cns.iter().map(|c| c.discard_input_bytes).sum();
        assert_eq!(total_discard, l.input_bytes());
    }

    #[test]
    fn conservation_outputs() {
        let l = conv3x3_same(16);
        let cns = split_layer(&l, CnGranularity::Lines(4));
        let total_out: u64 = cns.iter().map(|c| c.final_output_bytes).sum();
        assert_eq!(total_out, l.output_bytes());
    }

    #[test]
    fn interior_cns_discard_stride_times_lines_rows() {
        // 3x3 pad-1 stride-1: each interior CN of 4 lines discards
        // exactly 4 input rows (the halo shifts down by 4).
        let l = conv3x3_same(16);
        let cns = split_layer(&l, CnGranularity::Lines(4));
        let row_bytes = 2 * 8; // c * in_w * 1 byte
        // first CN: window rows 0..6 (clipped), next starts at 3 -> 3 rows
        assert_eq!(cns[0].discard_input_bytes, 3 * row_bytes);
        // interior CN: rows 3..10, next starts at 7 -> 4 rows
        assert_eq!(cns[1].discard_input_bytes, 4 * row_bytes);
        // last CN frees its whole remaining window
        assert_eq!(cns[3].discard_input_bytes, 5 * row_bytes);
    }

    #[test]
    fn strided_conv_discards_more() {
        let mut l = LayerBuilder::new("c", OpType::Conv)
            .k(4)
            .c(2)
            .spatial(8, 8)
            .filter(3, 3)
            .stride(2)
            .pad(1)
            .build();
        l.id = LayerId(0);
        let cns = split_layer(&l, CnGranularity::Lines(2));
        // interior CN discards lines*stride = 4 rows
        let row_bytes = 2 * l.in_width() as u64;
        assert_eq!(cns[1].discard_input_bytes, 4 * row_bytes);
    }

    #[test]
    fn single_cn_discards_everything() {
        let l = conv3x3_same(16);
        let cns = split_layer(&l, CnGranularity::LayerByLayer);
        assert_eq!(cns[0].discard_input_bytes, l.input_bytes());
        assert_eq!(cns[0].final_output_bytes, l.output_bytes());
    }
}
