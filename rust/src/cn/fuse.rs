//! Fuse/cut patterns: per-edge fusion decisions as a search axis.
//!
//! The classic pipeline picks ONE [`CnGranularity`] for the whole
//! workload — every boundary is either fused (line-granular CNs
//! streaming into each other) or cut (layer-by-layer materialization).
//! A [`FusePattern`] makes that decision **per workload edge**: each
//! producer→consumer edge carries a gene that either fuses the boundary
//! at a line granularity drawn from a small menu, or cuts it, forcing
//! the producer's output to fully materialize before the consumer
//! starts (the LayerByLayer dependency shape on exactly that boundary).
//!
//! Decoding is where the mixed-granularity CN split comes from:
//!
//! - fused edges connect layers into **segments** (connected components
//!   over the fused edges); every layer of a segment splits at
//!   `Lines(k)` where `k` is the minimum menu granularity among the
//!   segment's fused edges (the finest streaming consumer wins),
//!   clamped by [`CnGranularity::for_arch`];
//! - a layer none of whose incident edges fuse stays a single CN
//!   (`LayerByLayer`) — its inputs and outputs all materialize;
//! - a layer with no workload edges at all splits at the base menu
//!   granularity, so the **all-fuse** gene vector decodes to exactly
//!   the uniform `Lines(menu[0])` pipeline for *every* workload, and
//!   the **all-cut** vector to the uniform `LayerByLayer` pipeline for
//!   every workload whose layers each touch at least one edge (all zoo
//!   models) — the two bit-identity anchors of
//!   `rust/tests/fusion_axis_equivalence.rs`.
//!
//! The gene encoding is `v % (menu.len() + 1)`: 0 cuts the edge,
//! `m > 0` fuses it at `menu[m - 1]` lines.  With the default
//! single-entry menu that degenerates to one fuse/cut bit per edge; a
//! longer menu adds the per-segment line-granularity axis on the same
//! genes.
//!
//! [`FusePattern::fingerprint`] hashes the decoded decisions (not the
//! raw genes), so gene vectors that decode to the same pattern share
//! one precomputed graph/cost/scheduler context — and distinct
//! patterns can never alias a [`ScheduleCache`](crate::cost::ScheduleCache)
//! slot once the fingerprint is mixed into the cache key
//! ([`crate::cost::compose_fp`]).

use super::{split_workload_mixed, CnGranularity, CnSet};
use crate::arch::Accelerator;
use crate::workload::{LayerId, WorkloadGraph};

/// One workload edge in canonical order: consumers in `LayerId` order,
/// each consumer's predecessors in declaration order.  The fuse-gene
/// vector is indexed in exactly this order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FuseEdge {
    pub producer: LayerId,
    pub consumer: LayerId,
    /// Index of `producer` within `consumer.predecessors`.
    pub pred_idx: usize,
}

/// The workload's edges in canonical (consumer, pred_idx) order.
pub fn workload_edges(workload: &WorkloadGraph) -> Vec<FuseEdge> {
    let mut edges = Vec::new();
    for consumer in workload.layers() {
        for (pred_idx, &producer) in consumer.predecessors.iter().enumerate() {
            edges.push(FuseEdge { producer, consumer: consumer.id, pred_idx });
        }
    }
    edges
}

/// Number of fuse genes a co-search genome carries for this workload
/// (one per workload edge).
pub fn n_fuse_genes(workload: &WorkloadGraph) -> usize {
    workload.layers().iter().map(|l| l.predecessors.len()).sum()
}

/// A decoded fuse/cut pattern: per-edge decisions plus the per-layer
/// granularities they imply.  Construct via [`FusePattern::decode`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusePattern {
    /// The workload's edges, canonical order (see [`workload_edges`]).
    pub edges: Vec<FuseEdge>,
    /// Per-edge decision, parallel to `edges`: `Some(lines)` = fused at
    /// that (pre-clamp) granularity, `None` = cut.
    pub decisions: Vec<Option<usize>>,
    /// Decoded per-layer CN granularity (arch-clamped), indexed by
    /// `LayerId`.
    pub per_layer: Vec<CnGranularity>,
    /// First edge index of each consumer layer (edge index =
    /// `edge_offset[consumer] + pred_idx`).
    edge_offset: Vec<usize>,
}

impl FusePattern {
    /// Decode a fuse-gene vector (one gene per workload edge, canonical
    /// order) into a pattern.  `menu` lists the candidate line
    /// granularities for fused segments; gene value `v` means cut when
    /// `v % (menu.len() + 1) == 0`, else fuse at
    /// `menu[v % (menu.len() + 1) - 1]` lines.
    ///
    /// # Panics
    ///
    /// If `menu` is empty or contains a zero, or `genes` has the wrong
    /// length.
    pub fn decode(
        workload: &WorkloadGraph,
        arch: &Accelerator,
        menu: &[usize],
        genes: &[u16],
    ) -> FusePattern {
        assert!(!menu.is_empty(), "fuse menu must list at least one line granularity");
        assert!(menu.iter().all(|&l| l > 0), "fuse menu granularities must be positive");
        let edges = workload_edges(workload);
        assert_eq!(
            genes.len(),
            edges.len(),
            "one fuse gene per workload edge ({} edges)",
            edges.len()
        );
        let n_choices = menu.len() as u16 + 1;
        let decisions: Vec<Option<usize>> = genes
            .iter()
            .map(|&v| {
                let d = (v % n_choices) as usize;
                if d == 0 {
                    None
                } else {
                    Some(menu[d - 1])
                }
            })
            .collect();

        // Segments: connected components of layers over the fused
        // edges (union-find), carrying the minimum fused granularity.
        let n = workload.len();
        let mut parent: Vec<usize> = (0..n).collect();
        fn find(parent: &mut [usize], mut x: usize) -> usize {
            while parent[x] != x {
                parent[x] = parent[parent[x]];
                x = parent[x];
            }
            x
        }
        for (e, d) in edges.iter().zip(&decisions) {
            if d.is_some() {
                let (a, b) = (find(&mut parent, e.producer.0), find(&mut parent, e.consumer.0));
                if a != b {
                    parent[a.max(b)] = a.min(b);
                }
            }
        }
        let mut seg_lines: Vec<Option<usize>> = vec![None; n];
        for (e, d) in edges.iter().zip(&decisions) {
            if let Some(lines) = d {
                let root = find(&mut parent, e.producer.0);
                let cur = seg_lines[root].get_or_insert(*lines);
                *cur = (*cur).min(*lines);
            }
        }

        // Whether a layer touches any workload edge at all.
        let mut has_edge = vec![false; n];
        for e in &edges {
            has_edge[e.producer.0] = true;
            has_edge[e.consumer.0] = true;
        }

        let per_layer: Vec<CnGranularity> = (0..n)
            .map(|l| {
                let root = find(&mut parent, l);
                match seg_lines[root] {
                    Some(lines) => CnGranularity::Lines(lines).for_arch(arch),
                    // isolated layers (no edges) take the base menu
                    // granularity so all-fuse stays exactly the uniform
                    // Lines(menu[0]) pipeline; layers whose every
                    // incident edge is cut materialize fully
                    None if !has_edge[l] => CnGranularity::Lines(menu[0]).for_arch(arch),
                    None => CnGranularity::LayerByLayer,
                }
            })
            .collect();

        let mut edge_offset = Vec::with_capacity(n);
        let mut acc = 0usize;
        for layer in workload.layers() {
            edge_offset.push(acc);
            acc += layer.predecessors.len();
        }

        FusePattern { edges, decisions, per_layer, edge_offset }
    }

    /// The gene vector that fuses every edge at the base menu
    /// granularity (decodes to the uniform `Lines(menu[0])` pipeline).
    pub fn genes_all_fuse(workload: &WorkloadGraph) -> Vec<u16> {
        vec![1; n_fuse_genes(workload)]
    }

    /// The gene vector that cuts every edge (decodes to the uniform
    /// `LayerByLayer` pipeline when every layer touches an edge).
    pub fn genes_all_cut(workload: &WorkloadGraph) -> Vec<u16> {
        vec![0; n_fuse_genes(workload)]
    }

    /// Fused line granularity of the (consumer, pred_idx) edge, or
    /// `None` if that boundary is cut.
    pub fn fused_lines(&self, consumer: LayerId, pred_idx: usize) -> Option<usize> {
        self.decisions[self.edge_offset[consumer.0] + pred_idx]
    }

    /// Whether the (consumer, pred_idx) boundary is cut (producer
    /// output fully materializes).
    pub fn is_cut(&self, consumer: LayerId, pred_idx: usize) -> bool {
        self.fused_lines(consumer, pred_idx).is_none()
    }

    /// Decoded granularity of one layer.
    pub fn layer_granularity(&self, layer: LayerId) -> CnGranularity {
        self.per_layer[layer.0]
    }

    /// Number of cut edges.
    pub fn n_cut(&self) -> usize {
        self.decisions.iter().filter(|d| d.is_none()).count()
    }

    /// Number of fused edges.
    pub fn n_fused(&self) -> usize {
        self.decisions.len() - self.n_cut()
    }

    /// Whether any edge is fused and any is cut (a genuinely mixed
    /// pattern, neither regime).
    pub fn is_mixed(&self) -> bool {
        self.n_cut() > 0 && self.n_fused() > 0
    }

    /// 64-bit FNV-1a over the *decoded* pattern (per-layer
    /// granularities + per-edge decisions).  Gene vectors decoding to
    /// the same pattern share a fingerprint; distinct patterns get
    /// distinct cache keys once this is mixed into the schedule-cache
    /// key via [`crate::cost::compose_fp`].
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        for g in &self.per_layer {
            eat(match g {
                CnGranularity::LayerByLayer => 0,
                CnGranularity::Lines(l) => *l as u64,
            });
        }
        for d in &self.decisions {
            eat(match d {
                None => 0,
                Some(l) => *l as u64,
            });
        }
        h
    }

    /// Step 1 under this pattern: the mixed-granularity CN set.
    pub fn build_cns(&self, workload: &WorkloadGraph) -> CnSet {
        split_workload_mixed(workload, &self.per_layer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cn::split_workload;
    use crate::workload::models::{tiny_branchy, tiny_segment};

    #[test]
    fn edge_order_is_consumer_then_pred() {
        let w = tiny_branchy();
        let edges = workload_edges(&w);
        assert_eq!(edges.len(), n_fuse_genes(&w));
        // consumers appear in LayerId order, pred_idx resets per consumer
        for pair in edges.windows(2) {
            assert!(
                pair[0].consumer < pair[1].consumer
                    || (pair[0].consumer == pair[1].consumer
                        && pair[0].pred_idx + 1 == pair[1].pred_idx)
            );
        }
    }

    #[test]
    fn all_fuse_decodes_to_uniform_lines() {
        let w = tiny_segment();
        let arch = presets::hetero_quad();
        let p = FusePattern::decode(&w, &arch, &[4], &FusePattern::genes_all_fuse(&w));
        let want = CnGranularity::Lines(4).for_arch(&arch);
        for l in w.layers() {
            assert_eq!(p.layer_granularity(l.id), want);
        }
        assert_eq!(p.n_cut(), 0);
        assert!(!p.is_mixed());
        // the CN set is the uniform split, node for node
        let mixed = p.build_cns(&w);
        let uniform = split_workload(&w, CnGranularity::Lines(4).for_arch(&arch));
        assert_eq!(mixed.len(), uniform.len());
        for (a, b) in mixed.nodes.iter().zip(&uniform.nodes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.out_rect, b.out_rect);
            assert_eq!(a.macs, b.macs);
        }
    }

    #[test]
    fn all_cut_decodes_to_layer_by_layer() {
        let w = tiny_branchy();
        let arch = presets::hetero_quad();
        let p = FusePattern::decode(&w, &arch, &[4], &FusePattern::genes_all_cut(&w));
        for l in w.layers() {
            assert_eq!(p.layer_granularity(l.id), CnGranularity::LayerByLayer);
        }
        assert_eq!(p.n_fused(), 0);
        let cns = p.build_cns(&w);
        assert_eq!(cns.len(), w.len(), "one CN per layer");
    }

    #[test]
    fn mixed_pattern_splits_only_fused_segments() {
        // tiny_segment is a chain (+ one residual add): cut the first
        // edge, fuse the rest -> layer 0 materializes alone, the tail
        // segment splits at Lines
        let w = tiny_segment();
        let arch = presets::hetero_quad();
        let mut genes = FusePattern::genes_all_fuse(&w);
        genes[0] = 0; // cut the first canonical edge
        let p = FusePattern::decode(&w, &arch, &[4], &genes);
        assert!(p.is_mixed());
        let first_consumer = p.edges[0].consumer;
        let first_producer = p.edges[0].producer;
        assert!(p.is_cut(first_consumer, p.edges[0].pred_idx));
        // the producer of the cut edge has no other fused edge in this
        // chain start, so it materializes
        assert_eq!(p.layer_granularity(first_producer), CnGranularity::LayerByLayer);
        // downstream layers still stream
        let want = CnGranularity::Lines(4).for_arch(&arch);
        assert_eq!(p.layer_granularity(first_consumer), want);
    }

    #[test]
    fn segment_granularity_is_min_over_menu_choices() {
        let w = tiny_segment();
        let arch = presets::test_dual();
        let menu = [4usize, 8];
        // gene 1 -> menu[0] = 4, gene 2 -> menu[1] = 8: a segment mixing
        // both fuses at the finer 4
        let genes: Vec<u16> =
            (0..n_fuse_genes(&w)).map(|i| if i % 2 == 0 { 1 } else { 2 }).collect();
        let p = FusePattern::decode(&w, &arch, &menu, &genes);
        let want = CnGranularity::Lines(4).for_arch(&arch);
        for l in w.layers() {
            assert_eq!(p.layer_granularity(l.id), want);
        }
    }

    #[test]
    fn gene_values_wrap_modulo_choices() {
        let w = tiny_segment();
        let arch = presets::hetero_quad();
        let n = n_fuse_genes(&w);
        // with a 1-entry menu, even genes cut and odd genes fuse
        let a = FusePattern::decode(&w, &arch, &[4], &vec![2u16; n]);
        let b = FusePattern::decode(&w, &arch, &[4], &vec![0u16; n]);
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = FusePattern::decode(&w, &arch, &[4], &vec![3u16; n]);
        let d = FusePattern::decode(&w, &arch, &[4], &vec![1u16; n]);
        assert_eq!(c.fingerprint(), d.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn fingerprint_separates_patterns() {
        let w = tiny_branchy();
        let arch = presets::hetero_quad();
        let all_fuse =
            FusePattern::decode(&w, &arch, &[4], &FusePattern::genes_all_fuse(&w));
        let all_cut = FusePattern::decode(&w, &arch, &[4], &FusePattern::genes_all_cut(&w));
        assert_ne!(all_fuse.fingerprint(), all_cut.fingerprint());
        // flipping a single edge changes the fingerprint
        let mut genes = FusePattern::genes_all_fuse(&w);
        genes[1] = 0;
        let mixed = FusePattern::decode(&w, &arch, &[4], &genes);
        assert_ne!(mixed.fingerprint(), all_fuse.fingerprint());
        assert_ne!(mixed.fingerprint(), all_cut.fingerprint());
    }
}
