//! Step 1 — CN identification & attribute extraction.
//!
//! Every layer is split into individually schedulable **computation
//! nodes** (CNs) by isolating a subset of inner for-loops; the remaining
//! outer-CN loops (here: blocks of output lines, `OY`) determine the
//! CNs' relative execution order.  The split follows the paper's two
//! principles:
//!
//! 1. **Layer topology awareness** — fully-connected layers have no
//!    spatial locality, so their single CN encapsulates every loop
//!    (automatically breaking the fused stack); spatially-local layers
//!    (conv / dwconv / pool / add / concat, and the transformer ops
//!    matmul / layernorm / softmax / gelu whose `OY` rows are sequence
//!    tokens) split along `OY`.
//! 2. **HW dataflow awareness** — a CN must minimally encompass every
//!    for-loop dimension that is spatially unrolled in *any* core of the
//!    target architecture, so no core is forced below full spatial
//!    utilization by the granularity itself ([`CnGranularity::for_arch`]).
//!
//! Each CN carries the two attributes of paper Fig. 5: the number of
//! **discardable inputs** (inputs used by no later CN of the same layer)
//! and the number of **newly generated final outputs**.

mod attrs;
pub mod fuse;
mod split;

pub use attrs::extract_attributes;
pub use fuse::{n_fuse_genes, FusePattern};
pub use split::{split_layer, split_workload, split_workload_mixed};

use crate::arch::Accelerator;
use crate::rtree::Rect;
use crate::workload::{Dim, LayerId, WorkloadGraph};

/// Identifier of a CN inside one [`CnSet`] / dependency graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CnId(pub usize);

impl std::fmt::Display for CnId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CN{}", self.0)
    }
}

/// Scheduling granularity of the CN split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CnGranularity {
    /// Traditional layer-by-layer: one CN per layer (paper baseline).
    LayerByLayer,
    /// Layer-fused: CNs of `lines` output rows each (depth-first /
    /// line-buffered processing).
    Lines(usize),
}

impl CnGranularity {
    /// Clamp the requested line granularity up to the minimum imposed by
    /// the architecture's spatial dataflows (HW dataflow awareness): a
    /// CN must contain at least the `OY` lines any core unrolls
    /// spatially.
    pub fn for_arch(self, arch: &Accelerator) -> CnGranularity {
        match self {
            CnGranularity::LayerByLayer => self,
            CnGranularity::Lines(lines) => {
                let min_oy = arch
                    .cores
                    .iter()
                    .map(|c| c.dataflow.unroll(Dim::OY))
                    .max()
                    .unwrap_or(1);
                CnGranularity::Lines(lines.max(min_oy))
            }
        }
    }
}

/// One computation node: a block of a layer's output lines.
#[derive(Debug, Clone)]
pub struct ComputationNode {
    pub id: CnId,
    pub layer: LayerId,
    /// Index among the CNs of this layer (outer-CN loop order).
    pub idx: usize,
    /// Output ranges in (K, OY, OX) space.
    pub out_rect: Rect,
    /// Input ranges in (C, IY, IX) space, clipped to the valid tensor
    /// (padding regions excluded).
    pub in_rect: Rect,
    /// MAC (or SIMD-op) count of this CN.
    pub macs: u64,
    /// Activation input bytes read (valid region only).
    pub input_bytes: u64,
    /// Activation output bytes produced.
    pub output_bytes: u64,
    /// Fig. 5 attribute 1: input bytes that can be discarded once this
    /// CN finishes (used by no later CN of the same layer).
    pub discard_input_bytes: u64,
    /// Fig. 5 attribute 2: newly generated *final* output bytes.
    pub final_output_bytes: u64,
}

impl ComputationNode {
    /// Number of output lines this CN covers.
    pub fn out_lines(&self) -> usize {
        (self.out_rect.hi[1] - self.out_rect.lo[1]) as usize
    }
}

/// All CNs of a workload, grouped per layer, with global contiguous ids.
#[derive(Debug)]
pub struct CnSet {
    pub nodes: Vec<ComputationNode>,
    /// Global CN id range per layer: `per_layer[l] = (first, count)`.
    pub per_layer: Vec<(usize, usize)>,
}

impl CnSet {
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: CnId) -> &ComputationNode {
        &self.nodes[id.0]
    }

    /// The CNs of one layer, in outer-CN loop order.
    pub fn layer_cns(&self, layer: LayerId) -> &[ComputationNode] {
        let (first, count) = self.per_layer[layer.0];
        &self.nodes[first..first + count]
    }

    /// Build the set from a workload at the given granularity.
    pub fn build(workload: &WorkloadGraph, gran: CnGranularity) -> CnSet {
        split_workload(workload, gran)
    }
}
