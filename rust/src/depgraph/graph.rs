//! The fine-grained CN graph: CN set + dependency edges + adjacency.

use crate::cn::{CnId, CnSet};

/// Edge kind: data dependency (carries bytes) or pure ordering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Inter-layer data dependency: `bytes` move producer -> consumer.
    Data,
    /// Intra-layer outer-CN-loop ordering (no data transfer).
    Order,
}

/// One dependency edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CnEdge {
    pub from: CnId,
    pub to: CnId,
    pub bytes: u64,
    pub kind: EdgeKind,
}

/// CN set plus dependency adjacency.
#[derive(Debug)]
pub struct CnGraph {
    pub cns: CnSet,
    pub edges: Vec<CnEdge>,
    preds: Vec<Vec<usize>>, // indices into `edges`
    succs: Vec<Vec<usize>>,
}

impl CnGraph {
    pub fn new(cns: CnSet, edges: Vec<CnEdge>) -> CnGraph {
        let n = cns.len();
        let mut preds = vec![Vec::new(); n];
        let mut succs = vec![Vec::new(); n];
        for (i, e) in edges.iter().enumerate() {
            preds[e.to.0].push(i);
            succs[e.from.0].push(i);
        }
        CnGraph { cns, edges, preds, succs }
    }

    pub fn len(&self) -> usize {
        self.cns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cns.is_empty()
    }

    /// Incoming edges of a CN.
    pub fn pred_edges(&self, id: CnId) -> impl Iterator<Item = &CnEdge> {
        self.preds[id.0].iter().map(move |&i| &self.edges[i])
    }

    /// Outgoing edges of a CN.
    pub fn succ_edges(&self, id: CnId) -> impl Iterator<Item = &CnEdge> {
        self.succs[id.0].iter().map(move |&i| &self.edges[i])
    }

    pub fn pred_count(&self, id: CnId) -> usize {
        self.preds[id.0].len()
    }

    /// CNs with no incoming edges (schedule entry points).
    pub fn sources(&self) -> Vec<CnId> {
        (0..self.len()).filter(|&i| self.preds[i].is_empty()).map(CnId).collect()
    }

    /// Verify the graph is acyclic & edges point id-forward within
    /// layers (construction invariant; used by tests/proptests).
    pub fn check_acyclic(&self) -> bool {
        // Kahn's algorithm
        let mut indeg: Vec<usize> = (0..self.len()).map(|i| self.preds[i].len()).collect();
        let mut stack: Vec<usize> =
            indeg.iter().enumerate().filter(|(_, &d)| d == 0).map(|(i, _)| i).collect();
        let mut seen = 0;
        while let Some(i) = stack.pop() {
            seen += 1;
            for &ei in &self.succs[i] {
                let t = self.edges[ei].to.0;
                indeg[t] -= 1;
                if indeg[t] == 0 {
                    stack.push(t);
                }
            }
        }
        seen == self.len()
    }
}
