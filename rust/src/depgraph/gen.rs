//! Dependency edge generation (R-tree fast path + pairwise oracle).

use super::graph::{CnEdge, CnGraph, EdgeKind};
use crate::cn::fuse::FusePattern;
use crate::cn::{CnSet, ComputationNode};
use crate::rtree::{RTree, Rect};
use crate::workload::{Layer, OpType, WorkloadGraph};

/// The input region a consumer CN needs, expressed in the *producer's
/// output coordinate space* (K, OY, OX), for producer `pred_idx` among
/// the consumer's predecessors.
///
/// - conv/pool: channels map 1:1, rows through stride/pad halo;
/// - add (and layernorm/softmax/gelu): element-wise, rows map 1:1;
/// - concat: the consumer's input channel range maps to the producer's
///   K range shifted by the channel offset of that predecessor;
/// - fc: needs the producer's entire output (no spatial locality);
/// - matmul: operand A (pred 0) maps rows 1:1 like the element-wise
///   ops, while operand B (pred 1) needs the producer's *entire*
///   output for every CN — the shared `[C, K]` matrix.  The exclusive
///   window attributes B's bytes to the first CN only, so the transfer
///   is streamed in once and held for the whole layer.
pub fn consumer_input_rect(
    consumer: &Layer,
    cn: &ComputationNode,
    producer: &Layer,
    pred_idx: usize,
    chan_offset: i64,
) -> Rect {
    let prod_bounds = Rect::chw(
        0..producer.k as i64,
        0..producer.oy as i64,
        0..producer.ox as i64,
    );
    match consumer.op {
        OpType::Fc => prod_bounds,
        // MatMul operand B: the whole [C, K] matrix, for every CN.
        OpType::MatMul if pred_idx > 0 => prod_bounds,
        OpType::Concat => {
            // consumer channel range [chan_offset, chan_offset + prod.k)
            // comes from this producer; rows/cols map 1:1
            let r = Rect::chw(
                (cn.in_rect.lo[0] - chan_offset)..(cn.in_rect.hi[0] - chan_offset),
                cn.in_rect.lo[1]..cn.in_rect.hi[1],
                cn.in_rect.lo[2]..cn.in_rect.hi[2],
            );
            r.clip(&prod_bounds)
        }
        OpType::Add => {
            let _ = pred_idx;
            cn.in_rect.clip(&prod_bounds)
        }
        _ => {
            // conv/dwconv/pool: the CN's input window, clipped to what
            // the producer actually produces
            cn.in_rect.clip(&prod_bounds)
        }
    }
}

/// The *exclusive* part of a consumer CN's input window: the rows no
/// earlier CN of the same layer also reads.  Consecutive CN windows
/// overlap by their halo; attributing each input row to the first CN
/// that reads it makes the per-edge transfer bytes partition the
/// producer's output, so communication volume is counted exactly once
/// (dependency *edges* still use the full window).
pub fn exclusive_input_rect(
    consumer: &Layer,
    layer_cns: &[ComputationNode],
    idx: usize,
    producer: &Layer,
    pred_idx: usize,
    chan_offset: i64,
) -> Rect {
    let full = consumer_input_rect(consumer, &layer_cns[idx], producer, pred_idx, chan_offset);
    if full.is_empty() || idx == 0 {
        return full;
    }
    let prev =
        consumer_input_rect(consumer, &layer_cns[idx - 1], producer, pred_idx, chan_offset);
    if prev.is_empty() {
        return full;
    }
    // rows strictly below the previous CN's window end are fresh
    let lo_y = full.lo[1].max(prev.hi[1]);
    Rect::new([full.lo[0], lo_y.min(full.hi[1]), full.lo[2]], full.hi)
}

/// Channel offsets of each predecessor in the consumer's input space
/// (non-zero only for Concat consumers).
fn chan_offsets(workload: &WorkloadGraph, consumer: &Layer) -> Vec<i64> {
    let mut offs = Vec::with_capacity(consumer.predecessors.len());
    let mut acc = 0i64;
    for &p in &consumer.predecessors {
        offs.push(acc);
        if consumer.op == OpType::Concat {
            acc += workload.layer(p).k as i64;
        }
    }
    offs
}

/// Generate all edges (intra-layer ordering + inter-layer data) with the
/// R-tree algorithm and assemble the [`CnGraph`].
pub fn generate(workload: &WorkloadGraph, cns: CnSet) -> CnGraph {
    generate_inner(workload, cns, None)
}

/// Like [`generate`], but honoring a decoded fuse/cut pattern: fused
/// boundaries keep the streaming R-tree edges, cut boundaries degrade
/// to full-layer materialization ([`materialized_edges`] semantics).
/// With a pattern that cuts nothing this is [`generate`], edge for
/// edge.
pub fn generate_fused(workload: &WorkloadGraph, cns: CnSet, pattern: &FusePattern) -> CnGraph {
    generate_inner(workload, cns, Some(pattern))
}

fn generate_inner(
    workload: &WorkloadGraph,
    cns: CnSet,
    pattern: Option<&FusePattern>,
) -> CnGraph {
    let mut edges = Vec::new();

    // --- intra-layer ordering edges (outer-CN loop order) ---
    for layer in workload.layers() {
        let layer_cns = cns.layer_cns(layer.id);
        for pair in layer_cns.windows(2) {
            edges.push(CnEdge {
                from: pair[0].id,
                to: pair[1].id,
                bytes: 0,
                kind: EdgeKind::Order,
            });
        }
    }

    // --- inter-layer data edges, one producer-consumer layer pair at a
    //     time (paper Fig. 6); cut boundaries materialize instead ---
    for consumer in workload.layers() {
        let offsets = chan_offsets(workload, consumer);
        for (pi, &prod_id) in consumer.predecessors.iter().enumerate() {
            let producer = workload.layer(prod_id);
            if pattern.is_some_and(|p| p.is_cut(consumer.id, pi)) {
                materialized_edges(&cns, producer, consumer, pi, offsets[pi], &mut edges);
            } else {
                inter_layer_edges_rtree(
                    workload, &cns, producer, consumer, pi, offsets[pi], &mut edges,
                );
            }
        }
    }

    CnGraph::new(cns, edges)
}

/// Edges across a **cut** fusion boundary: the producer's whole output
/// materializes before the consumer may start, so every consumer CN
/// depends on the producer's *last* CN (whose end time — through the
/// intra-layer order chain — is the materialization time).  Transfer
/// bytes still use the exclusive input windows, taken against the full
/// producer output, so the boundary traffic partitions the producer's
/// output exactly as on a fused boundary.  When both layers are
/// single-CN (the all-cut pattern) this emits the identical edge the
/// R-tree path would.
fn materialized_edges(
    cns: &CnSet,
    producer: &Layer,
    consumer: &Layer,
    pred_idx: usize,
    chan_offset: i64,
    edges: &mut Vec<CnEdge>,
) {
    let cons_cns = cns.layer_cns(consumer.id);
    let Some(last) = cns.layer_cns(producer.id).last() else {
        return;
    };
    let prod_bounds = Rect::chw(
        0..producer.k as i64,
        0..producer.oy as i64,
        0..producer.ox as i64,
    );
    let act_bits = producer.act_bits as u64;
    for (ci, ccn) in cons_cns.iter().enumerate() {
        let r = consumer_input_rect(consumer, ccn, producer, pred_idx, chan_offset);
        if r.is_empty() {
            continue;
        }
        let ex = exclusive_input_rect(consumer, cons_cns, ci, producer, pred_idx, chan_offset);
        edges.push(CnEdge {
            from: last.id,
            to: ccn.id,
            bytes: prod_bounds.intersection_volume(&ex) * act_bits / 8,
            kind: EdgeKind::Data,
        });
    }
}

fn inter_layer_edges_rtree(
    _workload: &WorkloadGraph,
    cns: &CnSet,
    producer: &Layer,
    consumer: &Layer,
    pred_idx: usize,
    chan_offset: i64,
    edges: &mut Vec<CnEdge>,
) {
    let cons_cns = cns.layer_cns(consumer.id);
    // exclusive windows give the transfer byte counts
    let exclusive: Vec<Rect> = (0..cons_cns.len())
        .map(|i| exclusive_input_rect(consumer, cons_cns, i, producer, pred_idx, chan_offset))
        .collect();

    // 1) build the R-tree over consumer CNs' required input ranges
    let items: Vec<(Rect, u32)> = cons_cns
        .iter()
        .enumerate()
        .filter_map(|(i, cn)| {
            let r = consumer_input_rect(consumer, cn, producer, pred_idx, chan_offset);
            if r.is_empty() {
                None
            } else {
                Some((r, i as u32))
            }
        })
        .collect();
    let tree = RTree::bulk_load(items);

    // 2) query with each producer CN's output range
    let act_bits = producer.act_bits as u64;
    for pcn in cns.layer_cns(producer.id) {
        tree.query(&pcn.out_rect, |_, ci| {
            let bytes =
                pcn.out_rect.intersection_volume(&exclusive[ci as usize]) * act_bits / 8;
            edges.push(CnEdge {
                from: pcn.id,
                to: cons_cns[ci as usize].id,
                bytes,
                kind: EdgeKind::Data,
            });
        });
    }
}

/// Quadratic baseline: check every producer-consumer CN pair one by one.
/// Used as the correctness oracle and the speedup-bench baseline.
pub fn generate_pairwise(workload: &WorkloadGraph, cns: CnSet) -> CnGraph {
    let mut edges = Vec::new();

    for layer in workload.layers() {
        let layer_cns = cns.layer_cns(layer.id);
        for pair in layer_cns.windows(2) {
            edges.push(CnEdge {
                from: pair[0].id,
                to: pair[1].id,
                bytes: 0,
                kind: EdgeKind::Order,
            });
        }
    }

    for consumer in workload.layers() {
        let offsets = chan_offsets(workload, consumer);
        for (pi, &prod_id) in consumer.predecessors.iter().enumerate() {
            let producer = workload.layer(prod_id);
            let cons_cns = cns.layer_cns(consumer.id);
            let act_bits = producer.act_bits as u64;
            for pcn in cns.layer_cns(producer.id) {
                for (ci, ccn) in cons_cns.iter().enumerate() {
                    let r = consumer_input_rect(consumer, ccn, producer, pi, offsets[pi]);
                    if r.is_empty() || !pcn.out_rect.intersects(&r) {
                        continue;
                    }
                    let ex = exclusive_input_rect(consumer, cons_cns, ci, producer, pi, offsets[pi]);
                    edges.push(CnEdge {
                        from: pcn.id,
                        to: ccn.id,
                        bytes: pcn.out_rect.intersection_volume(&ex) * act_bits / 8,
                        kind: EdgeKind::Data,
                    });
                }
            }
        }
    }

    CnGraph::new(cns, edges)
}

/// Canonical edge multiset for equivalence checks (tests + proptests).
pub fn edge_set(g: &CnGraph) -> std::collections::HashMap<(usize, usize), u64> {
    let mut m = std::collections::HashMap::new();
    for e in &g.edges {
        *m.entry((e.from.0, e.to.0)).or_insert(0) += e.bytes;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cn::CnGranularity;
    use crate::workload::models::{
        resnet18_first_segment, squeezenet, tiny_branchy, tiny_segment,
    };

    fn build(w: &WorkloadGraph, lines: usize) -> (CnGraph, CnGraph) {
        let a = generate(w, CnSet::build(w, CnGranularity::Lines(lines)));
        let b = generate_pairwise(w, CnSet::build(w, CnGranularity::Lines(lines)));
        (a, b)
    }

    #[test]
    fn rtree_equals_pairwise_segment() {
        let w = tiny_segment();
        let (a, b) = build(&w, 4);
        assert_eq!(edge_set(&a), edge_set(&b));
    }

    #[test]
    fn rtree_equals_pairwise_branchy() {
        let w = tiny_branchy();
        let (a, b) = build(&w, 2);
        assert_eq!(edge_set(&a), edge_set(&b));
    }

    #[test]
    fn rtree_equals_pairwise_concat() {
        let w = squeezenet();
        // restrict to a manageable CN count but still exercise concat
        let (a, b) = build(&w, 16);
        assert_eq!(edge_set(&a), edge_set(&b));
    }

    #[test]
    fn matmul_b_operand_edges() {
        use crate::workload::{LayerBuilder, LayerId, OpType};
        // q/k sources over 16 tokens of dim 8 -> scores[16, 16]
        let q = LayerBuilder::new("q", OpType::Conv).k(8).c(8).spatial(16, 1).build();
        let k = LayerBuilder::new("k", OpType::Conv).k(8).c(8).spatial(16, 1).build();
        let scores = LayerBuilder::new("scores", OpType::MatMul)
            .k(16)
            .c(8)
            .spatial(16, 1)
            .preds(&[LayerId(0), LayerId(1)])
            .build();
        let w = WorkloadGraph::new("attn", vec![q, k, scores]).unwrap();
        w.validate_channels().unwrap();

        // r-tree path must agree with the pairwise oracle on the new arm
        let (a, b) = build(&w, 4);
        assert_eq!(edge_set(&a), edge_set(&b));

        let g = a;
        let k_cns = g.cns.layer_cns(LayerId(1));
        let s_cns = g.cns.layer_cns(LayerId(2));
        assert_eq!(s_cns.len(), 4, "matmul splits by token rows");
        // every scores CN depends on EVERY k-producer CN (full B)...
        for scn in s_cns {
            let b_preds = g
                .pred_edges(scn.id)
                .filter(|e| {
                    e.kind == EdgeKind::Data
                        && g.cns.node(e.from).layer == LayerId(1)
                })
                .count();
            assert_eq!(b_preds, k_cns.len());
        }
        // ...but B's bytes are attributed to the first CN only, and sum
        // to the k-producer's whole output (streamed in once, held)
        let b_bytes_to = |cn: crate::cn::CnId| -> u64 {
            g.pred_edges(cn)
                .filter(|e| g.cns.node(e.from).layer == LayerId(1))
                .map(|e| e.bytes)
                .sum()
        };
        assert_eq!(b_bytes_to(s_cns[0].id), w.layer(LayerId(1)).output_bytes());
        for scn in &s_cns[1..] {
            assert_eq!(b_bytes_to(scn.id), 0);
        }
        // operand A maps rows 1:1: each scores CN takes bytes from
        // exactly its own q rows
        let a_bytes: u64 = s_cns
            .iter()
            .flat_map(|scn| {
                g.pred_edges(scn.id)
                    .filter(|e| g.cns.node(e.from).layer == LayerId(0))
                    .map(|e| e.bytes)
                    .collect::<Vec<_>>()
            })
            .sum();
        assert_eq!(a_bytes, w.layer(LayerId(0)).output_bytes());
    }

    #[test]
    fn graph_is_acyclic() {
        let w = resnet18_first_segment();
        let (g, _) = build(&w, 4);
        assert!(g.check_acyclic());
    }

    #[test]
    fn strided_conv_fan_in() {
        // conv7x7/s2 consumer rows 4..8 need producer rows 5..18:
        // with 4-line producer CNs that's producers 1..4 -> fan-in 4 on
        // the input edge side (plus the intra-layer order edge)
        let w = tiny_segment();
        let g = generate(&w, CnSet::build(&w, CnGranularity::Lines(4)));
        // layer1 (pool) CN #1
        let pool_cns = g.cns.layer_cns(crate::workload::LayerId(1));
        let target = pool_cns[1].id;
        let data_preds: Vec<_> = g
            .pred_edges(target)
            .filter(|e| e.kind == EdgeKind::Data)
            .collect();
        // pool CN rows 4..8 needs conv1 rows 7..16 -> conv1 CNs 1,2,3
        assert_eq!(data_preds.len(), 3);
    }

    #[test]
    fn layer_by_layer_has_layer_graph_shape() {
        let w = tiny_branchy();
        let g = generate(&w, CnSet::build(&w, CnGranularity::LayerByLayer));
        // one CN per layer, data edges mirror the workload edges
        assert_eq!(g.len(), w.len());
        let n_data = g.edges.iter().filter(|e| e.kind == EdgeKind::Data).count();
        let n_workload_edges: usize =
            w.layers().iter().map(|l| l.predecessors.len()).sum();
        assert_eq!(n_data, n_workload_edges);
    }

    #[test]
    fn sources_are_first_layer_cns() {
        let w = tiny_segment();
        let g = generate(&w, CnSet::build(&w, CnGranularity::Lines(4)));
        let sources = g.sources();
        // only the first CN of layer 0 has no preds (others chain)
        assert_eq!(sources.len(), 1);
        assert_eq!(g.cns.node(sources[0]).layer, crate::workload::LayerId(0));
    }

    #[test]
    fn edge_bytes_conservation() {
        // total inter-layer data bytes from a producer == its output
        // bytes when the consumer covers it fully (conv3x3a -> conv3x3b)
        let w = tiny_segment();
        let g = generate(&w, CnSet::build(&w, CnGranularity::LayerByLayer));
        let conv_a = g.cns.layer_cns(crate::workload::LayerId(2))[0].id;
        let out: u64 = g
            .succ_edges(conv_a)
            .filter(|e| e.kind == EdgeKind::Data)
            .map(|e| e.bytes)
            .sum();
        let expect = w.layer(crate::workload::LayerId(2)).output_bytes();
        assert_eq!(out, expect);
    }
}
