//! Step 2 — fine-grained CN dependency graph generation.
//!
//! *Intra-layer* edges chain the CNs of a layer in outer-CN loop order
//! (structured tensor access with loop counters).  *Inter-layer* edges
//! connect producer CNs to the consumer CNs whose input windows overlap
//! the produced data — found by bulk-loading the consumer CNs' required
//! input ranges into an [`crate::rtree::RTree`] and querying it with
//! each producer CN's output range (paper Fig. 6).
//!
//! A quadratic pairwise generator ([`generate_pairwise`]) is kept as the
//! correctness oracle and as the baseline of the paper's 10^3x speedup
//! claim (`benches/rtree_speedup.rs`).

mod gen;
mod graph;

pub use gen::{consumer_input_rect, edge_set, generate, generate_fused, generate_pairwise};
pub use graph::{CnEdge, CnGraph, EdgeKind};
