//! The Stream pipeline: Steps 1–5 behind one call (paper Fig. 3).
//!
//! See `docs/ARCHITECTURE.md` for the full walkthrough of the steps
//! and their modules.  The GA inside `run()` evaluates fitness on
//! [`GaParams::threads`](crate::allocator::GaParams) worker threads
//! (0 = auto via `STREAM_THREADS`, 1 = serial; results are
//! bit-identical either way) and memoizes schedule costs in a
//! [`ScheduleCache`](crate::cost::ScheduleCache).
//!
//! ```no_run
//! use stream::prelude::*;
//! let result = stream::pipeline::Stream::new(
//!     stream::workload::models::resnet18(),
//!     stream::arch::presets::hetero_quad(),
//!     StreamOpts::default(),
//! ).run().unwrap();
//! ```

use crate::allocator::{
    allocation_from_genome, FusionGa, Ga, GaParams, Objective, PatternCache,
};
use crate::arch::{Accelerator, CoreId};
use crate::cn::{CnGranularity, CnSet, FusePattern};
use crate::cost::ScheduleCache;
use crate::depgraph::{generate, CnGraph};
use crate::mapping::CostModel;
use crate::scheduler::{ScheduleResult, Scheduler};
use crate::workload::WorkloadGraph;

pub use crate::allocator::{FuseSearchOpts, FusionResult, GaResult};
pub use crate::scheduler::SchedulePriority;

/// Pipeline options.
#[derive(Debug, Clone)]
pub struct StreamOpts {
    /// CN granularity before HW-dataflow clamping (Step 1).
    pub granularity: CnGranularity,
    /// Scheduler candidate priority (Step 5).
    pub priority: SchedulePriority,
    /// GA optimization criterion (Step 4).
    pub objective: Objective,
    pub ga: GaParams,
    /// Fixed per-layer allocation: skips the GA when set (used by the
    /// validation experiments, which pin the measured mapping).
    pub allocation: Option<Vec<CoreId>>,
    /// Fusion co-search: when set (and no fixed allocation is given),
    /// [`Stream::run`] searches per-edge fuse/cut decisions jointly
    /// with the core allocation ([`Stream::run_fuse_search`]) instead
    /// of scheduling under the single fixed [`granularity`](Self::granularity).
    pub fuse: Option<FuseSearchOpts>,
}

impl Default for StreamOpts {
    fn default() -> Self {
        StreamOpts {
            granularity: CnGranularity::Lines(4),
            priority: SchedulePriority::Latency,
            objective: Objective::Edp,
            ga: GaParams::default(),
            allocation: None,
            fuse: None,
        }
    }
}

impl StreamOpts {
    /// Layer-by-layer baseline options (the Section V comparison point).
    pub fn layer_by_layer() -> StreamOpts {
        StreamOpts { granularity: CnGranularity::LayerByLayer, ..Default::default() }
    }

    /// Fusion co-search options with the default single-entry menu.
    pub fn fuse_search() -> StreamOpts {
        StreamOpts { fuse: Some(FuseSearchOpts::default()), ..Default::default() }
    }
}

/// Errors from the pipeline.
#[derive(Debug)]
pub enum StreamError {
    EmptyWorkload,
    BadAllocation(String),
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::EmptyWorkload => write!(f, "workload has no layers"),
            StreamError::BadAllocation(m) => write!(f, "bad allocation: {m}"),
        }
    }
}

impl std::error::Error for StreamError {}

/// The fuse pattern a co-search point was scheduled under.
#[derive(Debug, Clone)]
pub struct FuseChoice {
    /// The decoded fuse genes (one per workload edge).
    pub genes: Vec<u16>,
    /// [`FusePattern::fingerprint`] of the decoded pattern.
    pub pattern_fp: u64,
    pub n_cut: usize,
    pub n_fused: usize,
}

/// One fully-scheduled allocation in the result set.
pub struct ScheduledPoint {
    pub allocation: Vec<CoreId>,
    pub result: ScheduleResult,
    /// The fuse pattern this point was scheduled under (`None` on the
    /// classic fixed-granularity path).
    pub fuse: Option<FuseChoice>,
}

/// The pipeline output: the Pareto set of scheduled allocations.
pub struct StreamResult {
    pub points: Vec<ScheduledPoint>,
    /// Number of CNs in the fine-grained graph (diagnostics).
    pub n_cns: usize,
    /// Number of dependency edges (diagnostics).
    pub n_edges: usize,
}

impl StreamResult {
    /// The minimum-EDP point.
    pub fn best_edp(&self) -> Option<&ScheduledPoint> {
        // total_cmp, not partial_cmp-or-Equal: a NaN objective (which
        // would make min_by's comparator inconsistent and the winner
        // arbitrary) sorts deterministically after every real value
        self.points
            .iter()
            .min_by(|a, b| a.result.edp().total_cmp(&b.result.edp()))
    }

    /// The minimum-latency point.
    pub fn best_latency(&self) -> Option<&ScheduledPoint> {
        self.points.iter().min_by_key(|p| p.result.latency())
    }

    /// The minimum-peak-memory point.
    pub fn best_memory(&self) -> Option<&ScheduledPoint> {
        self.points
            .iter()
            .min_by(|a, b| a.result.peak_mem().total_cmp(&b.result.peak_mem()))
    }
}

impl ScheduledPoint {
    pub fn edp(&self) -> f64 {
        self.result.edp()
    }
}

/// The Stream framework instance.
pub struct Stream {
    pub workload: WorkloadGraph,
    pub arch: Accelerator,
    pub opts: StreamOpts,
}

impl Stream {
    pub fn new(workload: WorkloadGraph, arch: Accelerator, opts: StreamOpts) -> Stream {
        Stream { workload, arch, opts }
    }

    /// Steps 1–2: split into CNs and build the dependency graph.
    pub fn build_graph(&self) -> CnGraph {
        let gran = self.opts.granularity.for_arch(&self.arch);
        let cns = CnSet::build(&self.workload, gran);
        generate(&self.workload, cns)
    }

    /// Step 3: the intra-core cost model for this (workload, arch).
    pub fn build_costs(&self, graph: &CnGraph) -> CostModel {
        CostModel::build(&self.workload, &graph.cns, &self.arch)
    }

    /// Run the full pipeline (Steps 1–5).  With
    /// [`StreamOpts::fuse`] set (and no fixed allocation), Steps 1–2
    /// become part of the search space: the run delegates to
    /// [`Stream::run_fuse_search`].
    pub fn run(&self) -> Result<StreamResult, StreamError> {
        if self.workload.is_empty() {
            return Err(StreamError::EmptyWorkload);
        }
        if self.opts.fuse.is_some() && self.opts.allocation.is_none() {
            return self.run_fuse_search();
        }
        let graph = self.build_graph();
        let costs = self.build_costs(&graph);
        let scheduler = Scheduler::new(&self.workload, &graph, &costs, &self.arch);

        let allocations: Vec<Vec<CoreId>> = match &self.opts.allocation {
            Some(fixed) => {
                if fixed.len() != self.workload.len() {
                    return Err(StreamError::BadAllocation(format!(
                        "expected {} entries, got {}",
                        self.workload.len(),
                        fixed.len()
                    )));
                }
                vec![fixed.clone()]
            }
            None => {
                let mut ga = Ga::new(
                    &self.workload,
                    &self.arch,
                    &scheduler,
                    self.opts.priority,
                    self.opts.objective,
                    self.opts.ga,
                );
                let front = ga.run();
                if front.is_empty() {
                    // degenerate: no dense layers — single default genome
                    vec![allocation_from_genome(&self.workload, &self.arch, &[])]
                } else {
                    front.into_iter().map(|r| r.allocation).collect()
                }
            }
        };

        let points = allocations
            .into_iter()
            .map(|allocation| {
                let result = scheduler.run(&allocation, self.opts.priority);
                ScheduledPoint { allocation, result, fuse: None }
            })
            .collect();

        Ok(StreamResult { points, n_cns: graph.len(), n_edges: graph.edges.len() })
    }

    /// Co-search fuse/cut decisions and core allocation (the fusion
    /// axis; see `docs/ARCHITECTURE.md`).
    ///
    /// Three phases over shared caches (one [`PatternCache`] of Step
    /// 1–3 precomputations, one [`ScheduleCache`] of metrics keyed by
    /// composed (topology, pattern) fingerprints):
    ///
    /// 1. **Regimes** — two pinned [`FusionGa`] runs reproduce the
    ///    classic all-fuse and all-cut searches bit-for-bit (same
    ///    genome shape, seeds and RNG stream as the plain GA);
    /// 2. **Co-search** — a free run over `[core][fuse]` genomes,
    ///    seeded with both regimes' front genomes (interleaved, best
    ///    first) plus every heuristic prefix under both uniform
    ///    suffixes.  Re-evaluating a regime winner is an exact cache
    ///    hit, and the final front is computed over every genome the
    ///    run saw — so the co-search front weakly dominates both
    ///    regimes *by construction*;
    /// 3. **Scheduling** — each front point is re-scheduled under its
    ///    own pattern's context and reported with its [`FuseChoice`].
    pub fn run_fuse_search(&self) -> Result<StreamResult, StreamError> {
        if self.workload.is_empty() {
            return Err(StreamError::EmptyWorkload);
        }
        let menu = self.opts.fuse.clone().unwrap_or_default().menu;
        let patterns = PatternCache::new();
        let cache = ScheduleCache::new();
        let new_ga = || {
            FusionGa::new(
                &self.workload,
                &self.arch,
                self.opts.priority,
                self.opts.objective,
                self.opts.ga,
                menu.clone(),
                &patterns,
                &cache,
            )
        };

        // phase 1: the two classic regimes as pinned searches
        let all_fuse = FusePattern::genes_all_fuse(&self.workload);
        let all_cut = FusePattern::genes_all_cut(&self.workload);
        let mut per_regime: Vec<Vec<Vec<u16>>> = Vec::new();
        for suffix in [&all_fuse, &all_cut] {
            let regime_front = new_ga().pinned(suffix.clone()).run();
            per_regime.push(
                regime_front
                    .into_iter()
                    .map(|r| {
                        let mut g = r.core_genes;
                        g.extend_from_slice(suffix);
                        g
                    })
                    .collect(),
            );
        }
        // interleave (best-EDP first per regime) so both regime bests
        // survive any seed truncation to the population size
        let mut regime_seeds = Vec::new();
        let longest = per_regime.iter().map(|v| v.len()).max().unwrap_or(0);
        for i in 0..longest {
            for regime in &per_regime {
                if let Some(g) = regime.get(i) {
                    regime_seeds.push(g.clone());
                }
            }
        }

        // phase 2: the free co-search
        let front = new_ga().with_extra_seeds(regime_seeds).run();

        // phase 3: schedule each front point under its own pattern
        let mut points = Vec::new();
        let (mut n_cns, mut n_edges) = (0usize, 0usize);
        let fallback: Vec<FusionResult>;
        let front = if front.is_empty() {
            // degenerate (no genes at all): the default allocation
            // under the all-fuse pattern
            let pattern =
                FusePattern::decode(&self.workload, &self.arch, &menu, &all_fuse);
            fallback = vec![FusionResult {
                genome: Vec::new(),
                core_genes: Vec::new(),
                fuse_genes: all_fuse.clone(),
                allocation: allocation_from_genome(&self.workload, &self.arch, &[]),
                metrics: Default::default(),
                pattern_fp: pattern.fingerprint(),
                n_cut: pattern.n_cut(),
                n_fused: pattern.n_fused(),
            }];
            &fallback
        } else {
            &front
        };
        for r in front {
            let pattern =
                FusePattern::decode(&self.workload, &self.arch, &menu, &r.fuse_genes);
            let ctx = patterns.get_or_build(&self.workload, &self.arch, pattern);
            if points.is_empty() {
                // diagnostics reflect the best point's graph
                n_cns = ctx.graph.len();
                n_edges = ctx.graph.edges.len();
            }
            let scheduler =
                Scheduler::new(&self.workload, &ctx.graph, &ctx.costs, &self.arch);
            let result = scheduler.run(&r.allocation, self.opts.priority);
            points.push(ScheduledPoint {
                allocation: r.allocation.clone(),
                result,
                fuse: Some(FuseChoice {
                    genes: r.fuse_genes.clone(),
                    pattern_fp: r.pattern_fp,
                    n_cut: r.n_cut,
                    n_fused: r.n_fused,
                }),
            });
        }

        Ok(StreamResult { points, n_cns, n_edges })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::models::{tiny_branchy, tiny_segment};

    fn small_ga() -> GaParams {
        GaParams { population: 8, generations: 4, ..Default::default() }
    }

    #[test]
    fn full_pipeline_runs() {
        let s = Stream::new(
            tiny_segment(),
            presets::hetero_quad(),
            StreamOpts { ga: small_ga(), ..Default::default() },
        );
        let r = s.run().unwrap();
        assert!(!r.points.is_empty());
        assert!(r.n_cns > 5);
        assert!(r.best_edp().unwrap().result.latency() > 0);
    }

    #[test]
    fn fixed_allocation_skips_ga() {
        let w = tiny_segment();
        let arch = presets::test_dual();
        let simd = arch.simd_core().unwrap();
        let alloc: Vec<CoreId> = w
            .layers()
            .iter()
            .map(|l| if l.op.is_dense() { CoreId(0) } else { simd })
            .collect();
        let s = Stream::new(
            w,
            arch,
            StreamOpts { allocation: Some(alloc.clone()), ..Default::default() },
        );
        let r = s.run().unwrap();
        assert_eq!(r.points.len(), 1);
        assert_eq!(r.points[0].allocation, alloc);
    }

    #[test]
    fn bad_allocation_length_rejected() {
        let s = Stream::new(
            tiny_segment(),
            presets::test_dual(),
            StreamOpts { allocation: Some(vec![CoreId(0)]), ..Default::default() },
        );
        assert!(s.run().is_err());
    }

    #[test]
    fn fused_beats_lbl_on_edp_multicore() {
        let run = |opts: StreamOpts| {
            Stream::new(tiny_branchy(), presets::hetero_quad(), opts)
                .run()
                .unwrap()
                .best_edp()
                .unwrap()
                .edp()
        };
        let fused = run(StreamOpts { ga: small_ga(), ..Default::default() });
        let lbl = run(StreamOpts { ga: small_ga(), ..StreamOpts::layer_by_layer() });
        assert!(fused < lbl, "fused {fused} vs lbl {lbl}");
    }

    #[test]
    fn fuse_search_weakly_dominates_both_regimes() {
        let run = |opts: StreamOpts| {
            Stream::new(tiny_branchy(), presets::hetero_quad(), opts)
                .run()
                .unwrap()
                .best_edp()
                .unwrap()
                .edp()
        };
        let co = run(StreamOpts { ga: small_ga(), ..StreamOpts::fuse_search() });
        let fused = run(StreamOpts { ga: small_ga(), ..Default::default() });
        let lbl = run(StreamOpts { ga: small_ga(), ..StreamOpts::layer_by_layer() });
        // the regime winners are seeded into the co-search, so its
        // best EDP can never be worse than either regime's
        assert!(co <= fused.min(lbl), "co {co} vs fused {fused} / lbl {lbl}");
    }

    #[test]
    fn fuse_search_points_carry_their_pattern() {
        let s = Stream::new(
            tiny_segment(),
            presets::hetero_quad(),
            StreamOpts { ga: small_ga(), ..StreamOpts::fuse_search() },
        );
        let r = s.run().unwrap();
        assert!(!r.points.is_empty());
        let n_edges = crate::cn::n_fuse_genes(&tiny_segment());
        for p in &r.points {
            let f = p.fuse.as_ref().expect("co-search points carry a FuseChoice");
            assert_eq!(f.genes.len(), n_edges);
            assert_eq!(f.n_cut + f.n_fused, n_edges);
        }
    }
}
