//! The Stream pipeline: Steps 1–5 behind one call (paper Fig. 3).
//!
//! See `docs/ARCHITECTURE.md` for the full walkthrough of the steps
//! and their modules.  The GA inside `run()` evaluates fitness on
//! [`GaParams::threads`](crate::allocator::GaParams) worker threads
//! (0 = auto via `STREAM_THREADS`, 1 = serial; results are
//! bit-identical either way) and memoizes schedule costs in a
//! [`ScheduleCache`](crate::cost::ScheduleCache).
//!
//! ```no_run
//! use stream::prelude::*;
//! let result = stream::pipeline::Stream::new(
//!     stream::workload::models::resnet18(),
//!     stream::arch::presets::hetero_quad(),
//!     StreamOpts::default(),
//! ).run().unwrap();
//! ```

use crate::allocator::{allocation_from_genome, Ga, GaParams, Objective};
use crate::arch::{Accelerator, CoreId};
use crate::cn::{CnGranularity, CnSet};
use crate::depgraph::{generate, CnGraph};
use crate::mapping::CostModel;
use crate::scheduler::{ScheduleResult, Scheduler};
use crate::workload::WorkloadGraph;

pub use crate::allocator::GaResult;
pub use crate::scheduler::SchedulePriority;

/// Pipeline options.
#[derive(Debug, Clone)]
pub struct StreamOpts {
    /// CN granularity before HW-dataflow clamping (Step 1).
    pub granularity: CnGranularity,
    /// Scheduler candidate priority (Step 5).
    pub priority: SchedulePriority,
    /// GA optimization criterion (Step 4).
    pub objective: Objective,
    pub ga: GaParams,
    /// Fixed per-layer allocation: skips the GA when set (used by the
    /// validation experiments, which pin the measured mapping).
    pub allocation: Option<Vec<CoreId>>,
}

impl Default for StreamOpts {
    fn default() -> Self {
        StreamOpts {
            granularity: CnGranularity::Lines(4),
            priority: SchedulePriority::Latency,
            objective: Objective::Edp,
            ga: GaParams::default(),
            allocation: None,
        }
    }
}

impl StreamOpts {
    /// Layer-by-layer baseline options (the Section V comparison point).
    pub fn layer_by_layer() -> StreamOpts {
        StreamOpts { granularity: CnGranularity::LayerByLayer, ..Default::default() }
    }
}

/// Errors from the pipeline.
#[derive(Debug)]
pub enum StreamError {
    EmptyWorkload,
    BadAllocation(String),
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::EmptyWorkload => write!(f, "workload has no layers"),
            StreamError::BadAllocation(m) => write!(f, "bad allocation: {m}"),
        }
    }
}

impl std::error::Error for StreamError {}

/// One fully-scheduled allocation in the result set.
pub struct ScheduledPoint {
    pub allocation: Vec<CoreId>,
    pub result: ScheduleResult,
}

/// The pipeline output: the Pareto set of scheduled allocations.
pub struct StreamResult {
    pub points: Vec<ScheduledPoint>,
    /// Number of CNs in the fine-grained graph (diagnostics).
    pub n_cns: usize,
    /// Number of dependency edges (diagnostics).
    pub n_edges: usize,
}

impl StreamResult {
    /// The minimum-EDP point.
    pub fn best_edp(&self) -> Option<&ScheduledPoint> {
        self.points.iter().min_by(|a, b| {
            a.result
                .edp()
                .partial_cmp(&b.result.edp())
                .unwrap_or(std::cmp::Ordering::Equal)
        })
    }

    /// The minimum-latency point.
    pub fn best_latency(&self) -> Option<&ScheduledPoint> {
        self.points.iter().min_by_key(|p| p.result.latency())
    }

    /// The minimum-peak-memory point.
    pub fn best_memory(&self) -> Option<&ScheduledPoint> {
        self.points.iter().min_by(|a, b| {
            a.result
                .peak_mem()
                .partial_cmp(&b.result.peak_mem())
                .unwrap_or(std::cmp::Ordering::Equal)
        })
    }
}

impl ScheduledPoint {
    pub fn edp(&self) -> f64 {
        self.result.edp()
    }
}

/// The Stream framework instance.
pub struct Stream {
    pub workload: WorkloadGraph,
    pub arch: Accelerator,
    pub opts: StreamOpts,
}

impl Stream {
    pub fn new(workload: WorkloadGraph, arch: Accelerator, opts: StreamOpts) -> Stream {
        Stream { workload, arch, opts }
    }

    /// Steps 1–2: split into CNs and build the dependency graph.
    pub fn build_graph(&self) -> CnGraph {
        let gran = self.opts.granularity.for_arch(&self.arch);
        let cns = CnSet::build(&self.workload, gran);
        generate(&self.workload, cns)
    }

    /// Step 3: the intra-core cost model for this (workload, arch).
    pub fn build_costs(&self, graph: &CnGraph) -> CostModel {
        CostModel::build(&self.workload, &graph.cns, &self.arch)
    }

    /// Run the full pipeline (Steps 1–5).
    pub fn run(&self) -> Result<StreamResult, StreamError> {
        if self.workload.is_empty() {
            return Err(StreamError::EmptyWorkload);
        }
        let graph = self.build_graph();
        let costs = self.build_costs(&graph);
        let scheduler = Scheduler::new(&self.workload, &graph, &costs, &self.arch);

        let allocations: Vec<Vec<CoreId>> = match &self.opts.allocation {
            Some(fixed) => {
                if fixed.len() != self.workload.len() {
                    return Err(StreamError::BadAllocation(format!(
                        "expected {} entries, got {}",
                        self.workload.len(),
                        fixed.len()
                    )));
                }
                vec![fixed.clone()]
            }
            None => {
                let mut ga = Ga::new(
                    &self.workload,
                    &self.arch,
                    &scheduler,
                    self.opts.priority,
                    self.opts.objective,
                    self.opts.ga,
                );
                let front = ga.run();
                if front.is_empty() {
                    // degenerate: no dense layers — single default genome
                    vec![allocation_from_genome(&self.workload, &self.arch, &[])]
                } else {
                    front.into_iter().map(|r| r.allocation).collect()
                }
            }
        };

        let points = allocations
            .into_iter()
            .map(|allocation| {
                let result = scheduler.run(&allocation, self.opts.priority);
                ScheduledPoint { allocation, result }
            })
            .collect();

        Ok(StreamResult { points, n_cns: graph.len(), n_edges: graph.edges.len() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::workload::models::{tiny_branchy, tiny_segment};

    fn small_ga() -> GaParams {
        GaParams { population: 8, generations: 4, ..Default::default() }
    }

    #[test]
    fn full_pipeline_runs() {
        let s = Stream::new(
            tiny_segment(),
            presets::hetero_quad(),
            StreamOpts { ga: small_ga(), ..Default::default() },
        );
        let r = s.run().unwrap();
        assert!(!r.points.is_empty());
        assert!(r.n_cns > 5);
        assert!(r.best_edp().unwrap().result.latency() > 0);
    }

    #[test]
    fn fixed_allocation_skips_ga() {
        let w = tiny_segment();
        let arch = presets::test_dual();
        let simd = arch.simd_core().unwrap();
        let alloc: Vec<CoreId> = w
            .layers()
            .iter()
            .map(|l| if l.op.is_dense() { CoreId(0) } else { simd })
            .collect();
        let s = Stream::new(
            w,
            arch,
            StreamOpts { allocation: Some(alloc.clone()), ..Default::default() },
        );
        let r = s.run().unwrap();
        assert_eq!(r.points.len(), 1);
        assert_eq!(r.points[0].allocation, alloc);
    }

    #[test]
    fn bad_allocation_length_rejected() {
        let s = Stream::new(
            tiny_segment(),
            presets::test_dual(),
            StreamOpts { allocation: Some(vec![CoreId(0)]), ..Default::default() },
        );
        assert!(s.run().is_err());
    }

    #[test]
    fn fused_beats_lbl_on_edp_multicore() {
        let run = |opts: StreamOpts| {
            Stream::new(tiny_branchy(), presets::hetero_quad(), opts)
                .run()
                .unwrap()
                .best_edp()
                .unwrap()
                .edp()
        };
        let fused = run(StreamOpts { ga: small_ga(), ..Default::default() });
        let lbl = run(StreamOpts { ga: small_ga(), ..StreamOpts::layer_by_layer() });
        assert!(fused < lbl, "fused {fused} vs lbl {lbl}");
    }
}
