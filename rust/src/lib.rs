//! # Stream — DSE of layer-fused DNNs on heterogeneous multi-core accelerators
//!
//! A Rust reproduction of *"Towards Heterogeneous Multi-core Accelerators
//! Exploiting Fine-grained Scheduling of Layer-Fused Deep Neural Networks"*
//! (Symons et al., KU Leuven, 2022 — the Stream framework).
//!
//! Stream takes a DNN workload graph and a high-level multi-core
//! accelerator description, and derives an optimized execution schedule
//! together with its energy, latency and memory footprint:
//!
//! 1. [`cn`] — split every layer into **computation nodes** (CNs) at a
//!    granularity aware of the layer topology and of every core's spatial
//!    dataflow (paper Step 1);
//! 2. [`depgraph`] — generate the fine-grained CN dependency graph, using
//!    an [`rtree`] for fast inter-layer overlap queries (Step 2);
//! 3. [`mapping`] — extract per-(CN, core) energy/latency with a
//!    ZigZag-lite analytic intra-core model over [`arch`] descriptions
//!    and the [`cacti`] memory-energy model (Step 3);
//! 4. [`allocator`] — explore the layer–core allocation space with a
//!    genetic algorithm using NSGA-II selection (Step 4); fitness
//!    evaluation is data-parallel (`GaParams::threads` /
//!    `STREAM_THREADS`, bit-identical to the serial path) and memoized
//!    through the [`cost`] module's `ScheduleCache`;
//! 5. [`scheduler`] — schedule CNs onto cores with latency- or
//!    memory-prioritized heuristics in O(log n) per pick, routing every
//!    transfer over the architecture's interconnect topology
//!    ([`arch::topology`]: shared bus, ring, 2-D mesh, crossbar or
//!    custom fabrics) with per-link FCFS contention, nearest-DRAM-port
//!    selection and FIFO weight eviction (Step 5.1), and trace
//!    activation memory usage over time (Step 5.2).
//!
//! On top of the per-inference pipeline, [`scenario`] (Step 6) turns
//! the simulator into a **serving-scenario explorer**: multi-tenant
//! request streams with deadlines and priorities are co-scheduled over
//! the shared cores/links/DRAM ports under fifo / priority / EDF
//! arbitration, reporting per-tenant p50/p99 latency, deadline-miss
//! rate and throughput, with NSGA-II co-optimization of the
//! `(tenant, layer) → core` partitioning.
//!
//! `docs/ARCHITECTURE.md` in the repository walks through the pipeline
//! step by step and maps every module to its paper section.
//!
//! The [`pipeline`] module orchestrates the five steps behind one call;
//! [`runtime`] loads the AOT-compiled XLA artifacts (built once from
//! JAX/Pallas by `python/compile/aot.py`) and *executes* the resulting
//! schedules numerically on the PJRT CPU client, proving the fused
//! schedules compute exactly what the layer-by-layer baseline computes.
//!
//! ```no_run
//! use stream::prelude::*;
//!
//! let workload = stream::workload::models::resnet18();
//! let arch = stream::arch::presets::hetero_quad();
//! let opts = StreamOpts::default();
//! let result = stream::pipeline::Stream::new(workload, arch, opts).run().unwrap();
//! println!("best EDP = {:.3e}", result.best_edp().unwrap().edp());
//! ```

pub mod allocator;
pub mod arch;
pub mod experiments;
pub mod cacti;
pub mod cn;
pub mod cost;
pub mod depgraph;
pub mod mapping;
pub mod obs;
pub mod pipeline;
pub mod rtree;
pub mod runtime;
pub mod scenario;
pub mod scheduler;
pub mod util;
pub mod viz;
pub mod workload;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::arch::{Accelerator, Core, Dataflow};
    pub use crate::cn::{CnGranularity, ComputationNode};
    pub use crate::cost::{EnergyBreakdown, ScheduleMetrics};
    pub use crate::pipeline::{SchedulePriority, Stream, StreamOpts, StreamResult};
    pub use crate::scenario::{Arbitration, Scenario, ScenarioResult, ScenarioSim, Tenant};
    pub use crate::scheduler::ScheduleResult;
    pub use crate::workload::{Layer, LayerId, OpType, WorkloadGraph};
}
