//! Builders for the paper's evaluation and validation workloads.
//!
//! Exploration networks (Section V): ResNet-18, MobileNetV2, SqueezeNet,
//! Tiny-YOLO, FSRCNN.  Validation workloads (Section IV): FSRCNN at
//! 560x960 (DepFiN), ResNet-50 segment (Jia et al. 4x4 AiMC), ResNet-18
//! first segment (DIANA).  Transformer frontier: [`vit_tiny`],
//! [`bert_small`] and the [`llm_decode`] GPT-style decode step over
//! the unified attention ops.  Plus tiny synthetic networks for tests.
//!
//! Layer dimensions follow the original papers at the canonical input
//! resolutions (224x224 for the classification networks, 416x416 for
//! Tiny-YOLO, 560x960 for FSRCNN).

mod fsrcnn;
mod mobilenetv2;
mod resnet;
mod squeezenet;
mod tiny;
mod tinyyolo;
mod transformer;

pub use fsrcnn::fsrcnn;
pub use mobilenetv2::mobilenetv2;
pub use resnet::{resnet18, resnet18_first_segment, resnet50_segment};
pub use squeezenet::squeezenet;
pub use tiny::{tiny_branchy, tiny_linear, tiny_segment};
pub use tinyyolo::tiny_yolo;
pub use transformer::{bert_small, llm_decode, vit_stack, vit_tiny};

use super::{Layer, LayerBuilder, LayerId, OpType, PoolKind, WorkloadGraph};

/// The five exploration networks of Section V, by name.
pub fn exploration_networks() -> Vec<WorkloadGraph> {
    vec![
        resnet18(),
        mobilenetv2(),
        squeezenet(),
        tiny_yolo(),
        fsrcnn(560, 960),
    ]
}

/// Look a workload up by CLI name.
pub fn by_name(name: &str) -> Option<WorkloadGraph> {
    match name {
        "resnet18" => Some(resnet18()),
        "mobilenetv2" => Some(mobilenetv2()),
        "squeezenet" => Some(squeezenet()),
        "tinyyolo" | "tiny-yolo" => Some(tiny_yolo()),
        "fsrcnn" => Some(fsrcnn(560, 960)),
        "vit-tiny" | "vit_tiny" => Some(vit_tiny()),
        "bert-small" | "bert_small" => Some(bert_small()),
        "llm-decode" | "llm_decode" => Some(llm_decode()),
        "resnet18-first-segment" => Some(resnet18_first_segment()),
        "resnet50-segment" => Some(resnet50_segment()),
        "tiny-linear" => Some(tiny_linear()),
        "tiny-branchy" => Some(tiny_branchy()),
        "tiny-segment" => Some(tiny_segment()),
        _ => None,
    }
}

pub const WORKLOAD_NAMES: &[&str] = &[
    "resnet18",
    "mobilenetv2",
    "squeezenet",
    "tinyyolo",
    "fsrcnn",
    "vit-tiny",
    "bert-small",
    "llm-decode",
    "resnet18-first-segment",
    "resnet50-segment",
    "tiny-linear",
    "tiny-branchy",
    "tiny-segment",
];

// ---------------------------------------------------------------------------
// shared builder helpers
// ---------------------------------------------------------------------------

pub(crate) fn conv(
    name: &str,
    pred: Option<LayerId>,
    k: usize,
    c: usize,
    oy: usize,
    ox: usize,
    f: usize,
    stride: usize,
    pad: usize,
) -> Layer {
    let b = LayerBuilder::new(name, OpType::Conv)
        .k(k)
        .c(c)
        .spatial(oy, ox)
        .filter(f, f)
        .stride(stride)
        .pad(pad);
    match pred {
        Some(p) => b.preds(&[p]).build(),
        None => b.build(),
    }
}

pub(crate) fn dwconv(
    name: &str,
    pred: LayerId,
    c: usize,
    oy: usize,
    ox: usize,
    f: usize,
    stride: usize,
    pad: usize,
) -> Layer {
    LayerBuilder::new(name, OpType::DwConv)
        .k(c)
        .c(c)
        .spatial(oy, ox)
        .filter(f, f)
        .stride(stride)
        .pad(pad)
        .preds(&[pred])
        .build()
}

pub(crate) fn maxpool(
    name: &str,
    pred: LayerId,
    c: usize,
    oy: usize,
    ox: usize,
    f: usize,
    stride: usize,
    pad: usize,
) -> Layer {
    LayerBuilder::new(name, OpType::Pool(PoolKind::Max))
        .k(c)
        .c(c)
        .spatial(oy, ox)
        .filter(f, f)
        .stride(stride)
        .pad(pad)
        .preds(&[pred])
        .build()
}

pub(crate) fn avgpool(
    name: &str,
    pred: LayerId,
    c: usize,
    oy: usize,
    ox: usize,
    f: usize,
    stride: usize,
) -> Layer {
    LayerBuilder::new(name, OpType::Pool(PoolKind::Average))
        .k(c)
        .c(c)
        .spatial(oy, ox)
        .filter(f, f)
        .stride(stride)
        .preds(&[pred])
        .build()
}

pub(crate) fn add(name: &str, a: LayerId, b: LayerId, c: usize, oy: usize, ox: usize) -> Layer {
    LayerBuilder::new(name, OpType::Add)
        .k(c)
        .c(c)
        .spatial(oy, ox)
        .preds(&[a, b])
        .build()
}

pub(crate) fn concat(name: &str, preds: &[LayerId], k: usize, oy: usize, ox: usize) -> Layer {
    LayerBuilder::new(name, OpType::Concat)
        .k(k)
        .c(k)
        .spatial(oy, ox)
        .preds(preds)
        .build()
}

pub(crate) fn fc(name: &str, pred: LayerId, k: usize, c: usize) -> Layer {
    LayerBuilder::new(name, OpType::Fc)
        .k(k)
        .c(c)
        .preds(&[pred])
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_networks_build_and_validate() {
        for name in WORKLOAD_NAMES {
            let g = by_name(name).unwrap();
            assert!(!g.is_empty(), "{name}");
            g.validate_channels().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn exploration_set_has_five() {
        assert_eq!(exploration_networks().len(), 5);
    }

    #[test]
    fn resnet18_census() {
        let g = resnet18();
        let c = g.op_census();
        assert_eq!(c["conv"], 20); // 17 main + 3 downsample
        assert_eq!(c["add"], 8);
        assert_eq!(c["fc"], 1);
        assert_eq!(c["pool"], 2);
    }

    #[test]
    fn resnet18_macs_ballpark() {
        // ~1.8 GMACs at 224x224
        let m = resnet18().total_macs();
        assert!(m > 1_600_000_000 && m < 2_000_000_000, "{m}");
    }

    #[test]
    fn mobilenetv2_macs_ballpark() {
        // ~300 MMACs at 224x224
        let m = mobilenetv2().total_macs();
        assert!(m > 250_000_000 && m < 400_000_000, "{m}");
    }

    #[test]
    fn squeezenet_macs_ballpark() {
        // ~850 MMACs for v1.0 at 224x224
        let m = squeezenet().total_macs();
        assert!(m > 600_000_000 && m < 1_100_000_000, "{m}");
    }

    #[test]
    fn fsrcnn_is_uniform_spatial() {
        let g = fsrcnn(560, 960);
        for l in g.layers() {
            if l.op.is_dense() {
                assert_eq!((l.oy, l.ox), (560, 960), "{}", l.name);
            }
        }
    }
}
