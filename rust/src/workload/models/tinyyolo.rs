//! Tiny-YOLO (v3-tiny trunk, 416x416): the object-detection exploration
//! network — deep, linear, with interleaved maxpools.

use super::*;

/// Tiny-YOLO at 416x416: the classic conv/maxpool trunk plus the
/// detection head convolutions.
pub fn tiny_yolo() -> WorkloadGraph {
    let mut layers = Vec::new();

    // trunk: conv3x3(c) + maxpool2x2/2, channel doubling each stage
    let stages: &[(usize, usize)] = &[
        // (channels, output spatial of the conv)
        (16, 416),
        (32, 208),
        (64, 104),
        (128, 52),
        (256, 26),
        (512, 13),
    ];
    let mut prev: Option<LayerId> = None;
    let mut cin = 3;
    for (i, &(c, sp)) in stages.iter().enumerate() {
        layers.push(conv(&format!("conv{i}"), prev, c, cin, sp, sp, 3, 1, 1));
        let cid = LayerId(layers.len() - 1);
        // final maxpool is stride 1 in v3-tiny (keeps 13x13)
        let (pstride, psp) = if i == stages.len() - 1 { (1, 13) } else { (2, sp / 2) };
        layers.push(maxpool(&format!("pool{i}"), cid, c, psp, psp, 2, pstride, 0));
        prev = Some(LayerId(layers.len() - 1));
        cin = c;
    }
    let trunk = prev.unwrap();

    // head
    layers.push(conv("conv6", Some(trunk), 1024, 512, 13, 13, 3, 1, 1));
    let c6 = LayerId(layers.len() - 1);
    layers.push(conv("conv7", Some(c6), 256, 1024, 13, 13, 1, 1, 0));
    let c7 = LayerId(layers.len() - 1);
    layers.push(conv("conv8", Some(c7), 512, 256, 13, 13, 3, 1, 1));
    let c8 = LayerId(layers.len() - 1);
    layers.push(conv("det", Some(c8), 255, 512, 13, 13, 1, 1, 0));

    WorkloadGraph::new("tinyyolo", layers).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channels_validate() {
        tiny_yolo().validate_channels().unwrap();
    }

    #[test]
    fn macs_ballpark() {
        // v3-tiny trunk+head is ~2.7 GMACs at 416x416
        let m = tiny_yolo().total_macs();
        assert!(m > 2_000_000_000 && m < 3_500_000_000, "{m}");
    }

    #[test]
    fn pool_every_stage() {
        assert_eq!(tiny_yolo().op_census()["pool"], 6);
    }
}
