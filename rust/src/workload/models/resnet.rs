//! ResNet-18 (He et al., 224x224), its first segment (the DIANA
//! validation workload) and a ResNet-50 stage-3 segment (the Jia et al.
//! 4x4-AiMC validation workload).

use super::*;

/// One basic block: conv3x3 -> conv3x3 -> add, with an optional strided
/// 1x1 downsample on the skip path. Returns (layers, output id offset).
fn basic_block(
    layers: &mut Vec<Layer>,
    name: &str,
    input: LayerId,
    in_c: usize,
    out_c: usize,
    spatial: usize,
    stride: usize,
) -> LayerId {
    let id = |layers: &Vec<Layer>| LayerId(layers.len());

    layers.push(conv(
        &format!("{name}.conv1"),
        Some(input),
        out_c,
        in_c,
        spatial,
        spatial,
        3,
        stride,
        1,
    ));
    let c1 = LayerId(layers.len() - 1);

    layers.push(conv(
        &format!("{name}.conv2"),
        Some(c1),
        out_c,
        out_c,
        spatial,
        spatial,
        3,
        1,
        1,
    ));
    let c2 = LayerId(layers.len() - 1);

    let skip = if stride != 1 || in_c != out_c {
        layers.push(conv(
            &format!("{name}.down"),
            Some(input),
            out_c,
            in_c,
            spatial,
            spatial,
            1,
            stride,
            0,
        ));
        LayerId(layers.len() - 1)
    } else {
        input
    };

    layers.push(add(&format!("{name}.add"), c2, skip, out_c, spatial, spatial));
    let _ = id;
    LayerId(layers.len() - 1)
}

/// Full ResNet-18 at 224x224 (batch 1).
pub fn resnet18() -> WorkloadGraph {
    let mut layers = Vec::new();
    layers.push(conv("conv1", None, 64, 3, 112, 112, 7, 2, 3));
    layers.push(maxpool("maxpool", LayerId(0), 64, 56, 56, 3, 2, 1));
    let mut x = LayerId(1);

    for (stage, (c, s0)) in [(64usize, 1usize), (128, 2), (256, 2), (512, 2)]
        .iter()
        .enumerate()
    {
        let spatial = 56 >> stage;
        let in_c = if stage == 0 { 64 } else { c / 2 };
        x = basic_block(&mut layers, &format!("s{stage}.b0"), x, in_c, *c, spatial, *s0);
        x = basic_block(&mut layers, &format!("s{stage}.b1"), x, *c, *c, spatial, 1);
    }

    layers.push(avgpool("avgpool", x, 512, 1, 1, 7, 1));
    let p = LayerId(layers.len() - 1);
    layers.push(fc("fc", p, 1000, 512));

    WorkloadGraph::new("resnet18", layers).unwrap()
}

/// The first segment of ResNet-18 — conv7x7/s2 -> maxpool -> conv3x3 ->
/// conv3x3 -> residual add — the workload of the DIANA validation
/// (Section IV-C / Fig. 10c) and of the runtime end-to-end example.
pub fn resnet18_first_segment() -> WorkloadGraph {
    let mut layers = Vec::new();
    layers.push(conv("conv1", None, 64, 3, 112, 112, 7, 2, 3));
    layers.push(maxpool("maxpool", LayerId(0), 64, 56, 56, 3, 2, 1));
    layers.push(conv("conv2a", Some(LayerId(1)), 64, 64, 56, 56, 3, 1, 1));
    layers.push(conv("conv2b", Some(LayerId(2)), 64, 64, 56, 56, 3, 1, 1));
    layers.push(add("add", LayerId(3), LayerId(1), 64, 56, 56));
    WorkloadGraph::new("resnet18-first-segment", layers).unwrap()
}

/// A ResNet-50 stage-3 segment: two bottleneck blocks at 28x28
/// (1x1/128 -> 3x3/128 -> 1x1/512 -> add), the pipelined workload class
/// measured on the 4x4 AiMC multi-core of Jia et al. (Section IV-B).
pub fn resnet50_segment() -> WorkloadGraph {
    let mut layers: Vec<Layer> = Vec::new();
    let sp = 28;
    // segment input: 512-channel feature map produced upstream
    layers.push(conv("in_proj", None, 512, 256, sp, sp, 1, 1, 0));
    let mut x = LayerId(0);

    for b in 0..2 {
        let n = format!("b{b}");
        layers.push(conv(&format!("{n}.red"), Some(x), 128, 512, sp, sp, 1, 1, 0));
        let r = LayerId(layers.len() - 1);
        layers.push(conv(&format!("{n}.conv3"), Some(r), 128, 128, sp, sp, 3, 1, 1));
        let c3 = LayerId(layers.len() - 1);
        layers.push(conv(&format!("{n}.exp"), Some(c3), 512, 128, sp, sp, 1, 1, 0));
        let e = LayerId(layers.len() - 1);
        layers.push(add(&format!("{n}.add"), e, x, 512, sp, sp));
        x = LayerId(layers.len() - 1);
    }
    WorkloadGraph::new("resnet50-segment", layers).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::OpType;

    #[test]
    fn first_segment_matches_python_geometry() {
        // Mirrors python/compile/model.py::segment_spec at 224 input.
        let g = resnet18_first_segment();
        assert_eq!(g.len(), 5);
        let c1 = g.layer(LayerId(0));
        assert_eq!((c1.k, c1.oy, c1.ox, c1.fy, c1.stride, c1.pad), (64, 112, 112, 7, 2, 3));
        assert_eq!(c1.in_height(), 224);
        let addl = g.layer(LayerId(4));
        assert_eq!(addl.predecessors, vec![LayerId(3), LayerId(1)]);
    }

    #[test]
    fn resnet18_depth() {
        let g = resnet18();
        // 20 convs + 2 pools + 8 adds + 1 fc
        assert_eq!(g.len(), 31);
        g.validate_channels().unwrap();
    }

    #[test]
    fn resnet18_stage_spatial_halving() {
        let g = resnet18();
        let spatials: Vec<usize> = g
            .layers()
            .iter()
            .filter(|l| matches!(l.op, OpType::Conv) && l.fy == 3)
            .map(|l| l.oy)
            .collect();
        assert!(spatials.contains(&56));
        assert!(spatials.contains(&28));
        assert!(spatials.contains(&14));
        assert!(spatials.contains(&7));
    }

    #[test]
    fn resnet50_segment_channels() {
        let g = resnet50_segment();
        g.validate_channels().unwrap();
        assert_eq!(g.op_census()["add"], 2);
    }
}
