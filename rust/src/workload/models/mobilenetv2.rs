//! MobileNetV2 (Sandler et al., 224x224): inverted-residual bottlenecks
//! with depthwise convolutions — the paper's most layer-type-diverse
//! exploration network.

use super::*;

/// One inverted residual: 1x1 expand (t*cin) -> dw3x3 (stride) ->
/// 1x1 project (cout) -> add if stride==1 && cin==cout.
#[allow(clippy::too_many_arguments)]
fn bottleneck(
    layers: &mut Vec<Layer>,
    name: &str,
    input: LayerId,
    cin: usize,
    cout: usize,
    t: usize,
    stride: usize,
    out_spatial: usize,
) -> LayerId {
    let hidden = cin * t;
    let in_spatial = out_spatial * stride;
    let mut x = input;

    if t != 1 {
        layers.push(conv(
            &format!("{name}.expand"),
            Some(x),
            hidden,
            cin,
            in_spatial,
            in_spatial,
            1,
            1,
            0,
        ));
        x = LayerId(layers.len() - 1);
    }

    layers.push(dwconv(
        &format!("{name}.dw"),
        x,
        hidden,
        out_spatial,
        out_spatial,
        3,
        stride,
        1,
    ));
    x = LayerId(layers.len() - 1);

    layers.push(conv(
        &format!("{name}.project"),
        Some(x),
        cout,
        hidden,
        out_spatial,
        out_spatial,
        1,
        1,
        0,
    ));
    x = LayerId(layers.len() - 1);

    if stride == 1 && cin == cout {
        layers.push(add(&format!("{name}.add"), x, input, cout, out_spatial, out_spatial));
        x = LayerId(layers.len() - 1);
    }
    x
}

/// Full MobileNetV2 at 224x224 (width multiplier 1.0).
pub fn mobilenetv2() -> WorkloadGraph {
    let mut layers = Vec::new();
    layers.push(conv("conv0", None, 32, 3, 112, 112, 3, 2, 1));
    let mut x = LayerId(0);

    // (t, c, n, s) table from the paper
    let cfg: &[(usize, usize, usize, usize)] = &[
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32;
    let mut spatial = 112;
    for (bi, &(t, c, n, s)) in cfg.iter().enumerate() {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            if stride == 2 {
                spatial /= 2;
            }
            x = bottleneck(
                &mut layers,
                &format!("bn{bi}.{i}"),
                x,
                cin,
                c,
                t,
                stride,
                spatial,
            );
            cin = c;
        }
    }

    layers.push(conv("conv_last", Some(x), 1280, 320, 7, 7, 1, 1, 0));
    let cl = LayerId(layers.len() - 1);
    layers.push(avgpool("avgpool", cl, 1280, 1, 1, 7, 1));
    let ap = LayerId(layers.len() - 1);
    layers.push(fc("fc", ap, 1000, 1280));

    WorkloadGraph::new("mobilenetv2", layers).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::OpType;

    #[test]
    fn channels_validate() {
        mobilenetv2().validate_channels().unwrap();
    }

    #[test]
    fn has_depthwise_layers() {
        let g = mobilenetv2();
        assert_eq!(g.op_census()["dwconv"], 17);
    }

    #[test]
    fn residual_adds_only_where_shapes_match() {
        let g = mobilenetv2();
        for l in g.layers() {
            if matches!(l.op, OpType::Add) {
                for &p in &l.predecessors {
                    assert_eq!(g.layer(p).k, l.k, "{}", l.name);
                    assert_eq!(g.layer(p).oy, l.oy, "{}", l.name);
                }
            }
        }
    }

    #[test]
    fn final_spatial_is_7() {
        let g = mobilenetv2();
        let last_proj = g
            .layers()
            .iter()
            .filter(|l| l.name.contains("project"))
            .next_back()
            .unwrap();
        assert_eq!(last_proj.oy, 7);
        assert_eq!(last_proj.k, 320);
    }
}
