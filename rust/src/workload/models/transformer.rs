//! Transformer workloads: ViT-Tiny, BERT-Small and a GPT-style
//! single-token decode step — the attention frontier of the zoo.
//!
//! All three use the token-tensor convention of
//! [`OpType`](crate::workload::OpType): a sequence of `s` tokens with
//! embedding dim `d` is the activation `(K = d, OY = s, OX = 1)`, so
//! the sequence dimension is the spatial `OY` axis that line-granular
//! CN splitting fuses over.  Multi-head attention is folded across
//! heads: the per-head score GEMMs `h x (s x dh x s)` sum to exactly
//! `s x d x s` MACs, so a single `MatMul` with `C = d` is MAC- and
//! byte-exact for the whole head group (same for attention x V).
//!
//! The decode model represents its KV-cache reads as **streamed-B
//! matmuls** (a `MatMul` with only the query operand in-graph): the
//! `[C, K]` cache matrix streams from DRAM on every CN, and the
//! per-step K/V projections are sink layers whose outputs store back
//! to DRAM — the cache append.

use super::*;

/// 1x1 projection over token rows: `X[s, c] x W[c, k]` with resident
/// weights, i.e. a pointwise conv on the `(K, OY=s, OX=1)` tensor.
fn proj(name: &str, pred: LayerId, k: usize, c: usize, tokens: usize) -> Layer {
    LayerBuilder::new(name, OpType::Conv)
        .k(k)
        .c(c)
        .spatial(tokens, 1)
        .preds(&[pred])
        .build()
}

fn layernorm(name: &str, pred: Option<LayerId>, d: usize, tokens: usize) -> Layer {
    let b = LayerBuilder::new(name, OpType::LayerNorm).k(d).c(d).spatial(tokens, 1);
    match pred {
        Some(p) => b.preds(&[p]).build(),
        None => b.build(),
    }
}

fn softmax(name: &str, pred: LayerId, scores_k: usize, tokens: usize) -> Layer {
    LayerBuilder::new(name, OpType::Softmax)
        .k(scores_k)
        .c(scores_k)
        .spatial(tokens, 1)
        .preds(&[pred])
        .build()
}

fn gelu(name: &str, pred: LayerId, d: usize, tokens: usize) -> Layer {
    LayerBuilder::new(name, OpType::Gelu).k(d).c(d).spatial(tokens, 1).preds(&[pred]).build()
}

/// `A[tokens, c] x B[c, k]`, both operands produced in-graph.
fn matmul2(name: &str, a: LayerId, b: LayerId, k: usize, c: usize, tokens: usize) -> Layer {
    LayerBuilder::new(name, OpType::MatMul)
        .k(k)
        .c(c)
        .spatial(tokens, 1)
        .preds(&[a, b])
        .build()
}

/// `A[tokens, c] x B[c, k]` with B streamed from DRAM (KV-cache read).
fn matmul_kv(name: &str, a: LayerId, k: usize, c: usize, tokens: usize) -> Layer {
    LayerBuilder::new(name, OpType::MatMul)
        .k(k)
        .c(c)
        .spatial(tokens, 1)
        .preds(&[a])
        .build()
}

/// One encoder block over `tokens` rows of dim `d` with an `ff`-wide
/// MLP.  `pre_ln` selects ViT/GPT-style pre-norm (LN before the
/// sublayer) vs BERT-style post-norm (LN after the residual add).
/// Returns the block's output layer id.
fn encoder_block(
    layers: &mut Vec<Layer>,
    name: &str,
    x: LayerId,
    tokens: usize,
    d: usize,
    ff: usize,
    pre_ln: bool,
) -> LayerId {
    fn push(l: Layer, layers: &mut Vec<Layer>) -> LayerId {
        layers.push(l);
        LayerId(layers.len() - 1)
    }

    // --- attention sublayer -------------------------------------------
    let attn_in = if pre_ln {
        push(layernorm(&format!("{name}.ln1"), Some(x), d, tokens), layers)
    } else {
        x
    };
    let q = push(proj(&format!("{name}.q"), attn_in, d, d, tokens), layers);
    let k = push(proj(&format!("{name}.k"), attn_in, d, d, tokens), layers);
    let v = push(proj(&format!("{name}.v"), attn_in, d, d, tokens), layers);
    // scores[s, s] = Q[s, d] x K^T[d, s]  (all heads folded)
    let scores = push(matmul2(&format!("{name}.scores"), q, k, tokens, d, tokens), layers);
    let sm = push(softmax(&format!("{name}.softmax"), scores, tokens, tokens), layers);
    // ctx[s, d] = softmax[s, s] x V[s, d]
    let ctx = push(matmul2(&format!("{name}.attnv"), sm, v, d, tokens, tokens), layers);
    let o = push(proj(&format!("{name}.oproj"), ctx, d, d, tokens), layers);
    let add1 = push(add(&format!("{name}.add1"), o, x, d, tokens, 1), layers);
    let attn_out = if pre_ln {
        add1
    } else {
        push(layernorm(&format!("{name}.ln1"), Some(add1), d, tokens), layers)
    };

    // --- MLP sublayer -------------------------------------------------
    let mlp_in = if pre_ln {
        push(layernorm(&format!("{name}.ln2"), Some(attn_out), d, tokens), layers)
    } else {
        attn_out
    };
    let f1 = push(proj(&format!("{name}.fc1"), mlp_in, ff, d, tokens), layers);
    let g = push(gelu(&format!("{name}.gelu"), f1, ff, tokens), layers);
    let f2 = push(proj(&format!("{name}.fc2"), g, d, ff, tokens), layers);
    let add2 = push(add(&format!("{name}.add2"), f2, attn_out, d, tokens, 1), layers);
    if pre_ln {
        add2
    } else {
        push(layernorm(&format!("{name}.ln2"), Some(add2), d, tokens), layers)
    }
}

/// A bare pre-norm encoder stack over `tokens` rows of dim `d` (MLP
/// width `ff`, `depth` blocks), fed by a source LayerNorm that streams
/// the embedded sequence in from DRAM.  The fused-vs-layer-by-layer
/// ablations use this at ViT-Base@384-class dims, where a single MLP
/// activation (`tokens x ff`) overflows the exploration architectures'
/// pooled SRAM and layer-by-layer execution must spill.
pub fn vit_stack(name: &str, tokens: usize, d: usize, ff: usize, depth: usize) -> WorkloadGraph {
    let mut layers = vec![layernorm("embed_ln", None, d, tokens)];
    let mut x = LayerId(0);
    for b in 0..depth {
        x = encoder_block(&mut layers, &format!("blk{b}"), x, tokens, d, ff, true);
    }
    WorkloadGraph::new(name, layers).unwrap()
}

/// ViT-Tiny/16 at 224x224: 196 patch tokens (the class token is
/// elided), d = 192, MLP 768, 12 pre-norm encoder blocks, mean-pool
/// head — ~1.25 GMACs / ~5.6 M weights, matching the timm `vit_tiny`
/// operating point.
///
/// The patch embedding is the 16x16/16 conv expressed directly in the
/// unrolled token layout `(OY = 196, OX = 1)`: `in_height` is then
/// 196 x 16 = 3136 rows of 16 pixels = exactly the 3 x 224 x 224
/// image, and each token's CN reads its own disjoint patch rows.
pub fn vit_tiny() -> WorkloadGraph {
    let (tokens, d, ff, depth) = (196, 192, 768, 12);
    let mut layers = Vec::new();
    layers.push(
        LayerBuilder::new("patch_embed", OpType::Conv)
            .k(d)
            .c(3)
            .spatial(tokens, 1)
            .filter(16, 16)
            .stride(16)
            .build(),
    );
    let mut x = LayerId(0);
    for b in 0..depth {
        x = encoder_block(&mut layers, &format!("blk{b}"), x, tokens, d, ff, true);
    }
    layers.push(layernorm("ln_final", Some(x), d, tokens));
    let lnf = LayerId(layers.len() - 1);
    // mean-pool over the token rows, then the classifier head
    layers.push(
        LayerBuilder::new("head_pool", OpType::Pool(PoolKind::Average))
            .k(d)
            .c(d)
            .spatial(1, 1)
            .filter(tokens, 1)
            .preds(&[lnf])
            .build(),
    );
    let p = LayerId(layers.len() - 1);
    layers.push(fc("head", p, 1000, d));
    WorkloadGraph::new("vit-tiny", layers).unwrap()
}

/// BERT-Small encoder (L = 4, H = 512, A = 8, FF = 2048) over a
/// 128-token sequence, post-norm blocks — ~1.68 GMACs / ~12.6 M
/// encoder weights.  The input embedding lookup is modeled as the
/// source `embed_ln` layer: the embedded sequence streams in from DRAM
/// and is normalized (BERT's post-embedding LayerNorm).
pub fn bert_small() -> WorkloadGraph {
    let (tokens, d, ff, depth) = (128, 512, 2048, 4);
    let mut layers = vec![layernorm("embed_ln", None, d, tokens)];
    let mut x = LayerId(0);
    for b in 0..depth {
        x = encoder_block(&mut layers, &format!("blk{b}"), x, tokens, d, ff, false);
    }
    WorkloadGraph::new("bert-small", layers).unwrap()
}

/// GPT-style single-token decode step: 6 pre-norm blocks at d = 512,
/// FF = 2048, attending over a 256-token KV cache, with a 32000-way LM
/// head — ~37 MMACs against ~35 MB of streamed weights + cache, the
/// memory-bound regime that makes decode serving an interconnect/DRAM
/// problem rather than a compute problem.
///
/// Cache reads are streamed-B matmuls (`scores` and `attnv` carry only
/// their query-side predecessor; the `[C, K]` cache matrix streams
/// from DRAM each step).  The per-step `k_new` / `v_new` projections
/// are sinks: their outputs store straight back to DRAM — the cache
/// append.
pub fn llm_decode() -> WorkloadGraph {
    let (d, ff, depth, context, vocab) = (512, 2048, 6, 256, 32000);
    let mut layers = vec![layernorm("embed", None, d, 1)];
    let mut x = LayerId(0);
    for b in 0..depth {
        let n = format!("blk{b}");
        layers.push(layernorm(&format!("{n}.ln1"), Some(x), d, 1));
        let ln1 = LayerId(layers.len() - 1);
        layers.push(proj(&format!("{n}.q"), ln1, d, d, 1));
        let q = LayerId(layers.len() - 1);
        // cache-append projections: sinks, stored to DRAM
        layers.push(proj(&format!("{n}.k_new"), ln1, d, d, 1));
        layers.push(proj(&format!("{n}.v_new"), ln1, d, d, 1));
        // scores[1, context] = q[1, d] x Kcache^T[d, context] (streamed)
        layers.push(matmul_kv(&format!("{n}.scores"), q, context, d, 1));
        let sc = LayerId(layers.len() - 1);
        layers.push(softmax(&format!("{n}.softmax"), sc, context, 1));
        let sm = LayerId(layers.len() - 1);
        // ctx[1, d] = softmax[1, context] x Vcache[context, d] (streamed)
        layers.push(matmul_kv(&format!("{n}.attnv"), sm, d, context, 1));
        let ctx = LayerId(layers.len() - 1);
        layers.push(proj(&format!("{n}.oproj"), ctx, d, d, 1));
        let o = LayerId(layers.len() - 1);
        layers.push(add(&format!("{n}.add1"), o, x, d, 1, 1));
        let add1 = LayerId(layers.len() - 1);
        layers.push(layernorm(&format!("{n}.ln2"), Some(add1), d, 1));
        let ln2 = LayerId(layers.len() - 1);
        layers.push(proj(&format!("{n}.fc1"), ln2, ff, d, 1));
        let f1 = LayerId(layers.len() - 1);
        layers.push(gelu(&format!("{n}.gelu"), f1, ff, 1));
        let g = LayerId(layers.len() - 1);
        layers.push(proj(&format!("{n}.fc2"), g, d, ff, 1));
        let f2 = LayerId(layers.len() - 1);
        layers.push(add(&format!("{n}.add2"), f2, add1, d, 1, 1));
        x = LayerId(layers.len() - 1);
    }
    layers.push(layernorm("ln_final", Some(x), d, 1));
    let lnf = LayerId(layers.len() - 1);
    layers.push(fc("lm_head", lnf, vocab, d));
    WorkloadGraph::new("llm-decode", layers).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::OpType;

    #[test]
    fn all_transformers_validate() {
        for g in [vit_tiny(), bert_small(), llm_decode()] {
            g.validate_channels().unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn vit_tiny_shape() {
        let g = vit_tiny();
        // 1 patch + 12 x 14 block layers + final ln + pool + head
        assert_eq!(g.len(), 1 + 12 * 14 + 3);
        let c = g.op_census();
        assert_eq!(c["matmul"], 24);
        assert_eq!(c["softmax"], 12);
        assert_eq!(c["layernorm"], 25);
        assert_eq!(c["gelu"], 12);
        assert_eq!(c["conv"], 73);
        // patch embedding reads exactly the 3 x 224 x 224 image
        let pe = g.layer(LayerId(0));
        assert_eq!(pe.input_bytes(), 3 * 224 * 224);
        // ~1.25 GMACs, like timm's vit_tiny_patch16_224
        let m = g.total_macs();
        assert!(m > 1_150_000_000 && m < 1_350_000_000, "{m}");
    }

    #[test]
    fn bert_small_shape() {
        let g = bert_small();
        assert_eq!(g.len(), 1 + 4 * 14);
        let c = g.op_census();
        assert_eq!(c["matmul"], 8);
        assert_eq!(c["layernorm"], 9);
        // ~1.68 GMACs at seq 128
        let m = g.total_macs();
        assert!(m > 1_500_000_000 && m < 1_850_000_000, "{m}");
        // encoder weights ~12.6 MB at int8
        let w = g.total_weight_bytes();
        assert!(w > 12_000_000 && w < 13_000_000, "{w}");
    }

    #[test]
    fn llm_decode_streams_kv_and_appends_cache() {
        let g = llm_decode();
        assert_eq!(g.len(), 1 + 6 * 14 + 2);
        let mut kv_reads = 0;
        let mut cache_appends = 0;
        for l in g.layers() {
            if l.op == OpType::MatMul {
                assert!(l.streams_b_from_dram(), "{}: decode matmuls stream B", l.name);
                assert_eq!(l.oy, 1, "single-token step");
                kv_reads += 1;
            }
            if l.name.ends_with("k_new") || l.name.ends_with("v_new") {
                assert!(g.successors(l.id).is_empty(), "{}: cache append is a sink", l.name);
            }
            if g.successors(l.id).is_empty() && l.op == OpType::Conv {
                cache_appends += 1;
            }
        }
        assert_eq!(kv_reads, 12);
        assert_eq!(cache_appends, 12);
        // memory-bound: streamed bytes (weights + KV) dwarf the MACs
        let streamed: u64 = g.total_weight_bytes()
            + g.layers()
                .iter()
                .filter(|l| l.streams_b_from_dram())
                .map(|l| l.matmul_b_bytes())
                .sum::<u64>();
        assert!(streamed as f64 > 0.9 * g.total_macs() as f64, "decode must be memory-bound");
    }

    #[test]
    fn encoder_attention_wiring() {
        let g = vit_tiny();
        // every scores matmul has [q, k] preds and every attnv
        // [softmax, v]; B operands are in-graph (not streamed)
        for l in g.layers() {
            if l.op == OpType::MatMul {
                assert_eq!(l.predecessors.len(), 2, "{}", l.name);
                assert!(!l.streams_b_from_dram());
                if l.name.ends_with("scores") {
                    assert_eq!(l.k, 196);
                    assert_eq!(l.c, 192);
                } else {
                    assert_eq!(l.k, 192);
                    assert_eq!(l.c, 196);
                }
            }
        }
    }
}
