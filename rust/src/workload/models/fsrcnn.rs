//! FSRCNN (Dong et al.): super-resolution CNN with large uniform feature
//! maps — the DepFiN validation workload (560x960) and the fifth
//! exploration network.

use super::*;

/// FSRCNN(d=56, s=12, m=4) at `h x w` low-resolution input.
///
/// feature extraction conv5x5/56 -> shrink conv1x1/12 -> 4x mapping
/// conv3x3/12 -> expand conv1x1/56 -> deconv9x9 modeled as a conv9x9
/// producing 4 sub-pixel channels (depth-to-space x2 upscaling), all at
/// the LR grid — matching the line-buffered processing DepFiN measures.
pub fn fsrcnn(h: usize, w: usize) -> WorkloadGraph {
    let mut layers = Vec::new();
    layers.push(conv("feat", None, 56, 1, h, w, 5, 1, 2));
    let mut x = LayerId(0);
    layers.push(conv("shrink", Some(x), 12, 56, h, w, 1, 1, 0));
    x = LayerId(1);
    for i in 0..4 {
        layers.push(conv(&format!("map{i}"), Some(x), 12, 12, h, w, 3, 1, 1));
        x = LayerId(layers.len() - 1);
    }
    layers.push(conv("expand", Some(x), 56, 12, h, w, 1, 1, 0));
    x = LayerId(layers.len() - 1);
    // deconv as sub-pixel conv: 4 = (2x)^2 output channels
    layers.push(conv("deconv", Some(x), 4, 56, h, w, 9, 1, 4));

    WorkloadGraph::new("fsrcnn", layers).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channels_validate() {
        fsrcnn(560, 960).validate_channels().unwrap();
    }

    #[test]
    fn depth() {
        assert_eq!(fsrcnn(560, 960).len(), 8);
    }

    #[test]
    fn activation_sizes_are_large() {
        // the paper: layer-by-layer peak memory 28.3 MB at 560x960.
        let g = fsrcnn(560, 960);
        let max_out = g.layers().iter().map(|l| l.output_bytes()).max().unwrap();
        assert!(max_out > 25_000_000, "{max_out}"); // feat: 56*560*960 B
    }

    #[test]
    fn scales_with_resolution() {
        assert!(fsrcnn(560, 960).total_macs() > 4 * fsrcnn(280, 480).total_macs() - 1000);
    }
}
