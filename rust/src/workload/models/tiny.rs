//! Tiny synthetic networks for unit/integration tests and the quickstart
//! example — small enough to hand-trace schedules.

use super::*;

/// Four-layer linear stack: conv -> conv -> pool -> fc.
pub fn tiny_linear() -> WorkloadGraph {
    let mut layers = Vec::new();
    layers.push(conv("conv0", None, 8, 3, 16, 16, 3, 1, 1));
    layers.push(conv("conv1", Some(LayerId(0)), 16, 8, 16, 16, 3, 1, 1));
    layers.push(maxpool("pool", LayerId(1), 16, 8, 8, 2, 2, 0));
    layers.push(fc("fc", LayerId(2), 10, 16 * 8 * 8));
    WorkloadGraph::new("tiny-linear", layers).unwrap()
}

/// Diamond-shaped branchy network: conv -> (conv || conv) -> add -> conv.
pub fn tiny_branchy() -> WorkloadGraph {
    let mut layers = Vec::new();
    layers.push(conv("stem", None, 8, 3, 16, 16, 3, 1, 1));
    layers.push(conv("left", Some(LayerId(0)), 8, 8, 16, 16, 3, 1, 1));
    layers.push(conv("right", Some(LayerId(0)), 8, 8, 16, 16, 1, 1, 0));
    layers.push(add("add", LayerId(1), LayerId(2), 8, 16, 16));
    layers.push(conv("out", Some(LayerId(3)), 4, 8, 16, 16, 3, 1, 1));
    WorkloadGraph::new("tiny-branchy", layers).unwrap()
}

/// The runtime segment at the Python artifact geometry (112x112 input):
/// mirrors `python/compile/model.py::segment_spec` exactly, so the CN
/// graph Stream builds for it matches the AOT tile artifacts.
pub fn tiny_segment() -> WorkloadGraph {
    let mut layers = Vec::new();
    layers.push(conv("conv7x7", None, 64, 3, 56, 56, 7, 2, 3));
    layers.push(maxpool("maxpool", LayerId(0), 64, 28, 28, 3, 2, 1));
    layers.push(conv("conv3x3a", Some(LayerId(1)), 64, 64, 28, 28, 3, 1, 1));
    layers.push(conv("conv3x3b", Some(LayerId(2)), 64, 64, 28, 28, 3, 1, 1));
    layers.push(add("add", LayerId(3), LayerId(1), 64, 28, 28));
    WorkloadGraph::new("tiny-segment", layers).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tiny_validate() {
        tiny_linear().validate_channels().unwrap();
        tiny_branchy().validate_channels().unwrap();
        tiny_segment().validate_channels().unwrap();
    }

    #[test]
    fn branchy_fanout() {
        let g = tiny_branchy();
        assert_eq!(g.successors(LayerId(0)).len(), 2);
        assert_eq!(g.predecessors(LayerId(3)).len(), 2);
    }

    #[test]
    fn segment_matches_artifact_geometry() {
        let g = tiny_segment();
        let c1 = g.layer(LayerId(0));
        assert_eq!(c1.in_height(), 112);
        assert_eq!(c1.oy, 56);
        assert_eq!(g.layer(LayerId(4)).oy, 28);
    }
}
