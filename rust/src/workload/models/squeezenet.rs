//! SqueezeNet v1.0 (Iandola et al., 224x224): fire modules with channel
//! concatenation — the paper's "uniform" small network.

use super::*;

/// Fire module: squeeze 1x1 -> (expand 1x1 || expand 3x3) -> concat.
fn fire(
    layers: &mut Vec<Layer>,
    name: &str,
    input: LayerId,
    cin: usize,
    squeeze: usize,
    expand: usize,
    spatial: usize,
) -> LayerId {
    layers.push(conv(
        &format!("{name}.squeeze"),
        Some(input),
        squeeze,
        cin,
        spatial,
        spatial,
        1,
        1,
        0,
    ));
    let s = LayerId(layers.len() - 1);
    layers.push(conv(
        &format!("{name}.exp1"),
        Some(s),
        expand,
        squeeze,
        spatial,
        spatial,
        1,
        1,
        0,
    ));
    let e1 = LayerId(layers.len() - 1);
    layers.push(conv(
        &format!("{name}.exp3"),
        Some(s),
        expand,
        squeeze,
        spatial,
        spatial,
        3,
        1,
        1,
    ));
    let e3 = LayerId(layers.len() - 1);
    layers.push(concat(
        &format!("{name}.concat"),
        &[e1, e3],
        2 * expand,
        spatial,
        spatial,
    ));
    LayerId(layers.len() - 1)
}

/// Full SqueezeNet v1.0 at 224x224.
pub fn squeezenet() -> WorkloadGraph {
    let mut layers = Vec::new();
    // conv1: 7x7/2, 96, valid padding: 224 -> 109
    layers.push(conv("conv1", None, 96, 3, 109, 109, 7, 2, 0));
    layers.push(maxpool("pool1", LayerId(0), 96, 54, 54, 3, 2, 0));
    let mut x = LayerId(1);

    x = fire(&mut layers, "fire2", x, 96, 16, 64, 54);
    x = fire(&mut layers, "fire3", x, 128, 16, 64, 54);
    x = fire(&mut layers, "fire4", x, 128, 32, 128, 54);
    layers.push(maxpool("pool4", x, 256, 27, 27, 3, 2, 0));
    x = LayerId(layers.len() - 1);

    x = fire(&mut layers, "fire5", x, 256, 32, 128, 27);
    x = fire(&mut layers, "fire6", x, 256, 48, 192, 27);
    x = fire(&mut layers, "fire7", x, 384, 48, 192, 27);
    x = fire(&mut layers, "fire8", x, 384, 64, 256, 27);
    layers.push(maxpool("pool8", x, 512, 13, 13, 3, 2, 0));
    x = LayerId(layers.len() - 1);

    x = fire(&mut layers, "fire9", x, 512, 64, 256, 13);
    layers.push(conv("conv10", Some(x), 1000, 512, 13, 13, 1, 1, 0));
    let c10 = LayerId(layers.len() - 1);
    layers.push(avgpool("avgpool", c10, 1000, 1, 1, 13, 1));

    WorkloadGraph::new("squeezenet", layers).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channels_validate() {
        squeezenet().validate_channels().unwrap();
    }

    #[test]
    fn fire_count() {
        let g = squeezenet();
        assert_eq!(g.op_census()["concat"], 8);
        // 1 stem + 8*3 fire convs + conv10
        assert_eq!(g.op_census()["conv"], 26);
    }

    #[test]
    fn concat_doubles_channels() {
        let g = squeezenet();
        for l in g.layers() {
            if matches!(l.op, crate::workload::OpType::Concat) {
                let sum: usize = l.predecessors.iter().map(|p| g.layer(*p).k).sum();
                assert_eq!(l.k, sum);
            }
        }
    }
}
