//! Workload representation: DNN layer graphs.
//!
//! A [`WorkloadGraph`] is a DAG of [`Layer`]s, each described by the
//! seven canonical nested-loop dimensions of dense DNN operators
//! (`B, K, C, OY, OX, FY, FX`), plus stride/padding and operand
//! precisions — the same ONNX-level abstraction the paper ingests.
//!
//! [`models`] provides builders for the paper's evaluation networks
//! (ResNet-18, MobileNetV2, SqueezeNet, Tiny-YOLO, FSRCNN) and the
//! validation workloads (ResNet-50 segments, the ResNet-18 first
//! segment used for DIANA).

mod graph;
mod layer;
pub mod models;

pub use graph::{GraphError, WorkloadGraph};
pub use layer::{Dim, Layer, LayerBuilder, LayerId, OpType, PoolKind};
