//! [`WorkloadGraph`]: the DNN as a DAG of layers.

use std::collections::HashMap;

use super::layer::{Layer, LayerId, OpType};

/// Errors raised by graph construction / validation.
#[derive(Debug)]
pub enum GraphError {
    /// A predecessor id does not exist (or points forward).
    DanglingEdge { layer: LayerId, pred: LayerId },
    /// The graph contains a cycle.
    Cycle,
    /// Channel bookkeeping between producer and consumer is inconsistent.
    ChannelMismatch { layer: LayerId, expect: usize, got: usize },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::DanglingEdge { layer, pred } => {
                write!(f, "{layer} references unknown predecessor {pred}")
            }
            GraphError::Cycle => write!(f, "workload graph contains a cycle"),
            GraphError::ChannelMismatch { layer, expect, got } => {
                write!(f, "{layer}: expected {expect} input channels, got {got}")
            }
        }
    }
}

impl std::error::Error for GraphError {}

/// The DNN workload: layers in topological id order plus adjacency.
#[derive(Debug, Clone)]
pub struct WorkloadGraph {
    pub name: String,
    layers: Vec<Layer>,
    /// successors\[i\] = ids of layers consuming layer i's output.
    successors: Vec<Vec<LayerId>>,
}

impl WorkloadGraph {
    /// Build from a list of layers whose `predecessors` reference earlier
    /// list positions. Ids are assigned by position (guaranteeing
    /// topological order by construction).
    pub fn new(name: &str, mut layers: Vec<Layer>) -> Result<Self, GraphError> {
        for (i, l) in layers.iter_mut().enumerate() {
            l.id = LayerId(i);
        }
        let n = layers.len();
        let mut successors = vec![Vec::new(); n];
        for l in &layers {
            for &p in &l.predecessors {
                if p.0 >= l.id.0 {
                    return Err(GraphError::DanglingEdge { layer: l.id, pred: p });
                }
                successors[p.0].push(l.id);
            }
        }
        Ok(WorkloadGraph {
            name: name.to_string(),
            layers,
            successors,
        })
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id.0]
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn successors(&self, id: LayerId) -> &[LayerId] {
        &self.successors[id.0]
    }

    pub fn predecessors(&self, id: LayerId) -> &[LayerId] {
        &self.layers[id.0].predecessors
    }

    /// Layers with no predecessors (network inputs).
    pub fn sources(&self) -> Vec<LayerId> {
        self.layers
            .iter()
            .filter(|l| l.predecessors.is_empty())
            .map(|l| l.id)
            .collect()
    }

    /// Layers with no successors (network outputs).
    pub fn sinks(&self) -> Vec<LayerId> {
        self.layers
            .iter()
            .filter(|l| self.successors[l.id.0].is_empty())
            .map(|l| l.id)
            .collect()
    }

    /// Ids in topological order (== id order by construction).
    pub fn topo_order(&self) -> Vec<LayerId> {
        (0..self.layers.len()).map(LayerId).collect()
    }

    /// Total MAC count of the network's dense layers.
    pub fn total_macs(&self) -> u64 {
        self.layers
            .iter()
            .filter(|l| l.op.is_dense())
            .map(|l| l.macs())
            .sum()
    }

    /// Total weight footprint of the network in bytes.
    pub fn total_weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_bytes()).sum()
    }

    /// Dense layers (the ones the GA allocates to dataflow cores).
    pub fn dense_layers(&self) -> Vec<LayerId> {
        self.layers
            .iter()
            .filter(|l| l.op.is_dense())
            .map(|l| l.id)
            .collect()
    }

    /// Validate channel consistency: for every non-concat consumer the
    /// summed producer K must equal the consumer C; for Concat, K must
    /// equal the summed producer Ks.
    pub fn validate_channels(&self) -> Result<(), GraphError> {
        for l in &self.layers {
            if l.predecessors.is_empty() {
                continue;
            }
            let pred_k: usize = l.predecessors.iter().map(|p| self.layer(*p).k).sum();
            match l.op {
                OpType::Concat => {
                    if l.k != pred_k {
                        return Err(GraphError::ChannelMismatch {
                            layer: l.id,
                            expect: pred_k,
                            got: l.k,
                        });
                    }
                }
                OpType::Add | OpType::LayerNorm | OpType::Softmax | OpType::Gelu => {
                    // elementwise over the token tensor: every
                    // predecessor must share K == layer C == layer K
                    for &p in &l.predecessors {
                        if self.layer(p).k != l.k {
                            return Err(GraphError::ChannelMismatch {
                                layer: l.id,
                                expect: l.k,
                                got: self.layer(p).k,
                            });
                        }
                    }
                }
                OpType::MatMul => {
                    // operand A (first pred) is the token tensor: its K
                    // must equal the reduction dim C; operand B (second
                    // pred, when in-graph) must carry the full [C, K]
                    // matrix, i.e. C*K elements.
                    let a = self.layer(l.predecessors[0]);
                    if l.c != a.k {
                        return Err(GraphError::ChannelMismatch {
                            layer: l.id,
                            expect: a.k,
                            got: l.c,
                        });
                    }
                    if let Some(&bp) = l.predecessors.get(1) {
                        let b = self.layer(bp);
                        let b_elems = b.k * b.oy * b.ox;
                        if b_elems != l.c * l.k {
                            return Err(GraphError::ChannelMismatch {
                                layer: l.id,
                                expect: l.c * l.k,
                                got: b_elems,
                            });
                        }
                    }
                }
                OpType::Fc => {
                    // FC consumes the flattened producer output: C may be
                    // K or K * OY * OX of the producer.
                    let p = self.layer(l.predecessors[0]);
                    let flat = p.k * p.oy * p.ox;
                    if l.c != p.k && l.c != flat {
                        return Err(GraphError::ChannelMismatch {
                            layer: l.id,
                            expect: flat,
                            got: l.c,
                        });
                    }
                }
                _ => {
                    // Conv/Pool: single data predecessor path; C must
                    // match the (first) producer's K.
                    let first_k = self.layer(l.predecessors[0]).k;
                    if l.c != first_k {
                        return Err(GraphError::ChannelMismatch {
                            layer: l.id,
                            expect: first_k,
                            got: l.c,
                        });
                    }
                }
            }
        }
        Ok(())
    }

    /// Quick per-op-type census (used by reports and tests).
    pub fn op_census(&self) -> HashMap<&'static str, usize> {
        let mut m = HashMap::new();
        for l in &self.layers {
            let key = match l.op {
                OpType::Conv => "conv",
                OpType::DwConv => "dwconv",
                OpType::Fc => "fc",
                OpType::MatMul => "matmul",
                OpType::Pool(_) => "pool",
                OpType::Add => "add",
                OpType::Concat => "concat",
                OpType::LayerNorm => "layernorm",
                OpType::Softmax => "softmax",
                OpType::Gelu => "gelu",
            };
            *m.entry(key).or_insert(0) += 1;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::super::layer::{LayerBuilder, PoolKind};
    use super::*;

    fn tiny() -> WorkloadGraph {
        let l0 = LayerBuilder::new("conv0", OpType::Conv)
            .k(8)
            .c(3)
            .spatial(16, 16)
            .filter(3, 3)
            .pad(1)
            .build();
        let l1 = LayerBuilder::new("pool", OpType::Pool(PoolKind::Max))
            .k(8)
            .c(8)
            .spatial(8, 8)
            .filter(2, 2)
            .stride(2)
            .preds(&[LayerId(0)])
            .build();
        let l2 = LayerBuilder::new("fc", OpType::Fc)
            .k(10)
            .c(8 * 8 * 8)
            .preds(&[LayerId(1)])
            .build();
        WorkloadGraph::new("tiny", vec![l0, l1, l2]).unwrap()
    }

    #[test]
    fn construction_and_adjacency() {
        let g = tiny();
        assert_eq!(g.len(), 3);
        assert_eq!(g.successors(LayerId(0)), &[LayerId(1)]);
        assert_eq!(g.predecessors(LayerId(2)), &[LayerId(1)]);
        assert_eq!(g.sources(), vec![LayerId(0)]);
        assert_eq!(g.sinks(), vec![LayerId(2)]);
    }

    #[test]
    fn forward_edge_rejected() {
        let l0 = LayerBuilder::new("bad", OpType::Conv)
            .preds(&[LayerId(5)])
            .build();
        assert!(WorkloadGraph::new("bad", vec![l0]).is_err());
    }

    #[test]
    fn dense_layer_filter() {
        let g = tiny();
        assert_eq!(g.dense_layers(), vec![LayerId(0), LayerId(2)]);
    }

    #[test]
    fn census() {
        let g = tiny();
        let c = g.op_census();
        assert_eq!(c["conv"], 1);
        assert_eq!(c["pool"], 1);
        assert_eq!(c["fc"], 1);
    }

    #[test]
    fn matmul_channel_rules() {
        // q[K=8, 4 tokens] and k[K=8, 4 tokens] -> scores[K=4, 4 rows]
        let q = LayerBuilder::new("q", OpType::Conv).k(8).c(8).spatial(4, 1).build();
        let k = LayerBuilder::new("k", OpType::Conv).k(8).c(8).spatial(4, 1).build();
        let ok = LayerBuilder::new("scores", OpType::MatMul)
            .k(4)
            .c(8)
            .spatial(4, 1)
            .preds(&[LayerId(0), LayerId(1)])
            .build();
        // need a source for q/k channels: give them no preds (sources)
        let g = WorkloadGraph::new("mm", vec![q.clone(), k.clone(), ok]).unwrap();
        g.validate_channels().unwrap();

        // wrong reduction dim: C != A.k
        let bad_a = LayerBuilder::new("scores", OpType::MatMul)
            .k(4)
            .c(7)
            .spatial(4, 1)
            .preds(&[LayerId(0), LayerId(1)])
            .build();
        let g = WorkloadGraph::new("mm", vec![q.clone(), k.clone(), bad_a]).unwrap();
        assert!(g.validate_channels().is_err());

        // B operand element count must be C*K
        let bad_b = LayerBuilder::new("scores", OpType::MatMul)
            .k(5)
            .c(8)
            .spatial(4, 1)
            .preds(&[LayerId(0), LayerId(1)])
            .build();
        let g = WorkloadGraph::new("mm", vec![q, k, bad_b]).unwrap();
        assert!(g.validate_channels().is_err());
    }

    #[test]
    fn elementwise_transformer_ops_validate_like_add() {
        let x = LayerBuilder::new("x", OpType::Conv).k(8).c(3).spatial(4, 1).build();
        let ln = LayerBuilder::new("ln", OpType::LayerNorm)
            .k(8)
            .c(8)
            .spatial(4, 1)
            .preds(&[LayerId(0)])
            .build();
        let g = WorkloadGraph::new("t", vec![x.clone(), ln]).unwrap();
        g.validate_channels().unwrap();
        assert_eq!(g.op_census()["layernorm"], 1);

        let bad = LayerBuilder::new("sm", OpType::Softmax)
            .k(9)
            .c(9)
            .spatial(4, 1)
            .preds(&[LayerId(0)])
            .build();
        let g = WorkloadGraph::new("t", vec![x, bad]).unwrap();
        assert!(g.validate_channels().is_err());
    }

    #[test]
    fn total_macs_only_dense() {
        let g = tiny();
        let conv_macs = 8 * 3 * 16 * 16 * 9u64;
        let fc_macs = 10 * 8 * 8 * 8u64;
        assert_eq!(g.total_macs(), conv_macs + fc_macs);
    }
}
