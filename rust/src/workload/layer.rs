//! The [`Layer`] type: one dense/SIMD operator described by its nested
//! for-loop dimensions, the unified representation of paper Section III.

/// Identifier of a layer inside one [`super::WorkloadGraph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LayerId(pub usize);

impl std::fmt::Display for LayerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}", self.0)
    }
}

/// The seven canonical for-loop dimensions of dense DNN operators.
///
/// `B` batch, `K` output channels, `C` input channels, `OY`/`OX` output
/// spatial, `FY`/`FX` filter spatial.  Spatial dataflows of accelerator
/// cores are expressed as unrollings of these dims ([`crate::arch::Dataflow`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dim {
    B,
    K,
    C,
    OY,
    OX,
    FY,
    FX,
}

/// Pooling flavor — both run on the SIMD core, max is the common case.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Average,
}

/// Operator type. Dense types (`Conv`, `DwConv`, `Fc`, `MatMul`) run on
/// dataflow cores; `Pool`/`Add`/`Concat`/`LayerNorm`/`Softmax`/`Gelu`
/// run on the SIMD core (paper Section V-B).
///
/// Transformer layers use the token-tensor convention: a sequence of
/// `s` tokens with embedding dimension `d` is the activation tensor
/// `(K = d, OY = s, OX = 1)` — one output *row* per token, so the
/// sequence dimension carries the spatial locality that line-granular
/// CN splitting (and thus layer fusion) exploits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpType {
    /// Standard convolution (K, C, OY, OX, FY, FX all meaningful).
    Conv,
    /// Depthwise convolution: one filter per channel (C == K groups).
    DwConv,
    /// Fully connected / matrix-vector: no spatial locality, so the
    /// layer collapses into a single CN (paper Step 1, topology rule).
    Fc,
    /// Dynamic matrix-matrix multiply `A[OY, C] x B[C, K] -> O[OY, K]`
    /// (attention score / attention-value GEMMs): **both** operands are
    /// activations, so the layer has *zero resident weights*.  Operand
    /// A is the ordinary (first) data predecessor; operand B is the
    /// second predecessor when present, and otherwise streams from DRAM
    /// per CN (an LLM-decode KV-cache read).  Unlike `Fc`, every output
    /// row only needs the matching A row, so MatMul keeps sequence-dim
    /// (OY) spatial locality and splits into fine-grain CNs.
    MatMul,
    /// Spatial pooling window.
    Pool(PoolKind),
    /// Elementwise residual addition.
    Add,
    /// Channel concatenation (SqueezeNet / Tiny-YOLO style).
    Concat,
    /// Per-token normalization over the embedding (K) dimension —
    /// two SIMD passes (statistics + normalize) per element.
    LayerNorm,
    /// Per-row softmax over the score (K) dimension — two SIMD passes
    /// (max/sum + exp/scale) per element.
    Softmax,
    /// Elementwise GELU activation.
    Gelu,
}

impl OpType {
    /// Does this op run on a dense dataflow core (true) or on the
    /// auxiliary SIMD core (false)?
    pub fn is_dense(&self) -> bool {
        matches!(self, OpType::Conv | OpType::DwConv | OpType::Fc | OpType::MatMul)
    }

    /// Does the operator have spatial locality in OY (and can therefore
    /// be split into line-granular CNs)?  FC does not — its CN must
    /// encapsulate every loop (paper's "layer topology awareness").
    /// MatMul *does*: each output row depends only on its own A row
    /// (plus the shared B operand), so attention stacks fuse per token
    /// block.
    pub fn has_spatial_locality(&self) -> bool {
        !matches!(self, OpType::Fc)
    }
}

/// One DNN layer: operator type + loop bounds + geometry + precision.
#[derive(Debug, Clone)]
pub struct Layer {
    pub id: LayerId,
    pub name: String,
    pub op: OpType,
    /// Output channels (K). For DwConv, K == C.
    pub k: usize,
    /// Input channels (C).
    pub c: usize,
    /// Output spatial height/width.
    pub oy: usize,
    pub ox: usize,
    /// Filter spatial height/width (1 for FC/Add/Concat).
    pub fy: usize,
    pub fx: usize,
    pub stride: usize,
    pub pad: usize,
    /// Activation / weight precision in bits.
    pub act_bits: usize,
    pub wgt_bits: usize,
    /// Data predecessors (graph edges are stored on the consumer side).
    pub predecessors: Vec<LayerId>,
}

impl Layer {
    /// Input feature-map height as stored by the producer, inverted from
    /// the output geometry.  Padded ('same'-style) layers use
    /// `oy * stride` (the framework convention: padding is chosen so the
    /// input is an exact multiple of the stride); valid layers use
    /// `(oy-1) * stride + fy`.
    pub fn in_height(&self) -> usize {
        match self.op {
            OpType::Add
            | OpType::Concat
            | OpType::Fc
            | OpType::MatMul
            | OpType::LayerNorm
            | OpType::Softmax
            | OpType::Gelu => self.oy,
            _ if self.pad > 0 => self.oy * self.stride,
            _ => (self.oy - 1) * self.stride + self.fy,
        }
    }

    /// Input feature-map width (same derivation as [`Self::in_height`]).
    pub fn in_width(&self) -> usize {
        match self.op {
            OpType::Add
            | OpType::Concat
            | OpType::Fc
            | OpType::MatMul
            | OpType::LayerNorm
            | OpType::Softmax
            | OpType::Gelu => self.ox,
            _ if self.pad > 0 => self.ox * self.stride,
            _ => (self.ox - 1) * self.stride + self.fx,
        }
    }

    /// Multiply-accumulate count of the whole layer.
    pub fn macs(&self) -> u64 {
        let (k, c, oy, ox, fy, fx) = (
            self.k as u64,
            self.c as u64,
            self.oy as u64,
            self.ox as u64,
            self.fy.max(1) as u64,
            self.fx.max(1) as u64,
        );
        match self.op {
            OpType::Conv => k * c * oy * ox * fy * fx,
            // Depthwise: one input channel per output channel.
            OpType::DwConv => k * oy * ox * fy * fx,
            OpType::Fc => k * c,
            // A[OY, C] x B[C, K]: one MAC per (row, out-col, reduction).
            OpType::MatMul => k * c * oy * ox,
            // SIMD ops: one "op" per output element (no MACs, but we
            // count vector ops for the SIMD-core latency model).
            OpType::Pool(_) => k * oy * ox * fy * fx,
            OpType::Add => k * oy * ox,
            OpType::Concat => 0,
            // Two vector passes per element: statistics (mean/var or
            // max/sum) then normalize (scale or exp/divide).
            OpType::LayerNorm | OpType::Softmax => 2 * k * oy * ox,
            OpType::Gelu => k * oy * ox,
        }
    }

    /// Loop bound of one dimension (used by the spatial-utilization model).
    pub fn dim(&self, d: Dim) -> usize {
        match d {
            Dim::B => 1,
            Dim::K => self.k,
            Dim::C => match self.op {
                OpType::DwConv => 1, // per-channel group reduction is 1
                _ => self.c,
            },
            Dim::OY => self.oy,
            Dim::OX => self.ox,
            Dim::FY => self.fy.max(1),
            Dim::FX => self.fx.max(1),
        }
    }

    /// Total weight footprint in bytes.  `MatMul` has **zero** resident
    /// weights: its B operand is a streamed activation tensor, so the
    /// weight tracker never holds anything for it.
    pub fn weight_bytes(&self) -> u64 {
        let elems: u64 = match self.op {
            OpType::Conv => (self.k * self.c * self.fy * self.fx) as u64,
            OpType::DwConv => (self.k * self.fy * self.fx) as u64,
            OpType::Fc => (self.k * self.c) as u64,
            _ => 0,
        };
        elems * self.wgt_bits as u64 / 8
    }

    /// Byte footprint of a `MatMul`'s B operand (the full `[C, K]`
    /// matrix sitting in the dataflow's weight position), at activation
    /// precision — it is an activation tensor, not weights.
    pub fn matmul_b_bytes(&self) -> u64 {
        (self.k * self.c) as u64 * self.act_bits as u64 / 8
    }

    /// A `MatMul` without an in-graph B producer (fewer than two
    /// predecessors) streams its B operand from DRAM for every CN —
    /// the model of an LLM-decode KV-cache read.  With two
    /// predecessors, B arrives over ordinary data edges instead.
    pub fn streams_b_from_dram(&self) -> bool {
        self.op == OpType::MatMul && self.predecessors.len() < 2
    }

    /// Total output activation footprint in bytes.
    pub fn output_bytes(&self) -> u64 {
        (self.k * self.oy * self.ox) as u64 * self.act_bits as u64 / 8
    }

    /// Total input activation footprint in bytes (all predecessors).
    pub fn input_bytes(&self) -> u64 {
        (self.c * self.in_height() * self.in_width()) as u64 * self.act_bits as u64 / 8
    }
}

/// Fluent builder used by [`super::models`].
pub struct LayerBuilder {
    layer: Layer,
}

impl LayerBuilder {
    pub fn new(name: &str, op: OpType) -> Self {
        LayerBuilder {
            layer: Layer {
                id: LayerId(usize::MAX),
                name: name.to_string(),
                op,
                k: 1,
                c: 1,
                oy: 1,
                ox: 1,
                fy: 1,
                fx: 1,
                stride: 1,
                pad: 0,
                act_bits: 8,
                wgt_bits: 8,
                predecessors: vec![],
            },
        }
    }

    pub fn k(mut self, k: usize) -> Self {
        self.layer.k = k;
        self
    }
    pub fn c(mut self, c: usize) -> Self {
        self.layer.c = c;
        self
    }
    pub fn spatial(mut self, oy: usize, ox: usize) -> Self {
        self.layer.oy = oy;
        self.layer.ox = ox;
        self
    }
    pub fn filter(mut self, fy: usize, fx: usize) -> Self {
        self.layer.fy = fy;
        self.layer.fx = fx;
        self
    }
    pub fn stride(mut self, s: usize) -> Self {
        self.layer.stride = s;
        self
    }
    pub fn pad(mut self, p: usize) -> Self {
        self.layer.pad = p;
        self
    }
    pub fn bits(mut self, act: usize, wgt: usize) -> Self {
        self.layer.act_bits = act;
        self.layer.wgt_bits = wgt;
        self
    }
    pub fn preds(mut self, preds: &[LayerId]) -> Self {
        self.layer.predecessors = preds.to_vec();
        self
    }
    pub fn build(self) -> Layer {
        self.layer
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv3x3() -> Layer {
        LayerBuilder::new("c", OpType::Conv)
            .k(64)
            .c(64)
            .spatial(28, 28)
            .filter(3, 3)
            .pad(1)
            .build()
    }

    #[test]
    fn macs_conv() {
        let l = conv3x3();
        assert_eq!(l.macs(), 64 * 64 * 28 * 28 * 9);
    }

    #[test]
    fn macs_dwconv_excludes_c() {
        let l = LayerBuilder::new("dw", OpType::DwConv)
            .k(32)
            .c(32)
            .spatial(14, 14)
            .filter(3, 3)
            .build();
        assert_eq!(l.macs(), 32 * 14 * 14 * 9);
    }

    #[test]
    fn macs_fc() {
        let l = LayerBuilder::new("fc", OpType::Fc).k(1000).c(512).build();
        assert_eq!(l.macs(), 512_000);
    }

    #[test]
    fn geometry_same_padding() {
        let l = conv3x3();
        // 'same' conv: input spatial == output spatial
        assert_eq!(l.in_height(), 28);
        assert_eq!(l.in_width(), 28);
    }

    #[test]
    fn geometry_strided() {
        let l = LayerBuilder::new("c", OpType::Conv)
            .k(64)
            .c(3)
            .spatial(112, 112)
            .filter(7, 7)
            .stride(2)
            .pad(3)
            .build();
        assert_eq!(l.in_height(), 224);
        assert_eq!(l.in_width(), 224);
    }

    #[test]
    fn geometry_valid_pool() {
        let l = LayerBuilder::new("p", OpType::Pool(PoolKind::Max))
            .k(96)
            .c(96)
            .spatial(54, 54)
            .filter(3, 3)
            .stride(2)
            .build();
        assert_eq!(l.in_height(), 53 * 2 + 3); // 109
    }

    #[test]
    fn bytes() {
        let l = conv3x3();
        assert_eq!(l.weight_bytes(), 64 * 64 * 9);
        assert_eq!(l.output_bytes(), 64 * 28 * 28);
        assert_eq!(l.input_bytes(), 64 * 28 * 28);
    }

    #[test]
    fn fc_has_no_spatial_locality() {
        assert!(!OpType::Fc.has_spatial_locality());
        assert!(OpType::Conv.has_spatial_locality());
        assert!(OpType::Pool(PoolKind::Max).has_spatial_locality());
    }

    #[test]
    fn matmul_keeps_sequence_locality() {
        // attention GEMMs split per token row, unlike FC
        assert!(OpType::MatMul.has_spatial_locality());
        assert!(OpType::MatMul.is_dense());
    }

    #[test]
    fn dense_classification() {
        assert!(OpType::Conv.is_dense());
        assert!(OpType::DwConv.is_dense());
        assert!(OpType::Fc.is_dense());
        assert!(!OpType::Add.is_dense());
        assert!(!OpType::Pool(PoolKind::Max).is_dense());
        assert!(!OpType::Concat.is_dense());
        assert!(!OpType::LayerNorm.is_dense());
        assert!(!OpType::Softmax.is_dense());
        assert!(!OpType::Gelu.is_dense());
    }

    fn scores_matmul(s: usize, d: usize) -> Layer {
        // Q[s, d] x K^T[d, s] -> scores[s, s]
        LayerBuilder::new("scores", OpType::MatMul)
            .k(s)
            .c(d)
            .spatial(s, 1)
            .build()
    }

    #[test]
    fn matmul_macs_and_zero_weights() {
        let l = scores_matmul(196, 192);
        assert_eq!(l.macs(), 196 * 192 * 196);
        // both operands dynamic: nothing resident in weight memory
        assert_eq!(l.weight_bytes(), 0);
        // B operand footprint at activation precision: C x K elements
        assert_eq!(l.matmul_b_bytes(), 192 * 196);
    }

    #[test]
    fn matmul_streams_b_without_second_pred() {
        let mut l = scores_matmul(1, 64);
        assert!(l.streams_b_from_dram(), "no preds: KV read streams");
        l.predecessors = vec![LayerId(0)];
        assert!(l.streams_b_from_dram(), "single pred: B still streams");
        l.predecessors = vec![LayerId(0), LayerId(1)];
        assert!(!l.streams_b_from_dram(), "in-graph B producer");
    }

    #[test]
    fn matmul_geometry_is_token_rows() {
        let l = scores_matmul(196, 192);
        assert_eq!(l.in_height(), 196);
        assert_eq!(l.in_width(), 1);
        assert_eq!(l.input_bytes(), 192 * 196); // operand A only
        assert_eq!(l.output_bytes(), 196 * 196);
    }

    #[test]
    fn simd_transformer_op_counts() {
        let ln = LayerBuilder::new("ln", OpType::LayerNorm).k(192).c(192).spatial(196, 1).build();
        assert_eq!(ln.macs(), 2 * 192 * 196);
        assert_eq!(ln.in_height(), 196);
        let sm = LayerBuilder::new("sm", OpType::Softmax).k(196).c(196).spatial(196, 1).build();
        assert_eq!(sm.macs(), 2 * 196 * 196);
        let ge = LayerBuilder::new("ge", OpType::Gelu).k(768).c(768).spatial(196, 1).build();
        assert_eq!(ge.macs(), 768 * 196);
        for l in [&ln, &sm, &ge] {
            assert_eq!(l.weight_bytes(), 0);
        }
    }

    #[test]
    fn dim_lookup() {
        let l = conv3x3();
        assert_eq!(l.dim(Dim::K), 64);
        assert_eq!(l.dim(Dim::OY), 28);
        assert_eq!(l.dim(Dim::FY), 3);
        assert_eq!(l.dim(Dim::B), 1);
    }
}
