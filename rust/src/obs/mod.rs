//! Flight-recorder observability: spans, counters, histograms and
//! Chrome-trace export for the simulation core, the GA and the
//! scenario engine.
//!
//! The subsystem is an in-tree, zero-dependency facade with three
//! pillars:
//!
//! 1. **spans & events** — wall-clock RAII spans ([`span`]) and
//!    instants ([`instant`]) recorded into a thread-local buffer that
//!    drains into a global registry (one mutex acquisition per
//!    [`FLUSH_EVERY`] events or per thread exit, so parallel GA
//!    workers and parsim chips never contend per event);
//! 2. **counters & histograms** — fixed enum-indexed atomics
//!    ([`Counter`], [`Hist`]) incremented from the engines' seams
//!    (cache get/insert, pool push/pop, snapshot/resume boundaries,
//!    GA generations) and *aggregated* — not sampled per step — from
//!    the simulation outcome when a run finishes;
//! 3. **export** — [`chrome`] renders a run as Chrome/Perfetto
//!    `trace_event` JSON, and [`report::RunReport`] snapshots the
//!    counters into a per-run summary attached to
//!    `ScheduleResult`/`ScenarioResult` and printed by the CLI.
//!
//! # Zero cost when off
//!
//! The recorder is **disabled by default**.  Every entry point first
//! checks [`enabled`] — a single relaxed atomic load — and returns
//! immediately when the recorder is off; no allocation, no mutex, no
//! time syscall.  The hot simulation loop (`SimContext::step`) carries
//! **no instrumentation at all**: per-run totals (decisions, transfer
//! counts, evictions, link occupancy) are derived once in
//! `SimContext::finish` from state the engine already maintains, so a
//! disabled recorder adds only the per-CN pool-push/pop check.  More
//! importantly, tracing can never perturb *results*: the recorder
//! observes the engines and is never read back by them, so enabled and
//! disabled runs are bit-identical by construction (pinned by
//! `rust/tests/obs_equivalence.rs`).
//!
//! # Enabling
//!
//! Programmatic: [`set_enabled`].  From the CLI / environment:
//! `STREAM_TRACE=0` (or unset) — off; `STREAM_TRACE=1` — record
//! counters and events in memory (the CLI `--report` path);
//! `STREAM_TRACE=path.json` — additionally write a Chrome trace to
//! `path.json` at command exit ([`init_from_env`] + [`trace_path`]).
//!
//! The registry is global (process-wide), matching its use as a
//! flight recorder: tests that assert on counter values serialize via
//! their own mutex and call [`reset`] around the section under test.

pub mod chrome;
pub mod report;

pub use report::{LinkLoad, RunReport, ServingSummary};

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Monotonic event counters, one atomic cell each.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Counter {
    /// Completed `SimContext::simulate` runs (any path).
    SimRuns = 0,
    /// Scheduling decisions across all runs (== CNs placed).
    SimDecisions,
    /// Decisions taken in multi-lane runs, i.e. inter-request
    /// arbitration picks.
    ArbitrationPicks,
    /// Inter-core communication transfers.
    CommTransfers,
    /// DRAM transfers (weight/act fetches, output stores).
    DramTransfers,
    /// Weight-SRAM DRAM fetches.
    WeightFetches,
    /// FIFO weight evictions.
    WeightEvictions,
    /// Candidate-pool insertions.
    PoolPushes,
    /// Candidate-pool pops (scheduling picks).
    PoolPops,
    /// `ScheduleCache` exact-hit lookups.
    SchedCacheHits,
    /// `ScheduleCache` misses (including fingerprint collisions).
    SchedCacheMisses,
    /// `DeltaCache` segmented-parent hits.
    DeltaCacheHits,
    /// `DeltaCache` misses.
    DeltaCacheMisses,
    /// Child genomes resumed from a parent snapshot.
    DeltaResumes,
    /// Traced cold runs (no usable parent snapshot).
    DeltaColdRuns,
    /// Resumable snapshots frozen by traced runs.
    SnapshotsTaken,
    /// Parallel (chip-partitioned) simulations that engaged.
    ParsimEngaged,
    /// Parallel simulations that fell back to sequential.
    ParsimFallbacks,
    /// NSGA-II generations completed.
    GaGenerations,
    /// Genomes actually simulated (cache misses dispatched).
    GaEvals,
    /// Genomes killed by the lower-bound early-abort.
    GaPruned,
    /// Completed scenario-engine runs.
    ScenarioRuns,
    /// Requests admitted (injected as lanes) by the streaming serving
    /// driver.
    ServingAdmitted,
    /// Requests retired (completed + freed) by the streaming driver.
    ServingRetired,
    /// High-water mark of the streaming driver's live lane set
    /// (max-merged across runs, not summed).
    ServingLivePeak,
}

impl Counter {
    pub const COUNT: usize = 25;

    pub const ALL: [Counter; Counter::COUNT] = [
        Counter::SimRuns,
        Counter::SimDecisions,
        Counter::ArbitrationPicks,
        Counter::CommTransfers,
        Counter::DramTransfers,
        Counter::WeightFetches,
        Counter::WeightEvictions,
        Counter::PoolPushes,
        Counter::PoolPops,
        Counter::SchedCacheHits,
        Counter::SchedCacheMisses,
        Counter::DeltaCacheHits,
        Counter::DeltaCacheMisses,
        Counter::DeltaResumes,
        Counter::DeltaColdRuns,
        Counter::SnapshotsTaken,
        Counter::ParsimEngaged,
        Counter::ParsimFallbacks,
        Counter::GaGenerations,
        Counter::GaEvals,
        Counter::GaPruned,
        Counter::ScenarioRuns,
        Counter::ServingAdmitted,
        Counter::ServingRetired,
        Counter::ServingLivePeak,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::SimRuns => "sim.runs",
            Counter::SimDecisions => "sim.decisions",
            Counter::ArbitrationPicks => "sim.arbitration_picks",
            Counter::CommTransfers => "sim.comm_transfers",
            Counter::DramTransfers => "sim.dram_transfers",
            Counter::WeightFetches => "weights.fetches",
            Counter::WeightEvictions => "weights.evictions",
            Counter::PoolPushes => "pool.pushes",
            Counter::PoolPops => "pool.pops",
            Counter::SchedCacheHits => "cache.sched.hits",
            Counter::SchedCacheMisses => "cache.sched.misses",
            Counter::DeltaCacheHits => "cache.delta.hits",
            Counter::DeltaCacheMisses => "cache.delta.misses",
            Counter::DeltaResumes => "delta.resumes",
            Counter::DeltaColdRuns => "delta.cold_runs",
            Counter::SnapshotsTaken => "delta.snapshots_taken",
            Counter::ParsimEngaged => "parsim.engaged",
            Counter::ParsimFallbacks => "parsim.fallbacks",
            Counter::GaGenerations => "ga.generations",
            Counter::GaEvals => "ga.evals",
            Counter::GaPruned => "ga.pruned",
            Counter::ScenarioRuns => "scenario.runs",
            Counter::ServingAdmitted => "serving.admitted",
            Counter::ServingRetired => "serving.retired",
            Counter::ServingLivePeak => "serving.live_peak",
        }
    }
}

/// Number of buckets every histogram carries.
pub const HIST_BUCKETS: usize = 16;

/// Fixed-bucket histograms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Hist {
    /// Decisions inherited for free per delta resume (log2 buckets).
    ResumeDepth = 0,
    /// Per-link busy occupancy as a percentage of the run makespan
    /// (10-point linear buckets, 0–100).
    LinkBusyPct,
    /// Pareto-front size per GA generation (log2 buckets).
    GaFrontSize,
}

impl Hist {
    pub const COUNT: usize = 3;

    pub const ALL: [Hist; Hist::COUNT] =
        [Hist::ResumeDepth, Hist::LinkBusyPct, Hist::GaFrontSize];

    pub fn name(self) -> &'static str {
        match self {
            Hist::ResumeDepth => "delta.resume_depth",
            Hist::LinkBusyPct => "links.busy_pct",
            Hist::GaFrontSize => "ga.front_size",
        }
    }

    /// Bucket index for a sample (always in `0..HIST_BUCKETS`).
    pub fn bucket(self, v: u64) -> usize {
        match self {
            // 0-9 → 0, 10-19 → 1, …, 100+ → 10
            Hist::LinkBusyPct => ((v / 10) as usize).min(10),
            // log2: 0 → 0, 1 → 1, 2-3 → 2, 4-7 → 3, …
            _ => {
                if v == 0 {
                    0
                } else {
                    ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
                }
            }
        }
    }

    /// Human-readable lower bound of a bucket.
    pub fn bucket_label(self, i: usize) -> String {
        match self {
            Hist::LinkBusyPct => format!("{}%", i * 10),
            _ => {
                if i == 0 {
                    "0".to_string()
                } else {
                    format!(">={}", 1u64 << (i - 1))
                }
            }
        }
    }
}

/// One recorded trace event (wall-clock, microseconds since the
/// recorder's epoch).  `ph` follows the Chrome `trace_event` phases:
/// `'X'` complete span, `'i'` instant.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    pub cat: &'static str,
    pub ph: char,
    pub ts_us: f64,
    pub dur_us: f64,
    pub pid: u64,
    pub tid: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
#[allow(clippy::declare_interior_mutable_const)]
const ZROW: [AtomicU64; HIST_BUCKETS] = [ZERO; HIST_BUCKETS];

static COUNTERS: [AtomicU64; Counter::COUNT] = [ZERO; Counter::COUNT];
static HISTS: [[AtomicU64; HIST_BUCKETS]; Hist::COUNT] = [ZROW; Hist::COUNT];
static EVENTS: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static TRACE_PATH: Mutex<Option<String>> = Mutex::new(None);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Thread-local event buffer; drained into [`EVENTS`] every
/// [`FLUSH_EVERY`] events and on thread exit.
const FLUSH_EVERY: usize = 64;

struct TlBuf(Vec<TraceEvent>);

impl Drop for TlBuf {
    fn drop(&mut self) {
        if !self.0.is_empty() {
            if let Ok(mut g) = EVENTS.lock() {
                g.append(&mut self.0);
            }
        }
    }
}

thread_local! {
    static TL_EVENTS: RefCell<TlBuf> = RefCell::new(TlBuf(Vec::new()));
}

/// Is the recorder on?  The single relaxed load every instrumentation
/// site pays when tracing is disabled.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on/off (process-wide).
pub fn set_enabled(on: bool) {
    if on {
        // pin the epoch before any span can start
        let _ = EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Configure from `STREAM_TRACE`: unset/`0`/empty — off; `1` — on,
/// in-memory only; anything else — on, and [`trace_path`] returns the
/// value as the Chrome-trace output path (written by the CLI).
pub fn init_from_env() {
    match std::env::var("STREAM_TRACE") {
        Err(_) => {}
        Ok(v) if v.is_empty() || v == "0" => {}
        Ok(v) if v == "1" => set_enabled(true),
        Ok(path) => {
            set_enabled(true);
            *TRACE_PATH.lock().unwrap() = Some(path);
        }
    }
}

/// The `STREAM_TRACE` output path, when one was configured.
pub fn trace_path() -> Option<String> {
    TRACE_PATH.lock().unwrap().clone()
}

/// Bump a counter by `n` (no-op when disabled).
#[inline]
pub fn count(c: Counter, n: u64) {
    if enabled() {
        COUNTERS[c as usize].fetch_add(n, Ordering::Relaxed);
    }
}

/// Raise a counter to at least `v` (no-op when disabled) — for
/// high-water-mark counters like [`Counter::ServingLivePeak`], which
/// max-merge across runs instead of summing.
#[inline]
pub fn count_max(c: Counter, v: u64) {
    if enabled() {
        COUNTERS[c as usize].fetch_max(v, Ordering::Relaxed);
    }
}

/// Current value of a counter.
pub fn counter(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

/// Record one histogram sample (no-op when disabled).
#[inline]
pub fn hist(h: Hist, v: u64) {
    if enabled() {
        HISTS[h as usize][h.bucket(v)].fetch_add(1, Ordering::Relaxed);
    }
}

/// Bucket counts of a histogram.
pub fn hist_counts(h: Hist) -> [u64; HIST_BUCKETS] {
    let mut out = [0u64; HIST_BUCKETS];
    for (o, c) in out.iter_mut().zip(&HISTS[h as usize]) {
        *o = c.load(Ordering::Relaxed);
    }
    out
}

/// All nonzero counters, in declaration order.
pub fn snapshot_counters() -> Vec<(&'static str, u64)> {
    Counter::ALL
        .iter()
        .map(|&c| (c.name(), counter(c)))
        .filter(|&(_, v)| v > 0)
        .collect()
}

/// All histograms with at least one sample, as
/// `(name, [(bucket_label, count)])` with empty buckets dropped.
pub fn snapshot_hists() -> Vec<(&'static str, Vec<(String, u64)>)> {
    Hist::ALL
        .iter()
        .filter_map(|&h| {
            let counts = hist_counts(h);
            let buckets: Vec<(String, u64)> = counts
                .iter()
                .enumerate()
                .filter(|&(_, &c)| c > 0)
                .map(|(i, &c)| (h.bucket_label(i), c))
                .collect();
            (!buckets.is_empty()).then(|| (h.name(), buckets))
        })
        .collect()
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

fn buffer_event(ev: TraceEvent) {
    TL_EVENTS.with(|b| {
        let mut b = b.borrow_mut();
        b.0.push(ev);
        if b.0.len() >= FLUSH_EVERY {
            if let Ok(mut g) = EVENTS.lock() {
                g.append(&mut b.0);
            }
        }
    });
}

/// Append an already-built event (no-op when disabled).
pub fn push_event(ev: TraceEvent) {
    if enabled() {
        buffer_event(ev);
    }
}

/// Flush the calling thread's buffered events into the global
/// registry.
pub fn flush() {
    TL_EVENTS.with(|b| {
        let mut b = b.borrow_mut();
        if !b.0.is_empty() {
            if let Ok(mut g) = EVENTS.lock() {
                g.append(&mut b.0);
            }
        }
    });
}

/// Drain all recorded events (flushes the calling thread first;
/// events still buffered on *other* live threads are not included
/// until those threads flush or exit).
pub fn take_events() -> Vec<TraceEvent> {
    flush();
    std::mem::take(&mut *EVENTS.lock().unwrap())
}

/// Zero every counter and histogram and drop all recorded events.
/// Leaves the enabled flag untouched — tests bracket their section
/// with `reset()` … asserts … `reset()`.
pub fn reset() {
    for c in &COUNTERS {
        c.store(0, Ordering::Relaxed);
    }
    for row in &HISTS {
        for c in row {
            c.store(0, Ordering::Relaxed);
        }
    }
    flush();
    EVENTS.lock().unwrap().clear();
}

/// RAII wall-clock span: records an `'X'` event from construction to
/// drop under pid 0 ("runtime").  Cost when disabled: one relaxed
/// load, no timestamp.
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    start: Option<Instant>,
    tid: u64,
}

impl SpanGuard {
    /// Stop timing without recording (e.g. abandoned phases).
    pub fn cancel(mut self) {
        self.start = None;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let ep = epoch();
            let ts_us = start.duration_since(ep).as_secs_f64() * 1e6;
            let dur_us = start.elapsed().as_secs_f64() * 1e6;
            buffer_event(TraceEvent {
                name: self.name.to_string(),
                cat: self.cat,
                ph: 'X',
                ts_us,
                dur_us,
                pid: 0,
                tid: self.tid,
            });
        }
    }
}

/// Open a wall-clock span on runtime lane 0.
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    span_tid(cat, name, 0)
}

/// Open a wall-clock span on a specific runtime lane (e.g. one per
/// parsim worker).
pub fn span_tid(cat: &'static str, name: &'static str, tid: u64) -> SpanGuard {
    let start = if enabled() {
        let _ = epoch();
        Some(Instant::now())
    } else {
        None
    };
    SpanGuard { name, cat, start, tid }
}

/// Open a wall-clock span on a per-thread runtime lane: a stable hash
/// of the current thread id, offset past the explicit worker lanes.
/// Use this for code that runs concurrently on pool threads (GA
/// fitness workers, parsim chip workers) so spans from different
/// threads land on different lanes and never appear to overlap.
pub fn span_here(cat: &'static str, name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name, cat, start: None, tid: 0 };
    }
    span_tid(cat, name, thread_lane())
}

/// Stable per-thread lane id: hashed `ThreadId`, masked to 32 bits
/// (exactly representable as an f64 timeline tid) and offset by 2^16
/// to stay clear of the fixed lanes (0 = main, small ids = workers).
pub fn thread_lane() -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    std::thread::current().id().hash(&mut h);
    (1 << 16) + (h.finish() & 0xffff_ffff)
}

/// Record an instant event on the runtime lane (no-op when disabled).
pub fn instant(cat: &'static str, name: &str) {
    if enabled() {
        let ts_us = epoch().elapsed().as_secs_f64() * 1e6;
        buffer_event(TraceEvent {
            name: name.to_string(),
            cat,
            ph: 'i',
            ts_us,
            dur_us: 0.0,
            pid: 0,
            tid: 0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    // the registry is process-global; serialize the tests that touch it
    static LOCK: StdMutex<()> = StdMutex::new(());

    #[test]
    fn disabled_recorder_ignores_everything() {
        let _g = LOCK.lock().unwrap();
        set_enabled(false);
        reset();
        count(Counter::SimRuns, 3);
        hist(Hist::ResumeDepth, 5);
        instant("t", "x");
        drop(span("t", "s"));
        assert_eq!(counter(Counter::SimRuns), 0);
        assert_eq!(hist_counts(Hist::ResumeDepth), [0; HIST_BUCKETS]);
        assert!(take_events().is_empty());
    }

    #[test]
    fn counters_and_hists_accumulate_when_enabled() {
        let _g = LOCK.lock().unwrap();
        set_enabled(true);
        reset();
        count(Counter::GaEvals, 2);
        count(Counter::GaEvals, 3);
        hist(Hist::GaFrontSize, 0);
        hist(Hist::GaFrontSize, 1);
        hist(Hist::GaFrontSize, 6);
        assert_eq!(counter(Counter::GaEvals), 5);
        let h = hist_counts(Hist::GaFrontSize);
        assert_eq!(h[0], 1);
        assert_eq!(h[1], 1);
        assert_eq!(h[3], 1); // 6 → bucket [4,8)
        let snap = snapshot_counters();
        assert!(snap.contains(&("ga.evals", 5)));
        set_enabled(false);
        reset();
    }

    #[test]
    fn spans_record_nonnegative_windows() {
        let _g = LOCK.lock().unwrap();
        set_enabled(true);
        reset();
        {
            let _outer = span("test", "outer");
            instant("test", "mark");
        }
        let evs = take_events();
        assert_eq!(evs.len(), 2);
        for e in &evs {
            assert!(e.ts_us >= 0.0 && e.dur_us >= 0.0);
        }
        assert!(evs.iter().any(|e| e.ph == 'X' && e.name == "outer"));
        assert!(evs.iter().any(|e| e.ph == 'i' && e.name == "mark"));
        set_enabled(false);
        reset();
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(Hist::ResumeDepth.bucket(0), 0);
        assert_eq!(Hist::ResumeDepth.bucket(1), 1);
        assert_eq!(Hist::ResumeDepth.bucket(2), 2);
        assert_eq!(Hist::ResumeDepth.bucket(3), 2);
        assert_eq!(Hist::ResumeDepth.bucket(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(Hist::LinkBusyPct.bucket(0), 0);
        assert_eq!(Hist::LinkBusyPct.bucket(99), 9);
        assert_eq!(Hist::LinkBusyPct.bucket(100), 10);
    }
}
