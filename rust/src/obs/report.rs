//! Per-run observability summary ([`RunReport`]): engine totals
//! derived from the simulation outcome plus a snapshot of the global
//! counters/histograms, attached to
//! [`ScheduleResult`](crate::scheduler::ScheduleResult) /
//! [`ScenarioResult`](crate::scenario::ScenarioResult) when the
//! recorder is enabled and printed by the CLI under `--report`.

use std::fmt;

use crate::scheduler::FallbackReason;

/// Occupancy of one interconnect link over a run.
#[derive(Debug, Clone, Default)]
pub struct LinkLoad {
    pub name: String,
    pub busy_cc: u64,
    pub bytes: u64,
    /// Busy cycles over the run makespan, in [0, 1].
    pub util: f64,
}

/// Snapshot of what one engine run did, attached to its result when
/// the recorder is enabled ([`crate::obs::enabled`]); always `None`
/// when disabled, so result structs stay bit-identical to the
/// untraced path.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    /// Scheduling decisions (== CNs placed across all lanes).
    pub decisions: u64,
    /// Inter-core transfers performed.
    pub comm_transfers: u64,
    /// DRAM transfers performed (all kinds).
    pub dram_transfers: u64,
    /// Weight fetches from DRAM.
    pub weight_fetches: u64,
    /// FIFO weight evictions.
    pub weight_evictions: u64,
    /// Chip partitions the simulation ran under (1 = sequential).
    pub partitions: usize,
    /// Why the parallel sim core did not engage, when it didn't.
    pub fallback: Option<FallbackReason>,
    /// Run makespan in cycles.
    pub makespan_cc: u64,
    /// Busiest links first (top 8), with utilization over the
    /// makespan.
    pub links: Vec<LinkLoad>,
    /// Global counter snapshot (nonzero only) at report time.
    pub counters: Vec<(&'static str, u64)>,
    /// Global histogram snapshot (non-empty only) at report time.
    pub hists: Vec<(&'static str, Vec<(String, u64)>)>,
    /// Streamed-serving summary, attached only by the streaming
    /// serving path.
    pub serving: Option<ServingSummary>,
}

/// Live-set accounting and per-window tail latency of one streamed
/// serving run ([`ScenarioRunner::run_streamed`]).
///
/// [`ScenarioRunner::run_streamed`]: crate::scenario::ScenarioRunner::run_streamed
#[derive(Debug, Clone, Default)]
pub struct ServingSummary {
    /// Requests admitted into the live set.
    pub admitted: u64,
    /// Requests retired (completed and freed).
    pub retired: u64,
    /// High-water mark of the live lane set.
    pub live_peak: usize,
    /// High-water mark of the arrived (truly in-flight) subset.
    pub inflight_peak: usize,
    /// `(window start cc, completed, p99 cc)` per retained completion
    /// window, oldest first.
    pub window_p99: Vec<(u64, u64, u64)>,
}

impl RunReport {
    /// Fill [`RunReport::counters`] / [`RunReport::hists`] from the
    /// global recorder.
    pub fn capture_globals(&mut self) {
        self.counters = super::snapshot_counters();
        self.hists = super::snapshot_hists();
    }

    /// Hit rate of a `(hits, misses)` counter pair from the captured
    /// snapshot, when both were recorded.
    pub fn hit_rate(&self, hits_name: &str, misses_name: &str) -> Option<f64> {
        let get = |n: &str| {
            self.counters.iter().find(|(k, _)| *k == n).map(|&(_, v)| v)
        };
        let h = get(hits_name).unwrap_or(0);
        let m = get(misses_name).unwrap_or(0);
        (h + m > 0).then(|| h as f64 / (h + m) as f64)
    }
}

impl fmt::Display for RunReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "run report")?;
        writeln!(f, "  decisions          {}", self.decisions)?;
        writeln!(f, "  comm transfers     {}", self.comm_transfers)?;
        writeln!(f, "  dram transfers     {}", self.dram_transfers)?;
        writeln!(f, "  weight fetches     {}", self.weight_fetches)?;
        writeln!(f, "  weight evictions   {}", self.weight_evictions)?;
        writeln!(f, "  makespan           {} cc", self.makespan_cc)?;
        match self.fallback {
            None => writeln!(f, "  partitions         {} (parallel)", self.partitions)?,
            Some(r) => {
                writeln!(f, "  partitions         {} (sequential: {})", self.partitions, r)?
            }
        }
        if !self.links.is_empty() {
            writeln!(f, "  busiest links:")?;
            for l in &self.links {
                writeln!(
                    f,
                    "    {:<20} {:>12} cc  {:>12} B  {:5.1}%",
                    l.name,
                    l.busy_cc,
                    l.bytes,
                    l.util * 100.0
                )?;
            }
        }
        if let Some(s) = &self.serving {
            writeln!(
                f,
                "  serving            admitted {}  retired {}  live peak {} (in-flight {})",
                s.admitted, s.retired, s.live_peak, s.inflight_peak
            )?;
            if !s.window_p99.is_empty() {
                writeln!(f, "  window p99:")?;
                for &(start, completed, p99) in &s.window_p99 {
                    writeln!(
                        f,
                        "    @{start:<14} {completed:>8} done  p99 {p99} cc"
                    )?;
                }
            }
        }
        if !self.counters.is_empty() {
            writeln!(f, "  counters:")?;
            for (k, v) in &self.counters {
                writeln!(f, "    {k:<24} {v}")?;
            }
        }
        for (name, buckets) in &self.hists {
            writeln!(f, "  hist {name}:")?;
            for (label, c) in buckets {
                writeln!(f, "    {label:<10} {c}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_fallback_and_links() {
        let mut r = RunReport {
            decisions: 10,
            partitions: 1,
            fallback: Some(FallbackReason::SingleRequest),
            makespan_cc: 1000,
            ..Default::default()
        };
        r.links.push(LinkLoad {
            name: "bus".into(),
            busy_cc: 500,
            bytes: 4096,
            util: 0.5,
        });
        let s = r.to_string();
        assert!(s.contains("single request"));
        assert!(s.contains("bus"));
        assert!(s.contains("50.0%"));
    }

    #[test]
    fn display_includes_serving_summary() {
        let mut r = RunReport { makespan_cc: 10, partitions: 1, ..Default::default() };
        r.serving = Some(ServingSummary {
            admitted: 100,
            retired: 100,
            live_peak: 7,
            inflight_peak: 3,
            window_p99: vec![(0, 40, 1200), (1_000, 60, 900)],
        });
        let s = r.to_string();
        assert!(s.contains("admitted 100"));
        assert!(s.contains("live peak 7"));
        assert!(s.contains("p99 1200 cc"));
    }

    #[test]
    fn hit_rate_reads_captured_counters() {
        let mut r = RunReport::default();
        r.counters = vec![("cache.sched.hits", 3), ("cache.sched.misses", 1)];
        let rate = r.hit_rate("cache.sched.hits", "cache.sched.misses").unwrap();
        assert!((rate - 0.75).abs() < 1e-12);
        assert!(r.hit_rate("cache.delta.hits", "cache.delta.misses").is_none());
    }
}
