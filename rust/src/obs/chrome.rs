//! Chrome/Perfetto `trace_event` JSON export and validation.
//!
//! A run is rendered as one JSON object `{"traceEvents": [...]}` that
//! opens directly in `chrome://tracing` or <https://ui.perfetto.dev>:
//!
//! - **pid 0** — the *runtime* process: wall-clock spans and instants
//!   recorded by the [`Recorder`](super) facade (GA generations,
//!   parsim worker/merge phases, simulate calls), timestamps in real
//!   microseconds since the recorder epoch;
//! - **pid c+1** — chip `c` of the package: simulated-time spans,
//!   timestamps in *cycles rendered as microseconds* (the trace_event
//!   format has no unit field; 1 µs ≡ 1 cycle);
//!   - **tid = core id** — one lane per core, `'X'` span per CN;
//!   - **tid = 1000 + link id** — one lane per interconnect link,
//!     `'X'` span per transfer window the link was reserved for
//!     (comms and DRAM traffic).  Inter-chip links live on pid 0.
//!
//! Lanes are sound by construction: cores execute CNs serially
//! (`core_avail` is monotone) and `FcfsLink` reserves disjoint windows
//! per link, so every simulated lane holds disjoint-or-touching spans
//! — which is exactly what [`validate_trace`] checks (and what the CI
//! smoke job runs over real traces via `stream trace-check`).

use std::collections::BTreeMap;

use crate::arch::Accelerator;
use crate::scenario::ScenarioResult;
use crate::scheduler::{CommEvent, DramEvent, DramKind, ScheduleResult};
use crate::util::Json;

use super::TraceEvent;

/// Link lanes are offset so they never collide with core ids.
const LINK_TID_BASE: u64 = 1000;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn xev(name: String, cat: &str, ts: f64, dur: f64, pid: u64, tid: u64) -> Json {
    obj(vec![
        ("name", Json::Str(name)),
        ("cat", Json::Str(cat.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(ts)),
        ("dur", Json::Num(dur)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
    ])
}

fn meta(pid: u64, tid: Option<u64>, what: &str, name: &str) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(what.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("args", obj(vec![("name", Json::Str(name.to_string()))])),
    ];
    if let Some(t) = tid {
        pairs.push(("tid", Json::Num(t as f64)));
    }
    obj(pairs)
}

/// pid of the chip a core lives on (chip `c` renders as pid `c + 1`).
fn core_pid(arch: &Accelerator, core: usize) -> u64 {
    arch.topology.chip_of_core(crate::arch::CoreId(core)) as u64 + 1
}

/// pid of the chip a link lives on; inter-chip links render on pid 0.
fn link_pid(arch: &Accelerator, link: usize) -> u64 {
    arch.topology.chip_of_link(crate::arch::LinkId(link)).map(|c| c as u64 + 1).unwrap_or(0)
}

/// Process/thread naming metadata for every chip, core and link lane.
fn meta_events(arch: &Accelerator) -> Vec<Json> {
    let mut out = vec![meta(0, None, "process_name", "runtime")];
    for c in 0..arch.topology.n_chips() {
        out.push(meta(c as u64 + 1, None, "process_name", &format!("chip{c}")));
    }
    for core in &arch.cores {
        out.push(meta(
            core_pid(arch, core.id.0),
            Some(core.id.0 as u64),
            "thread_name",
            &core.name,
        ));
    }
    for (l, link) in arch.topology.links().iter().enumerate() {
        out.push(meta(
            link_pid(arch, l),
            Some(LINK_TID_BASE + l as u64),
            "thread_name",
            &link.name,
        ));
    }
    out
}

fn comm_events(arch: &Accelerator, comms: &[CommEvent], req: Option<&[usize]>, out: &mut Vec<Json>) {
    for (i, ev) in comms.iter().enumerate() {
        let name = match req.and_then(|r| r.get(i)) {
            Some(r) => format!("r{} comm {}B", r, ev.bytes),
            None => format!("comm {}B", ev.bytes),
        };
        for l in ev.links.iter() {
            out.push(xev(
                name.clone(),
                "comm",
                ev.start as f64,
                (ev.end - ev.start) as f64,
                link_pid(arch, l.0),
                LINK_TID_BASE + l.0 as u64,
            ));
        }
    }
}

fn dram_events(arch: &Accelerator, drams: &[DramEvent], req: Option<&[usize]>, out: &mut Vec<Json>) {
    for (i, ev) in drams.iter().enumerate() {
        let kind = match ev.kind {
            DramKind::WeightFetch => "wgt",
            DramKind::ActFetch => "act-in",
            DramKind::ActStore => "act-out",
        };
        let name = match req.and_then(|r| r.get(i)) {
            Some(r) => format!("r{} {} {}B", r, kind, ev.bytes),
            None => format!("{} {}B", kind, ev.bytes),
        };
        for l in ev.links.iter() {
            out.push(xev(
                name.clone(),
                "dram",
                ev.start as f64,
                (ev.end - ev.start) as f64,
                link_pid(arch, l.0),
                LINK_TID_BASE + l.0 as u64,
            ));
        }
    }
}

/// The recorder's wall-clock events as trace_event objects (pid 0).
pub fn runtime_events(events: &[TraceEvent]) -> Vec<Json> {
    events
        .iter()
        .map(|e| {
            let mut pairs = vec![
                ("name", Json::Str(e.name.clone())),
                ("cat", Json::Str(e.cat.to_string())),
                ("ph", Json::Str(e.ph.to_string())),
                ("ts", Json::Num(e.ts_us)),
                ("pid", Json::Num(e.pid as f64)),
                ("tid", Json::Num(e.tid as f64)),
            ];
            if e.ph == 'X' {
                pairs.push(("dur", Json::Num(e.dur_us)));
            }
            if e.ph == 'i' {
                pairs.push(("s", Json::Str("g".to_string())));
            }
            obj(pairs)
        })
        .collect()
}

fn wrap(events: Vec<Json>) -> String {
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(events));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(top).to_string_compact()
}

/// Render a one-shot schedule as Chrome trace JSON.  `runtime` is the
/// recorder's drained wall-clock event buffer
/// ([`take_events`](super::take_events)); pass `&[]` for a pure
/// simulated-time trace.
pub fn schedule_trace(res: &ScheduleResult, arch: &Accelerator, runtime: &[TraceEvent]) -> String {
    let mut events = meta_events(arch);
    for cn in &res.cns {
        events.push(xev(
            format!("cn{}", cn.cn.0),
            "cn",
            cn.start as f64,
            (cn.end - cn.start) as f64,
            core_pid(arch, cn.core.0),
            cn.core.0 as u64,
        ));
    }
    comm_events(arch, &res.comms, None, &mut events);
    dram_events(arch, &res.drams, None, &mut events);
    events.extend(runtime_events(runtime));
    wrap(events)
}

/// Render a multi-tenant scenario as Chrome trace JSON; CN and
/// transfer spans carry their request tag in the name.
pub fn scenario_trace(res: &ScenarioResult, arch: &Accelerator, runtime: &[TraceEvent]) -> String {
    let mut events = meta_events(arch);
    for cn in &res.cns {
        events.push(xev(
            format!("r{} cn{}", cn.request, cn.placed.cn.0),
            "cn",
            cn.placed.start as f64,
            (cn.placed.end - cn.placed.start) as f64,
            core_pid(arch, cn.placed.core.0),
            cn.placed.core.0 as u64,
        ));
    }
    comm_events(arch, &res.comms, Some(&res.comm_req), &mut events);
    dram_events(arch, &res.drams, Some(&res.dram_req), &mut events);
    events.extend(runtime_events(runtime));
    wrap(events)
}

/// Summary of a validated trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceSummary {
    /// Total trace events of any phase.
    pub events: usize,
    /// `'X'` complete spans.
    pub spans: usize,
    /// Distinct `(pid, tid)` lanes carrying at least one span.
    pub lanes: usize,
}

/// Float-rounding slack for wall-clock lanes (µs); simulated lanes
/// carry exact integers.
const EPS: f64 = 0.5;

/// Parse a Chrome trace and check its structure: `traceEvents` is
/// present, every `'X'` span carries numeric `ts`/`dur`/`pid`/`tid`
/// and a name, metadata events carry `args.name`, and the spans of
/// every `(pid, tid)` lane are disjoint or properly nested.  Used by
/// the golden-schema test and the `stream trace-check` CLI.
pub fn validate_trace(text: &str) -> Result<TraceSummary, String> {
    let json = Json::parse(text).map_err(|e| e.to_string())?;
    let events = json
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or("missing traceEvents array")?;
    let mut lanes: BTreeMap<(u64, u64), Vec<(f64, f64)>> = BTreeMap::new();
    let mut spans = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str())
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        match ph {
            "M" => {
                ev.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| format!("event {i}: metadata without args.name"))?;
            }
            "X" => {
                ev.get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| format!("event {i}: span without name"))?;
                let num = |k: &str| {
                    ev.get(k)
                        .and_then(|v| v.as_f64())
                        .ok_or_else(|| format!("event {i}: span without numeric {k}"))
                };
                let (ts, dur) = (num("ts")?, num("dur")?);
                if !(ts >= 0.0 && dur >= 0.0) {
                    return Err(format!("event {i}: negative ts/dur"));
                }
                let (pid, tid) = (num("pid")? as u64, num("tid")? as u64);
                lanes.entry((pid, tid)).or_default().push((ts, dur));
                spans += 1;
            }
            "i" | "I" | "C" => {
                ev.get("ts")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("event {i}: missing ts"))?;
            }
            other => return Err(format!("event {i}: unsupported phase {other:?}")),
        }
    }
    // span nesting per lane: sorted by (start asc, duration desc), a
    // span must either start after every open span ended, or end
    // within the innermost still-open one
    for ((pid, tid), lane) in lanes.iter_mut() {
        lane.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1)));
        let mut open: Vec<f64> = Vec::new(); // end times, outermost first
        for &(ts, dur) in lane.iter() {
            while matches!(open.last(), Some(&end) if end <= ts + EPS) {
                open.pop();
            }
            if let Some(&end) = open.last() {
                if ts + dur > end + EPS {
                    return Err(format!(
                        "lane pid {pid} tid {tid}: span [{ts}, {}) overlaps one ending at {end}",
                        ts + dur
                    ));
                }
            }
            open.push(ts + dur);
        }
    }
    Ok(TraceSummary { events: events.len(), spans, lanes: lanes.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span_json(ts: f64, dur: f64, tid: u64) -> String {
        format!(
            r#"{{"name":"s","ph":"X","ts":{ts},"dur":{dur},"pid":1,"tid":{tid}}}"#
        )
    }

    #[test]
    fn validator_accepts_disjoint_and_nested() {
        let t = format!(
            r#"{{"traceEvents":[{},{},{},{}]}}"#,
            span_json(0.0, 100.0, 1),
            span_json(10.0, 20.0, 1),  // nested
            span_json(100.0, 50.0, 1), // touching
            span_json(0.0, 10.0, 2),   // other lane
        );
        let s = validate_trace(&t).unwrap();
        assert_eq!(s.spans, 4);
        assert_eq!(s.lanes, 2);
    }

    #[test]
    fn validator_rejects_overlap() {
        let t = format!(
            r#"{{"traceEvents":[{},{}]}}"#,
            span_json(0.0, 100.0, 1),
            span_json(50.0, 100.0, 1), // straddles the first's end
        );
        let err = validate_trace(&t).unwrap_err();
        assert!(err.contains("overlaps"), "{err}");
    }

    #[test]
    fn validator_requires_schema_fields() {
        assert!(validate_trace("{}").is_err());
        assert!(validate_trace(r#"{"traceEvents":[{"ph":"X"}]}"#).is_err());
        assert!(validate_trace(r#"{"traceEvents":[{"ph":"M","pid":0}]}"#).is_err());
        assert!(validate_trace("not json").is_err());
        // empty trace is structurally fine
        let s = validate_trace(r#"{"traceEvents":[]}"#).unwrap();
        assert_eq!(s.events, 0);
    }

    #[test]
    fn runtime_events_render_phases() {
        let evs = vec![
            TraceEvent {
                name: "gen".into(),
                cat: "ga",
                ph: 'X',
                ts_us: 1.0,
                dur_us: 2.0,
                pid: 0,
                tid: 7,
            },
            TraceEvent {
                name: "mark".into(),
                cat: "sim",
                ph: 'i',
                ts_us: 3.0,
                dur_us: 0.0,
                pid: 0,
                tid: 0,
            },
        ];
        let rendered = runtime_events(&evs);
        let text = wrap(rendered);
        let s = validate_trace(&text).unwrap();
        assert_eq!(s.events, 2);
        assert_eq!(s.spans, 1);
    }
}
