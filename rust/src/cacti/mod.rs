//! CACTI-lite: analytic SRAM/DRAM access-energy and area model.
//!
//! The paper extracts all memory read/write costs with CACTI 7 [4].
//! CACTI itself is a large C++ tool we substitute with a closed-form fit
//! (DESIGN.md §Substitutions): access energy per bit grows with the
//! square root of capacity (bitline/wordline length), plus a constant
//! sense/periphery term.  The coefficients are calibrated to published
//! 28–32 nm CACTI datapoints (8 KB ≈ 0.05 pJ/bit, 64 KB ≈ 0.12 pJ/bit,
//! 1 MB ≈ 0.42 pJ/bit read) so the *relative* ordering the exploration
//! depends on — register < small SRAM < large SRAM << DRAM — is
//! preserved.

/// Read energy of an SRAM access, in pJ per access of `word_bits` bits.
pub fn sram_read_pj(capacity_bytes: u64, word_bits: u64) -> f64 {
    word_bits as f64 * e_bit_read(capacity_bytes)
}

/// Write energy (slightly above read: bitline full-swing).
pub fn sram_write_pj(capacity_bytes: u64, word_bits: u64) -> f64 {
    1.2 * sram_read_pj(capacity_bytes, word_bits)
}

/// pJ/bit for a read of an SRAM of the given capacity.
fn e_bit_read(capacity_bytes: u64) -> f64 {
    let kb = (capacity_bytes as f64 / 1024.0).max(0.125);
    0.012 + 0.013 * kb.sqrt()
}

/// Off-chip DRAM energy in pJ/bit (LPDDR4-class interface+core).
pub const DRAM_PJ_PER_BIT: f64 = 3.7;

/// DRAM access energy for a burst of `bits`.
pub fn dram_pj(bits: u64) -> f64 {
    bits as f64 * DRAM_PJ_PER_BIT
}

/// Inter-core bus energy in pJ/bit (on-chip long wires + arbitration).
pub const BUS_PJ_PER_BIT: f64 = 0.15;

/// Per-hop NoC link energy in pJ/bit (one short link + one router
/// crossing — shorter wires than the chip-spanning shared bus, so a
/// mesh/ring hop costs a fraction of `BUS_PJ_PER_BIT`; multi-hop routes
/// pay once per hop).
pub const NOC_HOP_PJ_PER_BIT: f64 = 0.06;

/// Inter-chip (die-to-die) link energy in pJ/bit: SerDes lanes or a
/// silicon-interposer channel — an order of magnitude above an on-chip
/// NoC hop, still well below going all the way out to DRAM.
pub const SERDES_PJ_PER_BIT: f64 = 0.8;

/// Digital MAC energy at 8-bit precision, pJ (28 nm class).
pub const MAC_PJ_DIGITAL_8B: f64 = 0.1;

/// Analog in-memory-compute MAC energy, pJ (capacitor-based AiMC).
pub const MAC_PJ_AIMC: f64 = 0.008;

/// SIMD-core vector op energy, pJ per element op.
pub const SIMD_OP_PJ: f64 = 0.05;

/// SRAM macro area in mm² (28 nm, ~0.3 mm²/Mb + periphery).
pub fn sram_area_mm2(capacity_bytes: u64) -> f64 {
    let mb = capacity_bytes as f64 * 8.0 / 1e6;
    0.05 + 0.3 * mb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_grows_with_capacity() {
        let small = sram_read_pj(8 * 1024, 8);
        let big = sram_read_pj(1024 * 1024, 8);
        assert!(big > 2.0 * small, "{small} vs {big}");
    }

    #[test]
    fn calibration_points() {
        // ~0.05 pJ/bit at 8 KB, ~0.12 at 64 KB, ~0.42 at 1 MB
        assert!((sram_read_pj(8 * 1024, 1) - 0.049).abs() < 0.02);
        assert!((sram_read_pj(64 * 1024, 1) - 0.116).abs() < 0.03);
        assert!((sram_read_pj(1024 * 1024, 1) - 0.428).abs() < 0.08);
    }

    #[test]
    fn dram_dominates_sram() {
        // the fusion advantage hinges on DRAM >> on-chip SRAM energy
        assert!(DRAM_PJ_PER_BIT > 5.0 * sram_read_pj(256 * 1024, 1));
    }

    #[test]
    fn write_above_read() {
        assert!(sram_write_pj(64 * 1024, 64) > sram_read_pj(64 * 1024, 64));
    }

    #[test]
    fn area_scales() {
        assert!(sram_area_mm2(1024 * 1024) > sram_area_mm2(64 * 1024));
    }
}
