//! PJRT CPU client wrapper: compile HLO-text artifacts, cache
//! executables, run them with host [`Tensor`]s.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::artifacts::{Manifest, Tensor};

/// The runtime: one PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU runtime over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Execute artifact `name` with the given inputs; returns the single
    /// output tensor (artifacts are lowered with `return_tuple=True` and
    /// exactly one result).
    pub fn execute(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        self.load(name)?;
        let meta = &self.manifest.artifacts[name];
        if meta.inputs.len() != inputs.len() {
            bail!("{name}: expected {} inputs, got {}", meta.inputs.len(), inputs.len());
        }
        for (i, (t, expect)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if &t.shape != expect {
                bail!("{name}: input {i} shape {:?} != manifest {:?}", t.shape, expect);
            }
        }

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .context("reshaping input literal")
            })
            .collect::<Result<_>>()?;

        let exe = &self.cache[name];
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        let values = out.to_vec::<f32>().context("reading f32 result")?;

        let shape = meta.output.clone();
        let t = Tensor::new(shape, values)?;
        Ok(t)
    }
}
