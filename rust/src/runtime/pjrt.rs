//! PJRT CPU client wrapper: compile HLO-text artifacts, cache
//! executables, run them with host [`Tensor`]s.
//!
//! The real XLA/PJRT bindings are only available when the crate is
//! built with the `pjrt` cargo feature **and** the `xla` crate has been
//! vendored into the workspace (the offline build environment has no
//! registry access).  Without the feature, the in-tree `stub` module
//! below stands in: every API type-checks identically, and
//! [`Runtime::new`] returns a descriptive error at *runtime* instead —
//! so the scheduler/allocator pipeline, which never touches PJRT, is
//! unaffected.

use std::collections::HashMap;

use crate::bail;
use crate::util::error::{Context, Result};

use super::artifacts::{Manifest, Tensor};

/// Offline stand-in for the `xla` crate (see module docs).  Compiled
/// only when the `pjrt` feature is off; with the feature on, the same
/// paths resolve to the real vendored `xla` crate.
#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::util::error::Error;

    fn unavailable() -> Error {
        Error::msg(
            "PJRT execution requires building with `--features pjrt` \
             and a vendored `xla` crate (see README.md § Numerical execution)",
        )
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            Err(unavailable())
        }

        pub fn platform_name(&self) -> String {
            "pjrt-stub".into()
        }

        pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            Err(unavailable())
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            Err(unavailable())
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            Err(unavailable())
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            Err(unavailable())
        }
    }

    pub struct Literal;

    impl Literal {
        pub fn vec1(_data: &[f32]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            Err(unavailable())
        }

        pub fn to_tuple1(&self) -> Result<Literal, Error> {
            Err(unavailable())
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            Err(unavailable())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
use stub as xla;

// With the feature on, the `xla::` paths above must resolve to the real
// bindings.  Until the crate is vendored this guard turns the otherwise
// cryptic unresolved-module errors into one actionable diagnostic;
// delete it together with adding `xla` to [dependencies].
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the vendored `xla` crate: add it to [dependencies] \
     in Cargo.toml and remove this guard (see README.md § Numerical execution)"
);

/// The runtime: one PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU runtime over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Execute artifact `name` with the given inputs; returns the single
    /// output tensor (artifacts are lowered with `return_tuple=True` and
    /// exactly one result).
    pub fn execute(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        self.load(name)?;
        let meta = &self.manifest.artifacts[name];
        if meta.inputs.len() != inputs.len() {
            bail!("{name}: expected {} inputs, got {}", meta.inputs.len(), inputs.len());
        }
        for (i, (t, expect)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if &t.shape != expect {
                bail!("{name}: input {i} shape {:?} != manifest {:?}", t.shape, expect);
            }
        }

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .context("reshaping input literal")
            })
            .collect::<Result<_>>()?;

        let exe = &self.cache[name];
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        let values = out.to_vec::<f32>().context("reading f32 result")?;

        let shape = meta.output.clone();
        let t = Tensor::new(shape, values)?;
        Ok(t)
    }
}
