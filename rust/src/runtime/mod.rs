//! Runtime: load AOT-compiled XLA artifacts and execute schedules
//! numerically on the PJRT CPU client.
//!
//! The Python side (`python/compile/aot.py`) lowers every CN tile
//! function and every full-layer function of the ResNet-18 first
//! segment to HLO text, once, at build time (`make artifacts`).  This
//! module is the *only* consumer: [`pjrt::Runtime`] compiles the text
//! through `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` and caches the executables; [`executor`] then
//! runs either the layer-by-layer baseline or a layer-fused schedule CN
//! by CN — slicing input tiles with exactly the halo/padding geometry
//! the manifest describes — and verifies both against the Python
//! oracle dump.  Python is never on this path.
//!
//! The XLA bindings are optional: built without the `pjrt` cargo
//! feature (the offline default), [`pjrt`] compiles against an in-tree
//! stub whose client constructor returns a descriptive error, and the
//! integration tests self-skip when no artifacts are present.

pub mod artifacts;
pub mod executor;
pub mod pjrt;

pub use artifacts::{Manifest, SegmentLayerSpec, Tensor};
pub use executor::SegmentExecutor;
pub use pjrt::Runtime;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
