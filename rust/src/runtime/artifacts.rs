//! Artifact manifest parsing and the host-side [`Tensor`] type.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::bail;

use crate::util::Json;

/// `manifest.json` — written by `python/compile/aot.py`.
#[derive(Debug)]
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactMeta>,
    pub segment: SegmentSpec,
    pub weights: HashMap<String, WeightMeta>,
    pub dir: PathBuf,
}

#[derive(Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub output: Vec<usize>,
}

#[derive(Debug)]
pub struct WeightMeta {
    pub file: String,
    pub shape: Vec<usize>,
}

/// Segment geometry mirrored from `python/compile/model.py`.
#[derive(Debug)]
pub struct SegmentSpec {
    pub in_shape: Vec<usize>,
    pub rows_per_cn: usize,
    pub layers: Vec<SegmentLayerSpec>,
}

#[derive(Debug, Clone)]
pub struct SegmentLayerSpec {
    pub name: String,
    pub kind: String, // conv | pool | add
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub fy: usize,
    pub fx: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
    pub residual_of: i64,
    pub artifact: String,
    pub layer_artifact: String,
    pub n_cns: usize,
    pub tile_in_shape: Vec<usize>,
    pub tile_out_shape: Vec<usize>,
    pub tile_in_rows: usize,
}

impl SegmentLayerSpec {
    /// First input row a CN needs (may be negative -> padded).
    pub fn cn_input_row_start(&self, cn_idx: usize, rows_per_cn: usize) -> i64 {
        if self.kind == "add" {
            (cn_idx * rows_per_cn) as i64
        } else {
            (cn_idx * rows_per_cn * self.stride) as i64 - self.pad as i64
        }
    }
}

fn jstr(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .and_then(|v| v.as_str())
        .with_context(|| format!("manifest: missing string {key}"))?
        .to_string())
}

fn jusize(j: &Json, key: &str) -> Result<usize> {
    j.get(key).and_then(|v| v.as_usize()).with_context(|| format!("manifest: missing number {key}"))
}

fn jshape(j: &Json, key: &str) -> Result<Vec<usize>> {
    j.get(key)
        .and_then(|v| v.as_usize_vec())
        .with_context(|| format!("manifest: missing shape {key}"))
}

impl Manifest {
    /// Load `manifest.json` from the artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| crate::anyhow!("{e}"))?;

        let mut artifacts = HashMap::new();
        for (name, meta) in j.get("artifacts").and_then(|v| v.as_obj()).context("artifacts")? {
            let inputs = meta
                .get("inputs")
                .and_then(|v| v.as_arr())
                .context("inputs")?
                .iter()
                .map(|s| s.as_usize_vec().context("input shape"))
                .collect::<Result<_>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta { file: jstr(meta, "file")?, inputs, output: jshape(meta, "output")? },
            );
        }

        let mut weights = HashMap::new();
        for (name, meta) in j.get("weights").and_then(|v| v.as_obj()).context("weights")? {
            weights.insert(
                name.clone(),
                WeightMeta { file: jstr(meta, "file")?, shape: jshape(meta, "shape")? },
            );
        }

        let seg = j.get("segment").context("segment")?;
        let layers = seg
            .get("layers")
            .and_then(|v| v.as_arr())
            .context("segment.layers")?
            .iter()
            .map(|l| {
                Ok(SegmentLayerSpec {
                    name: jstr(l, "name")?,
                    kind: jstr(l, "kind")?,
                    in_shape: jshape(l, "in_shape")?,
                    out_shape: jshape(l, "out_shape")?,
                    fy: l.get("fy").and_then(|v| v.as_usize()).unwrap_or(0),
                    fx: l.get("fx").and_then(|v| v.as_usize()).unwrap_or(0),
                    stride: jusize(l, "stride")?,
                    pad: l.get("pad").and_then(|v| v.as_usize()).unwrap_or(0),
                    relu: l.get("relu").and_then(|v| v.as_bool()).unwrap_or(false),
                    residual_of: l.get("residual_of").and_then(|v| v.as_i64()).unwrap_or(-1),
                    artifact: jstr(l, "artifact")?,
                    layer_artifact: jstr(l, "layer_artifact")?,
                    n_cns: jusize(l, "n_cns")?,
                    tile_in_shape: jshape(l, "tile_in_shape")?,
                    tile_out_shape: jshape(l, "tile_out_shape")?,
                    tile_in_rows: jusize(l, "tile_in_rows")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let segment = SegmentSpec {
            in_shape: jshape(seg, "in_shape")?,
            rows_per_cn: jusize(seg, "rows_per_cn")?,
            layers,
        };

        Ok(Manifest { artifacts, segment, weights, dir })
    }

    /// Absolute path of an artifact's HLO text.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let meta =
            self.artifacts.get(name).with_context(|| format!("unknown artifact {name}"))?;
        Ok(self.dir.join(&meta.file))
    }

    /// Load a raw-f32 weight dump as a [`Tensor`].
    pub fn load_weight(&self, name: &str) -> Result<Tensor> {
        let meta =
            self.weights.get(name).with_context(|| format!("unknown weight {name}"))?;
        let bytes = std::fs::read(self.dir.join(&meta.file))?;
        if bytes.len() % 4 != 0 {
            bail!("{name}: byte count {} not a multiple of 4", bytes.len());
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let t = Tensor::new(meta.shape.clone(), data)?;
        Ok(t)
    }
}

/// A host-side dense f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} needs {n} elems, got {}", data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// CHW accessor (3-D tensors).
    pub fn at3(&self, c: usize, y: usize, x: usize) -> f32 {
        let (_, h, w) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(c * h + y) * w + x]
    }

    /// Slice `rows` input rows starting at (possibly negative) `row0`
    /// out of a CHW tensor, padding out-of-range rows and `pad_w`
    /// columns on each side with `pad_value` — the Rust mirror of the
    /// tile slicer validated in `python/tests/test_model.py`.
    pub fn slice_rows_padded(
        &self,
        row0: i64,
        rows: usize,
        pad_w: usize,
        pad_value: f32,
    ) -> Tensor {
        let (c, h, w) = (self.shape[0], self.shape[1], self.shape[2]);
        let ow = w + 2 * pad_w;
        let mut out = vec![pad_value; c * rows * ow];
        for ci in 0..c {
            for r in 0..rows {
                let src = row0 + r as i64;
                if src < 0 || src >= h as i64 {
                    continue;
                }
                let src_off = (ci * h + src as usize) * w;
                let dst_off = (ci * rows + r) * ow + pad_w;
                out[dst_off..dst_off + w]
                    .copy_from_slice(&self.data[src_off..src_off + w]);
            }
        }
        Tensor { shape: vec![c, rows, ow], data: out }
    }

    /// Write `tile` (C, rows, W) into rows `[row0, row0+rows)` of self.
    pub fn write_rows(&mut self, row0: usize, tile: &Tensor) {
        let (c, h, w) = (self.shape[0], self.shape[1], self.shape[2]);
        let rows = tile.shape[1];
        assert_eq!(tile.shape[0], c);
        assert_eq!(tile.shape[2], w);
        assert!(row0 + rows <= h);
        for ci in 0..c {
            for r in 0..rows {
                let dst = (ci * h + row0 + r) * w;
                let src = (ci * rows + r) * w;
                self.data[dst..dst + w].copy_from_slice(&tile.data[src..src + w]);
            }
        }
    }

    /// Slice rows `[row0, row0+rows)` without padding (for add tiles).
    pub fn slice_rows(&self, row0: usize, rows: usize) -> Tensor {
        self.slice_rows_padded(row0 as i64, rows, 0, 0.0)
    }

    /// Max absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq_tensor(c: usize, h: usize, w: usize) -> Tensor {
        let data = (0..c * h * w).map(|i| i as f32).collect();
        Tensor::new(vec![c, h, w], data).unwrap()
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(vec![2, 2], vec![1.0; 5]).is_err());
    }

    #[test]
    fn slice_interior() {
        let t = seq_tensor(1, 6, 4);
        let s = t.slice_rows_padded(2, 2, 0, 0.0);
        assert_eq!(s.shape, vec![1, 2, 4]);
        assert_eq!(s.data, vec![8.0, 9.0, 10.0, 11.0, 12.0, 13.0, 14.0, 15.0]);
    }

    #[test]
    fn slice_with_negative_start_pads() {
        let t = seq_tensor(1, 4, 2);
        let s = t.slice_rows_padded(-1, 3, 1, -5.0);
        assert_eq!(s.shape, vec![1, 3, 4]);
        // first row fully padded
        assert_eq!(&s.data[0..4], &[-5.0, -5.0, -5.0, -5.0]);
        // second row = source row 0 with width pad
        assert_eq!(&s.data[4..8], &[-5.0, 0.0, 1.0, -5.0]);
    }

    #[test]
    fn slice_past_end_pads() {
        let t = seq_tensor(1, 3, 2);
        let s = t.slice_rows_padded(2, 3, 0, 9.0);
        assert_eq!(&s.data[2..6], &[9.0, 9.0, 9.0, 9.0]);
    }

    #[test]
    fn write_then_read_rows_roundtrip() {
        let mut t = Tensor::zeros(vec![2, 6, 3]);
        let tile = seq_tensor(2, 2, 3);
        t.write_rows(2, &tile);
        let back = t.slice_rows(2, 2);
        assert_eq!(back, tile);
    }

    #[test]
    fn at3_indexing() {
        let t = seq_tensor(2, 3, 4);
        assert_eq!(t.at3(1, 2, 3), (1 * 3 * 4 + 2 * 4 + 3) as f32);
    }

    #[test]
    fn max_abs_diff_zero_for_self() {
        let t = seq_tensor(1, 2, 2);
        assert_eq!(t.max_abs_diff(&t), 0.0);
    }
}
