//! Segment executor: run the ResNet-18 first segment layer-by-layer or
//! layer-fused (CN by CN, in an arbitrary dependency-respecting order)
//! on the PJRT runtime, and verify against the Python oracle.

use crate::util::error::{Context, Result};
use crate::bail;

use super::artifacts::Tensor;
use super::pjrt::Runtime;

/// Executes the AOT-compiled segment.
///
/// Holds the weights loaded from the artifact dumps; layer inputs /
/// outputs are threaded through [`Tensor`]s on the host, mirroring the
/// shared-memory data movement the L3 scheduler models.
pub struct SegmentExecutor {
    weights: Vec<Option<(Tensor, Tensor)>>, // per layer: (w, b)
    pub input: Tensor,
    pub oracle: Tensor,
}

impl SegmentExecutor {
    pub fn new(rt: &Runtime) -> Result<SegmentExecutor> {
        let m = &rt.manifest;
        let n_layers = m.segment.layers.len();
        let mut weights = vec![None; n_layers];
        // conv layers: 0 -> (w0,b0), 2 -> (w2,b2), 3 -> (w3,b3)
        weights[0] = Some((m.load_weight("w0")?, m.load_weight("b0")?));
        weights[2] = Some((m.load_weight("w2")?, m.load_weight("b2")?));
        weights[3] = Some((m.load_weight("w3")?, m.load_weight("b3")?));
        let input = m.load_weight("input")?;
        let oracle = m.load_weight("oracle_output")?;
        Ok(SegmentExecutor { weights, input, oracle })
    }

    /// Activation buffer chain for the segment: `acts[l]` is the output
    /// of layer `l-1` (`acts[0]` = network input).
    fn layer_input_index(&self, layer_idx: usize) -> usize {
        layer_idx
    }

    /// Layer-by-layer baseline: one artifact call per layer.
    pub fn run_layer_by_layer(&self, rt: &mut Runtime) -> Result<Tensor> {
        let specs: Vec<super::artifacts::SegmentLayerSpec> =
            rt.manifest.segment.layers.clone();
        let mut acts: Vec<Tensor> = Vec::with_capacity(specs.len() + 1);
        acts.push(self.input.clone());
        for (li, spec) in specs.iter().enumerate() {
            let name = spec.layer_artifact.clone();
            let out = match spec.kind.as_str() {
                "conv" => {
                    let (w, b) = self.weights[li].as_ref().context("conv weights")?;
                    let x = &acts[self.layer_input_index(li)];
                    rt.execute(&name, &[x, w, b])?
                }
                "pool" => {
                    let x = &acts[self.layer_input_index(li)];
                    rt.execute(&name, &[x])?
                }
                "add" => {
                    let a = &acts[li]; // previous layer output
                    let res = &acts[(spec.residual_of + 1) as usize];
                    rt.execute(&name, &[a, res])?
                }
                k => bail!("unknown layer kind {k}"),
            };
            acts.push(out);
        }
        Ok(acts.pop().unwrap())
    }

    /// Layer-fused execution: run CNs in `order` (pairs of layer index,
    /// CN index), slicing input tiles with the manifest geometry.  The
    /// order must respect data dependencies (produced rows available
    /// before a consumer tile needs them); this is checked and a
    /// violation is an error — which is precisely what makes executing a
    /// Stream schedule a real validation of the scheduler.
    pub fn run_fused(&self, rt: &mut Runtime, order: &[(usize, usize)]) -> Result<Tensor> {
        let rows_per_cn = rt.manifest.segment.rows_per_cn;
        let n_layers = rt.manifest.segment.layers.len();

        // output buffer + per-layer count of contiguously completed rows
        let out_shapes: Vec<Vec<usize>> = rt
            .manifest
            .segment
            .layers
            .iter()
            .map(|l| l.out_shape.clone())
            .collect();
        let mut outs: Vec<Tensor> = out_shapes.into_iter().map(Tensor::zeros).collect();
        let mut done_rows = vec![0usize; n_layers];

        let expected: usize =
            rt.manifest.segment.layers.iter().map(|l| l.n_cns).sum();
        if order.len() != expected {
            bail!("order has {} CNs, segment needs {expected}", order.len());
        }

        for &(li, ci) in order {
            let spec = rt.manifest.segment.layers[li].clone();
            let spec = &spec;
            let row0_out = ci * rows_per_cn;
            // intra-layer ordering: CNs of a layer run in index order
            if row0_out != done_rows[li] {
                bail!("layer {li} CN {ci} out of order (done rows {})", done_rows[li]);
            }

            // check + gather the input tile
            let in_start = spec.cn_input_row_start(ci, rows_per_cn);
            let in_rows = spec.tile_in_rows;
            let needed_hi = (in_start + in_rows as i64).min(spec.in_shape[1] as i64);

            let (out_tile, name) = match spec.kind.as_str() {
                "conv" => {
                    let src: &Tensor =
                        if li == 0 { &self.input } else { &outs[li - 1] };
                    if li > 0 && (done_rows[li - 1] as i64) < needed_hi {
                        bail!("layer {li} CN {ci}: producer rows not ready");
                    }
                    let tile = src.slice_rows_padded(in_start, in_rows, spec.pad, 0.0);
                    let (w, b) = self.weights[li].as_ref().context("weights")?;
                    (rt.execute(&spec.artifact, &[&tile, w, b])?, &spec.artifact)
                }
                "pool" => {
                    let src = &outs[li - 1];
                    if (done_rows[li - 1] as i64) < needed_hi {
                        bail!("layer {li} CN {ci}: producer rows not ready");
                    }
                    // post-ReLU activations are >= 0, so 0-padding is an
                    // exact stand-in for -inf pool padding
                    let tile = src.slice_rows_padded(in_start, in_rows, spec.pad, 0.0);
                    (rt.execute(&spec.artifact, &[&tile])?, &spec.artifact)
                }
                "add" => {
                    let a_src = &outs[li - 1];
                    let r_li = spec.residual_of as usize;
                    let r_src = &outs[r_li];
                    let need = row0_out + rows_per_cn;
                    if done_rows[li - 1] < need || done_rows[r_li] < need {
                        bail!("layer {li} CN {ci}: addend rows not ready");
                    }
                    let a = a_src.slice_rows(row0_out, rows_per_cn);
                    let r = r_src.slice_rows(row0_out, rows_per_cn);
                    (rt.execute(&spec.artifact, &[&a, &r])?, &spec.artifact)
                }
                k => bail!("unknown layer kind {k}"),
            };
            let _ = name;
            outs[li].write_rows(row0_out, &out_tile);
            done_rows[li] += rows_per_cn;
        }

        for (li, spec) in rt.manifest.segment.layers.iter().enumerate() {
            if done_rows[li] != spec.out_shape[1] {
                bail!("layer {li} incomplete: {} of {} rows", done_rows[li], spec.out_shape[1]);
            }
        }
        Ok(outs.pop().unwrap())
    }

    /// Depth-first reference order: for each output row-block, run every
    /// layer's CN as soon as its inputs exist (a valid fused order used
    /// by tests; Stream schedules provide the interesting orders).
    pub fn depth_first_order(&self, rt: &Runtime) -> Vec<(usize, usize)> {
        let specs = &rt.manifest.segment.layers;
        let rows = rt.manifest.segment.rows_per_cn;
        let mut done = vec![0usize; specs.len()];
        let mut order = Vec::new();
        let total: usize = specs.iter().map(|s| s.n_cns).sum();
        while order.len() < total {
            let mut progressed = false;
            for li in 0..specs.len() {
                let spec = &specs[li];
                while done[li] < spec.n_cns {
                    let ci = done[li];
                    let in_start = spec.cn_input_row_start(ci, rows);
                    let hi = (in_start + spec.tile_in_rows as i64)
                        .min(spec.in_shape[1] as i64);
                    let ready = match spec.kind.as_str() {
                        "conv" if li == 0 => true,
                        "add" => {
                            let need = (ci + 1) * rows;
                            done[li - 1] * rows >= need
                                && done[spec.residual_of as usize] * rows >= need
                        }
                        _ => (done[li - 1] * rows) as i64 >= hi,
                    };
                    if !ready {
                        break;
                    }
                    order.push((li, ci));
                    done[li] += 1;
                    progressed = true;
                }
            }
            assert!(progressed, "depth-first order stuck");
        }
        order
    }

    /// Verify a result against the Python oracle dump.
    pub fn verify(&self, out: &Tensor, tol: f32) -> Result<f32> {
        if out.shape != self.oracle.shape {
            bail!("shape {:?} != oracle {:?}", out.shape, self.oracle.shape);
        }
        let diff = out.max_abs_diff(&self.oracle);
        if diff > tol {
            bail!("max |diff| {diff} exceeds tolerance {tol}");
        }
        Ok(diff)
    }
}
