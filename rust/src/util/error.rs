//! Minimal error-context type — the offline substitute for `anyhow`
//! (DESIGN.md §Substitutions).
//!
//! Mirrors the subset of the `anyhow` API this crate uses: an opaque
//! [`Error`] built from any message or `std::error::Error`, the
//! [`anyhow!`](crate::anyhow!) and [`bail!`](crate::bail!) macros, and
//! a [`Context`] extension trait for `Result` and `Option`.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error` itself — that is what makes the blanket
//! `From<E: std::error::Error>` conversion (and thus `?` on mixed error
//! types) possible without colliding with `impl From<T> for T`.
//!
//! # Examples
//!
//! ```
//! use stream::util::error::{Context, Result};
//!
//! fn parse(s: &str) -> Result<u32> {
//!     s.parse::<u32>().with_context(|| format!("bad number {s:?}"))
//! }
//!
//! assert!(parse("42").is_ok());
//! assert!(parse("nope").unwrap_err().to_string().contains("bad number"));
//! ```

use std::fmt;

/// Opaque string-backed error with a context chain.
pub struct Error {
    msg: String,
}

/// Crate-wide result alias (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (`"ctx: cause"`), like `anyhow`.
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (mirrors `anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_chains() {
        let e: Result<()> = Err(Error::msg("root"));
        let e = e.context("outer");
        assert_eq!(e.unwrap_err().to_string(), "outer: root");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn std_error_converts() {
        fn f() -> Result<u32> {
            let n = "x".parse::<u32>()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                crate::bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 7");
        let e = crate::anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
    }
}
