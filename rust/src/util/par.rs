//! Scoped-thread parallel map (offline substitute for rayon) used by
//! the GA fitness evaluation and the exploration sweep.
//!
//! The worker count is the **parallelism knob** of the whole crate:
//! every data-parallel loop funnels through [`parallel_map`] /
//! [`parallel_map_with`], and the default count comes from
//! [`thread_count`] — `STREAM_THREADS` in the environment when set,
//! otherwise `std::thread::available_parallelism()`.  Pass an explicit
//! count of 1 (e.g. `GaParams { threads: 1, .. }`) for a fully serial
//! run; results are bit-identical either way because each item's
//! computation is independent and deterministic and output order is
//! preserved.

/// Resolve a requested worker count: `requested` when nonzero, else the
/// `STREAM_THREADS` environment variable when set to a positive
/// integer, else `std::thread::available_parallelism()` (fallback 4).
///
/// # Examples
///
/// ```
/// assert_eq!(stream::util::thread_count(3), 3);
/// assert!(stream::util::thread_count(0) >= 1);
/// ```
pub fn thread_count(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var("STREAM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Worker count for the partition-parallel *simulation* core (distinct
/// from the GA-level `STREAM_THREADS` fan-out): the
/// `STREAM_SIM_THREADS` environment variable when set to a positive
/// integer, else 1 (sequential).  Deliberately opt-in — the parallel
/// core only pays off when a single co-schedule spans several chips,
/// and nesting it under an already-saturated GA worker pool would
/// oversubscribe the machine.
///
/// # Examples
///
/// ```
/// assert!(stream::util::sim_thread_count() >= 1);
/// ```
pub fn sim_thread_count() -> usize {
    if let Ok(v) = std::env::var("STREAM_SIM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    1
}

/// Map `f` over `items` on up to [`thread_count`]`(0)` worker threads,
/// preserving order.  Falls back to sequential for tiny inputs.
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    parallel_map_with(items, f, thread_count(0))
}

/// Same with an explicit worker count.
pub fn parallel_map_with<T, R, F>(items: Vec<T>, f: F, threads: usize) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n <= 1 || threads <= 1 {
        return items.into_iter().map(f).collect();
    }
    let threads = threads.min(n);

    // work-stealing by atomic index over a shared Vec<Option<T>>
    let slots: Vec<std::sync::Mutex<Option<T>>> =
        items.into_iter().map(|t| std::sync::Mutex::new(Some(t))).collect();
    let results: Vec<std::sync::Mutex<Option<R>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("each slot taken once");
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("all computed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v: Vec<usize> = (0..100).collect();
        let r = parallel_map(v, |x| x * 2);
        assert_eq!(r, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(parallel_map(Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(parallel_map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn explicit_thread_count() {
        let v: Vec<usize> = (0..37).collect();
        let r = parallel_map_with(v, |x| x + 1, 3);
        assert_eq!(r.len(), 37);
        assert_eq!(r[36], 37);
    }
}
