//! Scoped-thread parallel map (offline substitute for rayon) used by
//! the GA fitness evaluation and the exploration sweep.

/// Map `f` over `items` on up to `threads` worker threads, preserving
/// order.  Falls back to sequential for tiny inputs.
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    parallel_map_with(items, f, threads)
}

/// Same with an explicit worker count.
pub fn parallel_map_with<T, R, F>(items: Vec<T>, f: F, threads: usize) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n <= 1 || threads <= 1 {
        return items.into_iter().map(f).collect();
    }
    let threads = threads.min(n);

    // work-stealing by atomic index over a shared Vec<Option<T>>
    let slots: Vec<std::sync::Mutex<Option<T>>> =
        items.into_iter().map(|t| std::sync::Mutex::new(Some(t))).collect();
    let results: Vec<std::sync::Mutex<Option<R>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("each slot taken once");
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("all computed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v: Vec<usize> = (0..100).collect();
        let r = parallel_map(v, |x| x * 2);
        assert_eq!(r, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(parallel_map(Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(parallel_map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn explicit_thread_count() {
        let v: Vec<usize> = (0..37).collect();
        let r = parallel_map_with(v, |x| x + 1, 3);
        assert_eq!(r.len(), 37);
        assert_eq!(r[36], 37);
    }
}
