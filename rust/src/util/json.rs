//! Minimal JSON parser + writer (offline substitute for serde_json).
//!
//! Supports the full JSON value model; enough for `manifest.json`
//! parsing and schedule export.  Not performance-critical: the manifest
//! is read once at runtime startup.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Usize vector helper (shapes).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap().as_str(), Some("hi\n"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(0).unwrap().as_usize(), Some(1));
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("c"));
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parse_manifest_like() {
        let j = Json::parse(
            r#"{"artifacts": {"x": {"file": "x.hlo.txt", "inputs": [[3, 13, 118]], "output": [64, 4, 56]}}}"#,
        )
        .unwrap();
        let x = j.get("artifacts").unwrap().get("x").unwrap();
        assert_eq!(x.get("inputs").unwrap().idx(0).unwrap().as_usize_vec(), Some(vec![3, 13, 118]));
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"a":[1,2.5,"s"],"b":true,"c":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
    }
}
