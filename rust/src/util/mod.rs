//! Small shared utilities: deterministic RNG, JSON, parallel map,
//! scope timing — in-tree substitutes for crates unavailable in the
//! offline build environment (DESIGN.md §Substitutions).

pub mod bench;
pub mod error;
pub mod json;
pub mod par;

pub use bench::{bench, BenchStats};
pub use json::Json;
pub use par::{parallel_map, parallel_map_with, sim_thread_count, thread_count};

/// Deterministic xorshift64* RNG for tests/benches that must not depend
/// on the `rand` crate's version-specific streams.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> XorShift64 {
        XorShift64 { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform float in [0, 1).
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Simple wall-clock scope timer for the perf pass and bench harnesses.
pub struct ScopeTimer {
    start: std::time::Instant,
}

impl ScopeTimer {
    pub fn start() -> ScopeTimer {
        ScopeTimer { start: std::time::Instant::now() }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = XorShift64::new(9);
        let mut b = XorShift64::new(9);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = XorShift64::new(3);
        for _ in 0..100 {
            assert!(r.below(7) < 7);
            let u = r.unit();
            assert!((0.0..1.0).contains(&u));
        }
    }
}
