//! Minimal benchmarking harness (offline substitute for criterion):
//! warmup + N timed iterations, reporting min/median/mean.

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub min_ms: f64,
    pub median_ms: f64,
    pub mean_ms: f64,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} ms (median, n={}; min {:.3}, mean {:.3})",
            self.name, self.median_ms, self.iters, self.min_ms, self.mean_ms
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = std::time::Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        min_ms: min,
        median_ms: median,
        mean_ms: mean,
    }
}

/// Benchmark scale from the environment: `STREAM_BENCH_SCALE=paper` runs
/// the full paper-size configuration, anything else a reduced one.
pub fn paper_scale() -> bool {
    std::env::var("STREAM_BENCH_SCALE").map(|v| v == "paper").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_times() {
        let s = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_ms >= 0.0);
        assert!(s.median_ms >= s.min_ms);
        assert_eq!(s.iters, 5);
    }
}
