//! `stream` — CLI for the Stream DSE framework.
//!
//! ```text
//! stream list                                   # workloads & architectures
//! stream schedule -w resnet18 -a hetero --gantt # run pipeline, print Gantt
//! stream schedule -w resnet18 -a hetero@mesh    # same cores, 2-D-mesh NoC
//! stream explore  -w resnet18,fsrcnn -a sc-tpu,hetero@ring
//! stream validate                               # Table I reproduction
//! stream allocation                             # Fig. 12 reproduction
//! stream execute  [--artifacts DIR]             # run fused schedule on PJRT
//! ```
//!
//! Argument parsing is hand-rolled (the build environment has no clap).

use stream::util::error::Result;
use stream::{anyhow, bail};

use stream::allocator::GaParams;
use stream::arch::presets;
use stream::cn::CnGranularity;
use stream::cost::{fmt_bytes, fmt_cycles, fmt_energy};
use stream::experiments;
use stream::pipeline::{SchedulePriority, Stream, StreamOpts};
use stream::workload::models;

const USAGE: &str = "\
stream — DSE of layer-fused DNNs on heterogeneous multi-core accelerators

USAGE:
  stream list
  stream schedule -w <workload> -a <arch[@topology]> [--lines N] [--layer-by-layer]
                  [--priority latency|memory] [--population N]
                  [--generations N] [--gantt] [--json <path>]
  stream explore  [-w w1,w2,...] [-a a1,a2,...] [--population N] [--generations N]
  stream validate
  stream allocation [--population N] [--generations N]
  stream execute  [--artifacts <dir>]

Any architecture accepts an @topology suffix (bus|ring|mesh|crossbar)
selecting its interconnect, e.g. hetero@mesh or hom-tpu@ring.
";

/// Tiny flag parser: `--key value` / `--flag` / `-w value`.
struct Args {
    args: Vec<String>,
}

impl Args {
    fn new(args: Vec<String>) -> Args {
        Args { args }
    }

    fn opt(&self, names: &[&str]) -> Option<String> {
        for (i, a) in self.args.iter().enumerate() {
            if names.contains(&a.as_str()) {
                return self.args.get(i + 1).cloned();
            }
        }
        None
    }

    fn flag(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name)
    }

    fn usize_opt(&self, names: &[&str], default: usize) -> Result<usize> {
        match self.opt(names) {
            Some(v) => v.parse().map_err(|_| anyhow!("bad number for {names:?}: {v}")),
            None => Ok(default),
        }
    }
}

fn parse_priority(s: &str) -> Result<SchedulePriority> {
    match s {
        "latency" => Ok(SchedulePriority::Latency),
        "memory" => Ok(SchedulePriority::Memory),
        _ => bail!("priority must be latency|memory, got {s}"),
    }
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::new(argv);

    match cmd.as_str() {
        "list" => cmd_list(),
        "schedule" => cmd_schedule(&args),
        "explore" => cmd_explore(&args),
        "validate" => cmd_validate(),
        "allocation" => cmd_allocation(&args),
        "execute" => cmd_execute(&args),
        other => {
            print!("{USAGE}");
            bail!("unknown command {other}")
        }
    }
}

fn cmd_list() -> Result<()> {
    println!("workloads:");
    for w in models::WORKLOAD_NAMES {
        let g = models::by_name(w).unwrap();
        println!(
            "  {:<24} {:>3} layers {:>10.1} MMAC",
            w,
            g.len(),
            g.total_macs() as f64 / 1e6
        );
    }
    println!("architectures:");
    for a in presets::ARCH_NAMES {
        let arch = presets::by_name(a).unwrap();
        println!(
            "  {:<12} {:>2} cores {:>6} KB on-chip  {}",
            a,
            arch.cores.len(),
            arch.total_onchip_bytes() / 1024,
            arch.topology
        );
    }
    println!(
        "topologies (suffix any arch with @name): {}",
        presets::TOPOLOGY_NAMES.join(", ")
    );
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let workload =
        args.opt(&["-w", "--workload"]).ok_or_else(|| anyhow!("missing -w <workload>"))?;
    let arch = args.opt(&["-a", "--arch"]).ok_or_else(|| anyhow!("missing -a <arch>"))?;
    let w = models::by_name(&workload).ok_or_else(|| anyhow!("unknown workload {workload}"))?;
    let a = presets::by_name(&arch).ok_or_else(|| anyhow!("unknown arch {arch}"))?;

    let granularity = if args.flag("--layer-by-layer") {
        CnGranularity::LayerByLayer
    } else {
        CnGranularity::Lines(args.usize_opt(&["--lines"], 4)?)
    };
    let opts = StreamOpts {
        granularity,
        priority: parse_priority(
            &args.opt(&["-p", "--priority"]).unwrap_or_else(|| "latency".into()),
        )?,
        ga: GaParams {
            population: args.usize_opt(&["--population"], 32)?,
            generations: args.usize_opt(&["--generations"], 24)?,
            ..Default::default()
        },
        ..Default::default()
    };

    let t = stream::util::ScopeTimer::start();
    let s = Stream::new(w.clone(), a.clone(), opts);
    let r = s.run().map_err(|e| anyhow!("{e}"))?;
    let best = r.best_edp().ok_or_else(|| anyhow!("empty result"))?;
    println!(
        "{workload} on {arch}: {} CNs, {} edges, {:.1} ms runtime",
        r.n_cns,
        r.n_edges,
        t.elapsed_ms()
    );
    let m = &best.result.metrics;
    println!(
        "best EDP point: latency {} | energy {} | peak mem {} | EDP {:.3e}",
        fmt_cycles(m.latency_cc),
        fmt_energy(m.energy_pj),
        fmt_bytes(m.peak_mem_bytes),
        m.edp()
    );
    println!(
        "allocation: {:?}",
        best.allocation.iter().map(|c| c.0).collect::<Vec<_>>()
    );
    if args.flag("--gantt") {
        println!("{}", stream::viz::gantt(&best.result, &w, &a, 100));
    }
    if let Some(path) = args.opt(&["--json"]) {
        std::fs::write(&path, stream::viz::to_json(&best.result))?;
        println!("schedule written to {path}");
    }
    Ok(())
}

fn cmd_explore(args: &Args) -> Result<()> {
    let mut cfg = experiments::SweepConfig {
        ga: GaParams {
            population: args.usize_opt(&["--population"], 16)?,
            generations: args.usize_opt(&["--generations"], 10)?,
            ..Default::default()
        },
        ..Default::default()
    };
    if let Some(ws) = args.opt(&["-w", "--workloads"]) {
        cfg.workloads = ws.split(',').map(String::from).collect();
    }
    if let Some(as_) = args.opt(&["-a", "--archs"]) {
        cfg.archs = as_.split(',').map(String::from).collect();
    }
    for w in &cfg.workloads {
        if models::by_name(w).is_none() {
            bail!("unknown workload {w}");
        }
    }
    for a in &cfg.archs {
        if presets::by_name(a).is_none() {
            bail!("unknown arch {a}");
        }
    }
    let cells = experiments::exploration_sweep(&cfg);
    println!("{}", experiments::fig13::format_fig13(&cells));
    println!("{}", experiments::fig13::format_fig14(&cells));
    println!("{}", experiments::fig13::format_fig15(&cells));
    Ok(())
}

fn cmd_validate() -> Result<()> {
    let rows = experiments::table1();
    println!("{}", experiments::table1::format_table(&rows));
    Ok(())
}

fn cmd_allocation(args: &Args) -> Result<()> {
    let rows = experiments::fig12(GaParams {
        population: args.usize_opt(&["--population"], 16)?,
        generations: args.usize_opt(&["--generations"], 10)?,
        ..Default::default()
    });
    println!("{}", experiments::fig12::format_rows(&rows));
    Ok(())
}

fn cmd_execute(args: &Args) -> Result<()> {
    use stream::runtime::{Runtime, SegmentExecutor};
    let artifacts = args.opt(&["--artifacts"]).unwrap_or_else(|| "artifacts".into());
    let mut rt = Runtime::new(&artifacts)?;
    println!("PJRT platform: {}", rt.platform());
    let exec = SegmentExecutor::new(&rt)?;

    let t = stream::util::ScopeTimer::start();
    let lbl = exec.run_layer_by_layer(&mut rt)?;
    let d1 = exec.verify(&lbl, 1e-3)?;
    println!("layer-by-layer: max|diff| = {d1:.2e} vs oracle  ({:.1} ms)", t.elapsed_ms());

    let t = stream::util::ScopeTimer::start();
    let order = exec.depth_first_order(&rt);
    let fused = exec.run_fused(&mut rt, &order)?;
    let d2 = exec.verify(&fused, 1e-3)?;
    println!(
        "layer-fused ({} CNs): max|diff| = {d2:.2e} vs oracle  ({:.1} ms)",
        order.len(),
        t.elapsed_ms()
    );
    println!("fused == layer-by-layer == python oracle OK");
    Ok(())
}
