//! `stream` — CLI for the Stream DSE framework.
//!
//! ```text
//! stream list                                   # workloads & architectures
//! stream schedule -w resnet18 -a hetero --gantt # run pipeline, print Gantt
//! stream schedule -w resnet18 -a hetero@mesh    # same cores, 2-D-mesh NoC
//! stream scenario -a hetero_quad@mesh -s edge_mix   # multi-DNN serving
//! stream explore  -w resnet18,fsrcnn -a sc-tpu,hetero@ring
//! stream validate                               # Table I reproduction
//! stream allocation                             # Fig. 12 reproduction
//! stream execute  [--artifacts DIR]             # run fused schedule on PJRT
//! ```
//!
//! Argument parsing is hand-rolled (the build environment has no clap).

use stream::util::error::Result;
use stream::{anyhow, bail};

use stream::allocator::GaParams;
use stream::arch::presets;
use stream::cn::CnGranularity;
use stream::cost::{fmt_bytes, fmt_cycles, fmt_energy};
use stream::experiments;
use stream::pipeline::{SchedulePriority, Stream, StreamOpts};
use stream::workload::models;

const USAGE: &str = "\
stream — DSE of layer-fused DNNs on heterogeneous multi-core accelerators

USAGE:
  stream list
  stream schedule -w <workload> -a <arch[@topology]> [--lines N] [--layer-by-layer]
                  [--fuse-search] [--priority latency|memory] [--population N]
                  [--generations N] [--gantt] [--json <path>] [--report]
  stream scenario -a <arch[@topology]> -s <scenario> [--arbitration fifo|priority|edf]
                  [--optimize] [--population N] [--generations N] [--gantt] [--report]
                  [--duration CC] [--rate-scale F] [--seed N] [--windows N]
  stream explore  [-w w1,w2,...] [-a a1,a2,...] [--population N] [--generations N]
  stream validate
  stream allocation [--population N] [--generations N]
  stream execute  [--artifacts <dir>]
  stream trace-check <trace.json>

Any architecture accepts an @topology suffix (bus|ring|mesh|crossbar)
selecting its interconnect, e.g. hetero@mesh or hom-tpu@ring.
`stream schedule --fuse-search` co-searches per-edge fuse/cut decisions
alongside the core allocation (one fuse gene per workload edge; cut
edges materialize the producer before the consumer starts, fused edges
stream at --lines N granularity).  Without it, --lines /
--layer-by-layer fix one uniform granularity for the whole network.
`stream scenario` co-schedules a multi-DNN request stream (see
`stream list` for canned scenarios); --optimize runs the scenario-level
NSGA-II search over the (tenant, layer) -> core partitioning instead of
the default per-tenant GA.

Long traces: --duration CC extends every tenant's arrival pattern to
cover CC cycles and switches to the bounded-memory streaming engine
(requests are admitted lazily and retired as they complete; latency
percentiles and miss rates come from --windows N completion-time
windows, with the first 10% of the trace as warm-up).  --rate-scale F
compresses (>1) or stretches (<1) every inter-arrival gap; --seed N
seeds the per-tenant burst jitter.

Observability: STREAM_TRACE=1 enables the in-process flight recorder
(counters + spans); STREAM_TRACE=<path.json> additionally writes a
Chrome/Perfetto trace of the run there (open in https://ui.perfetto.dev).
--report enables the recorder and prints the per-run counter summary;
`stream trace-check` validates a written trace file.
";

/// Tiny flag parser: `--key value` / `--flag` / `-w value`.
struct Args {
    args: Vec<String>,
}

impl Args {
    fn new(args: Vec<String>) -> Args {
        Args { args }
    }

    fn opt(&self, names: &[&str]) -> Option<String> {
        for (i, a) in self.args.iter().enumerate() {
            if names.contains(&a.as_str()) {
                return self.args.get(i + 1).cloned();
            }
        }
        None
    }

    fn flag(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name)
    }

    fn usize_opt(&self, names: &[&str], default: usize) -> Result<usize> {
        match self.opt(names) {
            Some(v) => v.parse().map_err(|_| anyhow!("bad number for {names:?}: {v}")),
            None => Ok(default),
        }
    }

    fn u64_opt(&self, names: &[&str], default: u64) -> Result<u64> {
        match self.opt(names) {
            Some(v) => v.parse().map_err(|_| anyhow!("bad number for {names:?}: {v}")),
            None => Ok(default),
        }
    }

    fn f64_opt(&self, names: &[&str], default: f64) -> Result<f64> {
        match self.opt(names) {
            Some(v) => v.parse().map_err(|_| anyhow!("bad number for {names:?}: {v}")),
            None => Ok(default),
        }
    }
}

fn parse_priority(s: &str) -> Result<SchedulePriority> {
    match s {
        "latency" => Ok(SchedulePriority::Latency),
        "memory" => Ok(SchedulePriority::Memory),
        _ => bail!("priority must be latency|memory, got {s}"),
    }
}

fn main() -> Result<()> {
    stream::obs::init_from_env();
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::new(argv);
    if args.flag("--report") {
        stream::obs::set_enabled(true);
    }

    match cmd.as_str() {
        "list" => cmd_list(),
        "schedule" => cmd_schedule(&args),
        "scenario" => cmd_scenario(&args),
        "explore" => cmd_explore(&args),
        "validate" => cmd_validate(),
        "allocation" => cmd_allocation(&args),
        "execute" => cmd_execute(&args),
        "trace-check" => cmd_trace_check(&args),
        other => {
            print!("{USAGE}");
            bail!("unknown command {other}")
        }
    }
}

/// Write the Chrome trace collected over this process to the
/// `STREAM_TRACE=<path>` destination, if one was given.
fn write_trace(build: impl FnOnce(&[stream::obs::TraceEvent]) -> String) -> Result<()> {
    if let Some(path) = stream::obs::trace_path() {
        let events = stream::obs::take_events();
        std::fs::write(&path, build(&events))?;
        println!("chrome trace written to {path} (open in https://ui.perfetto.dev)");
    }
    Ok(())
}

fn cmd_trace_check(args: &Args) -> Result<()> {
    let path = args
        .args
        .first()
        .filter(|a| !a.starts_with('-'))
        .ok_or_else(|| anyhow!("usage: stream trace-check <trace.json>"))?;
    let text = std::fs::read_to_string(path)?;
    let s = stream::obs::chrome::validate_trace(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    println!(
        "{path}: OK — {} events ({} spans) across {} lanes",
        s.events, s.spans, s.lanes
    );
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("workloads:");
    for w in models::WORKLOAD_NAMES {
        let g = models::by_name(w).unwrap();
        println!(
            "  {:<24} {:>3} layers {:>10.1} MMAC",
            w,
            g.len(),
            g.total_macs() as f64 / 1e6
        );
    }
    println!("architectures:");
    for a in presets::ARCH_NAMES {
        let arch = presets::by_name(a).unwrap();
        println!(
            "  {:<12} {:>2} cores {:>6} KB on-chip  {}",
            a,
            arch.cores.len(),
            arch.total_onchip_bytes() / 1024,
            arch.topology
        );
    }
    println!(
        "topologies (suffix any arch with @name): {}",
        presets::TOPOLOGY_NAMES.join(", ")
    );
    println!("scenarios:");
    for s in stream::scenario::SCENARIO_NAMES {
        let sc = stream::scenario::by_name(s).unwrap();
        println!(
            "  {:<20} {:>2} tenants {:>3} requests",
            s,
            sc.tenants.len(),
            sc.n_requests()
        );
    }
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    use stream::scenario::{Arbitration, ScenarioGa, ScenarioSim, StreamingOpts};

    let arch_name =
        args.opt(&["-a", "--arch"]).ok_or_else(|| anyhow!("missing -a <arch>"))?;
    let scen_name =
        args.opt(&["-s", "--scenario"]).ok_or_else(|| anyhow!("missing -s <scenario>"))?;
    let arch = presets::by_name(&arch_name)
        .ok_or_else(|| anyhow!("unknown arch {arch_name}"))?;
    let mut scenario = stream::scenario::by_name(&scen_name)
        .ok_or_else(|| anyhow!("unknown scenario {scen_name}"))?;
    let arb_name = args.opt(&["--arbitration"]).unwrap_or_else(|| "edf".into());
    let arbitration = Arbitration::by_name(&arb_name)
        .ok_or_else(|| anyhow!("arbitration must be fifo|priority|edf, got {arb_name}"))?;
    let ga = GaParams {
        population: args.usize_opt(&["--population"], 8)?,
        generations: args.usize_opt(&["--generations"], 4)?,
        ..Default::default()
    };
    let seed = args.u64_opt(&["--seed"], 0)?;
    if seed != 0 {
        scenario = scenario.seed(seed);
    }
    let rate_scale = args.f64_opt(&["--rate-scale"], 1.0)?;
    if rate_scale <= 0.0 {
        bail!("--rate-scale must be positive, got {rate_scale}");
    }
    if rate_scale != 1.0 {
        scenario = scenario.scale_rate(rate_scale);
    }
    let duration = match args.opt(&["--duration"]) {
        Some(v) => {
            Some(v.parse::<u64>().map_err(|_| anyhow!("bad number for --duration: {v}"))?)
        }
        None => None,
    };
    if let Some(d) = duration {
        scenario = scenario.extend_to(d);
    }
    let n_windows = args.usize_opt(&["--windows"], 64)?.max(1);

    let t = stream::util::ScopeTimer::start();
    let sim = ScenarioSim::new(&scenario, &arch).map_err(|e| anyhow!("{e}"))?;
    let allocs = if args.flag("--optimize") {
        let mut sga = ScenarioGa::new(&sim, arbitration, ga);
        let front = sga.run();
        let best = front.first().ok_or_else(|| anyhow!("empty scenario front"))?;
        println!(
            "co-optimized partitioning: {} Pareto points, best (misses {}, p99 {}, energy {})",
            front.len(),
            best.misses,
            fmt_cycles(best.worst_p99_cc),
            fmt_energy(best.energy_pj),
        );
        best.allocations.clone()
    } else {
        stream::scenario::per_tenant_ga(&sim, ga)
    };
    let r = match duration {
        // long traces take the bounded-memory streaming engine
        Some(d) => {
            let opts = StreamingOpts {
                window_cc: (d / n_windows as u64).max(1),
                max_windows: n_windows,
                warmup_cc: d / 10,
                ..Default::default()
            };
            sim.runner().run_streamed(&allocs, arbitration, &opts)
        }
        None => sim.run(&allocs, arbitration),
    };

    let n_requests = match &r.streaming {
        Some(s) => s.retired as usize,
        None => r.outcomes.len(),
    };
    println!(
        "{scen_name} on {arch_name} [{arbitration}]: {n_requests} requests, makespan {}, {:.1} ms runtime",
        fmt_cycles(r.makespan_cc()),
        t.elapsed_ms()
    );
    println!(
        "energy {} | peak mem {} | dense-core util {:.0}%",
        fmt_energy(r.metrics.energy_pj),
        fmt_bytes(r.metrics.peak_mem_bytes),
        100.0 * r.metrics.avg_core_util
    );
    println!(
        "{:<14} {:>4} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "tenant", "req", "p50", "p99", "mean", "miss rate", "req/s"
    );
    for t in &r.tenants {
        println!(
            "{:<14} {:>4} {:>12} {:>12} {:>12} {:>9.0}% {:>10.1}",
            t.name,
            t.requests,
            fmt_cycles(t.p50_cc),
            fmt_cycles(t.p99_cc),
            fmt_cycles(t.mean_cc as u64),
            100.0 * t.miss_rate,
            t.throughput_rps,
        );
    }
    if let Some(s) = &r.streaming {
        println!(
            "streaming: admitted {} retired {} | live peak {} lanes (in-flight {}) | steady p99 {} | {:.1} req/s",
            s.admitted,
            s.retired,
            s.live_peak,
            s.inflight_peak,
            fmt_cycles(s.steady_p99_cc()),
            s.steady_throughput_rps(r.makespan_cc()),
        );
        let windows: Vec<_> = s.windows().collect();
        let tail = windows.len().saturating_sub(8);
        for w in &windows[tail..] {
            println!(
                "  window @{:<12} {:>6} done  p50 {:>10} p99 {:>10} miss {:>4.0}%  {:>8.1} req/s",
                fmt_cycles(w.start_cc),
                w.completed,
                fmt_cycles(w.hist.percentile_cc(50.0)),
                fmt_cycles(w.hist.percentile_cc(99.0)),
                100.0 * w.miss_rate(),
                w.throughput_rps(s.window_cc, s.clock_ghz),
            );
        }
    }
    for core in &arch.cores {
        println!("  {:<10} util {:>5.1}%", core.name, 100.0 * r.core_util(core.id));
    }
    let mut busiest: Vec<(usize, u64)> = r
        .link_stats
        .iter()
        .enumerate()
        .map(|(i, s)| (i, s.busy_cycles))
        .filter(|(_, b)| *b > 0)
        .collect();
    busiest.sort_by_key(|&(_, b)| std::cmp::Reverse(b));
    for (i, _) in busiest.iter().take(4) {
        println!(
            "  {:<10} util {:>5.1}%  {} moved",
            arch.topology.links()[*i].name,
            100.0 * r.link_util(*i),
            fmt_bytes(r.link_stats[*i].bytes_moved as f64),
        );
    }
    match r.fallback {
        None => println!("sim partitions: {} (chip-parallel)", r.partitions),
        Some(reason) => println!("sim partitions: {} (sequential: {reason})", r.partitions),
    }
    if let Some(rep) = &r.report {
        for (pair, label) in [
            (("cache.sched.hits", "cache.sched.misses"), "schedule cache"),
            (("cache.delta.hits", "cache.delta.misses"), "delta cache"),
        ] {
            if let Some(rate) = rep.hit_rate(pair.0, pair.1) {
                println!("{label} hit rate: {:.1}%", 100.0 * rate);
            }
        }
        if args.flag("--report") {
            print!("{rep}");
        }
    }
    if args.flag("--gantt") {
        println!("{}", stream::viz::scenario_gantt(&r, &arch, 100));
    }
    write_trace(|ev| stream::obs::chrome::scenario_trace(&r, &arch, ev))?;
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let workload =
        args.opt(&["-w", "--workload"]).ok_or_else(|| anyhow!("missing -w <workload>"))?;
    let arch = args.opt(&["-a", "--arch"]).ok_or_else(|| anyhow!("missing -a <arch>"))?;
    let w = models::by_name(&workload).ok_or_else(|| anyhow!("unknown workload {workload}"))?;
    let a = presets::by_name(&arch).ok_or_else(|| anyhow!("unknown arch {arch}"))?;

    let lines = args.usize_opt(&["--lines"], 4)?;
    let granularity = if args.flag("--layer-by-layer") {
        CnGranularity::LayerByLayer
    } else {
        CnGranularity::Lines(lines)
    };
    let fuse = args
        .flag("--fuse-search")
        .then(|| stream::pipeline::FuseSearchOpts { menu: vec![lines.max(1)] });
    let opts = StreamOpts {
        granularity,
        priority: parse_priority(
            &args.opt(&["-p", "--priority"]).unwrap_or_else(|| "latency".into()),
        )?,
        ga: GaParams {
            population: args.usize_opt(&["--population"], 32)?,
            generations: args.usize_opt(&["--generations"], 24)?,
            ..Default::default()
        },
        fuse,
        ..Default::default()
    };

    let t = stream::util::ScopeTimer::start();
    let s = Stream::new(w.clone(), a.clone(), opts);
    let r = s.run().map_err(|e| anyhow!("{e}"))?;
    let best = r.best_edp().ok_or_else(|| anyhow!("empty result"))?;
    println!(
        "{workload} on {arch}: {} CNs, {} edges, {:.1} ms runtime",
        r.n_cns,
        r.n_edges,
        t.elapsed_ms()
    );
    let m = &best.result.metrics;
    println!(
        "best EDP point: latency {} | energy {} | peak mem {} | EDP {:.3e}",
        fmt_cycles(m.latency_cc),
        fmt_energy(m.energy_pj),
        fmt_bytes(m.peak_mem_bytes),
        m.edp()
    );
    println!(
        "allocation: {:?}",
        best.allocation.iter().map(|c| c.0).collect::<Vec<_>>()
    );
    if let Some(f) = &best.fuse {
        println!(
            "fusion: {} fused edges, {} cut edges (pattern {:#018x})",
            f.n_fused, f.n_cut, f.pattern_fp
        );
    }
    if args.flag("--gantt") {
        println!("{}", stream::viz::gantt(&best.result, &w, &a, 100));
    }
    if let Some(path) = args.opt(&["--json"]) {
        std::fs::write(&path, stream::viz::to_json(&best.result))?;
        println!("schedule written to {path}");
    }
    if args.flag("--report") {
        if let Some(rep) = &best.result.report {
            print!("{rep}");
        }
    }
    write_trace(|ev| stream::obs::chrome::schedule_trace(&best.result, &a, ev))?;
    Ok(())
}

fn cmd_explore(args: &Args) -> Result<()> {
    let mut cfg = experiments::SweepConfig {
        ga: GaParams {
            population: args.usize_opt(&["--population"], 16)?,
            generations: args.usize_opt(&["--generations"], 10)?,
            ..Default::default()
        },
        ..Default::default()
    };
    if let Some(ws) = args.opt(&["-w", "--workloads"]) {
        cfg.workloads = ws.split(',').map(String::from).collect();
    }
    if let Some(as_) = args.opt(&["-a", "--archs"]) {
        cfg.archs = as_.split(',').map(String::from).collect();
    }
    for w in &cfg.workloads {
        if models::by_name(w).is_none() {
            bail!("unknown workload {w}");
        }
    }
    for a in &cfg.archs {
        if presets::by_name(a).is_none() {
            bail!("unknown arch {a}");
        }
    }
    let cells = experiments::exploration_sweep(&cfg);
    println!("{}", experiments::fig13::format_fig13(&cells));
    println!("{}", experiments::fig13::format_fig14(&cells));
    println!("{}", experiments::fig13::format_fig15(&cells));
    Ok(())
}

fn cmd_validate() -> Result<()> {
    let rows = experiments::table1();
    println!("{}", experiments::table1::format_table(&rows));
    Ok(())
}

fn cmd_allocation(args: &Args) -> Result<()> {
    let rows = experiments::fig12(GaParams {
        population: args.usize_opt(&["--population"], 16)?,
        generations: args.usize_opt(&["--generations"], 10)?,
        ..Default::default()
    });
    println!("{}", experiments::fig12::format_rows(&rows));
    Ok(())
}

fn cmd_execute(args: &Args) -> Result<()> {
    use stream::runtime::{Runtime, SegmentExecutor};
    let artifacts = args.opt(&["--artifacts"]).unwrap_or_else(|| "artifacts".into());
    let mut rt = Runtime::new(&artifacts)?;
    println!("PJRT platform: {}", rt.platform());
    let exec = SegmentExecutor::new(&rt)?;

    let t = stream::util::ScopeTimer::start();
    let lbl = exec.run_layer_by_layer(&mut rt)?;
    let d1 = exec.verify(&lbl, 1e-3)?;
    println!("layer-by-layer: max|diff| = {d1:.2e} vs oracle  ({:.1} ms)", t.elapsed_ms());

    let t = stream::util::ScopeTimer::start();
    let order = exec.depth_first_order(&rt);
    let fused = exec.run_fused(&mut rt, &order)?;
    let d2 = exec.verify(&fused, 1e-3)?;
    println!(
        "layer-fused ({} CNs): max|diff| = {d2:.2e} vs oracle  ({:.1} ms)",
        order.len(),
        t.elapsed_ms()
    );
    println!("fused == layer-by-layer == python oracle OK");
    Ok(())
}
