//! Multi-core accelerator architecture model (paper Fig. 2).
//!
//! An [`Accelerator`] is a set of [`Core`]s — dense dataflow PE arrays
//! and an auxiliary SIMD core — connected by an interconnect
//! [`Topology`]: a routed graph of bandwidth/energy links between the
//! cores and one or more off-chip DRAM ports ([`topology`]).  The
//! classic single-bus + single-DRAM-port model is the
//! [`Topology::shared_bus`] preset; ring, 2-D mesh and crossbar fabrics
//! open the chiplet-style region of the design space.
//! Each core carries its spatial [`Dataflow`] (the unrolled loop dims),
//! private activation/weight SRAMs and a local port bandwidth.
//!
//! [`presets`] defines the seven iso-area exploration architectures of
//! Fig. 11 and the three validation targets of Fig. 9, each with
//! `@ring` / `@mesh` / `@crossbar` chiplet variants.

pub mod presets;
pub mod topology;

pub use topology::{Link, LinkId, LinkKind, TopoKind, Topology};

use crate::cacti;
use crate::workload::Dim;

/// Identifier of a core within an accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CoreId(pub usize);

impl std::fmt::Display for CoreId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "core{}", self.0)
    }
}

/// A spatial dataflow: which loop dims the PE array unrolls, and by how
/// much.  E.g. the TPU-like core is `C 32 | K 32`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dataflow(pub Vec<(Dim, usize)>);

impl Dataflow {
    pub fn new(unrolls: &[(Dim, usize)]) -> Self {
        Dataflow(unrolls.to_vec())
    }

    /// Spatial unrolling factor of a dim (1 if not unrolled).
    pub fn unroll(&self, d: Dim) -> usize {
        self.0
            .iter()
            .filter(|(dd, _)| *dd == d)
            .map(|(_, u)| *u)
            .product::<usize>()
            .max(1)
    }

    /// Total PE count (product of all unrollings).
    pub fn pe_count(&self) -> usize {
        self.0.iter().map(|(_, u)| u).product::<usize>().max(1)
    }
}

impl std::fmt::Display for Dataflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> =
            self.0.iter().map(|(d, u)| format!("{d:?} {u}")).collect();
        write!(f, "{}", parts.join(" | "))
    }
}

/// The compute fabric of a core.
#[derive(Debug, Clone, PartialEq)]
pub enum CoreKind {
    /// Digital PE array with the given MAC energy (pJ/MAC).
    Digital { mac_pj: f64 },
    /// Analog in-memory-compute array: cheap MACs, weights live in the
    /// array itself and reloading them costs `weight_load_pj` per bit.
    /// `act_bits_per_cycle` models bit-serial DAC input application
    /// (Jia et al. apply 2 activation bits per cycle; DIANA's array
    /// takes the full word at once).
    Aimc { mac_pj: f64, weight_load_pj: f64, act_bits_per_cycle: usize },
    /// SIMD vector core for pool / elementwise layers.
    Simd { lanes: usize, op_pj: f64 },
}

/// One accelerator core (paper Fig. 2b).
#[derive(Debug, Clone)]
pub struct Core {
    pub id: CoreId,
    pub name: String,
    pub kind: CoreKind,
    /// Spatial dataflow of the PE array (empty for SIMD cores).
    pub dataflow: Dataflow,
    /// Private activation SRAM capacity in bytes.
    pub act_mem_bytes: u64,
    /// Private weight SRAM capacity in bytes (0 => streamed from DRAM).
    pub wgt_mem_bytes: u64,
    /// Local SRAM port bandwidth, bits per clock cycle.
    pub sram_bw_bits: u64,
}

impl Core {
    pub fn is_simd(&self) -> bool {
        matches!(self.kind, CoreKind::Simd { .. })
    }

    /// MAC / op energy of this fabric in pJ.
    pub fn mac_pj(&self) -> f64 {
        match self.kind {
            CoreKind::Digital { mac_pj } => mac_pj,
            CoreKind::Aimc { mac_pj, .. } => mac_pj,
            CoreKind::Simd { op_pj, .. } => op_pj,
        }
    }

    /// Parallel lanes: PE count for arrays, lane count for SIMD.
    pub fn parallelism(&self) -> usize {
        match self.kind {
            CoreKind::Simd { lanes, .. } => lanes,
            _ => self.dataflow.pe_count(),
        }
    }

    /// Activation SRAM access energies (pJ per `word_bits` access).
    pub fn act_read_pj(&self, word_bits: u64) -> f64 {
        cacti::sram_read_pj(self.act_mem_bytes.max(1024), word_bits)
    }

    pub fn act_write_pj(&self, word_bits: u64) -> f64 {
        cacti::sram_write_pj(self.act_mem_bytes.max(1024), word_bits)
    }

    pub fn wgt_read_pj(&self, word_bits: u64) -> f64 {
        cacti::sram_read_pj(self.wgt_mem_bytes.max(1024), word_bits)
    }
}

/// The whole multi-core accelerator (paper Fig. 2a).
#[derive(Debug, Clone)]
pub struct Accelerator {
    pub name: String,
    pub cores: Vec<Core>,
    /// The interconnect: cores + DRAM ports joined by routed links.
    pub topology: Topology,
}

impl Accelerator {
    pub fn core(&self, id: CoreId) -> &Core {
        &self.cores[id.0]
    }

    /// Swap in a different interconnect (must cover every core).
    pub fn with_topology(mut self, topology: Topology) -> Accelerator {
        assert_eq!(
            topology.n_cores(),
            self.cores.len(),
            "topology must describe exactly the accelerator's cores"
        );
        self.topology = topology;
        self
    }

    /// Ids of the dense dataflow cores (GA allocation targets).
    pub fn dense_cores(&self) -> Vec<CoreId> {
        self.cores.iter().filter(|c| !c.is_simd()).map(|c| c.id).collect()
    }

    /// Id of the SIMD core (pool / add layers), if present.  Multi-chip
    /// packages carry one per chip; this returns the first (see
    /// [`Accelerator::simd_cores`]).
    pub fn simd_core(&self) -> Option<CoreId> {
        self.cores.iter().find(|c| c.is_simd()).map(|c| c.id)
    }

    /// Ids of every SIMD core (one per chip in the chiplet presets; the
    /// allocator pins non-dense layers to the SIMD core of the chip
    /// their producer runs on).
    pub fn simd_cores(&self) -> Vec<CoreId> {
        self.cores.iter().filter(|c| c.is_simd()).map(|c| c.id).collect()
    }

    /// Total on-chip memory in bytes (area-parity bookkeeping).
    pub fn total_onchip_bytes(&self) -> u64 {
        self.cores.iter().map(|c| c.act_mem_bytes + c.wgt_mem_bytes).sum()
    }

    /// Total PE count across dense cores.
    pub fn total_pes(&self) -> usize {
        self.cores.iter().filter(|c| !c.is_simd()).map(|c| c.parallelism()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataflow_unroll_lookup() {
        let df = Dataflow::new(&[(Dim::C, 32), (Dim::K, 32)]);
        assert_eq!(df.unroll(Dim::C), 32);
        assert_eq!(df.unroll(Dim::OX), 1);
        assert_eq!(df.pe_count(), 1024);
    }

    #[test]
    fn eyeriss_like_dataflow() {
        let df = Dataflow::new(&[(Dim::OX, 64), (Dim::FX, 4), (Dim::FY, 4)]);
        assert_eq!(df.pe_count(), 1024);
        assert_eq!(df.unroll(Dim::FY), 4);
    }

    #[test]
    fn preset_iso_area() {
        // all seven exploration architectures share 1 MB on-chip memory
        // and 4096 dense PEs (paper: identical area footprint)
        for arch in presets::exploration_archs() {
            assert_eq!(arch.total_onchip_bytes(), 1024 * 1024, "{}", arch.name);
            assert_eq!(arch.total_pes(), 4096, "{}", arch.name);
            assert!(arch.simd_core().is_some(), "{}", arch.name);
        }
    }

    #[test]
    fn dense_core_listing() {
        let a = presets::hetero_quad();
        assert_eq!(a.dense_cores().len(), 4);
        assert!(a.simd_core().is_some());
    }
}
